//! Observability integration tests: the recorder driven through the
//! real execution stack (sharded runtime, graph artifacts, the serving
//! engine), the Chrome-trace/metrics exporters on files, and the VM
//! instruction-class counters against their static shadow.
//!
//! Recorder mechanics in isolation (nesting, thread-buffer merging, the
//! disabled fast path) are unit-tested in `obs::trace`; this file pins
//! the contract the layers above rely on.

use std::path::PathBuf;
use std::sync::OnceLock;

use tilelang::obs::{read_chrome_trace, write_chrome_trace, write_metrics, Event, Recorder};
use tilelang::runtime::{artifacts, ExecBackend, InterpOptions, Runtime};
use tilelang::serve::{Engine, EngineConfig, StreamSpec};
use tilelang::shard::exec::ShardedOptions;

/// One shared artifact directory per test binary (generation once).
fn artifacts_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir =
            std::env::temp_dir().join(format!("tilelang-obs-artifacts-{}", std::process::id()));
        artifacts::generate_default_set(&dir).expect("generate artifacts");
        dir
    })
    .clone()
}

fn compiled_backend() -> ExecBackend {
    ExecBackend::Compiled(InterpOptions {
        tune: false,
        compiled: true,
        ..Default::default()
    })
}

#[test]
fn sharded_execution_records_balanced_scatter_compute_gather_spans() {
    let dir = artifacts_dir();
    let mut opts = ShardedOptions::new(2);
    opts.interp.tune = false;
    let mut rt = Runtime::with_backend(&dir, ExecBackend::Sharded(opts)).expect("runtime");
    let rec = Recorder::enabled();
    rt.set_recorder(rec.clone());
    let name = "matmul_64x64x64";
    let inputs = rt.example_inputs(name).expect("inputs");
    rt.execute(name, &inputs).expect("sharded execute");

    let events = rec.events();
    let count = |n: &str| events.iter().filter(|e| e.name == n).count();
    let runtime_spans: Vec<&Event> = events.iter().filter(|e| e.name == name).collect();
    assert_eq!(runtime_spans.len(), 1, "one whole-request runtime span");
    assert_eq!(count("scatter"), 1);
    assert_eq!(count("gather"), 1);
    let computes: Vec<&Event> = events.iter().filter(|e| e.name == "compute").collect();
    assert_eq!(computes.len(), 2, "one compute span per shard");

    // spans balance: every shard-phase span nests inside the runtime
    // span's interval, and the scoped shard threads get distinct lanes
    let outer = runtime_spans[0];
    let end = outer.ts_us + outer.dur_us;
    for ev in events.iter().filter(|e| e.cat == "shard") {
        assert!(
            ev.ts_us >= outer.ts_us - 1.0 && ev.ts_us + ev.dur_us <= end + 1.0,
            "{} span [{}, {}] escapes the runtime span [{}, {}]",
            ev.name,
            ev.ts_us,
            ev.ts_us + ev.dur_us,
            outer.ts_us,
            end
        );
    }
    assert_ne!(computes[0].tid, computes[1].tid, "shard threads get their own lanes");
    let shard_ids: Vec<&str> = computes
        .iter()
        .filter_map(|e| e.args.iter().find(|(k, _)| k == "shard").map(|(_, v)| v.as_str()))
        .collect();
    assert_eq!(shard_ids.len(), 2, "compute spans carry their shard index");
}

#[test]
fn default_runtime_recorder_is_disabled_and_records_nothing() {
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, compiled_backend()).expect("runtime");
    let name = "matmul_64x64x64";
    let inputs = rt.example_inputs(name).expect("inputs");
    rt.execute(name, &inputs).expect("execute");
    assert!(!rt.recorder().is_enabled());
    assert!(rt.recorder().events().is_empty());
    assert!(rt.recorder().counters().is_empty());
    assert!(rt.recorder().samples().is_empty());
}

#[test]
fn vm_counters_match_the_graph_kernels_static_shadow() {
    let dir = artifacts_dir();
    let mut rt = Runtime::with_backend(&dir, compiled_backend()).expect("runtime");
    let rec = Recorder::enabled();
    rt.set_recorder(rec.clone());
    let name = "mlp_block_64x64x128";
    let inputs = rt.example_inputs(name).expect("inputs");
    rt.execute(name, &inputs).expect("graph execute");

    let loaded = rt.load(name).expect("load");
    let shadow = loaded.graph_kernel().expect("graph artifact").op_counts();
    let counters = rec.counters();
    let recorded = |key: &str| -> u64 {
        counters
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    };
    let mut saw_nonzero = false;
    for (key, want) in shadow.items() {
        assert_eq!(
            recorded(key),
            want,
            "counter {} diverged from the static shadow",
            key
        );
        saw_nonzero |= want > 0;
    }
    assert!(saw_nonzero, "a compiled GEMM graph must move tiles and bytes");

    // a second execution doubles every nonzero counter: the totals are
    // per-execution deltas, not a static snapshot re-added on load
    rt.execute(name, &inputs).expect("second execute");
    let counters = rt.recorder().counters();
    for (key, want) in shadow.items() {
        let got = counters
            .iter()
            .find(|(k, _)| k.as_str() == key)
            .map(|(_, v)| *v)
            .unwrap_or(0);
        assert_eq!(got, want * 2, "counter {} after two executions", key);
    }
}

#[test]
fn serve_trace_and_metrics_round_trip_through_files() {
    let rec = Recorder::enabled();
    let mut eng = Engine::new(EngineConfig {
        page_rows: 4,
        pool_pages: 32,
        compiled: true,
        ..Default::default()
    })
    .expect("engine");
    eng.set_recorder(rec.clone());
    let specs: Vec<StreamSpec> = (0..3)
        .map(|i| StreamSpec {
            id: i + 1,
            arrival_step: i as usize,
            prefill_rows: 2 + i as usize,
            decode_steps: 2,
        })
        .collect();
    eng.run(&specs).expect("engine run");

    let tmp = std::env::temp_dir().join(format!("tilelang-obs-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).expect("tmp dir");
    let trace_path = tmp.join("trace.json");
    let metrics_path = tmp.join("metrics.txt");
    write_chrome_trace(&rec, &trace_path).expect("write trace");
    write_metrics(&rec, &metrics_path).expect("write metrics");

    let text = std::fs::read_to_string(&trace_path).expect("read trace");
    let back = read_chrome_trace(&text).expect("parse trace");
    let orig = rec.events();
    assert!(!orig.is_empty());
    assert_eq!(back.len(), orig.len(), "every span survives the file round-trip");
    for (b, o) in back.iter().zip(&orig) {
        assert_eq!((b.name.as_str(), b.cat.as_str(), b.tid), (o.name.as_str(), o.cat.as_str(), o.tid));
        assert!((b.dur_us - o.dur_us).abs() < 1e-6);
    }
    for phase in ["admit", "prefill", "decode", "gather"] {
        assert!(
            back.iter().any(|e| e.cat == "serve" && e.name == phase),
            "missing serve phase span {}",
            phase
        );
    }
    assert!(
        back.iter().any(|e| e.cat == "graph"),
        "decode graph nodes must appear as graph spans"
    );

    let metrics = std::fs::read_to_string(&metrics_path).expect("read metrics");
    for family in [
        "# TYPE tilelang_serve_decode_us histogram",
        "tilelang_serve_pool_pages",
        "tilelang_serve_batch_size",
    ] {
        assert!(metrics.contains(family), "metrics dump missing {}:\n{}", family, metrics);
    }
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn enabling_tracing_does_not_change_decode_bits() {
    let cfg = EngineConfig {
        page_rows: 4,
        pool_pages: 32,
        compiled: true,
        ..Default::default()
    };
    let specs: Vec<StreamSpec> = (0..3)
        .map(|i| StreamSpec {
            id: i + 1,
            arrival_step: 0,
            prefill_rows: 3,
            decode_steps: 2,
        })
        .collect();
    let mut plain = Engine::new(cfg.clone()).expect("engine");
    let baseline = plain.run(&specs).expect("run");
    let mut traced = Engine::new(cfg).expect("engine");
    traced.set_recorder(Recorder::enabled());
    let report = traced.run(&specs).expect("traced run");
    for sp in &specs {
        let (a, b) = (&baseline.outputs[&sp.id], &report.outputs[&sp.id]);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!(
                x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits()),
                "stream {}: tracing changed decode bits",
                sp.id
            );
        }
    }
}
