//! Property tests for the register-bytecode VM (`tir::compile`).
//!
//! Two properties are pinned for every scenario in the default artifact
//! set (the same shapes and static-default configs `tilelang artifacts`
//! serves with `tune: false`), plus the fused-epilogue programs graph
//! nodes execute and the dynamic-M tail shapes:
//!
//! 1. **In-bounds offsets** — `CompiledProgram::validate()` statically
//!    sweeps every instruction's pre-resolved address ranges (chip
//!    segments, permutation tables, parameter views, element-loop
//!    domains) against the arena and parameter lengths.
//! 2. **Exactly-once writes** — `CompiledProgram::write_counts(out)` is
//!    a shadow pass that walks the instruction stream counting stores
//!    per output element without executing arithmetic: every output
//!    element must be written exactly once, and pure inputs never.

use std::collections::HashMap;

use tilelang::ir::dtype::DType;
use tilelang::ir::program::{specialize, TileProgram};
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::sim::device::Device;
use tilelang::tir::compile::{compile_lowered, CompiledProgram};
use tilelang::workloads::attention::{
    flash_attention_program, flash_decode_program, AttnConfig, DecodeConfig,
};
use tilelang::workloads::dequant::{dequant_matmul_program, DequantConfig, WeightFormat};
use tilelang::workloads::epilogue::{Activation, EpilogueOp};
use tilelang::workloads::linear_attention::{chunk_scan_program, chunk_state_program};
use tilelang::workloads::matmul::{
    matmul_program, matmul_program_dyn, matmul_program_ep, TileConfig,
};

/// Compile, validate, and check the write-count properties: the output
/// parameter is written exactly once per element, inputs never.
fn check_properties(prog: &TileProgram, dev: &Device, label: &str) -> CompiledProgram {
    let lowered = compile(prog, dev, &CompileOptions::default())
        .unwrap_or_else(|e| panic!("{label}: lowering failed: {e}"));
    let vm = compile_lowered(&lowered)
        .unwrap_or_else(|e| panic!("{label}: bytecode compile failed: {e}"));
    assert!(vm.instr_count() > 0, "{label}: empty instruction stream");
    vm.validate()
        .unwrap_or_else(|e| panic!("{label}: offset validation failed: {e}"));

    let out = prog.params.last().expect("program has params");
    let out_len: i64 = out
        .static_shape()
        .expect("static output shape")
        .iter()
        .product();
    let counts = vm
        .write_counts(out.id)
        .unwrap_or_else(|e| panic!("{label}: write_counts: {e}"));
    assert_eq!(counts.len(), out_len as usize, "{label}: count vector length");
    for (i, c) in counts.iter().enumerate() {
        assert_eq!(
            *c, 1,
            "{label}: output element {i} written {c} times (want exactly once)"
        );
    }
    // pure inputs are never stored to
    for p in &prog.params[..prog.params.len() - 1] {
        let counts = vm
            .write_counts(p.id)
            .unwrap_or_else(|e| panic!("{label}: write_counts({}): {e}", p.name));
        assert!(
            counts.iter().all(|&c| c == 0),
            "{label}: input {} receives stores",
            p.name
        );
    }
    vm
}

#[test]
fn gemm_artifact_scenarios_hold_vm_properties() {
    // matmul_64x64x64 and linear_64x256x64 with their static defaults
    for (m, n, k) in [(64i64, 64i64, 64i64), (64, 256, 64)] {
        let cfg = TileConfig::default_for(m, n, k);
        let prog = matmul_program(m, n, k, DType::F16, &cfg);
        let vm = check_properties(&prog, &Device::h100(), &format!("gemm {m}x{n}x{k}"));
        assert!(vm.chip_cells() > 0);
    }
}

#[test]
fn attention_artifact_scenarios_hold_vm_properties() {
    for causal in [false, true] {
        let (bh, seq, d) = (2i64, 128i64, 64i64);
        let cfg = AttnConfig::default_for(seq);
        let prog = flash_attention_program(bh, seq, d, causal, &cfg);
        check_properties(
            &prog,
            &Device::h100(),
            &format!("flash_attention causal={causal}"),
        );
    }
}

#[test]
fn decode_artifact_scenario_holds_vm_properties() {
    let (b, h, kv, d) = (4i64, 16i64, 64i64, 16i64);
    let cfg = DecodeConfig::default_for(h, kv);
    let prog = flash_decode_program(b, h, kv, d, &cfg, &[]);
    check_properties(&prog, &Device::h100(), "flash_decode");
}

#[test]
fn dequant_artifact_scenario_holds_vm_properties() {
    let (m, n, k) = (32i64, 64i64, 64i64);
    let prog = dequant_matmul_program(m, n, k, WeightFormat::Int4, &DequantConfig::default());
    check_properties(&prog, &Device::h100(), "dequant_int4");
}

#[test]
fn chunk_artifact_scenarios_hold_vm_properties() {
    let (bh, seq, n_state, p, chunk) = (2i64, 128i64, 32i64, 32i64, 64i64);
    let state = chunk_state_program(bh, seq, n_state, p, chunk, 2);
    check_properties(&state, &Device::h100(), "chunk_state");
    let scan = chunk_scan_program(bh, seq, n_state, p, chunk, 2);
    check_properties(&scan, &Device::h100(), "chunk_scan");
}

/// The fused-epilogue programs graph nodes execute (GEMM+bias+act+
/// residual, decode+residual): epilogue element loops must not break
/// the exactly-once property.
#[test]
fn graph_node_fused_programs_hold_vm_properties() {
    let cfg = TileConfig::default_for(64, 64, 64);
    let prog = matmul_program_ep(
        64,
        64,
        64,
        DType::F16,
        &cfg,
        &[
            EpilogueOp::BiasAdd { dim: 1 },
            EpilogueOp::Activation(Activation::Gelu),
            EpilogueOp::ResidualAdd,
        ],
    );
    check_properties(&prog, &Device::h100(), "gemm+bias+gelu+residual");

    let dcfg = DecodeConfig::default_for(16, 64);
    let prog = flash_decode_program(4, 16, 64, 16, &dcfg, &[EpilogueOp::ResidualAdd]);
    check_properties(&prog, &Device::h100(), "decode+residual");
}

/// Dynamic-M tails: out-of-bounds tail stores are dropped at compile
/// time by the guard ranges, so every *existing* output element is
/// still written exactly once — no double-writes, no gaps.
#[test]
fn dynamic_m_tail_scenarios_hold_vm_properties() {
    let (n, k) = (64i64, 64i64);
    let cfg = TileConfig::default_for(64, n, k);
    for &m in &[33i64, 80, 96] {
        let (prog, mvar) = matmul_program_dyn(n, k, DType::F16, &cfg);
        let mut bind = HashMap::new();
        bind.insert(mvar.id, m);
        let sp = specialize(&prog, &bind);
        check_properties(&sp, &Device::a100(), &format!("dyn-M m={m}"));
    }
}
