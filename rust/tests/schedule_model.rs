//! Schedule-model property suite (PR 10 guardrails).
//!
//! Pins the overlap-aware schedule model's invariants so autotuner
//! rankings and timeline semantics can't silently flip:
//!
//! * monotonicity — deeper async pipelining (more overlap) never
//!   increases a pipeline's modeled steady-state time,
//! * specialization never helps when the copy stage is negligible
//!   (compute-bound kernels on architectures without a wgmma-class
//!   specialized path pay the producer-warp compute tax for nothing),
//! * register-pressure rejection — `accepts` filters over-budget tiles,
//!   the search space never emits them, and the simulator hard-rejects
//!   candidates past the 2x spill horizon,
//! * pinned best-candidate regressions per kernel family, including the
//!   PR 10 selection change: on Hopper the attention tuner now picks an
//!   explicitly specialized schedule.

use tilelang::autotuner::{tune_attention, tune_gemm, Tunable};
use tilelang::ir::dtype::DType;
use tilelang::ir::program::GemmWarpPolicy;
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::sim::device::Device;
use tilelang::sim::model::{estimate, simulate_kernel, Penalties, MAX_REGS_PER_THREAD};
use tilelang::workloads::attention::{flash_attention_program, AttentionTunable, AttnConfig};
use tilelang::workloads::matmul::{matmul_program, GemmTunable, TileConfig};
use tilelang::workloads::shapes::AttnShape;

fn gemm_cfg(stages: usize, specialize: Option<bool>) -> TileConfig {
    TileConfig {
        block_m: 64,
        block_n: 64,
        block_k: 32,
        num_stages: stages,
        threads: 128,
        policy: GemmWarpPolicy::Square,
        rasterize: false,
        specialize,
    }
}

/// More overlap never increases modeled steady-state time: for a fixed
/// tile the per-pipeline `steady_us` is non-increasing in `num_stages`
/// (the async wait amortizes over `stages - 1` in-flight groups; copy
/// and compute totals are unchanged).
#[test]
fn deeper_pipelines_never_increase_steady_state() {
    for dev in [Device::a100(), Device::h100()] {
        let mut prev: Option<f64> = None;
        for stages in [2usize, 3, 4] {
            let prog =
                matmul_program(512, 512, 2048, DType::F16, &gemm_cfg(stages, Some(false)));
            let lowered = compile(&prog, &dev, &CompileOptions::default()).unwrap();
            let rep = estimate(&lowered, &dev, &Penalties::none());
            assert_eq!(rep.pipelines.len(), 1, "{}: one K pipeline expected", dev.name);
            let tl = &rep.pipelines[0];
            assert_eq!(tl.stages, stages);
            assert!(tl.uses_async, "{}: staged copies lower async", dev.name);
            if let Some(p) = prev {
                assert!(
                    tl.steady_us <= p + 1e-9,
                    "{}: steady-state regressed going deeper: {} -> {} us",
                    dev.name,
                    p,
                    tl.steady_us
                );
            }
            prev = Some(tl.steady_us);
        }
    }
}

/// Fill time grows with depth (more stage latencies to hide) while total
/// time stays finite and positive — the timeline decomposition is sane.
#[test]
fn fill_grows_with_depth_and_times_are_positive() {
    let dev = Device::a100();
    let mut prev_fill = 0.0;
    for stages in [2usize, 3, 4] {
        let prog = matmul_program(512, 512, 2048, DType::F16, &gemm_cfg(stages, Some(false)));
        let lowered = compile(&prog, &dev, &CompileOptions::default()).unwrap();
        let rep = estimate(&lowered, &dev, &Penalties::none());
        let tl = &rep.pipelines[0];
        assert!(tl.fill_us > prev_fill, "fill must grow with stage depth");
        assert!(tl.copy_us > 0.0 && tl.compute_us > 0.0 && tl.steady_us > 0.0);
        assert!(rep.time_us > tl.fill_us, "fill is a component, not the total");
        prev_fill = tl.fill_us;
    }
}

/// Specialization never helps when the copy stage is negligible: on a
/// compute-bound GEMM on Ampere (no wgmma-class specialized path),
/// donating warps to the producer role only slows the consumer side.
#[test]
fn specialization_never_helps_compute_bound_on_ampere() {
    let dev = Device::a100();
    let pen = Penalties::none();
    // 2048^3 fp16 GEMM: ~17 GFLOP vs ~32 MB unique traffic — firmly
    // compute-bound at A100 ratios for every tested tile.
    for stages in [2usize, 3] {
        let off = simulate_kernel(
            &matmul_program(2048, 2048, 2048, DType::F16, &gemm_cfg(stages, Some(false))),
            &dev,
            &pen,
        )
        .unwrap();
        let on = simulate_kernel(
            &matmul_program(2048, 2048, 2048, DType::F16, &gemm_cfg(stages, Some(true))),
            &dev,
            &pen,
        )
        .unwrap();
        assert!(
            on.time_us >= off.time_us,
            "stages={}: specialization must not help a compute-bound \
             Ampere kernel (on {} us < off {} us)",
            stages,
            on.time_us,
            off.time_us
        );
    }
}

/// The specialized flag round-trips into the report timeline: forcing it
/// on marks the pipeline specialized on any async-copy architecture,
/// forcing it off never does.
#[test]
fn timeline_reflects_forced_specialization() {
    for dev in [Device::a100(), Device::h100()] {
        for (sp, want) in [(Some(false), false), (Some(true), true)] {
            let prog = matmul_program(512, 512, 512, DType::F16, &gemm_cfg(3, sp));
            let lowered = compile(&prog, &dev, &CompileOptions::default()).unwrap();
            assert_eq!(
                lowered.schedule.warp_specialized, want,
                "{}: forced specialize {:?}",
                dev.name, sp
            );
            if want {
                assert!(lowered.schedule.producer_warps > 0);
                assert!(
                    lowered.schedule.producer_warps * 32 < prog.threads,
                    "producers must leave consumer warps"
                );
            } else {
                assert_eq!(lowered.schedule.producer_warps, 0);
            }
            let rep = estimate(&lowered, &dev, &Penalties::none());
            assert_eq!(rep.pipelines[0].specialized, want);
        }
    }
}

/// Register-pressure rejection, tier 1: `accepts` filters tiles whose
/// accumulator demand exceeds the architectural register file, and the
/// enumerated search space never contains one.
#[test]
fn accepts_rejects_register_over_budget_tiles() {
    let t = GemmTunable::new(1024, 1024, 1024, DType::F16);
    let over = TileConfig {
        block_m: 256,
        block_n: 256,
        block_k: 32,
        num_stages: 2,
        threads: 128,
        policy: GemmWarpPolicy::Square,
        rasterize: false,
        specialize: None,
    };
    assert!(
        !t.accepts(&over),
        "256x256 @ 128 threads = 512 accumulators/thread must be rejected"
    );
    for cfg in t.candidates() {
        assert!(
            cfg.block_m * cfg.block_n / cfg.threads <= MAX_REGS_PER_THREAD,
            "search space leaked an over-pressure tile: {:?}",
            cfg
        );
    }

    let shape = AttnShape {
        name: "pin",
        batch: 1,
        heads: 32,
        seq_len: 1024,
        head_dim: 128,
        causal: false,
    };
    let at = AttentionTunable { shape };
    for cfg in at.candidates() {
        assert!(
            cfg.block_m * (cfg.block_n + shape.head_dim) / cfg.threads
                <= MAX_REGS_PER_THREAD,
            "attention search space leaked an over-pressure tile: {:?}",
            cfg
        );
    }
}

/// Register-pressure rejection, tier 3: past 2x the register file the
/// simulator refuses the candidate outright (no spill model rescues it).
#[test]
fn simulator_hard_rejects_past_spill_horizon() {
    let over = TileConfig {
        block_m: 256,
        block_n: 256,
        block_k: 32,
        num_stages: 2,
        threads: 128,
        policy: GemmWarpPolicy::Square,
        rasterize: false,
        specialize: None,
    };
    let prog = matmul_program(1024, 1024, 1024, DType::F16, &over);
    let err = simulate_kernel(&prog, &Device::a100(), &Penalties::none())
        .expect_err("512 regs/thread is past the 2x spill horizon");
    assert!(
        err.contains("register pressure"),
        "rejection must name the cause, got: {}",
        err
    );
}

/// Tier 2 sits between: a mildly over-budget kernel still simulates but
/// pays a spill-traffic penalty relative to an in-budget twin of the
/// same shape (more DRAM bytes modeled, never fewer).
#[test]
fn spill_tier_charges_traffic_but_simulates() {
    let dev = Device::a100();
    // 256x128 @ 128 threads: 256 accumulators/thread — just past the
    // file, inside the 2x horizon. Doubling threads fits the same tile.
    let spilled = TileConfig {
        block_m: 256,
        block_n: 128,
        block_k: 32,
        num_stages: 2,
        threads: 128,
        policy: GemmWarpPolicy::Square,
        rasterize: false,
        specialize: None,
    };
    let fits = TileConfig { threads: 256, ..spilled };
    let rep_sp = simulate_kernel(
        &matmul_program(1024, 1024, 1024, DType::F16, &spilled),
        &dev,
        &Penalties::none(),
    )
    .unwrap();
    let rep_ok = simulate_kernel(
        &matmul_program(1024, 1024, 1024, DType::F16, &fits),
        &dev,
        &Penalties::none(),
    )
    .unwrap();
    assert!(
        rep_sp.dram_gb > rep_ok.dram_gb,
        "spilled twin must model extra DRAM traffic ({} vs {} GB)",
        rep_sp.dram_gb,
        rep_ok.dram_gb
    );
}

/// Pinned selection change (PR 10 acceptance): on Hopper the enlarged
/// stages x specialization space makes the attention tuner pick an
/// explicitly specialized schedule, and that winner strictly beats its
/// unspecialized twin.
#[test]
fn hopper_attention_tuner_picks_specialized_schedule() {
    let dev = Device::h100();
    let pen = Penalties::none();
    let shape = AttnShape {
        name: "FA2-like",
        batch: 1,
        heads: 32,
        seq_len: 1024,
        head_dim: 128,
        causal: false,
    };
    let win = tune_attention(&shape, &dev, &pen).unwrap();
    assert_eq!(
        win.config.specialize,
        Some(true),
        "Hopper attention winner must be the specialized schedule, got {:?}",
        win.config
    );

    let twin = AttnConfig { specialize: Some(false), ..win.config.clone() };
    let on = simulate_kernel(
        &flash_attention_program(
            shape.batch * shape.heads,
            shape.seq_len,
            shape.head_dim,
            shape.causal,
            &win.config,
        ),
        &dev,
        &pen,
    )
    .unwrap();
    let off = simulate_kernel(
        &flash_attention_program(
            shape.batch * shape.heads,
            shape.seq_len,
            shape.head_dim,
            shape.causal,
            &twin,
        ),
        &dev,
        &pen,
    )
    .unwrap();
    assert!(
        on.time_us < off.time_us,
        "specialized winner must strictly beat its twin ({} vs {} us)",
        on.time_us,
        off.time_us
    );
}

/// Pinned best-candidate regression, GEMM family: on Ampere the winner
/// for a large square GEMM stays unspecialized and multi-staged, and it
/// beats the heuristic default config.
#[test]
fn ampere_gemm_winner_pinned() {
    let dev = Device::a100();
    let pen = Penalties::none();
    let win = tune_gemm(2048, 2048, 2048, DType::F16, &dev, &pen).unwrap();
    assert_ne!(
        win.config.specialize,
        Some(true),
        "Ampere compute-bound GEMM must not choose specialization: {:?}",
        win.config
    );
    assert!(win.config.num_stages >= 2, "winner must pipeline: {:?}", win.config);
    assert!(win.evaluated > 1, "sweep must actually explore the space");

    let default = TileConfig::default_for(2048, 2048, 2048);
    let base = simulate_kernel(
        &matmul_program(2048, 2048, 2048, DType::F16, &default),
        &dev,
        &pen,
    )
    .unwrap();
    assert!(
        win.report.time_us <= base.time_us + 1e-9,
        "tuned config must not lose to the default ({} vs {} us)",
        win.report.time_us,
        base.time_us
    );
}
