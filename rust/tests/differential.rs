//! Differential tests: the thread-level interpreter (`tir::interp`)
//! executed against the CPU reference implementations in `workloads` —
//! the semantic-oracle check the crate docs promise. A seeded grid of
//! small shapes and tile configurations is swept per workload family so
//! lowering decisions (pipelining depth, warp policy, thread count,
//! vectorization) are exercised beyond the single configs the unit
//! tests pin.

use tilelang::ir::dtype::DType;
use tilelang::ir::program::GemmWarpPolicy;
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::sim::device::Device;
use tilelang::tir::interp::{Interp, Tensors};
use tilelang::workloads::attention::{flash_attention_program, reference_attention, AttnConfig};
use tilelang::workloads::dequant::{
    dequant_matmul_program, dequantize_weights, quantize_weights, DequantConfig, WeightFormat,
};
use tilelang::workloads::matmul::{matmul_program, reference_matmul, test_data, TileConfig};

/// SplitMix64 (same driver as tests/property.rs; no proptest offline).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next() % xs.len() as u64) as usize]
    }
}

#[test]
fn matmul_interp_matches_reference_over_seeded_grid() {
    let mut rng = Rng(0x5EED_0001);
    let devices = [
        Device::a100(),
        Device::h100(),
        Device::rtx4090(),
        Device::rtx3090(),
    ];
    let mut executed = 0;
    for case in 0..10 {
        let bm = *rng.pick(&[16i64, 32, 64]);
        let bn = *rng.pick(&[16i64, 32, 64]);
        let bk = *rng.pick(&[16i64, 32]);
        // non-square grids and odd tile multiples (1x..3x)
        let m = bm * *rng.pick(&[1i64, 2, 3]);
        let n = bn * *rng.pick(&[1i64, 2, 3]);
        let k = bk * *rng.pick(&[2i64, 3]);
        let cfg = TileConfig {
            block_m: bm,
            block_n: bn,
            block_k: bk,
            num_stages: *rng.pick(&[1usize, 2, 3]),
            threads: *rng.pick(&[64i64, 128]),
            policy: *rng.pick(&[
                GemmWarpPolicy::Square,
                GemmWarpPolicy::FullRow,
                GemmWarpPolicy::FullCol,
            ]),
            rasterize: case % 2 == 0,
            specialize: *rng.pick(&[None, Some(false), Some(true)]),
        };
        let dev = rng.pick(&devices);
        let prog = matmul_program(m, n, k, DType::F16, &cfg);
        let lowered = compile(&prog, dev, &CompileOptions::default())
            .unwrap_or_else(|e| panic!("case {case} ({cfg:?}) on {}: {e}", dev.name));
        let interp = Interp::new(&lowered).unwrap();
        let a = test_data(m * k, 1000 + case as u64);
        let b = test_data(k * n, 2000 + case as u64);
        let mut t = Tensors::new();
        t.insert(prog.params[0].id, a.clone());
        t.insert(prog.params[1].id, b.clone());
        interp
            .run(&mut t)
            .unwrap_or_else(|e| panic!("case {case} ({cfg:?}): {e}"));
        let want = reference_matmul(&a, &b, m, n, k);
        for (g, w) in t[&prog.params[2].id].iter().zip(&want) {
            assert!(
                (g - w).abs() < 0.05 + 0.02 * w.abs(),
                "case {case} ({m}x{n}x{k}, {cfg:?}): {g} vs {w}"
            );
        }
        executed += 1;
    }
    assert_eq!(executed, 10);
}

#[test]
fn attention_interp_matches_reference_over_seeded_grid() {
    let mut rng = Rng(0x5EED_0002);
    let mut executed = 0;
    for case in 0..8 {
        let seq = *rng.pick(&[64i64, 128, 256]);
        let d = *rng.pick(&[32i64, 64]);
        let bh = *rng.pick(&[1i64, 2]);
        let causal = case % 2 == 0;
        let bm = *rng.pick(&[32i64, 64]);
        let bn = *rng.pick(&[32i64, 64]);
        if seq % bm != 0 || seq % bn != 0 {
            continue;
        }
        let cfg = AttnConfig {
            block_m: bm,
            block_n: bn,
            num_stages: *rng.pick(&[1usize, 2]),
            threads: 128,
            specialize: *rng.pick(&[None, Some(false), Some(true)]),
        };
        let prog = flash_attention_program(bh, seq, d, causal, &cfg);
        let lowered = compile(&prog, &Device::h100(), &CompileOptions::default())
            .unwrap_or_else(|e| panic!("case {case} ({cfg:?}): {e}"));
        let interp = Interp::new(&lowered).unwrap();
        let q = test_data(bh * seq * d, 3000 + case as u64);
        let k = test_data(bh * seq * d, 4000 + case as u64);
        let v = test_data(bh * seq * d, 5000 + case as u64);
        let mut t = Tensors::new();
        t.insert(prog.params[0].id, q.clone());
        t.insert(prog.params[1].id, k.clone());
        t.insert(prog.params[2].id, v.clone());
        interp
            .run(&mut t)
            .unwrap_or_else(|e| panic!("case {case} ({cfg:?}): {e}"));
        let want = reference_attention(&q, &k, &v, bh, seq, d, causal);
        let mut max_err = 0f32;
        for (g, w) in t[&prog.params[3].id].iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(
            max_err < 0.03,
            "case {case} (seq={seq} d={d} causal={causal} {cfg:?}): max err {max_err}"
        );
        executed += 1;
    }
    assert!(executed >= 5, "grid too sparse: only {executed} cases ran");
}

/// End-to-end differential: the runtime's interp execution backend
/// (manifest -> workload program -> tuned config -> lowered IR ->
/// interpreter) against the CPU references, through the same
/// `Runtime::execute` path the coordinator serves from.
#[test]
fn interp_backend_runtime_matches_references_end_to_end() {
    use tilelang::runtime::{artifacts, ExecBackend, InterpOptions, Runtime};

    let dir =
        std::env::temp_dir().join(format!("tilelang-diff-artifacts-{}", std::process::id()));
    artifacts::generate_default_set(&dir).expect("generate artifacts");
    let rt = Runtime::with_backend(&dir, ExecBackend::Interp(InterpOptions::default()))
        .expect("runtime");

    // gemm artifact: full-output comparison against the CPU reference
    let ins = rt.example_inputs("matmul_64x64x64").expect("inputs");
    let got = rt.execute("matmul_64x64x64", &ins).expect("exec");
    let want = reference_matmul(&ins[0], &ins[1], 64, 64, 64);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 0.05 + 0.02 * w.abs(), "{} vs {}", g, w);
    }

    // attention artifact: end-to-end through the same path
    let ins = rt.example_inputs("flash_attention_2x128x64").expect("inputs");
    let got = rt.execute("flash_attention_2x128x64", &ins).expect("exec");
    let want = reference_attention(&ins[0], &ins[1], &ins[2], 2, 128, 64, false);
    let mut max_err = 0f32;
    for (g, w) in got.iter().zip(&want) {
        max_err = max_err.max((g - w).abs());
    }
    assert!(max_err < 0.03, "attention max err {max_err}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dequant_interp_matches_reference_over_config_grid() {
    let (m, n, k) = (32i64, 64i64, 64i64);
    let dev = Device::a100();
    for fmt in [
        WeightFormat::Int4,
        WeightFormat::Nf4,
        WeightFormat::Fp4,
        WeightFormat::Int2,
    ] {
        // W2A8 applies the group scale on the k-slice accumulator: it is
        // numerically coarser than the in-register fp decode paths
        let tol = if fmt == WeightFormat::Int2 { 0.5 } else { 0.05 };
        for (ci, (bm, bn, bk, stages)) in
            [(16i64, 32i64, 32i64, 2usize), (32, 64, 64, 3)].iter().enumerate()
        {
            let group = if fmt.act_dtype().is_float() { 32 } else { *bk };
            let cfg = DequantConfig {
                block_m: *bm,
                block_n: *bn,
                block_k: *bk,
                num_stages: *stages,
                threads: 128,
                group_size: group,
            };
            let prog = dequant_matmul_program(m, n, k, fmt, &cfg);
            let lowered = compile(&prog, &dev, &CompileOptions::default())
                .unwrap_or_else(|e| panic!("{fmt:?} cfg{ci}: {e}"));
            let interp = Interp::new(&lowered).unwrap();

            let mut aval = test_data(m * k, 6000 + ci as u64);
            if fmt == WeightFormat::Int2 {
                for x in aval.iter_mut() {
                    *x = (*x * 8.0).round().clamp(-4.0, 3.0);
                }
            }
            let w = test_data(n * k, 7000 + ci as u64);
            let (packed, scales) = quantize_weights(&w, n, k, fmt, group);

            let mut t = Tensors::new();
            t.insert(prog.params[0].id, aval.clone());
            t.insert(prog.params[1].id, packed.clone());
            t.insert(prog.params[2].id, scales.clone());
            interp
                .run(&mut t)
                .unwrap_or_else(|e| panic!("{fmt:?} cfg{ci}: {e}"));

            // reference: dequantize then GEMM against A^T
            let wdq = dequantize_weights(&packed, &scales, n, k, fmt, group);
            let got = &t[&prog.params[3].id];
            let mut max_err = 0f32;
            for i in 0..n as usize {
                for j in 0..m as usize {
                    let mut acc = 0f32;
                    for kk in 0..k as usize {
                        acc += wdq[i * k as usize + kk] * aval[j * k as usize + kk];
                    }
                    max_err = max_err.max((got[i * m as usize + j] - acc).abs());
                }
            }
            assert!(max_err < tol, "{fmt:?} cfg{ci}: max err {max_err}");
        }
    }
}

/// Dynamic-M tail shapes: a GEMM whose row count is a runtime scalar is
/// specialized (`ir::program::specialize`) to values that are NOT
/// multiples of the row tile. The last grid row runs as a predicated
/// tail — out-of-bounds rows read as zero and their stores are dropped —
/// so the first M output rows must match the CPU reference exactly
/// (within fp16 staging tolerance). This is the ROADMAP tail-split item
/// exercised end to end through the interpreter.
#[test]
fn dynamic_m_tail_shapes_specialize_and_match_reference() {
    use std::collections::HashMap;
    use tilelang::ir::program::specialize;
    use tilelang::workloads::matmul::matmul_program_dyn;

    let dev = Device::a100();
    let (n, k) = (64i64, 64i64);
    let cfg = TileConfig {
        block_m: 64,
        block_n: 32,
        block_k: 32,
        num_stages: 2,
        threads: 128,
        policy: GemmWarpPolicy::Square,
        rasterize: true,
        specialize: None,
    };
    // 96 and 80: one full block + a partial tail; 33: a single mostly-
    // empty block; 128: control (no tail at all)
    for &m in &[96i64, 80, 33, 128] {
        let (prog, mvar) = matmul_program_dyn(n, k, DType::F16, &cfg);
        assert!(!prog.dyn_params.is_empty());
        assert!(
            prog.grid[1].as_int().is_none(),
            "row grid must be symbolic before specialization"
        );
        let mut bind = HashMap::new();
        bind.insert(mvar.id, m);
        let sp = specialize(&prog, &bind);
        assert!(sp.dyn_params.is_empty());
        let grid: Vec<i64> = sp
            .grid
            .iter()
            .map(|g| g.as_int().expect("specialized grid is static"))
            .collect();
        assert_eq!(grid, vec![n / 32, (m + 63) / 64], "m = {m}");
        assert_eq!(sp.params[0].static_shape(), Some(vec![m, k]));

        let lowered = compile(&sp, &dev, &CompileOptions::default())
            .unwrap_or_else(|e| panic!("m={m}: {e}"));
        let interp = Interp::new(&lowered).unwrap();
        let a = test_data(m * k, 0x7A11 + m as u64);
        let b = test_data(k * n, 0x7A12);
        let mut t = Tensors::new();
        t.insert(sp.params[0].id, a.clone());
        t.insert(sp.params[1].id, b.clone());
        interp.run(&mut t).unwrap_or_else(|e| panic!("m={m}: {e}"));

        let got = &t[&sp.params[2].id];
        assert_eq!(got.len(), (m * n) as usize, "m = {m}");
        let want = reference_matmul(&a, &b, m, n, k);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 0.05 + 0.02 * w.abs(),
                "m={m} idx={i}: {g} vs {w}"
            );
        }
    }
}
