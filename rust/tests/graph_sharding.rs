//! Sharded-graph differential tests: a graph artifact partitioned
//! across N executors must produce the same numbers as the
//! single-executor `GraphKernel` and the CPU-reference composition, for
//! every shardable scenario (mlp_block, dequant_mlp, decode_block) at
//! shard counts 2 and 3 — plus the decode block's KV-cache lifecycle
//! across two successive steps, clean planner rejections
//! (attention_block's axis, over-split head counts), and end-to-end
//! serving through `Runtime`/`Coordinator` on the sharded backend.

use std::path::PathBuf;
use std::sync::OnceLock;

use tilelang::coordinator::{BatchPolicy, Coordinator};
use tilelang::graph::exec::GraphKernel;
use tilelang::graph::ir::{attention_block, decode_block, KernelGraph};
use tilelang::graph::memplan::{self, find_live_overlap};
use tilelang::runtime::{artifacts, ExecBackend, InterpOptions, Runtime};
use tilelang::shard::exec::ShardedOptions;
use tilelang::shard::graph::{plan_graph, GraphStrategy, ShardedGraphKernel};
use tilelang::sim::device::Device;
use tilelang::workloads::matmul::{reference_matmul, test_data};

/// Sharded graphs chain the same fp16-staged kernels as single-executor
/// graphs; the gather only reorders shard bands, so the graph golden
/// bound applies unchanged.
const TOL: f32 = tilelang::runtime::GRAPH_GOLDEN_TOL;

/// One shared artifact directory per test binary (generation once).
fn artifacts_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir()
            .join(format!("tilelang-graphshard-artifacts-{}", std::process::id()));
        artifacts::generate_default_set(&dir).expect("generate artifacts");
        dir
    })
    .clone()
}

fn fast_opts() -> InterpOptions {
    InterpOptions {
        tune: false,
        ..Default::default()
    }
}

fn fast_sharded(shards: usize) -> ShardedOptions {
    ShardedOptions {
        shards,
        interp: fast_opts(),
    }
}

fn h100() -> Device {
    Device::h100()
}

/// The shardable graph artifact defs (valid inputs — packed weights for
/// the dequant variant, caches for the decode block — plus reference
/// goldens): the differential corpus.
fn shardable_defs() -> Vec<artifacts::ArtifactDef> {
    artifacts::default_set()
        .into_iter()
        .filter(|d| {
            d.graph.is_some()
                && ["mlp_block", "dequant_mlp", "decode_block"]
                    .iter()
                    .any(|p| d.name.starts_with(p))
        })
        .collect()
}

#[test]
fn sharded_graphs_match_single_executor_and_reference() {
    let dir = artifacts_dir();
    let defs = shardable_defs();
    assert_eq!(defs.len(), 3, "mlp, dequant-MLP and decode-block scenarios");
    for d in defs {
        let graph = d.graph.as_ref().expect("graph def");
        let single = GraphKernel::prepare(graph, &fast_opts(), &dir)
            .unwrap_or_else(|e| panic!("{}: single-executor prepare: {}", d.name, e));
        let base = single
            .execute(&d.inputs)
            .unwrap_or_else(|e| panic!("{}: single-executor execution: {}", d.name, e));
        for shards in [2usize, 3] {
            let kernel = ShardedGraphKernel::prepare(graph, &fast_sharded(shards), &dir)
                .unwrap_or_else(|e| panic!("{} x{}: prepare: {}", d.name, shards, e));
            assert_eq!(kernel.plan().shards(), shards, "{}", d.name);
            let got = kernel
                .execute(&d.inputs)
                .unwrap_or_else(|e| panic!("{} x{}: execution: {}", d.name, shards, e));
            assert_eq!(got.len(), d.golden.len(), "{} x{}", d.name, shards);
            for (i, ((g, s), w)) in got.iter().zip(&base).zip(&d.golden).enumerate() {
                assert!(
                    (g - s).abs() < TOL,
                    "{} x{} idx {}: sharded {} vs single {}",
                    d.name,
                    shards,
                    i,
                    g,
                    s
                );
                assert!(
                    (g - w).abs() < TOL + 0.02 * w.abs(),
                    "{} x{} idx {}: sharded {} vs reference {}",
                    d.name,
                    shards,
                    i,
                    g,
                    w
                );
            }
        }
    }
}

#[test]
fn strategies_match_the_block_family() {
    for d in shardable_defs() {
        let graph = d.graph.as_ref().unwrap();
        let p = plan_graph(graph, 2, &h100()).unwrap_or_else(|e| panic!("{}: {}", d.name, e));
        let want = if d.name.starts_with("decode_block") {
            // the partition axis rides the flash grid's batch*heads dim
            GraphStrategy::HeadParallel
        } else {
            GraphStrategy::RowParallel
        };
        assert_eq!(p.strategy, want, "{}", d.name);
        // the decode block's KV caches scatter with the streams
        if d.name.starts_with("decode_block") {
            assert!(p.parts[0].inputs[2].dim.is_some(), "K cache must scatter");
            assert!(p.parts[0].inputs[3].dim.is_some(), "V cache must scatter");
        }
    }
}

#[test]
fn per_shard_memplans_reuse_buffers_without_aliasing() {
    for d in shardable_defs() {
        let graph = d.graph.as_ref().unwrap();
        let p = plan_graph(graph, 3, &h100()).unwrap_or_else(|e| panic!("{}: {}", d.name, e));
        for part in &p.parts {
            let mp = memplan::plan(&part.graph);
            if let Some((i, j)) = find_live_overlap(&mp) {
                panic!(
                    "{} shard {}: nodes {} and {} share a buffer while live",
                    d.name, part.index, i, j
                );
            }
            assert!(mp.peak_bytes <= mp.intermediate_bytes, "{}", d.name);
        }
    }
}

#[test]
fn attention_block_rejects_and_decode_head_audit_holds() {
    // the single-head attention block cannot shard: the [seq, d] ->
    // [1, seq, d] view moves the batch rows off the leading dim (and
    // the flash kernel mixes them) — a clean reason, not a panic
    let err = plan_graph(&attention_block(128, 64, false), 2, &h100())
        .unwrap_err()
        .to_string();
    assert!(err.contains("does not apply"), "{err}");

    // head-count feasibility audit: a decode block with 8 heads can
    // never hold a 16-head warp tile; the planner must reject it with
    // the builder's reason instead of producing an infeasible config
    let g = decode_block(64, 8, 16, 64);
    let err = plan_graph(&g, 2, &h100()).unwrap_err().to_string();
    assert!(
        err.contains("flash_decode") && err.contains("head"),
        "{err}"
    );
    // and the executor-side prepare path reports the same reason
    let dir = std::env::temp_dir().join(format!("tilelang-gshard-audit-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let err = GraphKernel::prepare(&g, &fast_opts(), &dir)
        .err()
        .expect("sub-16-head decode must not prepare")
        .to_string();
    assert!(err.contains("flash_decode"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn decode_block_kv_cache_carries_state_across_steps() {
    // two successive decode steps over a sliding-window KV cache: the
    // serving layer owns the cache update (compute the new position's
    // K/V, roll the fixed-size window), the graph artifact executes one
    // step — sharded and single-executor runs must agree with the
    // reference at both steps, and the cache must actually matter.
    let (streams, heads, dh, past) = (64i64, 16i64, 16i64, 64i64);
    let d_model = heads * dh;
    let g = decode_block(streams, heads, dh, past);
    let dir = artifacts_dir();

    let wq = test_data(d_model * d_model, 0x71);
    let wo = test_data(d_model * d_model, 0x72);
    let bo = test_data(d_model, 0x73);
    // per-stream MQA cache-update weights (owned by the serving layer,
    // not the graph): one shared K/V head per stream
    let wk = test_data(d_model * dh, 0x74);
    let wv = test_data(d_model * dh, 0x75);

    let x1 = test_data(streams * d_model, 0x76);
    let k1 = test_data(streams * past * dh, 0x77);
    let v1 = test_data(streams * past * dh, 0x78);

    let single = GraphKernel::prepare(&g, &fast_opts(), &dir).expect("single prepare");
    let sharded =
        ShardedGraphKernel::prepare(&g, &fast_sharded(2), &dir).expect("sharded prepare");

    let step = |kc: &[f32], vc: &[f32], x: &[f32]| {
        let inputs = vec![
            x.to_vec(),
            wq.clone(),
            kc.to_vec(),
            vc.to_vec(),
            wo.clone(),
            bo.clone(),
        ];
        let want = g.reference_execute(&inputs).expect("reference step");
        let got_single = single.execute(&inputs).expect("single step");
        let got_sharded = sharded.execute(&inputs).expect("sharded step");
        for (i, ((s, h), w)) in got_single
            .iter()
            .zip(&got_sharded)
            .zip(&want)
            .enumerate()
        {
            assert!((s - h).abs() < TOL, "idx {i}: single {s} vs sharded {h}");
            assert!(
                (s - w).abs() < TOL + 0.02 * w.abs(),
                "idx {i}: single {s} vs reference {w}"
            );
        }
        want
    };

    let y1 = step(&k1, &v1, &x1);

    // cache update: k_new[s] = x1[s] @ Wk, v_new[s] = x1[s] @ Wv; the
    // fixed-shape window rolls one position (drop the oldest row)
    let k_new = reference_matmul(&x1, &wk, streams, dh, d_model);
    let v_new = reference_matmul(&x1, &wv, streams, dh, d_model);
    let roll = |cache: &[f32], new_rows: &[f32]| -> Vec<f32> {
        let (p, d) = (past as usize, dh as usize);
        let mut out = vec![0f32; cache.len()];
        for s in 0..streams as usize {
            let src = &cache[s * p * d..(s + 1) * p * d];
            let dst = &mut out[s * p * d..(s + 1) * p * d];
            dst[..(p - 1) * d].copy_from_slice(&src[d..]);
            dst[(p - 1) * d..].copy_from_slice(&new_rows[s * d..(s + 1) * d]);
        }
        out
    };
    let k2 = roll(&k1, &k_new);
    let v2 = roll(&v1, &v_new);

    // step 2: the next token's hidden state is downstream of y1 in a
    // real model; any new activations work for the numerics check
    let x2 = test_data(streams * d_model, 0x79);
    let y2 = step(&k2, &v2, &x2);

    // the updated cache changes the answer: rerunning step 2's inputs
    // against the *old* cache must diverge (the attention actually
    // reads the cache operands)
    let stale = g
        .reference_execute(&[
            x2.clone(),
            wq.clone(),
            k1.clone(),
            v1.clone(),
            wo.clone(),
            bo.clone(),
        ])
        .expect("stale reference");
    let max_delta = y2
        .iter()
        .zip(&stale)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(
        max_delta > 1e-3,
        "cache update had no effect on the decode output ({max_delta})"
    );
    // sanity: both steps produced different outputs
    let diff = y1
        .iter()
        .zip(&y2)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(diff > 1e-3, "successive steps produced identical outputs");
}

#[test]
fn sharded_runtime_serves_graph_artifacts() {
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, ExecBackend::Sharded(fast_sharded(2)))
        .expect("sharded runtime");
    for name in ["mlp_block_64x64x128", "dequant_mlp_64x64x64", "decode_block_64x256x64"] {
        let err = rt.golden_check(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(err < TOL, "{name}: golden max err {err}");
        let loaded = rt.load(name).expect(name);
        let plan = loaded
            .graph_shard_plan()
            .expect("sharded graph artifacts expose their plan");
        assert_eq!(plan.shards(), 2, "{name}");
        assert!(loaded.shard_plan().is_none(), "{name}: not a single-kernel plan");
    }
    // the unshardable attention block still fails with a clear reason
    // (map to () first: LoadedKernel carries no Debug impl)
    let e = rt
        .load("attention_block_128x64")
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(e.contains("does not apply"), "{e}");
}

#[test]
fn sharded_coordinator_serves_decode_and_mlp_rows() {
    let dir = artifacts_dir();
    for model in ["mlp_block_64x64x128", "decode_block_64x256x64"] {
        let rt = Runtime::with_backend(&dir, ExecBackend::Sharded(fast_sharded(2)))
            .expect("runtime");
        let inputs = rt.example_inputs(model).expect("inputs");
        let spec = rt.spec(model).expect("spec").clone();
        let batch = spec.in_shapes[0][0] as usize;
        let row_len: usize = spec.in_shapes[0][1..].iter().product::<i64>() as usize;
        let out_row = spec.out_len() / batch;
        let direct = rt.execute(model, &inputs).expect("direct sharded execution");

        let coord = Coordinator::start_sharded(&dir, model, BatchPolicy::default(), 2)
            .expect("start sharded coordinator");
        let mut rxs = Vec::new();
        for slot in 0..batch.min(16) {
            let row = inputs[0][slot * row_len..(slot + 1) * row_len].to_vec();
            rxs.push((slot, coord.submit_row(model, row).expect("submit")));
        }
        for (slot, rx) in rxs {
            let reply = rx.recv().expect("reply");
            let out = reply
                .output
                .unwrap_or_else(|e| panic!("{model} slot {slot}: {e}"));
            assert_eq!(out.len(), out_row, "{model}");
            // same backend + same plan + shared tuning cache: served rows
            // reproduce the direct sharded execution
            let want = &direct[slot * out_row..(slot + 1) * out_row];
            for (g, w) in out.iter().zip(want) {
                assert!((g - w).abs() < 1e-4, "{model} slot {slot}: {g} vs {w}");
            }
        }
        coord.shutdown();
    }
}

#[test]
fn graph_artifact_files_still_round_trip_for_the_decode_block() {
    let dir = artifacts_dir();
    let path = dir.join("decode_block_64x256x64.graph.json");
    let g = KernelGraph::load(&path).expect("decode graph file");
    g.validate().expect("valid");
    assert_eq!(g.inputs.len(), 6);
    // stored unfused: the residual is a standalone element-wise node so
    // the fusion planner's fold into the flash O epilogue stays a
    // load-time decision
    assert_eq!(g.nodes.len(), 5);
}
