//! Continuous-batching soak test: many streams with seeded-random
//! arrivals, prompt lengths and decode lengths run through the serving
//! engine, and every emitted decode step must be *bit-identical* to the
//! one-stream-at-a-time serial decode oracle — on the interp backend,
//! on the compiled bytecode backend, and across the two backends.
//!
//! This is the end-to-end correctness property of the paged KV-cache
//! design: co-batching streams at different sequence lengths (through
//! the shared pool, the per-step paged gather, its 16-aligned padding,
//! and the length-masked decode kernel) must be unobservable in every
//! stream's outputs, no matter how admissions and retirements
//! interleave.

use std::collections::BTreeMap;

use tilelang::serve::{Engine, EngineConfig, StreamSpec};

/// SplitMix64 (same driver as tests/property.rs; no proptest offline).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// Nine streams, staggered arrivals in 0..3, random prompts crossing
/// page boundaries, random decode lengths. Arrival/decode ranges are
/// chosen so all nine are simultaneously live at step 2 (every stream
/// is admitted by then and the shortest decode hasn't retired yet) —
/// the acceptance bar of >= 8 co-batched streams.
fn soak_specs(seed: u64) -> Vec<StreamSpec> {
    let mut rng = Rng(seed);
    (0..9)
        .map(|i| StreamSpec {
            id: 10 + i,
            arrival_step: rng.below(3) as usize,
            prefill_rows: 1 + rng.below(21) as usize,
            decode_steps: 3 + rng.below(3) as usize,
        })
        .collect()
}

fn soak_config(compiled: bool) -> EngineConfig {
    EngineConfig {
        page_rows: 4,
        pool_pages: 64,
        compiled,
        seed: 0x50AE,
        ..Default::default()
    }
}

fn as_bits(outs: &BTreeMap<u64, Vec<Vec<f32>>>) -> BTreeMap<u64, Vec<Vec<u32>>> {
    outs.iter()
        .map(|(&id, steps)| {
            (
                id,
                steps
                    .iter()
                    .map(|row| row.iter().map(|v| v.to_bits()).collect())
                    .collect(),
            )
        })
        .collect()
}

fn assert_identical(
    label: &str,
    got: &BTreeMap<u64, Vec<Vec<f32>>>,
    want: &BTreeMap<u64, Vec<Vec<f32>>>,
) {
    assert_eq!(
        got.keys().collect::<Vec<_>>(),
        want.keys().collect::<Vec<_>>(),
        "{label}: stream sets differ"
    );
    for (&id, w_steps) in want {
        let g_steps = &got[&id];
        assert_eq!(
            g_steps.len(),
            w_steps.len(),
            "{label}: stream {id} emitted {} steps, expected {}",
            g_steps.len(),
            w_steps.len()
        );
        for (step, (g, w)) in g_steps.iter().zip(w_steps).enumerate() {
            for (i, (a, b)) in g.iter().zip(w).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{label}: stream {id} step {step} idx {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn continuous_batching_matches_serial_oracle_on_interp() {
    let specs = soak_specs(0xBA7C1);
    let mut eng = Engine::new(soak_config(false)).expect("engine");
    let report = eng.run(&specs).expect("batched run");
    assert!(
        report.peak_concurrency >= 8,
        "soak must co-batch >= 8 streams, peaked at {}",
        report.peak_concurrency
    );
    assert!(report.peak_pages <= report.pool_pages);
    assert_eq!(report.outputs.len(), specs.len());
    for sp in &specs {
        assert_eq!(report.outputs[&sp.id].len(), sp.decode_steps);
    }
    let oracle = eng.serial_oracle(&specs).expect("serial oracle");
    assert_identical("interp batched vs interp serial", &report.outputs, &oracle);
}

#[test]
fn continuous_batching_matches_serial_oracle_on_compiled_and_interp() {
    let specs = soak_specs(0xBA7C1);
    let mut compiled = Engine::new(soak_config(true)).expect("compiled engine");
    let report = compiled.run(&specs).expect("compiled batched run");
    assert!(report.peak_concurrency >= 8);
    let oracle = compiled.serial_oracle(&specs).expect("compiled serial oracle");
    assert_identical(
        "compiled batched vs compiled serial",
        &report.outputs,
        &oracle,
    );

    // cross-backend: the compiled engine's emitted steps must be the
    // same bits the interp engine emits (same seeds -> same weights)
    let mut interp = Engine::new(soak_config(false)).expect("interp engine");
    let interp_report = interp.run(&specs).expect("interp batched run");
    assert_eq!(as_bits(&report.outputs), as_bits(&interp_report.outputs));
}

/// Pool-pressure soak: a pool too small for every stream at once forces
/// deferred admissions (real queueing), and outputs still match the
/// oracle bit for bit.
#[test]
fn continuous_batching_under_pool_pressure_still_matches_oracle() {
    let specs = soak_specs(0xF001);
    // each stream needs at most ceil(26/4) = 7 pages; 24 pages admit
    // only ~3 at a time
    let cfg = EngineConfig {
        pool_pages: 24,
        ..soak_config(false)
    };
    let mut eng = Engine::new(cfg).expect("engine");
    let report = eng.run(&specs).expect("pressured run");
    assert!(
        report.queue.samples == specs.len(),
        "every stream gets a queue latency sample"
    );
    let oracle = eng.serial_oracle(&specs).expect("serial oracle");
    assert_identical("pressured batched vs serial", &report.outputs, &oracle);
}
