//! Data-movement accounting integration tests: the static per-tier
//! traffic shadow (`tir::compile`) against the interpreter's dynamic
//! counters, driven through the real runtime on every default artifact
//! — single kernels, fused graphs, sharded execution, and the paged
//! continuous-batching decode engine.
//!
//! The contract under test is bit-exactness: both halves count the same
//! logical tile movements (guards and replication ignored), so the
//! tree-walking interpreter, the bytecode VM, and the VM's static
//! shadow must agree to the byte on every artifact, and totals must
//! scale exactly linearly with execution count (each instruction is
//! counted exactly once per execution).

use std::path::PathBuf;
use std::sync::OnceLock;

use tilelang::obs::{Recorder, Traffic};
use tilelang::runtime::{artifacts, ExecBackend, InterpOptions, Runtime};
use tilelang::serve::{Engine, EngineConfig, StreamSpec};
use tilelang::shard::exec::ShardedOptions;

/// One shared artifact directory per test binary (generation once).
fn artifacts_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir()
            .join(format!("tilelang-traffic-artifacts-{}", std::process::id()));
        artifacts::generate_default_set(&dir).expect("generate artifacts");
        dir
    })
    .clone()
}

fn interp_backend() -> ExecBackend {
    ExecBackend::Interp(InterpOptions {
        tune: false,
        ..Default::default()
    })
}

fn compiled_backend() -> ExecBackend {
    ExecBackend::Compiled(InterpOptions {
        tune: false,
        compiled: true,
        ..Default::default()
    })
}

/// Execute `name` once under a fresh enabled recorder and return the
/// recorded `traffic.*` counter totals as a [`Traffic`].
fn recorded_traffic(rt: &mut Runtime, name: &str) -> Traffic {
    let rec = Recorder::enabled();
    rt.set_recorder(rec.clone());
    let inputs = rt.example_inputs(name).expect("inputs");
    rt.execute(name, &inputs).expect("execute");
    Traffic::from_counters(&rec.counters())
}

/// Sum a `node_traffic()` row set, asserting every row carries a static
/// shadow (compiled-backend artifacts must never report `None` lanes).
fn sum_shadow(rows: &[(String, Option<Traffic>)], ctx: &str) -> Traffic {
    let mut total = Traffic::default();
    for (unit, t) in rows {
        let t = t
            .as_ref()
            .unwrap_or_else(|| panic!("{}: unit {} has no static shadow", ctx, unit));
        total.merge(t);
    }
    total
}

#[test]
fn every_default_artifact_counts_identical_traffic_on_both_backends() {
    let dir = artifacts_dir();
    let mut interp_rt = Runtime::with_backend(&dir, interp_backend()).expect("interp runtime");
    let mut compiled_rt =
        Runtime::with_backend(&dir, compiled_backend()).expect("compiled runtime");

    let names = interp_rt.artifact_names();
    assert!(!names.is_empty(), "default artifact set is empty");
    for name in &names {
        let dynamic = recorded_traffic(&mut interp_rt, name);
        let shadowed = recorded_traffic(&mut compiled_rt, name);
        assert!(
            !dynamic.is_zero(),
            "{}: interpreter recorded no data movement",
            name
        );
        assert_eq!(
            dynamic, shadowed,
            "{}: interp dynamic counters != compiled counters",
            name
        );

        // the compiled backend's per-unit static shadows (what
        // `tilelang roofline` prints) sum to exactly the dynamic totals
        let loaded = compiled_rt.load(name).expect("load compiled");
        let stat = sum_shadow(&loaded.node_traffic(), name);
        assert_eq!(
            stat, dynamic,
            "{}: static shadow sum != dynamic counters",
            name
        );
        assert!(stat.flops > 0, "{}: zero FLOPs counted", name);
        assert!(stat.dram_bytes() > 0, "{}: zero DRAM bytes counted", name);
    }
}

#[test]
fn modeled_traffic_bit_matches_dynamic_counters_on_every_artifact() {
    // PR 10 differential guardrail: the schedule model's op/byte counts
    // (`LoadedKernel::modeled_traffic_exact`, fed by
    // `sim::model::modeled_traffic`) must bit-match the interpreter's
    // dynamic `traffic.*` counters for every default artifact — the
    // analytical model and the execution engines count the same moves.
    let dir = artifacts_dir();
    for backend in [interp_backend(), compiled_backend()] {
        let mut rt = Runtime::with_backend(&dir, backend).expect("runtime");
        let names = rt.artifact_names();
        for name in &names {
            let dynamic = recorded_traffic(&mut rt, name);
            let loaded = rt.load(name).expect("load");
            let modeled = loaded
                .modeled_traffic_exact()
                .unwrap_or_else(|| panic!("{}: model produced no traffic", name));
            assert_eq!(
                modeled, dynamic,
                "{}: modeled op/byte counts != dynamic counters",
                name
            );
        }
    }

    // sharded lanes: the model sums the same quantity per shard
    let mut opts = ShardedOptions::new(2);
    opts.interp.tune = false;
    opts.interp.compiled = true;
    let mut srt = Runtime::with_backend(&dir, ExecBackend::Sharded(opts)).expect("runtime");
    for name in ["linear_64x256x64", "mlp_block_64x64x128"] {
        let dynamic = recorded_traffic(&mut srt, name);
        let modeled = srt
            .load(name)
            .expect("load")
            .modeled_traffic_exact()
            .unwrap_or_else(|| panic!("{}: sharded model produced no traffic", name));
        assert_eq!(
            modeled, dynamic,
            "{}: sharded modeled counts != dynamic counters",
            name
        );
    }
}

#[test]
fn traffic_counters_scale_exactly_linearly_with_executions() {
    let dir = artifacts_dir();
    let mut rt = Runtime::with_backend(&dir, compiled_backend()).expect("runtime");
    let rec = Recorder::enabled();
    rt.set_recorder(rec.clone());
    let name = "matmul_64x64x64";
    let inputs = rt.example_inputs(name).expect("inputs");
    let shadow = sum_shadow(&rt.load(name).expect("load").node_traffic(), name);

    rt.execute(name, &inputs).expect("first execute");
    assert_eq!(Traffic::from_counters(&rec.counters()), shadow);

    // a second run adds exactly one more shadow: every instruction is
    // counted exactly once per execution, nothing is double-added on
    // cache hits and nothing is a load-time snapshot
    rt.execute(name, &inputs).expect("second execute");
    let mut twice = shadow;
    twice.merge(&shadow);
    assert_eq!(Traffic::from_counters(&rec.counters()), twice);
}

#[test]
fn sharded_lane_shadows_sum_to_dynamic_counters_on_both_engines() {
    let dir = artifacts_dir();
    // compiled per-shard kernels: static lane shadows exist
    let mut opts = ShardedOptions::new(2);
    opts.interp.tune = false;
    opts.interp.compiled = true;
    let mut rt = Runtime::with_backend(&dir, ExecBackend::Sharded(opts)).expect("runtime");

    // a plain kernel (per-lane sub-problem) and a fused graph (whole
    // block per shard) — both sharded execution paths
    for name in ["linear_64x256x64", "mlp_block_64x64x128"] {
        let dynamic = recorded_traffic(&mut rt, name);
        let loaded = rt.load(name).expect("load");
        let rows = loaded.node_traffic();
        assert_eq!(rows.len(), 2, "{}: one traffic row per lane", name);
        for (unit, _) in &rows {
            assert!(unit.starts_with("shard"), "{}: lane row named {}", name, unit);
        }
        let stat = sum_shadow(&rows, name);
        assert!(!stat.is_zero(), "{}: lanes moved no bytes", name);
        assert_eq!(
            stat, dynamic,
            "{}: lane shadow sum != recorded shard counters",
            name
        );

        // the tree-walking per-shard engine counts the same totals
        let mut iopts = ShardedOptions::new(2);
        iopts.interp.tune = false;
        let mut irt =
            Runtime::with_backend(&dir, ExecBackend::Sharded(iopts)).expect("interp runtime");
        let idynamic = recorded_traffic(&mut irt, name);
        assert_eq!(
            idynamic, dynamic,
            "{}: sharded interp counters != sharded compiled counters",
            name
        );
    }
}

#[test]
fn paged_decode_traffic_is_backend_invariant() {
    let specs: Vec<StreamSpec> = (0..3)
        .map(|i| StreamSpec {
            id: i + 1,
            arrival_step: i as usize,
            prefill_rows: 2 + i as usize,
            decode_steps: 3,
        })
        .collect();
    let run = |compiled: bool| -> Traffic {
        let rec = Recorder::enabled();
        let mut eng = Engine::new(EngineConfig {
            page_rows: 4,
            pool_pages: 32,
            compiled,
            ..Default::default()
        })
        .expect("engine");
        eng.set_recorder(rec.clone());
        eng.run(&specs).expect("engine run");
        Traffic::from_counters(&rec.counters())
    };

    let vm = run(true);
    let interp = run(false);
    assert!(vm.flops > 0, "paged decode counted no FLOPs");
    assert!(vm.dram_wr_bytes > 0, "prefill writes no pool bytes");
    assert_eq!(
        vm, interp,
        "paged decode traffic diverges between the VM and the interpreter"
    );
}

#[test]
fn serve_node_traffic_rows_carry_shadows_for_the_compiled_engine() {
    let mut eng = Engine::new(EngineConfig {
        page_rows: 4,
        pool_pages: 32,
        compiled: true,
        ..Default::default()
    })
    .expect("engine");
    let specs: Vec<StreamSpec> = (0..2)
        .map(|i| StreamSpec {
            id: i + 1,
            arrival_step: 0,
            prefill_rows: 3,
            decode_steps: 2,
        })
        .collect();
    eng.set_recorder(Recorder::enabled());
    eng.run(&specs).expect("engine run");

    let rows = eng.node_traffic();
    assert!(!rows.is_empty(), "compiled engine reports no decode-node traffic");
    let stat = sum_shadow(&rows, "serve decode graph");
    assert!(stat.flops > 0, "decode graph shadow counts no FLOPs");
    let modeled = eng.node_modeled_bytes();
    assert_eq!(rows.len(), modeled.len(), "traffic and modeled rows align");
}
