//! Sharded-vs-single-device differential tests: the `shard` subsystem
//! must produce the same numbers as the single-device interp backend
//! and the CPU references, for every strategy the acceptance criteria
//! name (gemm row-parallel, gemm split-K, flash-attention
//! head-parallel) across shard counts 2 and 4 — plus uneven remainder
//! splits at shards = 3 and end-to-end golden checks through
//! `Runtime`/`Coordinator` on the sharded backend.
//!
//! Planner *choice* tests (which strategy wins for which shape) live in
//! `shard::plan`'s unit tests; this file pins execution semantics.

use std::path::PathBuf;
use std::sync::OnceLock;

use tilelang::coordinator::{BatchPolicy, Coordinator};
use tilelang::runtime::{artifacts, ArtifactSpec, ExecBackend, InterpOptions, Runtime, WorkloadKind};
use tilelang::shard::exec::{ShardedKernel, ShardedOptions};
use tilelang::shard::plan::{plan_with_strategy, Collective, Strategy};
use tilelang::sim::device::Device;
use tilelang::workloads::attention::reference_attention;
use tilelang::workloads::matmul::{reference_matmul, test_data};

/// Interp execution stages tiles through fp16 shared memory; sharded
/// gathers additionally reorder partial sums (split-K), so compare with
/// the same tolerance the integration suite pins.
const TOL: f32 = 0.05;

/// One shared artifact directory per test binary (generation once).
fn artifacts_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir =
            std::env::temp_dir().join(format!("tilelang-shard-artifacts-{}", std::process::id()));
        artifacts::generate_default_set(&dir).expect("generate artifacts");
        dir
    })
    .clone()
}

/// Sharded options with tuning disabled: unit tests stay fast and cover
/// the static-default config path.
fn fast_opts(shards: usize) -> ShardedOptions {
    ShardedOptions {
        shards,
        interp: InterpOptions {
            tune: false,
            ..Default::default()
        },
    }
}

fn fast_interp() -> ExecBackend {
    ExecBackend::Interp(InterpOptions {
        tune: false,
        ..Default::default()
    })
}

#[test]
fn gemm_row_parallel_and_split_k_match_single_device() {
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, fast_interp()).expect("runtime");
    let spec = rt.spec("matmul_64x64x64").expect("spec").clone();
    let inputs = rt.example_inputs("matmul_64x64x64").expect("inputs");
    let single = rt.execute("matmul_64x64x64", &inputs).expect("single-device");
    let want = reference_matmul(&inputs[0], &inputs[1], 64, 64, 64);
    let dev = Device::by_name("h100").unwrap();

    for strategy in [Strategy::RowParallel, Strategy::SplitK] {
        for shards in [2usize, 4] {
            let plan = plan_with_strategy(
                &WorkloadKind::Gemm,
                &spec.in_shapes,
                &spec.out_shape,
                shards,
                strategy,
                &dev,
            )
            .unwrap_or_else(|e| panic!("{strategy:?} x{shards}: {e}"));
            assert_eq!(plan.shards(), shards);
            let kernel = ShardedKernel::prepare_with_plan(&spec, plan, &fast_opts(shards), &dir)
                .unwrap_or_else(|e| panic!("{strategy:?} x{shards}: {e}"));
            let got = kernel
                .execute(&inputs)
                .unwrap_or_else(|e| panic!("{strategy:?} x{shards}: {e}"));
            assert_eq!(got.len(), single.len());
            for (i, ((g, s), w)) in got.iter().zip(&single).zip(&want).enumerate() {
                assert!(
                    (g - s).abs() < TOL,
                    "{strategy:?} x{shards} idx {i}: sharded {g} vs single {s}"
                );
                assert!(
                    (g - w).abs() < TOL,
                    "{strategy:?} x{shards} idx {i}: sharded {g} vs reference {w}"
                );
            }
        }
    }
}

#[test]
fn uneven_shard_counts_match_single_device() {
    // shards = 3 does not divide M = 64 (or bh = 4): the planner hands
    // out remainder spans (32/16/16 rows; 2/1/1 heads) and the gathered
    // output must still equal the single-device run
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, fast_interp()).expect("runtime");
    let spec = rt.spec("matmul_64x64x64").expect("spec").clone();
    let inputs = rt.example_inputs("matmul_64x64x64").expect("inputs");
    let single = rt.execute("matmul_64x64x64", &inputs).expect("single-device");
    let want = reference_matmul(&inputs[0], &inputs[1], 64, 64, 64);
    let dev = Device::by_name("h100").unwrap();

    for strategy in [Strategy::RowParallel, Strategy::SplitK] {
        let plan = plan_with_strategy(
            &WorkloadKind::Gemm,
            &spec.in_shapes,
            &spec.out_shape,
            3,
            strategy,
            &dev,
        )
        .unwrap_or_else(|e| panic!("{strategy:?} x3: {e}"));
        assert_eq!(plan.shards(), 3);
        // remainder spans cover the dimension exactly
        let widths: Vec<i64> = plan
            .parts
            .iter()
            .map(|p| match strategy {
                Strategy::RowParallel => p.in_shapes[0][0],
                _ => p.in_shapes[0][1],
            })
            .collect();
        assert_eq!(widths.iter().sum::<i64>(), 64, "{strategy:?}: {widths:?}");
        assert_eq!(widths, vec![32, 16, 16], "{strategy:?}");
        let kernel = ShardedKernel::prepare_with_plan(&spec, plan, &fast_opts(3), &dir)
            .unwrap_or_else(|e| panic!("{strategy:?} x3: {e}"));
        let got = kernel
            .execute(&inputs)
            .unwrap_or_else(|e| panic!("{strategy:?} x3: {e}"));
        assert_eq!(got.len(), single.len());
        for (i, ((g, s), w)) in got.iter().zip(&single).zip(&want).enumerate() {
            assert!(
                (g - s).abs() < TOL,
                "{strategy:?} x3 idx {i}: sharded {g} vs single {s}"
            );
            assert!(
                (g - w).abs() < TOL,
                "{strategy:?} x3 idx {i}: sharded {g} vs reference {w}"
            );
        }
    }

    // head-parallel remainder: bh = 4 across 3 shards (2/1/1 heads)
    let spec = rt.spec("flash_attention_2x128x64").expect("spec").clone();
    // bh = 2 cannot split 3 ways: planning must error cleanly
    assert!(ShardedKernel::prepare(&spec, &fast_opts(3), &dir).is_err());
    let (bh, seq, d) = (4i64, 128i64, 64i64);
    let q = test_data(bh * seq * d, 0xA7);
    let k = test_data(bh * seq * d, 0xA8);
    let v = test_data(bh * seq * d, 0xA9);
    let fa_inputs = vec![q.clone(), k.clone(), v.clone()];
    let fa_spec = ArtifactSpec {
        name: "fa_uneven_test".to_string(),
        hlo_path: PathBuf::from("-"),
        in_shapes: vec![vec![bh, seq, d]; 3],
        out_shape: vec![bh, seq, d],
        workload: Some("flash_attention".to_string()),
        graph: None,
    };
    let kernel = ShardedKernel::prepare(&fa_spec, &fast_opts(3), &dir).expect("fa x3");
    assert_eq!(kernel.plan().shards(), 3);
    assert_eq!(kernel.plan().parts[0].out_shape, vec![2, seq, d]);
    let got = kernel.execute(&fa_inputs).expect("fa x3 execution");
    let want = reference_attention(&q, &k, &v, bh, seq, d, false);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < TOL, "fa x3 idx {i}: {g} vs {w}");
    }
}

#[test]
fn flash_attention_head_parallel_matches_reference() {
    // synthetic bh=4 spec so both shard counts divide the heads; no
    // artifact files are needed — the dir only hosts the tuning cache
    let dir = std::env::temp_dir().join(format!("tilelang-shard-fa-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let (bh, seq, d) = (4i64, 128i64, 64i64);
    let q = test_data(bh * seq * d, 0xF1);
    let k = test_data(bh * seq * d, 0xF2);
    let v = test_data(bh * seq * d, 0xF3);
    let inputs = vec![q.clone(), k.clone(), v.clone()];
    let want = reference_attention(&q, &k, &v, bh, seq, d, false);
    let spec = ArtifactSpec {
        name: "fa_head_parallel_test".to_string(),
        hlo_path: PathBuf::from("-"),
        in_shapes: vec![vec![bh, seq, d]; 3],
        out_shape: vec![bh, seq, d],
        workload: Some("flash_attention".to_string()),
        graph: None,
    };
    // shards = 1 doubles as the single-device baseline
    let mut baseline: Option<Vec<f32>> = None;
    for shards in [1usize, 2, 4] {
        let kernel = ShardedKernel::prepare(&spec, &fast_opts(shards), &dir)
            .unwrap_or_else(|e| panic!("x{shards}: {e}"));
        assert_eq!(kernel.plan().strategy, Strategy::HeadParallel);
        assert_eq!(kernel.plan().collective, Collective::HeadConcat);
        assert_eq!(kernel.plan().shards(), shards);
        let got = kernel.execute(&inputs).unwrap_or_else(|e| panic!("x{shards}: {e}"));
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < TOL, "x{shards} idx {i}: {g} vs reference {w}");
        }
        if let Some(base) = &baseline {
            // head-parallel never mixes heads: sharded output equals
            // the single-executor run bit-for-bit
            for (i, (g, b)) in got.iter().zip(base).enumerate() {
                assert!((g - b).abs() < 1e-6, "x{shards} idx {i}: {g} vs baseline {b}");
            }
        } else {
            baseline = Some(got);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharded_runtime_passes_golden_checks() {
    let dir = artifacts_dir();
    let rt =
        Runtime::with_backend(&dir, ExecBackend::Sharded(fast_opts(2))).expect("sharded runtime");
    assert_eq!(rt.backend_name(), "sharded");
    // every family the planner can split at bh/m = 2 serves end to end
    for name in [
        "matmul_64x64x64",
        "linear_64x256x64",
        "flash_attention_2x128x64",
        "flash_attention_causal_2x128x64",
        "flash_decode_4x16x64x16",
        "chunk_state_2x128",
        "chunk_scan_2x128",
    ] {
        let err = rt.golden_check(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(err < TOL, "{name}: golden max err {err}");
        let loaded = rt.load(name).expect(name);
        let plan = loaded.shard_plan().expect("sharded kernels expose their plan");
        assert_eq!(plan.shards(), 2, "{name}");
    }
    // the small dequant artifact cannot split its 64 output rows under
    // the default 64-wide tile: planning must fail with an error, not
    // panic or serve wrong numbers
    assert!(rt.load("dequant_int4_32x64x64").is_err());
}

#[test]
fn sharded_coordinator_serves_batched_rows() {
    let dir = artifacts_dir();
    let model = "linear_64x256x64";
    let rt =
        Runtime::with_backend(&dir, ExecBackend::Sharded(fast_opts(2))).expect("runtime");
    let inputs = rt.example_inputs(model).expect("inputs");
    let spec = rt.spec(model).expect("spec").clone();
    let batch = spec.in_shapes[0][0] as usize;
    let row_len: usize = spec.in_shapes[0][1..].iter().product::<i64>() as usize;
    let out_row = spec.out_len() / batch;
    let direct = rt.execute(model, &inputs).expect("direct sharded execution");
    let want = reference_matmul(&inputs[0], &inputs[1], 64, 256, 64);
    for (g, w) in direct.iter().zip(&want) {
        assert!((g - w).abs() < TOL, "sharded direct vs reference: {g} vs {w}");
    }

    let coord = Coordinator::start_batched_with_backend(
        &dir,
        ExecBackend::Sharded(fast_opts(2)),
        model,
        BatchPolicy::default(),
    )
    .expect("start sharded coordinator");
    let mut rxs = Vec::new();
    for slot in 0..batch {
        let row = inputs[0][slot * row_len..(slot + 1) * row_len].to_vec();
        rxs.push((slot, coord.submit_row(model, row).expect("submit")));
    }
    for (slot, rx) in rxs {
        let reply = rx.recv().expect("reply");
        let out = reply.output.unwrap_or_else(|e| panic!("slot {slot}: {e}"));
        assert_eq!(out.len(), out_row);
        // same backend + same plan + shared tuning cache: the served
        // rows reproduce the direct sharded execution exactly
        let wd = &direct[slot * out_row..(slot + 1) * out_row];
        for (g, w) in out.iter().zip(wd) {
            assert!((g - w).abs() < 1e-4, "slot {slot}: {g} vs {w}");
        }
        assert!(reply.batch_size >= 1 && reply.batch_size <= batch);
    }
    coord.shutdown();

    // the convenience constructor wires the same backend
    let coord = Coordinator::start_sharded(&dir, model, BatchPolicy::default(), 2)
        .expect("start_sharded");
    let row = inputs[0][..row_len].to_vec();
    let reply = coord
        .submit_row(model, row)
        .expect("submit")
        .recv()
        .expect("reply");
    let out = reply.output.expect("row output");
    for (g, w) in out.iter().zip(&direct[..out_row]) {
        assert!((g - w).abs() < TOL, "start_sharded row: {g} vs {w}");
    }
    coord.shutdown();
}
