//! Seeded property tests for the layout algebra (§4.1): index maps stay
//! within the bounds the interval analyzer reports, linearizing layouts
//! are bijections on the tile, composition agrees with function
//! application, and the fragment extension primitives preserve the
//! partition invariant across randomized shapes.

use tilelang::layout::{domain_iter, Fragment, IterVar, Layout};

/// SplitMix64 (no proptest in the offline vendor set).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next() % xs.len() as u64) as usize]
    }
}

/// Every index a layout produces must lie inside the shape its interval
/// analysis reports — the in-bounds invariant backing buffer sizing.
fn assert_in_bounds(l: &Layout, label: &str) {
    let out_shape = l.output_shape();
    for idx in domain_iter(&l.input_shape()) {
        let out = l.index(&idx);
        assert_eq!(out.len(), out_shape.len(), "{label}: arity");
        for (d, (&o, &hi)) in out.iter().zip(&out_shape).enumerate() {
            assert!(
                o >= 0 && o < hi,
                "{label}: index {idx:?} -> dim {d} value {o} outside [0, {hi})"
            );
        }
    }
}

#[test]
fn random_layouts_stay_in_bounds_and_linearizers_are_bijective() {
    let mut rng = Rng(0xA11CE);
    for _ in 0..24 {
        let rows = *rng.pick(&[4i64, 8, 16, 32, 64]);
        let cols = *rng.pick(&[8i64, 16, 32, 64]);

        let rm = Layout::row_major(&[rows, cols]);
        assert_in_bounds(&rm, "row_major");
        assert!(rm.is_bijective_linear());

        let cm = Layout::col_major(rows, cols);
        assert_in_bounds(&cm, "col_major");
        assert!(cm.is_bijective_linear());

        // padding: injective (no aliasing) but deliberately not onto
        let pad = *rng.pick(&[1i64, 2, 4]);
        let p = Layout::padded(rows, cols, pad);
        assert_in_bounds(&p, "padded");
        assert!(p.is_injective());
        assert!(!p.is_bijective_linear());
        assert!(p.output_size() >= rows * cols);

        // swizzle: a bank permutation must remain a bijection on the tile
        let bits = *rng.pick(&[8u32, 16, 32]);
        let s = Layout::swizzled(rows, cols, bits);
        assert_in_bounds(&s, "swizzled");
        assert!(
            s.is_bijective_linear(),
            "swizzle({rows},{cols},{bits}) aliases"
        );
    }
}

#[test]
fn composition_agrees_with_function_application() {
    let mut rng = Rng(0xC0DE);
    for _ in 0..16 {
        let rows = *rng.pick(&[2i64, 4, 8]);
        let cols = *rng.pick(&[4i64, 8, 16]);
        let inner = Layout::row_major(&[rows, cols]);
        // outer: 1-d -> 1-d affine stretch over the inner's range
        let stride = *rng.pick(&[1i64, 2, 3]);
        let kv = IterVar::new("k", rows * cols);
        let outer = Layout::new(vec![kv.clone()], vec![kv.var.expr() * stride]);
        let comp = inner.compose(&outer);
        assert_eq!(comp.input_shape(), vec![rows, cols]);
        for idx in domain_iter(&[rows, cols]) {
            let step = inner.index(&idx);
            let want = outer.index(&step);
            let got = comp.index(&idx);
            assert_eq!(got, want, "compose mismatch at {idx:?}");
        }
        // composing with an injective outer preserves injectivity
        assert!(comp.is_injective());
        assert_in_bounds(&comp, "composed");
    }
}

#[test]
fn linear_vectorized_fragments_partition_and_vectorize() {
    let mut rng = Rng(0xF1A6);
    for _ in 0..20 {
        let rows = *rng.pick(&[4i64, 8, 16]);
        let cols = *rng.pick(&[8i64, 16, 32]);
        let threads = *rng.pick(&[4i64, 16, 32, 64]);
        let vec = *rng.pick(&[1i64, 2, 4]);
        let f = Fragment::linear_vectorized(&[rows, cols], threads, vec);
        assert!(f.is_valid_partition(), "{rows}x{cols} t{threads} v{vec}");
        // vector chunks stay on one thread with consecutive register slots
        assert!(
            f.innermost_contiguity() >= vec,
            "{rows}x{cols} t{threads} v{vec}: contiguity {}",
            f.innermost_contiguity()
        );
        // a partition never stores more cells than the register file holds
        assert!(f.cells() * f.replicate <= f.num_threads * f.locals_per_thread());
    }
}

#[test]
fn fragment_algebra_chains_preserve_the_partition_invariant() {
    let mut rng = Rng(0xBEEF2);
    for case in 0..16 {
        let mut f = if case % 2 == 0 {
            Fragment::mma_ldmatrix_16x16()
        } else {
            Fragment::mma_c_16x8()
        };
        let mut expected_cells = f.cells();
        let mut expected_rep = f.replicate;
        for _ in 0..(rng.next() % 3 + 1) {
            match rng.next() % 3 {
                0 => {
                    let dim = (rng.next() % 2) as usize;
                    f = f.repeat(dim, 2, false);
                    expected_cells *= 2;
                }
                1 => {
                    let dim = (rng.next() % 2) as usize;
                    f = f.repeat(dim, 2, true);
                    expected_cells *= 2;
                }
                _ => {
                    f = f.replicate(2);
                    expected_rep *= 2;
                }
            }
            assert!(f.is_valid_partition(), "algebra step broke the partition");
            assert_eq!(f.cells(), expected_cells);
            assert_eq!(f.replicate, expected_rep);
            // ownership bookkeeping: every (cell, replica) fits the
            // thread x register grid injectively
            assert!(f.cells() * f.replicate <= f.num_threads * f.locals_per_thread());
        }
        // the dense-table backend answers identically to the algebra
        let t = f.to_table();
        assert_eq!(t.shape, f.shape);
        assert_eq!(t.locals_per_thread(), f.locals_per_thread());
        for idx in domain_iter(&f.shape).take(64) {
            assert_eq!(t.thread_at(&idx, 0), f.thread_at(&idx, 0));
            assert_eq!(t.local_at(&idx), f.local_at(&idx));
        }
    }
}

#[test]
fn block_gemm_fragments_partition_for_all_warp_grids() {
    for (bm, bn, wm, wn) in [
        (32i64, 32i64, 1i64, 2i64),
        (32, 32, 2, 1),
        (64, 64, 2, 2),
        (64, 128, 1, 4),
        (128, 64, 4, 1),
        (128, 128, 2, 4),
    ] {
        let f = Fragment::block_gemm_c(bm, bn, wm, wn);
        assert!(f.is_valid_partition(), "{bm}x{bn} warps {wm}x{wn}");
        assert!(f.covers_all_threads(), "{bm}x{bn} warps {wm}x{wn}");
        assert_eq!(f.num_threads, wm * wn * 32);
        assert_eq!(f.cells(), bm * bn);
    }
}
