//! Differential fuzz harness for the compiled bytecode VM.
//!
//! Every case runs the SAME lowered program on both execution engines —
//! the tree-walking interpreter (`tir::interp`, the oracle) and the
//! register-bytecode VM (`tir::compile`) — and demands *bit-for-bit*
//! equal outputs: both engines share `round_to_dtype` on every store
//! and the exact f32 accumulation order, so any divergence is a
//! compiler bug, not noise. Where a CPU reference exists the interp
//! output is additionally held to the usual fp16-staging tolerance, so
//! a case that passes proves compiled == interp == reference.
//!
//! Coverage: seeded-random shapes/configs/dtypes for the GEMM family
//! (with fused epilogue combos), flash attention (± causal), flash
//! decode, dequant GEMM, both Mamba-2 chunk kernels, dynamic-M tail
//! shapes (M ∈ {33, 80, 96}), and the sharded + graph execution paths
//! through the public `Runtime` API.

use std::collections::HashMap;

use tilelang::ir::buffer::BufferId;
use tilelang::ir::dtype::DType;
use tilelang::ir::program::{specialize, GemmWarpPolicy, TileProgram};
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::sim::device::Device;
use tilelang::tir::compile::compile_lowered;
use tilelang::tir::interp::{Interp, Tensors};
use tilelang::workloads::attention::{
    flash_attention_program, flash_decode_paged_program, flash_decode_program,
    reference_attention, reference_flash_decode, reference_flash_decode_paged, AttnConfig,
    DecodeConfig,
};
use tilelang::workloads::dequant::{
    dequant_matmul_program, dequantize_weights, quantize_weights, DequantConfig, WeightFormat,
};
use tilelang::workloads::epilogue::{reference_apply, Activation, EpilogueOp};
use tilelang::workloads::linear_attention::{
    chunk_scan_program, chunk_state_program, reference_chunk_scan, reference_chunk_state,
};
use tilelang::workloads::matmul::{
    matmul_program, matmul_program_dyn, matmul_program_ep, reference_matmul, test_data,
    TileConfig,
};

/// SplitMix64 (same driver as tests/property.rs; no proptest offline).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next() % xs.len() as u64) as usize]
    }
}

/// Lower `prog`, run it on both engines with the same inputs, assert the
/// outputs are bit-identical and return the (shared) output vector.
fn run_both(
    prog: &TileProgram,
    dev: &Device,
    inputs: &[(BufferId, Vec<f32>)],
    out: BufferId,
    label: &str,
) -> Vec<f32> {
    let lowered = compile(prog, dev, &CompileOptions::default())
        .unwrap_or_else(|e| panic!("{label}: lowering failed: {e}"));
    let interp = Interp::new(&lowered).unwrap_or_else(|e| panic!("{label}: interp init: {e}"));
    let mut ti = Tensors::new();
    for (id, v) in inputs {
        ti.insert(*id, v.clone());
    }
    interp
        .run(&mut ti)
        .unwrap_or_else(|e| panic!("{label}: interp run: {e}"));

    let vm = compile_lowered(&lowered)
        .unwrap_or_else(|e| panic!("{label}: bytecode compile failed: {e}"));
    vm.validate()
        .unwrap_or_else(|e| panic!("{label}: bytecode validation failed: {e}"));
    let mut tc = Tensors::new();
    for (id, v) in inputs {
        tc.insert(*id, v.clone());
    }
    vm.run(&mut tc)
        .unwrap_or_else(|e| panic!("{label}: compiled run: {e}"));

    let want = ti.remove(&out).unwrap_or_else(|| panic!("{label}: interp produced no output"));
    let got = tc.remove(&out).unwrap_or_else(|| panic!("{label}: vm produced no output"));
    assert_eq!(got.len(), want.len(), "{label}: output length mismatch");
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{label}: compiled diverged from interp oracle at {i}: {g} vs {w}"
        );
    }
    got
}

#[test]
fn gemm_family_compiled_matches_interp_and_reference() {
    let mut rng = Rng(0xD1FF_0001);
    let devices = [Device::a100(), Device::h100(), Device::rtx4090()];
    let mut executed = 0;
    for case in 0..10 {
        let bm = *rng.pick(&[16i64, 32, 64]);
        let bn = *rng.pick(&[16i64, 32, 64]);
        let bk = *rng.pick(&[16i64, 32]);
        let m = bm * *rng.pick(&[1i64, 2, 3]);
        let n = bn * *rng.pick(&[1i64, 2]);
        let k = bk * *rng.pick(&[2i64, 3]);
        let cfg = TileConfig {
            block_m: bm,
            block_n: bn,
            block_k: bk,
            num_stages: *rng.pick(&[1usize, 2, 3]),
            threads: *rng.pick(&[64i64, 128]),
            policy: *rng.pick(&[
                GemmWarpPolicy::Square,
                GemmWarpPolicy::FullRow,
                GemmWarpPolicy::FullCol,
            ]),
            rasterize: case % 2 == 0,
            specialize: *rng.pick(&[None, Some(false), Some(true)]),
        };
        let dev = rng.pick(&devices);
        let prog = matmul_program(m, n, k, DType::F16, &cfg);
        let a = test_data(m * k, 1000 + case as u64);
        let b = test_data(k * n, 2000 + case as u64);
        let got = run_both(
            &prog,
            dev,
            &[(prog.params[0].id, a.clone()), (prog.params[1].id, b.clone())],
            prog.params[2].id,
            &format!("gemm case {case} ({m}x{n}x{k})"),
        );
        let want = reference_matmul(&a, &b, m, n, k);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() < 0.05 + 0.02 * w.abs(),
                "gemm case {case}: {g} vs {w}"
            );
        }
        executed += 1;
    }
    assert_eq!(executed, 10);
}

/// Non-f16 input dtypes: both engines round stores through the same
/// `round_to_dtype`, so outputs stay bit-identical even where no CPU
/// reference tolerance is meaningful.
#[test]
fn gemm_other_dtypes_stay_bit_identical() {
    let cfg = TileConfig::default_for(32, 32, 32);
    for (dtype, seed) in [(DType::BF16, 0xB16u64), (DType::F32, 0xF32u64)] {
        let prog = matmul_program(32, 32, 32, dtype, &cfg);
        let a = test_data(32 * 32, seed);
        let b = test_data(32 * 32, seed + 1);
        let got = run_both(
            &prog,
            &Device::h100(),
            &[(prog.params[0].id, a.clone()), (prog.params[1].id, b.clone())],
            prog.params[2].id,
            &format!("gemm {dtype:?}"),
        );
        assert!(got.iter().any(|v| *v != 0.0), "{dtype:?}: all-zero output");
    }
}

#[test]
fn gemm_epilogue_combos_compiled_matches_interp_and_reference() {
    let mut rng = Rng(0xD1FF_0002);
    let menu: &[&[EpilogueOp]] = &[
        &[EpilogueOp::BiasAdd { dim: 1 }],
        &[EpilogueOp::Activation(Activation::Relu)],
        &[EpilogueOp::Activation(Activation::Gelu)],
        &[EpilogueOp::Activation(Activation::Silu)],
        &[EpilogueOp::ResidualAdd],
        &[EpilogueOp::Scale(0.5)],
        &[
            EpilogueOp::BiasAdd { dim: 1 },
            EpilogueOp::Activation(Activation::Gelu),
            EpilogueOp::ResidualAdd,
        ],
        &[EpilogueOp::Scale(2.0), EpilogueOp::Activation(Activation::Relu)],
    ];
    for (case, eps) in menu.iter().enumerate() {
        let (m, n, k) = (64i64, 32i64, 64i64);
        let cfg = TileConfig {
            block_m: 32,
            block_n: 32,
            block_k: 32,
            num_stages: *rng.pick(&[1usize, 2]),
            threads: 128,
            policy: GemmWarpPolicy::Square,
            rasterize: false,
            specialize: None,
        };
        let prog = matmul_program_ep(m, n, k, DType::F16, &cfg, eps);
        let a = test_data(m * k, 3000 + case as u64);
        let b = test_data(k * n, 4000 + case as u64);
        // params: A, B, <one operand per operand-taking op>, C
        let mut inputs = vec![
            (prog.params[0].id, a.clone()),
            (prog.params[1].id, b.clone()),
        ];
        let mut operands = Vec::new();
        let mut pi = 2;
        for (oi, op) in eps.iter().enumerate() {
            if op.takes_operand() {
                let len: i64 = op.operand_shape(&[m, n]).unwrap().iter().product();
                let data = test_data(len, 5000 + (case * 8 + oi) as u64);
                inputs.push((prog.params[pi].id, data.clone()));
                operands.push(Some(data));
                pi += 1;
            } else {
                operands.push(None);
            }
        }
        let out = prog.params[pi].id;
        let got = run_both(
            &prog,
            &Device::h100(),
            &inputs,
            out,
            &format!("gemm-ep case {case} ({eps:?})"),
        );
        let mut want = reference_matmul(&a, &b, m, n, k);
        for (op, operand) in eps.iter().zip(&operands) {
            reference_apply(op, &mut want, operand.as_deref(), &[m, n])
                .unwrap_or_else(|e| panic!("gemm-ep case {case}: reference: {e}"));
        }
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() < 0.06 + 0.02 * w.abs(),
                "gemm-ep case {case} ({eps:?}): {g} vs {w}"
            );
        }
    }
}

#[test]
fn attention_family_compiled_matches_interp_and_reference() {
    let mut rng = Rng(0xD1FF_0003);
    let mut executed = 0;
    for case in 0..8 {
        let seq = *rng.pick(&[64i64, 128]);
        let d = *rng.pick(&[32i64, 64]);
        let bh = *rng.pick(&[1i64, 2]);
        let causal = case % 2 == 0;
        let cfg = AttnConfig {
            block_m: *rng.pick(&[32i64, 64]),
            block_n: *rng.pick(&[32i64, 64]),
            num_stages: *rng.pick(&[1usize, 2]),
            threads: 128,
            specialize: *rng.pick(&[None, Some(false), Some(true)]),
        };
        if seq % cfg.block_m != 0 || seq % cfg.block_n != 0 {
            continue;
        }
        let prog = flash_attention_program(bh, seq, d, causal, &cfg);
        let q = test_data(bh * seq * d, 6000 + case as u64);
        let k = test_data(bh * seq * d, 7000 + case as u64);
        let v = test_data(bh * seq * d, 8000 + case as u64);
        let got = run_both(
            &prog,
            &Device::h100(),
            &[
                (prog.params[0].id, q.clone()),
                (prog.params[1].id, k.clone()),
                (prog.params[2].id, v.clone()),
            ],
            prog.params[3].id,
            &format!("attention case {case} (seq={seq} d={d} causal={causal})"),
        );
        let want = reference_attention(&q, &k, &v, bh, seq, d, causal);
        let mut max_err = 0f32;
        for (g, w) in got.iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(max_err < 0.03, "attention case {case}: max err {max_err}");
        executed += 1;
    }
    assert!(executed >= 5, "grid too sparse: only {executed} cases ran");
}

#[test]
fn flash_decode_compiled_matches_interp_and_reference() {
    for (case, (batch, heads, kv, d)) in
        [(2i64, 16i64, 64i64, 16i64), (4, 16, 64, 16), (1, 32, 128, 32)]
            .iter()
            .enumerate()
    {
        let cfg = DecodeConfig::default_for(*heads, *kv);
        let prog = flash_decode_program(*batch, *heads, *kv, *d, &cfg, &[]);
        let q = test_data(batch * heads * d, 9000 + case as u64);
        let k = test_data(batch * kv * d, 9100 + case as u64);
        let v = test_data(batch * kv * d, 9200 + case as u64);
        let got = run_both(
            &prog,
            &Device::h100(),
            &[
                (prog.params[0].id, q.clone()),
                (prog.params[1].id, k.clone()),
                (prog.params[2].id, v.clone()),
            ],
            prog.params[3].id,
            &format!("decode case {case}"),
        );
        let want = reference_flash_decode(&q, &k, &v, *batch, *heads, *kv, *d);
        let mut max_err = 0f32;
        for (g, w) in got.iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(max_err < 0.03, "decode case {case}: max err {max_err}");
    }
}

#[test]
fn dequant_family_compiled_matches_interp_and_reference() {
    let (m, n, k) = (32i64, 64i64, 64i64);
    for fmt in [
        WeightFormat::Int4,
        WeightFormat::Nf4,
        WeightFormat::Fp4,
        WeightFormat::Int2,
    ] {
        let tol = if fmt == WeightFormat::Int2 { 0.5 } else { 0.05 };
        let (bm, bn, bk, stages) = (16i64, 32i64, 32i64, 2usize);
        let group = if fmt.act_dtype().is_float() { 32 } else { bk };
        let cfg = DequantConfig {
            block_m: bm,
            block_n: bn,
            block_k: bk,
            num_stages: stages,
            threads: 128,
            group_size: group,
        };
        let prog = dequant_matmul_program(m, n, k, fmt, &cfg);
        let mut aval = test_data(m * k, 0xDE01);
        if fmt == WeightFormat::Int2 {
            for x in aval.iter_mut() {
                *x = (*x * 8.0).round().clamp(-4.0, 3.0);
            }
        }
        let w = test_data(n * k, 0xDE02);
        let (packed, scales) = quantize_weights(&w, n, k, fmt, group);
        let got = run_both(
            &prog,
            &Device::a100(),
            &[
                (prog.params[0].id, aval.clone()),
                (prog.params[1].id, packed.clone()),
                (prog.params[2].id, scales.clone()),
            ],
            prog.params[3].id,
            &format!("dequant {fmt:?}"),
        );
        let wdq = dequantize_weights(&packed, &scales, n, k, fmt, group);
        let mut max_err = 0f32;
        for i in 0..n as usize {
            for j in 0..m as usize {
                let mut acc = 0f32;
                for kk in 0..k as usize {
                    acc += wdq[i * k as usize + kk] * aval[j * k as usize + kk];
                }
                max_err = max_err.max((got[i * m as usize + j] - acc).abs());
            }
        }
        assert!(max_err < tol, "dequant {fmt:?}: max err {max_err}");
    }
}

#[test]
fn chunk_kernels_compiled_match_interp_and_reference() {
    let (bh, seq, n, p, chunk) = (2i64, 128i64, 32i64, 32i64, 64i64);
    let nchunks = seq / chunk;

    let prog = chunk_state_program(bh, seq, n, p, chunk, 2);
    let b = test_data(bh * seq * n, 41);
    let x = test_data(bh * seq * p, 42);
    let w: Vec<f32> = test_data(bh * seq, 43).iter().map(|v| v + 0.75).collect();
    let got = run_both(
        &prog,
        &Device::h100(),
        &[
            (prog.params[0].id, b.clone()),
            (prog.params[1].id, x.clone()),
            (prog.params[2].id, w.clone()),
        ],
        prog.params[3].id,
        "chunk_state",
    );
    let want = reference_chunk_state(&b, &x, &w, bh, seq, n, p, chunk);
    for (g, wv) in got.iter().zip(&want) {
        assert!((g - wv).abs() < 0.05 + 0.02 * wv.abs(), "chunk_state: {g} vs {wv}");
    }

    let prog = chunk_scan_program(bh, seq, n, p, chunk, 2);
    let c = test_data(bh * seq * n, 51);
    let s = test_data(bh * nchunks * n * p, 52);
    let w2: Vec<f32> = test_data(bh * seq, 53).iter().map(|v| v + 0.75).collect();
    let got = run_both(
        &prog,
        &Device::h100(),
        &[
            (prog.params[0].id, c.clone()),
            (prog.params[1].id, s.clone()),
            (prog.params[2].id, w2.clone()),
        ],
        prog.params[3].id,
        "chunk_scan",
    );
    let want = reference_chunk_scan(&c, &s, &w2, bh, seq, n, p, chunk);
    for (g, wv) in got.iter().zip(&want) {
        assert!((g - wv).abs() < 0.05 + 0.02 * wv.abs(), "chunk_scan: {g} vs {wv}");
    }
}

/// Dynamic-M tails: specialize the symbolic-M GEMM to non-tile-multiple
/// row counts. The predicated tail block is where pre-resolved offsets
/// can go wrong, so this is the sharpest single test of the VM's guard
/// ranges (OOB reads as zero, OOB stores dropped).
#[test]
fn dynamic_m_tails_compiled_matches_interp_and_reference() {
    let (n, k) = (64i64, 64i64);
    let cfg = TileConfig {
        block_m: 64,
        block_n: 32,
        block_k: 32,
        num_stages: 2,
        threads: 128,
        policy: GemmWarpPolicy::Square,
        rasterize: true,
        specialize: None,
    };
    for &m in &[33i64, 80, 96] {
        let (prog, mvar) = matmul_program_dyn(n, k, DType::F16, &cfg);
        let mut bind = HashMap::new();
        bind.insert(mvar.id, m);
        let sp = specialize(&prog, &bind);
        let a = test_data(m * k, 0xD11 + m as u64);
        let b = test_data(k * n, 0xD12);
        let got = run_both(
            &sp,
            &Device::a100(),
            &[(sp.params[0].id, a.clone()), (sp.params[1].id, b.clone())],
            sp.params[2].id,
            &format!("dyn-M m={m}"),
        );
        assert_eq!(got.len(), (m * n) as usize);
        let want = reference_matmul(&a, &b, m, n, k);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 0.05 + 0.02 * w.abs(),
                "dyn-M m={m} idx={i}: {g} vs {w}"
            );
        }
    }
}

/// End-to-end through the public Runtime API: every default artifact
/// (single kernels AND graphs) must produce bit-identical outputs on
/// `ExecBackend::Interp` vs `ExecBackend::Compiled`, and the sharded
/// backend must agree with itself across engines (per-shard kernels are
/// bit-identical and the gather collective is shared code).
#[test]
fn runtime_backends_agree_on_all_default_artifacts() {
    use tilelang::runtime::{artifacts, ExecBackend, InterpOptions, Runtime};
    use tilelang::shard::exec::ShardedOptions;

    let dir = std::env::temp_dir().join(format!(
        "tilelang-backend-diff-artifacts-{}",
        std::process::id()
    ));
    artifacts::generate_default_set(&dir).expect("generate artifacts");
    let fast = InterpOptions {
        tune: false,
        ..Default::default()
    };
    let interp_rt =
        Runtime::with_backend(&dir, ExecBackend::Interp(fast.clone())).expect("interp runtime");
    let compiled_rt =
        Runtime::with_backend(&dir, ExecBackend::Compiled(fast.clone())).expect("compiled runtime");
    assert_eq!(compiled_rt.backend_name(), "compiled");
    for name in interp_rt.artifact_names() {
        let inputs = interp_rt.example_inputs(&name).expect("inputs");
        let want = interp_rt.execute(&name, &inputs).expect("interp exec");
        let got = compiled_rt
            .execute(&name, &inputs)
            .unwrap_or_else(|e| panic!("{name}: compiled exec: {e}"));
        assert_eq!(got.len(), want.len(), "{name}: length mismatch");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                g.to_bits() == w.to_bits(),
                "{name}: compiled diverged from interp at {i}: {g} vs {w}"
            );
        }
    }

    // sharded path: same artifact, interp shards vs compiled shards
    for name in ["linear_64x256x64", "mlp_block_64x64x128"] {
        let mut oi = ShardedOptions::new(2);
        oi.interp = fast.clone();
        let mut oc = ShardedOptions::new(2);
        oc.interp = fast.clone();
        oc.interp.compiled = true;
        let rt_i = Runtime::with_backend(&dir, ExecBackend::Sharded(oi)).expect("sharded interp");
        let rt_c =
            Runtime::with_backend(&dir, ExecBackend::Sharded(oc)).expect("sharded compiled");
        let inputs = rt_i.example_inputs(name).expect("inputs");
        let want = rt_i.execute(name, &inputs).expect("sharded interp exec");
        let got = rt_c
            .execute(name, &inputs)
            .unwrap_or_else(|e| panic!("{name}: sharded compiled exec: {e}"));
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                g.to_bits() == w.to_bits(),
                "{name} sharded: compiled diverged from interp at {i}: {g} vs {w}"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The paged (length-masked) decode kernel that backs the continuous
/// batching engine: compiled must stay bit-identical to interp across
/// random per-stream lengths (including dead slots, len 0), and interp
/// must match the masked CPU reference. Uses the engine's pinned
/// config (block_h/block_n 16) — the one the serving path always runs.
#[test]
fn flash_decode_paged_compiled_matches_interp_and_reference() {
    let mut rng = Rng(0xFA6ED);
    let cfg = DecodeConfig {
        block_h: 16,
        block_n: 16,
        num_stages: 2,
        threads: 64,
    };
    for case in 0..4usize {
        let (batch, heads, d) = (4i64, 16i64, 16i64);
        let max_kv = *rng.pick(&[16i64, 48, 96]);
        let prog = flash_decode_paged_program(batch, heads, max_kv, d, &cfg, &[]);
        let q = test_data(batch * heads * d, 9500 + case as u64);
        let k = test_data(batch * max_kv * d, 9600 + case as u64);
        let v = test_data(batch * max_kv * d, 9700 + case as u64);
        // random valid lengths, always exercising a dead slot
        let mut lens: Vec<f32> =
            (0..batch).map(|_| (rng.next() % (max_kv as u64 + 1)) as f32).collect();
        lens[case % batch as usize] = 0.0;
        let got = run_both(
            &prog,
            &Device::h100(),
            &[
                (prog.params[0].id, q.clone()),
                (prog.params[1].id, k.clone()),
                (prog.params[2].id, v.clone()),
                (prog.params[3].id, lens.clone()),
            ],
            prog.params[4].id,
            &format!("paged decode case {case} (kv {max_kv}, lens {lens:?})"),
        );
        let want = reference_flash_decode_paged(&q, &k, &v, &lens, batch, heads, max_kv, d);
        let mut max_err = 0f32;
        for (g, w) in got.iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(max_err < 0.03, "paged decode case {case}: max err {max_err}");
    }
}

/// The multi-output decode graph end to end: interp and compiled
/// GraphKernels must agree bit for bit on the primary output AND both
/// extra outputs (the new K/V rows the serving engine appends to the
/// paged pool).
#[test]
fn paged_decode_graph_backends_agree_on_all_outputs() {
    use tilelang::graph::exec::GraphKernel;
    use tilelang::graph::ir::decode_block_paged;
    use tilelang::runtime::InterpOptions;

    let (slots, heads, hd, max_kv) = (16i64, 16i64, 16i64, 32i64);
    let dm = heads * hd;
    let g = decode_block_paged(slots, heads, hd, max_kv);
    let inputs: Vec<Vec<f32>> = vec![
        test_data(slots * dm, 0xA1),
        test_data(dm * dm, 0xA2).iter().map(|x| x * 0.06).collect(),
        test_data(slots * max_kv * hd, 0xA3),
        test_data(slots * max_kv * hd, 0xA4),
        (0..slots).map(|s| ((s * 7 + 3) % (max_kv + 1)) as f32).collect(),
        test_data(dm * hd, 0xA5).iter().map(|x| x * 0.06).collect(),
        test_data(dm * hd, 0xA6).iter().map(|x| x * 0.06).collect(),
        test_data(dm * dm, 0xA7).iter().map(|x| x * 0.06).collect(),
        test_data(dm, 0xA8).iter().map(|x| x * 0.06).collect(),
    ];
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let dir = std::env::temp_dir().join(format!(
        "tilelang-backend-diff-paged-graph-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let fast = InterpOptions {
        tune: false,
        ..Default::default()
    };
    let mut compiled_opts = fast.clone();
    compiled_opts.compiled = true;
    let ki = GraphKernel::prepare_unfused(&g, &fast, &dir).expect("interp graph");
    let kc = GraphKernel::prepare_unfused(&g, &compiled_opts, &dir).expect("compiled graph");
    let want = ki.execute_all_refs(&refs).expect("interp exec");
    let got = kc.execute_all_refs(&refs).expect("compiled exec");
    assert_eq!(want.len(), 3, "primary + K_new + V_new");
    assert_eq!(got.len(), 3);
    for (o, (w, gv)) in want.iter().zip(&got).enumerate() {
        assert_eq!(w.len(), gv.len(), "output {o}: length mismatch");
        for (i, (a, b)) in w.iter().zip(gv).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "output {o} idx {i}: compiled diverged from interp: {b} vs {a}"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
