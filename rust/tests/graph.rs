//! Graph-layer differential tests: every fused graph must match its
//! unfused node-by-node execution and the CPU-reference composition
//! (mlp_block, attention_block, dequant-MLP variant), the memory plan
//! must reuse buffers without aliasing live intermediates, and graph
//! artifacts must serve end to end through `Runtime` and `Coordinator`.

use std::path::PathBuf;
use std::sync::OnceLock;

use tilelang::coordinator::{BatchPolicy, Coordinator};
use tilelang::graph::exec::GraphKernel;
use tilelang::graph::memplan::{self, find_live_overlap};
use tilelang::graph::{fuse, ir::KernelGraph};
use tilelang::runtime::{artifacts, ExecBackend, InterpOptions, Runtime};
use tilelang::sim::device::Device;

/// Graph outputs chain two GEMMs through fp16 tiles, so rounding
/// compounds once relative to the f32 reference composition — the same
/// bound the runtime's golden gate applies to graph artifacts.
const TOL: f32 = tilelang::runtime::GRAPH_GOLDEN_TOL;

/// One shared artifact directory per test binary (generation once).
fn artifacts_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir =
            std::env::temp_dir().join(format!("tilelang-graph-artifacts-{}", std::process::id()));
        artifacts::generate_default_set(&dir).expect("generate artifacts");
        dir
    })
    .clone()
}

fn fast_opts() -> InterpOptions {
    InterpOptions {
        tune: false,
        ..Default::default()
    }
}

fn fast_interp() -> ExecBackend {
    ExecBackend::Interp(fast_opts())
}

/// The graph artifacts carry valid example inputs (packed weights for
/// the dequant variant) and reference goldens — reuse them as the
/// differential corpus.
fn graph_defs() -> Vec<artifacts::ArtifactDef> {
    artifacts::default_set()
        .into_iter()
        .filter(|d| d.graph.is_some())
        .collect()
}

#[test]
fn fused_matches_unfused_and_reference_for_every_graph() {
    let dir = artifacts_dir();
    let defs = graph_defs();
    assert_eq!(
        defs.len(),
        4,
        "mlp, attention, dequant-MLP and decode-block variants"
    );
    for d in defs {
        let graph = d.graph.as_ref().expect("graph def");
        let fused = GraphKernel::prepare(graph, &fast_opts(), &dir)
            .unwrap_or_else(|e| panic!("{}: prepare fused: {}", d.name, e));
        let unfused = GraphKernel::prepare_unfused(graph, &fast_opts(), &dir)
            .unwrap_or_else(|e| panic!("{}: prepare unfused: {}", d.name, e));
        assert!(
            !fused.fusions().is_empty(),
            "{}: the planner must fold at least one epilogue",
            d.name
        );
        let got_f = fused
            .execute(&d.inputs)
            .unwrap_or_else(|e| panic!("{}: fused execution: {}", d.name, e));
        let got_u = unfused
            .execute(&d.inputs)
            .unwrap_or_else(|e| panic!("{}: unfused execution: {}", d.name, e));
        assert_eq!(got_f.len(), d.golden.len(), "{}", d.name);
        for (i, ((f, u), w)) in got_f.iter().zip(&got_u).zip(&d.golden).enumerate() {
            assert!(
                (f - u).abs() < TOL,
                "{} idx {}: fused {} vs unfused {}",
                d.name,
                i,
                f,
                u
            );
            assert!(
                (f - w).abs() < TOL + 0.02 * w.abs(),
                "{} idx {}: fused {} vs reference {}",
                d.name,
                i,
                f,
                w
            );
            assert!(
                (u - w).abs() < TOL + 0.02 * w.abs(),
                "{} idx {}: unfused {} vs reference {}",
                d.name,
                i,
                u,
                w
            );
        }
    }
}

#[test]
fn mlp_block_fuses_and_beats_materializing_every_edge() {
    // the acceptance criteria in one place: >= 1 fusion on mlp_block,
    // and the memory plan's peak strictly below the sum of all
    // intermediate sizes
    let dev = Device::h100();
    let graph = tilelang::graph::ir::mlp_block(64, 64, 128);
    let fp = fuse::plan(&graph, &dev).expect("fusion plan");
    assert!(
        !fp.fused.is_empty(),
        "mlp_block must produce at least one fusion"
    );
    assert!(fp.fused_cost_us < fp.unfused_cost_us);
    // peak planned bytes strictly below materializing every edge — on
    // the *unfused* graph, which is where the intermediates live
    let mp = memplan::plan(&graph);
    assert!(
        mp.peak_bytes < mp.intermediate_bytes,
        "peak {} vs materialized {}",
        mp.peak_bytes,
        mp.intermediate_bytes
    );
    assert!(find_live_overlap(&mp).is_none());
    // the fused graph's plan is also overlap-free
    let mp_fused = memplan::plan(&fp.graph);
    assert!(find_live_overlap(&mp_fused).is_none());
}

#[test]
fn memplans_never_alias_live_intermediates() {
    let dev = Device::h100();
    for d in graph_defs() {
        let g = d.graph.as_ref().unwrap();
        for planned in [g.clone(), fuse::plan(g, &dev).expect("fuse").graph] {
            let mp = memplan::plan(&planned);
            if let Some((i, j)) = find_live_overlap(&mp) {
                panic!(
                    "{}: nodes {} and {} share a buffer while live",
                    d.name, i, j
                );
            }
        }
    }
}

#[test]
fn graph_artifacts_serve_through_the_runtime() {
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, fast_interp()).expect("runtime");
    for name in [
        "mlp_block_64x64x128",
        "attention_block_128x64",
        "dequant_mlp_64x64x64",
        "decode_block_64x256x64",
    ] {
        let err = rt.golden_check(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(err < TOL, "{name}: golden max err {err}");
        let loaded = rt.load(name).expect(name);
        let gk = loaded.graph_kernel().expect("graph artifacts expose their kernel");
        assert!(!gk.fusions().is_empty(), "{name}: no fusions");
        // fusion already removed most intermediates; the pool never
        // exceeds materializing the ones that remain
        assert!(
            gk.memplan().peak_bytes <= gk.memplan().intermediate_bytes,
            "{name}"
        );
    }
}

#[test]
fn coordinator_serves_a_full_block_per_row() {
    let dir = artifacts_dir();
    let model = "mlp_block_64x64x128";
    let rt = Runtime::with_backend(&dir, fast_interp()).expect("runtime");
    let inputs = rt.example_inputs(model).expect("inputs");
    let spec = rt.spec(model).expect("spec").clone();
    let batch = spec.in_shapes[0][0] as usize;
    let row_len: usize = spec.in_shapes[0][1..].iter().product::<i64>() as usize;
    let out_row = spec.out_len() / batch;
    let direct = rt.execute(model, &inputs).expect("direct execution");

    let coord =
        Coordinator::start_batched_with_backend(&dir, fast_interp(), model, BatchPolicy::default())
            .expect("start coordinator");
    let mut rxs = Vec::new();
    for slot in 0..batch.min(16) {
        let row = inputs[0][slot * row_len..(slot + 1) * row_len].to_vec();
        rxs.push((slot, coord.submit_row(model, row).expect("submit")));
    }
    for (slot, rx) in rxs {
        let reply = rx.recv().expect("reply");
        let out = reply.output.unwrap_or_else(|e| panic!("slot {slot}: {e}"));
        assert_eq!(out.len(), out_row);
        // the MLP's gemm+bias+gelu+gemm+bias mixes nothing across batch
        // rows; the residual reads the same row of X — but the worker
        // zero-pads *other* slots, whose residual rows differ from the
        // example batch, so compare only the requested slot
        let want = &direct[slot * out_row..(slot + 1) * out_row];
        for (g, w) in out.iter().zip(want) {
            assert!((g - w).abs() < 1e-4, "slot {slot}: {g} vs {w}");
        }
    }
    coord.shutdown();
}

#[test]
fn row_batchability_is_enforced_for_graph_serving() {
    use tilelang::graph::ir::{attention_block, decode_block, dequant_mlp_block, mlp_block};
    use tilelang::workloads::dequant::WeightFormat;
    // the MLP keeps request rows independent end to end, and so does the
    // decode block (each stream attends only its own cache); attention
    // mixes across the row dim and the dequant block transposes its
    // output
    assert!(mlp_block(64, 64, 128).row_batchable());
    assert!(decode_block(64, 16, 16, 64).row_batchable());
    assert!(!attention_block(128, 64, false).row_batchable());
    assert!(!dequant_mlp_block(32, 64, 64, 64, WeightFormat::Int4, 32).row_batchable());

    // a batched worker must refuse the attention block with a per-row
    // error instead of serving rows computed from co-batched strangers
    let dir = artifacts_dir();
    let coord = Coordinator::start_batched_with_backend(
        &dir,
        fast_interp(),
        "attention_block_128x64",
        BatchPolicy::default(),
    )
    .expect("start coordinator");
    let reply = coord
        .submit_row("attention_block_128x64", vec![0.0; 64])
        .expect("submit")
        .recv()
        .expect("reply");
    let err = reply.output.expect_err("attention rows must be refused");
    assert!(err.contains("not row-batchable"), "{err}");
    coord.shutdown();
}

#[test]
fn malformed_graph_files_error_instead_of_panicking() {
    use tilelang::graph::ir::mlp_block;
    use tilelang::workloads::epilogue::EpilogueOp;
    // an out-of-range bias dim must fail validation (it would otherwise
    // reach the builder asserts inside a serving worker)
    let mut g = mlp_block(64, 64, 128);
    g.nodes[1].op = tilelang::graph::ir::NodeOp::Elementwise(EpilogueOp::BiasAdd { dim: 2 });
    assert!(g.validate().is_err());
    // non-positive dims are rejected up front
    let mut g = mlp_block(64, 64, 128);
    g.nodes[0].out_shape = vec![64, -128];
    g.nodes[0].in_shapes[1] = vec![64, -128];
    assert!(g.validate().is_err());
    // a wrong-rank kernel operand (same element count) must fail
    // validation, not index-panic inside the program builders
    let mut g = mlp_block(64, 64, 128);
    g.nodes[0].in_shapes[0] = vec![64 * 64];
    assert!(g.validate().is_err());
    // duplicate node names would scramble fusion memos and diagnostics
    let mut g = mlp_block(64, 64, 128);
    g.nodes[1].name = "ffn1".into();
    assert!(g.validate().is_err());
}

#[test]
fn graph_artifact_files_round_trip() {
    let dir = artifacts_dir();
    for name in [
        "mlp_block_64x64x128",
        "attention_block_128x64",
        "dequant_mlp_64x64x64",
        "decode_block_64x256x64",
    ] {
        let path = dir.join(format!("{name}.graph.json"));
        let g = KernelGraph::load(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(g.name, name);
        g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        // saving and reloading preserves the structure
        let tmp = dir.join(format!("{name}.roundtrip.json"));
        g.save(&tmp).expect("save");
        let back = KernelGraph::load(&tmp).expect("reload");
        assert_eq!(back.nodes.len(), g.nodes.len());
        assert_eq!(back.output, g.output);
        let _ = std::fs::remove_file(&tmp);
    }
}
