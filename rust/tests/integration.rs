//! Integration tests across layers: runtime execution of generated
//! artifacts through the interp backend, the coordinator's raw and
//! batched serving paths, and the CLI compile pipeline over every
//! workload family.
//!
//! Artifacts are produced on the fly by the rust-native generator
//! (`runtime::artifacts`), so these tests execute for real in an
//! offline, dependency-free build — no Python, no HLO files, no `pjrt`
//! feature needed. With the `pjrt` feature the same tests exercise the
//! interp backend explicitly (the generated artifacts carry no HLO).

use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

use tilelang::coordinator::{BatchPolicy, Coordinator};
use tilelang::ir::dtype::DType;
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::runtime::{artifacts, ExecBackend, InterpOptions, Runtime};
use tilelang::sim::device::Device;
use tilelang::sim::model::{estimate, Penalties};
use tilelang::workloads::attention::{flash_attention_program, mla_program, AttnConfig};
use tilelang::workloads::dequant::{dequant_matmul_program, DequantConfig, WeightFormat};
use tilelang::workloads::linear_attention::{chunk_scan_program, chunk_state_program};
use tilelang::workloads::matmul::{matmul_program, reference_matmul, TileConfig};

// Tolerances for interp execution vs the f32 CPU-reference goldens are
// shared with the CLI's golden gate (graph artifacts chain two GEMMs
// and compound the fp16 rounding once).
use tilelang::runtime::GOLDEN_TOL;

/// The golden bound for one artifact.
fn tol_for(rt: &Runtime, name: &str) -> f32 {
    rt.spec(name)
        .map(tilelang::runtime::golden_tol)
        .unwrap_or(GOLDEN_TOL)
}

/// One shared artifact directory per test binary: generation and the
/// per-shape tuning sweeps happen once, later loads hit the caches.
fn artifacts_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir =
            std::env::temp_dir().join(format!("tilelang-it-artifacts-{}", std::process::id()));
        artifacts::generate_default_set(&dir).expect("generate artifacts");
        dir
    })
    .clone()
}

fn interp_backend() -> ExecBackend {
    ExecBackend::Interp(InterpOptions::default())
}

#[test]
fn runtime_golden_checks_all_artifacts() {
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, interp_backend()).expect("runtime");
    let names = rt.artifact_names();
    assert!(names.len() >= 6, "expected >= 6 artifacts, got {:?}", names);
    for name in names {
        let err = rt
            .golden_check(&name)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let tol = tol_for(&rt, &name);
        assert!(err < tol, "{name}: golden max err {err} (tol {tol})");
    }
}

#[test]
fn runtime_rejects_bad_inputs() {
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, interp_backend()).expect("runtime");
    assert!(rt.execute("matmul_64x64x64", &[vec![0.0; 3]]).is_err());
    assert!(rt.execute("nonexistent_kernel", &[]).is_err());
}

#[test]
fn coordinator_raw_worker_executes() {
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, interp_backend()).expect("runtime");
    let inputs = rt.example_inputs("matmul_64x64x64").expect("inputs");
    let want = rt.execute("matmul_64x64x64", &inputs).expect("direct");

    let coord = Coordinator::start_with_backend(&dir, interp_backend(), &["matmul_64x64x64"])
        .expect("start");
    let rx = coord.submit("matmul_64x64x64", inputs).expect("submit");
    let reply = rx.recv().expect("reply");
    let out = reply.output.expect("output");
    assert_eq!(out.len(), want.len());
    for (g, w) in out.iter().zip(&want) {
        assert!((g - w).abs() < 1e-5);
    }
    coord.shutdown();
}

#[test]
fn coordinator_batches_rows_and_matches_cpu_reference() {
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, interp_backend()).expect("runtime");
    let inputs = rt.example_inputs("linear_64x256x64").expect("inputs");
    let spec = rt.spec("linear_64x256x64").expect("spec").clone();
    let batch = spec.in_shapes[0][0] as usize;
    let row_len: usize = spec.in_shapes[0][1..].iter().product::<i64>() as usize;
    let out_row = spec.out_len() / batch;
    let direct = rt.execute("linear_64x256x64", &inputs).expect("direct");

    // the served numerics trace back to the CPU reference, not just to
    // another interp run
    let want = reference_matmul(&inputs[0], &inputs[1], 64, 256, 64);
    for (g, w) in direct.iter().zip(&want) {
        assert!(
            (g - w).abs() < GOLDEN_TOL,
            "direct execution diverges from CPU reference: {g} vs {w}"
        );
    }

    let coord = Coordinator::start_batched_with_backend(
        &dir,
        interp_backend(),
        "linear_64x256x64",
        BatchPolicy::default(),
    )
    .expect("start");
    // submit exactly one full batch at once
    let mut rxs = Vec::new();
    for slot in 0..batch {
        let row = inputs[0][slot * row_len..(slot + 1) * row_len].to_vec();
        rxs.push((
            slot,
            coord.submit_row("linear_64x256x64", row).expect("submit"),
        ));
    }
    for (slot, rx) in rxs {
        let reply = rx.recv().expect("reply");
        let out = reply.output.expect("output");
        let wd = &direct[slot * out_row..(slot + 1) * out_row];
        for (g, w) in out.iter().zip(wd) {
            assert!((g - w).abs() < 1e-4, "slot {slot}: {g} vs {w}");
        }
        let wr = &want[slot * out_row..(slot + 1) * out_row];
        for (g, w) in out.iter().zip(wr) {
            assert!((g - w).abs() < GOLDEN_TOL, "slot {slot} vs reference");
        }
        assert!(reply.batch_size >= 1 && reply.batch_size <= batch);
        // regression: RowReply reports the same queue/exec split as
        // KernelReply. A served row must have really executed, and the
        // components cannot exceed the end-to-end latency (small slack:
        // the three clocks are read at slightly different instants).
        assert!(reply.exec_us > 0, "served row reports exec_us == 0");
        assert!(
            reply.queue_us <= reply.latency_us,
            "queue {}us > latency {}us",
            reply.queue_us,
            reply.latency_us
        );
        assert!(
            reply.queue_us + reply.exec_us <= reply.latency_us + 1_000,
            "queue {}us + exec {}us inconsistent with latency {}us",
            reply.queue_us,
            reply.exec_us,
            reply.latency_us
        );
    }
    coord.shutdown();
}

#[test]
fn coordinator_micro_batches_concurrent_rows() {
    let dir = artifacts_dir();
    let rt = Runtime::with_backend(&dir, interp_backend()).expect("runtime");
    let inputs = rt.example_inputs("linear_64x256x64").expect("inputs");
    let spec = rt.spec("linear_64x256x64").expect("spec").clone();
    let batch = spec.in_shapes[0][0] as usize;
    let row_len: usize = spec.in_shapes[0][1..].iter().product::<i64>() as usize;
    let out_row = spec.out_len() / batch;
    let want = reference_matmul(&inputs[0], &inputs[1], 64, 256, 64);

    // generous flush window: rows submitted from racing threads must
    // coalesce into shared batches even on a slow machine
    let coord = Coordinator::start_batched_with_backend(
        &dir,
        interp_backend(),
        "linear_64x256x64",
        BatchPolicy {
            max_batch: None,
            max_wait: Duration::from_millis(50),
        },
    )
    .expect("start");

    let n_threads = 8usize;
    let rows_per_thread = 8usize;
    let mut replies = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let coord = &coord;
            let inputs = &inputs;
            handles.push(s.spawn(move || {
                let mut out = Vec::new();
                for i in 0..rows_per_thread {
                    let slot = (t * rows_per_thread + i) % batch;
                    let row = inputs[0][slot * row_len..(slot + 1) * row_len].to_vec();
                    let rx = coord.submit_row("linear_64x256x64", row).expect("submit");
                    out.push((slot, rx));
                }
                // receive after submitting everything so rows queue up
                out.into_iter()
                    .map(|(slot, rx)| (slot, rx.recv().expect("reply")))
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            replies.extend(h.join().expect("thread"));
        }
    });

    assert_eq!(replies.len(), n_threads * rows_per_thread);
    let mut max_batch_seen = 0usize;
    for (slot, reply) in replies {
        let out = reply.output.expect("row output");
        assert_eq!(out.len(), out_row);
        let wr = &want[slot * out_row..(slot + 1) * out_row];
        for (g, w) in out.iter().zip(wr) {
            assert!(
                (g - w).abs() < GOLDEN_TOL,
                "slot {slot}: {g} vs reference {w}"
            );
        }
        assert!(reply.batch_size >= 1 && reply.batch_size <= batch);
        max_batch_seen = max_batch_seen.max(reply.batch_size);
    }
    // 64 concurrent rows against a worker that is still loading (or a
    // 50ms window once warm) must coalesce: row-at-a-time serving means
    // micro-batching is broken
    assert!(
        max_batch_seen >= 2,
        "no micro-batching observed (max batch {max_batch_seen})"
    );
    coord.shutdown();
}

#[test]
fn batched_worker_refuses_non_row_batchable_artifacts() {
    // transposed (dequant) and re-chunked (chunk_state) outputs do not
    // keep the batch dim: row serving must fail each request with a
    // clear error instead of interleaving co-batched requests' data
    let dir = artifacts_dir();
    for name in ["dequant_int4_32x64x64", "chunk_state_2x128"] {
        let coord = Coordinator::start_batched_with_backend(
            &dir,
            interp_backend(),
            name,
            BatchPolicy::default(),
        )
        .expect("start");
        let reply = coord
            .submit_row(name, vec![0.0; 8])
            .expect("submit")
            .recv()
            .expect("reply");
        let err = reply.output.expect_err("must refuse non-row-batchable artifacts");
        assert!(err.contains("not row-batchable"), "{name}: {err}");
        coord.shutdown();
    }
}

#[test]
fn golden_round_trip_on_regenerated_artifacts() {
    // fresh directory (the `artifacts --force` path) + the untuned
    // interp configuration: default tile configs must also serve
    let dir =
        std::env::temp_dir().join(format!("tilelang-it-regen-{}", std::process::id()));
    let names = artifacts::generate_default_set(&dir).expect("generate");
    let backend = ExecBackend::Interp(InterpOptions {
        tune: false,
        ..Default::default()
    });
    let rt = Runtime::with_backend(&dir, backend).expect("runtime");
    for name in &names {
        let err = rt
            .golden_check(name)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let tol = tol_for(&rt, name);
        assert!(err < tol, "{name}: golden max err {err} (tol {tol})");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn untuned_path_is_deterministic_and_uses_default_configs() {
    // Seed-era gap: the untuned path (`tune: false`) used to be exercised
    // only incidentally. Pin its guarantees directly: with tuning off the
    // runtime must (a) fall back to the static default configs without
    // ever materializing a tuning cache, (b) serve every artifact
    // bit-identically across independent runtime instances, and (c) agree
    // bit-for-bit between the interp oracle and the compiled VM.
    let dir = std::env::temp_dir().join(format!("tilelang-it-untuned-{}", std::process::id()));
    let names = artifacts::generate_default_set(&dir).expect("generate");
    let untuned = |compiled: bool| {
        let opts = InterpOptions {
            tune: false,
            compiled,
            ..Default::default()
        };
        let backend = if compiled {
            ExecBackend::Compiled(opts)
        } else {
            ExecBackend::Interp(opts)
        };
        Runtime::with_backend(&dir, backend).expect("runtime")
    };

    let a = untuned(false);
    let b = untuned(false);
    let vm = untuned(true);
    assert_eq!(vm.backend_name(), "compiled");
    for name in &names {
        let inputs = a.example_inputs(name).expect("inputs");
        let ra = a.execute(name, &inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        let rb = b.execute(name, &inputs).expect("second run");
        let rc = vm.execute(name, &inputs).expect("compiled run");
        assert_eq!(ra.len(), rb.len());
        for (i, (x, y)) in ra.iter().zip(&rb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{name}[{i}]: untuned path nondeterministic ({x} vs {y})"
            );
        }
        for (i, (x, y)) in ra.iter().zip(&rc).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{name}[{i}]: compiled diverges from interp on untuned path"
            );
        }
    }
    // tuning off means the default-config fallback ran: the sweep that
    // writes the cache must never have started
    assert!(
        !dir.join("tune_cache.json").exists(),
        "tune: false still materialized a tuning cache"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compile_pipeline_covers_all_workload_families() {
    // every paper workload compiles on every modeled device
    let devices = [
        Device::rtx4090(),
        Device::a100(),
        Device::h100(),
        Device::mi300x(),
    ];
    for dev in &devices {
        let opts = CompileOptions::default();
        let gemm = matmul_program(256, 256, 128, DType::F16, &TileConfig::default_for(256, 256, 128));
        let fa = flash_attention_program(
            4,
            256,
            64,
            true,
            &AttnConfig { block_m: 64, block_n: 64, num_stages: 2, threads: 128, specialize: None },
        );
        let mla = mla_program(2, 32, 256, 128, 64, 16, 32, 2); // tile fits MI300X's 64KB LDS
        let dq = dequant_matmul_program(
            16,
            128,
            128,
            WeightFormat::Int4,
            &DequantConfig { block_m: 16, block_n: 64, block_k: 64, num_stages: 2, threads: 128, group_size: 32 },
        );
        let cs = chunk_state_program(4, 256, 64, 64, 64, 2);
        let cc = chunk_scan_program(4, 256, 64, 64, 64, 2);
        for (name, prog) in [
            ("gemm", gemm),
            ("flash_attention", fa),
            ("mla", mla),
            ("dequant", dq),
            ("chunk_state", cs),
            ("chunk_scan", cc),
        ] {
            let lowered = compile(&prog, dev, &opts)
                .unwrap_or_else(|e| panic!("{name} on {}: {e}", dev.name));
            let r = estimate(&lowered, dev, &Penalties::none());
            assert!(
                r.time_us.is_finite() && r.time_us > 0.0,
                "{name} on {}: bad sim time",
                dev.name
            );
        }
    }
}

#[test]
fn warp_specialization_only_on_hopper() {
    let prog = matmul_program(512, 512, 256, DType::F16, &TileConfig::default_for(512, 512, 256));
    let h = compile(&prog, &Device::h100(), &CompileOptions::default()).unwrap();
    let a = compile(&prog, &Device::a100(), &CompileOptions::default()).unwrap();
    assert!(h.schedule.warp_specialized);
    assert!(!a.schedule.warp_specialized);
    // ablation knob disables it
    let mut p2 = prog.clone();
    p2.annotations.no_warp_specialize = true;
    let h2 = compile(&p2, &Device::h100(), &CompileOptions::default()).unwrap();
    assert!(!h2.schedule.warp_specialized);
}

#[test]
fn pre_specialization_cache_entries_still_hit() {
    // Back-compat guardrail (PR 10): tune_cache.json entries written
    // before the `specialize` field existed carry no such key. They
    // must decode with the architecture-default schedule
    // (`specialize == None`), hit the cache, and return the stored
    // config unchanged — old caches keep working after the schedule
    // space grew.
    use tilelang::autotuner::{
        penalties_variant, tune_gemm_cached, CacheKey, TunableConfig, TuningCache,
    };
    use tilelang::util::json::Json;

    let dev = Device::a100();
    let pen = Penalties::none();
    let (m, n, k) = (512i64, 512, 512);
    let legacy_cfg = Json::Obj(vec![
        ("block_m".into(), Json::Num(64.0)),
        ("block_n".into(), Json::Num(64.0)),
        ("block_k".into(), Json::Num(32.0)),
        ("num_stages".into(), Json::Num(3.0)),
        ("threads".into(), Json::Num(128.0)),
        ("policy".into(), Json::Str("square".into())),
        ("rasterize".into(), Json::Bool(true)),
        // no "specialize" key: pre-PR-10 entry
    ]);
    let decoded = TileConfig::from_json(&legacy_cfg).expect("legacy entry decodes");
    assert_eq!(decoded.specialize, None, "missing key means architecture default");
    assert_eq!(
        (decoded.block_m, decoded.block_n, decoded.block_k, decoded.num_stages, decoded.threads),
        (64, 64, 32, 3, 128),
        "legacy fields decode unchanged"
    );

    let mut cache = TuningCache::in_memory();
    cache.put(
        CacheKey {
            workload: "gemm".into(),
            shape: vec![m, n, k],
            dtype: DType::F16.to_string(),
            device: dev.name.to_string(),
            variant: penalties_variant(&pen),
            shards: 1,
        },
        legacy_cfg,
        0.0,
    );
    let hit = tune_gemm_cached(m, n, k, DType::F16, &dev, &pen, &mut cache)
        .expect("cached tune");
    assert!(hit.cache_hit, "legacy entry must hit, not resweep");
    assert_eq!(hit.evaluated, 0, "hit re-scores only the stored config");
    assert_eq!(hit.config, decoded, "hit returns the stored config verbatim");
    assert_eq!(hit.config.specialize, None);

    // round-trip: a fresh sweep on a new shape writes the enlarged
    // config (with the specialize key) and re-reads it identically
    let miss = tune_gemm_cached(m, n, 2 * k, DType::F16, &dev, &pen, &mut cache)
        .expect("fresh tune");
    assert!(!miss.cache_hit);
    let again = tune_gemm_cached(m, n, 2 * k, DType::F16, &dev, &pen, &mut cache)
        .expect("re-read");
    assert!(again.cache_hit);
    assert_eq!(again.config, miss.config, "new-format entry round-trips");
}
