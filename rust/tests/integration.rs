//! Integration tests across layers: PJRT runtime execution of AOT
//! artifacts, the coordinator's batched serving path, and the CLI
//! compile pipeline over every workload family.
//!
//! The runtime/coordinator tests require `make artifacts` to have run;
//! they skip (pass with a notice) when the directory is absent so
//! `cargo test` stays green in a fresh checkout.

use tilelang::coordinator::{BatchPolicy, Coordinator};
use tilelang::ir::dtype::DType;
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::runtime::Runtime;
use tilelang::sim::device::Device;
use tilelang::sim::model::{estimate, Penalties};
use tilelang::workloads::attention::{flash_attention_program, mla_program, AttnConfig};
use tilelang::workloads::dequant::{dequant_matmul_program, DequantConfig, WeightFormat};
use tilelang::workloads::linear_attention::{chunk_scan_program, chunk_state_program};
use tilelang::workloads::matmul::{matmul_program, TileConfig};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if !Runtime::has_execution_backend() {
        eprintln!("skipping: built without the `pjrt` feature (no execution backend)");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn runtime_golden_checks_all_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let names = rt.artifact_names();
    assert!(names.len() >= 4, "expected >= 4 artifacts, got {:?}", names);
    for name in names {
        let err = rt.golden_check(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(err < 1e-3, "{name}: golden max err {err}");
    }
}

#[test]
fn runtime_rejects_bad_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    assert!(rt.execute("matmul_128", &[vec![0.0; 3]]).is_err());
    assert!(rt.execute("nonexistent_kernel", &[]).is_err());
}

#[test]
fn coordinator_raw_worker_executes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let inputs = rt.example_inputs("matmul_128").expect("inputs");
    let want = rt.execute("matmul_128", &inputs).expect("direct");

    let coord = Coordinator::start(&dir, &["matmul_128"]).expect("start");
    let rx = coord.submit("matmul_128", inputs).expect("submit");
    let reply = rx.recv().expect("reply");
    let out = reply.output.expect("output");
    assert_eq!(out.len(), want.len());
    for (g, w) in out.iter().zip(&want) {
        assert!((g - w).abs() < 1e-5);
    }
    coord.shutdown();
}

#[test]
fn coordinator_batches_rows() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let inputs = rt.example_inputs("transformer_block").expect("inputs");
    let spec = rt.spec("transformer_block").expect("spec").clone();
    let batch = spec.in_shapes[0][0] as usize;
    let row_len: usize = spec.in_shapes[0][1..].iter().product::<i64>() as usize;
    let out_row = spec.out_len() / batch;
    let direct = rt.execute("transformer_block", &inputs).expect("direct");

    let coord = Coordinator::start_batched(&dir, "transformer_block", BatchPolicy::default())
        .expect("start");
    // submit exactly one full batch at once: must be served as one batch
    let mut rxs = Vec::new();
    for slot in 0..batch {
        let row = inputs[0][slot * row_len..(slot + 1) * row_len].to_vec();
        rxs.push((slot, coord.submit_row("transformer_block", row).expect("submit")));
    }
    for (slot, rx) in rxs {
        let reply = rx.recv().expect("reply");
        let out = reply.output.expect("output");
        let want = &direct[slot * out_row..(slot + 1) * out_row];
        for (g, w) in out.iter().zip(want) {
            assert!((g - w).abs() < 1e-4, "slot {slot}");
        }
        assert!(reply.batch_size >= 1 && reply.batch_size <= batch);
    }
    coord.shutdown();
}

#[test]
fn compile_pipeline_covers_all_workload_families() {
    // every paper workload compiles on every modeled device
    let devices = [
        Device::rtx4090(),
        Device::a100(),
        Device::h100(),
        Device::mi300x(),
    ];
    for dev in &devices {
        let opts = CompileOptions::default();
        let gemm = matmul_program(256, 256, 128, DType::F16, &TileConfig::default_for(256, 256, 128));
        let fa = flash_attention_program(
            4,
            256,
            64,
            true,
            &AttnConfig { block_m: 64, block_n: 64, num_stages: 2, threads: 128 },
        );
        let mla = mla_program(2, 32, 256, 128, 64, 16, 32, 2); // tile fits MI300X's 64KB LDS
        let dq = dequant_matmul_program(
            16,
            128,
            128,
            WeightFormat::Int4,
            &DequantConfig { block_m: 16, block_n: 64, block_k: 64, num_stages: 2, threads: 128, group_size: 32 },
        );
        let cs = chunk_state_program(4, 256, 64, 64, 64, 2);
        let cc = chunk_scan_program(4, 256, 64, 64, 64, 2);
        for (name, prog) in [
            ("gemm", gemm),
            ("flash_attention", fa),
            ("mla", mla),
            ("dequant", dq),
            ("chunk_state", cs),
            ("chunk_scan", cc),
        ] {
            let lowered = compile(&prog, dev, &opts)
                .unwrap_or_else(|e| panic!("{name} on {}: {e}", dev.name));
            let r = estimate(&lowered, dev, &Penalties::none());
            assert!(
                r.time_us.is_finite() && r.time_us > 0.0,
                "{name} on {}: bad sim time",
                dev.name
            );
        }
    }
}

#[test]
fn warp_specialization_only_on_hopper() {
    let prog = matmul_program(512, 512, 256, DType::F16, &TileConfig::default_for(512, 512, 256));
    let h = compile(&prog, &Device::h100(), &CompileOptions::default()).unwrap();
    let a = compile(&prog, &Device::a100(), &CompileOptions::default()).unwrap();
    assert!(h.schedule.warp_specialized);
    assert!(!a.schedule.warp_specialized);
    // ablation knob disables it
    let mut p2 = prog.clone();
    p2.annotations.no_warp_specialize = true;
    let h2 = compile(&p2, &Device::h100(), &CompileOptions::default()).unwrap();
    assert!(!h2.schedule.warp_specialized);
}
