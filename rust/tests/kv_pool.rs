//! Property/fuzz suite for the paged KV-cache pool behind the
//! continuous-batching engine.
//!
//! A seeded random walk drives admit / append / retire against the
//! pool while a shadow model keeps each live stream's cache as a plain
//! contiguous Vec. After every operation the pool's full invariant set
//! is re-checked (`KvPool::validate`: no page aliased by two live
//! streams, free + live pages == pool, page counts match rows,
//! reservation accounting), and the paged gather must reproduce the
//! shadow cache *byte for byte* — including the zero-filled padding
//! tail that the masked decode kernel relies on.
//!
//! Admission reserves each stream's whole lifetime up front, so the
//! walk also proves the central scheduling guarantee: appends within a
//! reservation NEVER fail, even though pages are allocated lazily and
//! the free list over-states availability.

use std::collections::BTreeMap;

use tilelang::serve::KvPool;

/// SplitMix64 (same driver as tests/property.rs; no proptest offline).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

const HEAD_DIM: usize = 16;

fn random_row(rng: &mut Rng) -> Vec<f32> {
    (0..HEAD_DIM)
        .map(|_| ((rng.next() >> 40) as f32 / (1u64 << 24) as f32) - 0.5)
        .collect()
}

/// Shadow model: per stream, the contiguous (k, v) cache the pool's
/// paged layout must be able to reproduce exactly.
type Shadow = BTreeMap<u64, (Vec<f32>, Vec<f32>)>;

fn assert_gather_matches(pool: &KvPool, shadow: &Shadow) {
    for (&id, (sk, sv)) in shadow {
        let rows = sk.len() / HEAD_DIM;
        // pad past the committed length like the engine does, to prove
        // the tail comes back zeroed
        let padded = rows + 1 + rows % 3;
        let (gk, gv) = pool.gather(id, padded).expect("gather live stream");
        assert_eq!(gk.len(), padded * HEAD_DIM);
        let want_k: Vec<u32> = sk
            .iter()
            .copied()
            .chain(std::iter::repeat(0.0))
            .take(padded * HEAD_DIM)
            .map(f32::to_bits)
            .collect();
        let got_k: Vec<u32> = gk.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_k, want_k, "stream {id}: paged K gather != contiguous shadow");
        let want_v: Vec<u32> = sv
            .iter()
            .copied()
            .chain(std::iter::repeat(0.0))
            .take(padded * HEAD_DIM)
            .map(f32::to_bits)
            .collect();
        let got_v: Vec<u32> = gv.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_v, want_v, "stream {id}: paged V gather != contiguous shadow");
    }
}

#[test]
fn randomized_admit_append_retire_preserves_invariants() {
    for seed in [0x1234u64, 0xBEEF, 0xF00D, 0xDEAD_10CC] {
        let mut rng = Rng(seed);
        let page_rows = 1 + rng.below(5) as usize; // 1..=5 rows/page
        let pages = 8 + rng.below(24) as usize; // 8..=31 pages
        let mut pool = KvPool::new(pages, page_rows, HEAD_DIM).expect("pool");
        let mut shadow: Shadow = BTreeMap::new();
        // per-stream lifetime reservation (rows), fixed at admission
        let mut reserved: BTreeMap<u64, usize> = BTreeMap::new();
        let mut next_id = 0u64;
        let mut ops = 0usize;
        for _ in 0..600 {
            match rng.below(10) {
                // admit a fresh stream (ids never reused in this walk)
                // with a random lifetime reservation; when the pool
                // cannot reserve it, admit must refuse instead
                0 | 1 => {
                    let rows = 1 + rng.below(3 * page_rows as u64) as usize;
                    if pool.can_admit(rows) {
                        pool.admit(next_id, rows).expect("can_admit implies admit");
                        shadow.insert(next_id, (Vec::new(), Vec::new()));
                        reserved.insert(next_id, rows);
                        next_id += 1;
                    } else {
                        let err = pool.admit(next_id, rows).expect_err("over-reservation");
                        assert!(
                            err.to_string().contains("unreserved"),
                            "unexpected admit failure: {err}"
                        );
                    }
                }
                // retire a random live stream
                2 => {
                    if shadow.is_empty() {
                        continue;
                    }
                    let pick = rng.below(shadow.len() as u64) as usize;
                    let id = *shadow.keys().nth(pick).expect("picked live stream");
                    let before_free = pool.free_pages();
                    let freed = pool.table(id).expect("live").pages().len();
                    pool.retire(id).expect("retire live stream");
                    shadow.remove(&id);
                    reserved.remove(&id);
                    assert_eq!(
                        pool.free_pages(),
                        before_free + freed,
                        "retire must recycle every page"
                    );
                }
                // append a row to a random live stream
                _ => {
                    if shadow.is_empty() {
                        continue;
                    }
                    let pick = rng.below(shadow.len() as u64) as usize;
                    let id = *shadow.keys().nth(pick).expect("picked live stream");
                    let (k, v) = (random_row(&mut rng), random_row(&mut rng));
                    let budget = reserved[&id];
                    let rows_before = pool.rows_of(id).expect("live");
                    match pool.append_row(id, &k, &v) {
                        Ok(()) => {
                            assert!(
                                rows_before < budget,
                                "append past the reservation must fail"
                            );
                            let e = shadow.get_mut(&id).expect("shadowed");
                            e.0.extend_from_slice(&k);
                            e.1.extend_from_slice(&v);
                        }
                        Err(err) => {
                            // the ONLY legal failure is a spent
                            // reservation; admission reserved every
                            // lifetime page, so lazy growth can never
                            // exhaust the pool mid-stream
                            assert!(
                                err.to_string().contains("reservation"),
                                "unexpected append failure: {err}"
                            );
                            assert_eq!(
                                rows_before, budget,
                                "append may only fail once the reservation is spent"
                            );
                        }
                    }
                }
            }
            ops += 1;
            pool.validate()
                .unwrap_or_else(|e| panic!("seed {seed:#x} op {ops}: invariant broken: {e}"));
            assert_eq!(pool.live_count(), shadow.len());
            assert_eq!(
                pool.used_pages() + pool.free_pages(),
                pool.total_pages(),
                "page conservation"
            );
            assert!(
                pool.used_pages() <= pool.reserved_pages()
                    && pool.reserved_pages() <= pool.total_pages(),
                "allocated pages must stay within reservations, reservations within the pool"
            );
        }
        assert_gather_matches(&pool, &shadow);
        // drain: retire everything, pool must come back whole
        let ids: Vec<u64> = shadow.keys().copied().collect();
        for id in ids {
            pool.retire(id).expect("drain retire");
            shadow.remove(&id);
            pool.validate().expect("invariants during drain");
        }
        assert_eq!(pool.free_pages(), pool.total_pages());
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.reserved_pages(), 0, "drain must release every reservation");
    }
}

/// Committed rows never move: interleaved appends to other streams and
/// page recycling from retirements must leave every previously-gathered
/// prefix bit-identical.
#[test]
fn appends_and_recycling_never_move_committed_rows() {
    let mut rng = Rng(0x5EED);
    let mut pool = KvPool::new(12, 2, HEAD_DIM).expect("pool");
    let mut shadow: Shadow = BTreeMap::new();
    for id in 0..3u64 {
        // 6 rows (3 pages) lifetime each: 9 of 12 pages reserved,
        // leaving headroom for the churn streams below
        pool.admit(id, 6).expect("admit");
        shadow.insert(id, (Vec::new(), Vec::new()));
    }
    let mut snapshots: BTreeMap<u64, (Vec<u32>, usize)> = BTreeMap::new();
    for round in 0..20 {
        let id = rng.below(3);
        let (k, v) = (random_row(&mut rng), random_row(&mut rng));
        if pool.append_row(id, &k, &v).is_ok() {
            let e = shadow.get_mut(&id).expect("shadowed");
            e.0.extend_from_slice(&k);
            e.1.extend_from_slice(&v);
        }
        // churn the free list: a short-lived stream takes and returns
        // pages so later appends land on recycled pages
        if round % 5 == 4 {
            let tmp = 100 + round as u64;
            pool.admit(tmp, 1).expect("admit churn stream");
            let _ = pool.append_row(tmp, &random_row(&mut rng), &random_row(&mut rng));
            pool.retire(tmp).expect("retire churn stream");
        }
        pool.validate().expect("invariants");
        // every stream's previously-snapshotted prefix must be intact
        for (&sid, (bits, rows)) in &snapshots {
            let (gk, _) = pool.gather(sid, pool.rows_of(sid).expect("live")).expect("gather");
            let prefix: Vec<u32> =
                gk[..rows * HEAD_DIM].iter().map(|v| v.to_bits()).collect();
            assert_eq!(&prefix, bits, "stream {sid}: committed rows moved after round {round}");
        }
        // refresh snapshots
        for &sid in shadow.keys() {
            let rows = pool.rows_of(sid).expect("live");
            if rows > 0 {
                let (gk, _) = pool.gather(sid, rows).expect("gather");
                snapshots
                    .insert(sid, (gk.iter().map(|v| v.to_bits()).collect(), rows));
            }
        }
    }
    assert_gather_matches(&pool, &shadow);
}
