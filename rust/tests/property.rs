//! Property tests (built-in driver: SplitMix64 PRNG — no proptest in
//! the offline vendor set; see DESIGN.md dependency note).
//!
//! Invariants:
//! * any feasible tile configuration compiles and executes to the
//!   reference result (lowering preserves semantics),
//! * inferred fragments are always valid partitions covering their
//!   readers (the §4.2 invariant, re-checked dynamically by the
//!   interpreter's ownership checks),
//! * swizzled layouts remain bijections for arbitrary tile shapes,
//! * expression simplification never changes evaluation.

use tilelang::ir::dtype::DType;
use tilelang::layout::Layout;
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::sim::device::Device;
use tilelang::tir::interp::{Interp, Tensors};
use tilelang::workloads::matmul::{matmul_program, reference_matmul, test_data, TileConfig};

struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        // SplitMix64
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next() % xs.len() as u64) as usize]
    }
}

#[test]
fn random_gemm_configs_preserve_semantics() {
    let mut rng = Rng(0xC0FFEE);
    let devices = [Device::a100(), Device::h100(), Device::mi300x()];
    let mut executed = 0;
    for case in 0..12 {
        let bm = *rng.pick(&[16i64, 32, 64]);
        let bn = *rng.pick(&[16i64, 32, 64]);
        let bk = *rng.pick(&[16i64, 32]);
        let stages = *rng.pick(&[1usize, 2, 3]);
        let threads = *rng.pick(&[64i64, 128]);
        let policy = *rng.pick(&[
            tilelang::ir::program::GemmWarpPolicy::Square,
            tilelang::ir::program::GemmWarpPolicy::FullRow,
            tilelang::ir::program::GemmWarpPolicy::FullCol,
        ]);
        let (m, n, k) = (bm * 2, bn * 2, bk * 2);
        let cfg = TileConfig {
            block_m: bm,
            block_n: bn,
            block_k: bk,
            num_stages: stages,
            threads,
            policy,
            rasterize: case % 2 == 0,
            specialize: *rng.pick(&[None, Some(false), Some(true)]),
        };
        let prog = matmul_program(m, n, k, DType::F16, &cfg);
        let dev = rng.pick(&devices);
        let lowered = match compile(&prog, dev, &CompileOptions::default()) {
            Ok(l) => l,
            Err(e) => panic!("case {case} ({cfg:?}) failed to compile: {e}"),
        };
        // every inferred fragment must be a valid partition
        for f in lowered.layout.frags.values() {
            assert!(f.is_valid_partition(), "case {case}: invalid fragment");
        }
        let interp = Interp::new(&lowered).unwrap();
        let a = test_data(m * k, case as u64 + 1);
        let b = test_data(k * n, case as u64 + 100);
        let mut t = Tensors::new();
        t.insert(prog.params[0].id, a.clone());
        t.insert(prog.params[1].id, b.clone());
        interp
            .run(&mut t)
            .unwrap_or_else(|e| panic!("case {case} ({cfg:?}): {e}"));
        let want = reference_matmul(&a, &b, m, n, k);
        for (g, w) in t[&prog.params[2].id].iter().zip(&want) {
            assert!(
                (g - w).abs() < 0.05 + 0.02 * w.abs(),
                "case {case} ({cfg:?}): {g} vs {w}"
            );
        }
        executed += 1;
    }
    assert_eq!(executed, 12);
}

#[test]
fn random_swizzled_layouts_are_bijections() {
    let mut rng = Rng(0xDEAD);
    for _ in 0..24 {
        let rows = *rng.pick(&[8i64, 16, 32, 64]);
        let cols = *rng.pick(&[16i64, 32, 64, 128]);
        let bits = *rng.pick(&[8u32, 16, 32]);
        let l = Layout::swizzled(rows, cols, bits);
        assert!(
            l.is_bijective_linear(),
            "swizzle({rows},{cols},{bits}) aliases"
        );
        // composition with row-major stays injective
        let rm = Layout::row_major(&[rows, cols]);
        assert!(rm.is_injective());
    }
}

#[test]
fn random_fragment_algebra_preserves_partitions() {
    use tilelang::layout::Fragment;
    let mut rng = Rng(0xF00D);
    for _ in 0..16 {
        let base = Fragment::mma_ldmatrix_16x16();
        let mut f = base;
        for _ in 0..(rng.next() % 3 + 1) {
            match rng.next() % 3 {
                0 => f = f.repeat((rng.next() % 2) as usize, 2, false),
                1 => f = f.repeat((rng.next() % 2) as usize, 2, true),
                _ => f = f.replicate(2),
            }
            assert!(f.is_valid_partition(), "algebra step broke the partition");
        }
        // table roundtrip is exact
        let t = f.to_table();
        assert_eq!(t.shape, f.shape);
        assert_eq!(t.locals_per_thread(), f.locals_per_thread());
    }
}

#[test]
fn dynamic_specialization_matches_static_compile() {
    use std::collections::HashMap;
    use tilelang::ir::program::specialize;
    // a dynamically-shaped gemm specialized to (128,128,64) must lower
    // to the same schedule structure as the statically-built one
    let cfg = TileConfig {
        block_m: 64,
        block_n: 64,
        block_k: 32,
        num_stages: 2,
        threads: 128,
        policy: Default::default(),
        rasterize: true,
        specialize: None,
    };
    let stat = matmul_program(128, 128, 64, DType::F16, &cfg);
    let l_static = compile(&stat, &Device::a100(), &CompileOptions::default()).unwrap();

    // dynamic M variant
    let mut t = tilelang::ir::builder::KernelBuilder::new("dmm", 128);
    let mvar = t.dyn_var("M");
    use tilelang::ir::expr::Expr;
    let a = t.param_dyn("A", vec![mvar.expr(), Expr::int(64)], DType::F16);
    let b = t.param("B", &[64, 128], DType::F16);
    let c = t.param_dyn("C", vec![mvar.expr(), Expr::int(128)], DType::F32);
    let (bx, by) = t.kernel2(2, mvar.expr().floordiv(64));
    let a_s = t.alloc_shared("A_s", &[64, 32], DType::F16);
    let b_s = t.alloc_shared("B_s", &[32, 64], DType::F16);
    let c_l = t.alloc_fragment("C_l", &[64, 64], DType::F32);
    t.clear(c_l);
    t.pipelined(2, 2, |t, ko| {
        t.copy_in(a, vec![by.expr() * 64, ko.expr() * 32], a_s);
        t.copy_in(b, vec![ko.expr() * 32, bx.expr() * 64], b_s);
        t.gemm(a_s, b_s, c_l);
    });
    t.copy_out(c_l, c, vec![by.expr() * 64, bx.expr() * 64]);
    let dynp = t.finish();
    let mut bind = HashMap::new();
    bind.insert(mvar.id, 128i64);
    let spec = specialize(&dynp, &bind);
    assert!(spec.dyn_params.is_empty());
    let l_dyn = compile(&spec, &Device::a100(), &CompileOptions::default()).unwrap();
    assert_eq!(l_dyn.static_grid(), Some(vec![2, 2]));
    let (cs, cd) = (l_static.stmt_counts(), l_dyn.stmt_counts());
    assert_eq!(cs.gemms, cd.gemms);
    assert_eq!(cs.async_copies, cd.async_copies);
    assert_eq!(cs.waits, cd.waits);
}
