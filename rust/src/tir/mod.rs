//! Lowered, scheduled tile IR ("ThreadIR").
//!
//! `lower::compile` turns a `TileProgram` into a `LoweredProgram`: every
//! buffer has a resolved layout, every copy a thread binding + vector
//! width, every GEMM a selected instruction, and every `Pipelined` loop
//! has been *expanded* into the prologue / steady-state / epilogue form
//! with multi-buffered shared tiles and explicit async-copy, commit,
//! wait and barrier statements — the structure Fig. 1(c) shows as
//! generated CUDA. The interpreter (`interp`) executes this IR with
//! async-queue semantics, so a mis-scheduled pipeline produces wrong
//! numbers, not just a slow estimate.

pub mod compile;
pub mod interp;

use crate::ir::buffer::{Buffer, BufferId};
use crate::ir::expr::{Expr, Var};
use crate::ir::program::{AtomicKind, DequantScheme, ElemStmt, ReduceKind};
use crate::passes::layout_inference::LayoutMap;
use crate::sim::device::InstrSpec;

/// A reference to a tile-shaped region of a buffer in the lowered IR.
#[derive(Clone, Debug)]
pub struct RegionRef {
    pub buf: BufferId,
    /// Global buffers: element offsets per dim. On-chip: zeros.
    pub offsets: Vec<Expr>,
    pub shape: Vec<i64>,
    /// Multi-buffer slot index (pipelined shared tiles); `0` otherwise.
    pub slot: Expr,
}

impl RegionRef {
    pub fn whole(buf: BufferId, shape: Vec<i64>) -> RegionRef {
        RegionRef {
            buf,
            offsets: shape.iter().map(|_| Expr::int(0)).collect(),
            shape,
            slot: Expr::int(0),
        }
    }
}

/// Thread binding + vectorization decision for a copy (Fig. 8 output).
#[derive(Clone, Debug)]
pub struct CopyBinding {
    /// Elements moved per thread per vector transaction.
    pub vec: i64,
    /// Threads that participate.
    pub threads_used: i64,
    /// Fraction of a 128B transaction actually used on the global side.
    pub coalesced_frac: f64,
    /// Worst-case shared-memory bank conflict degree (1 = conflict-free).
    pub bank_conflict: i64,
    /// Lowered as an asynchronous copy (cp.async / TMA / DMA-to-LDS).
    pub is_async: bool,
}

/// Instruction selection result for one GEMM (§4.3).
#[derive(Clone, Debug)]
pub struct GemmSched {
    pub m: i64,
    pub n: i64,
    pub k: i64,
    pub instr: InstrSpec,
    /// True when lowered natively (inline PTX path); false = tile library.
    pub native: bool,
    pub warps_m: i64,
    pub warps_n: i64,
}

/// Per-ParallelFor binding summary.
#[derive(Clone, Debug)]
pub struct ParallelBinding {
    pub vec: i64,
    pub threads_used: i64,
}

/// Lowered statements.
#[derive(Clone, Debug)]
pub enum TStmt {
    For {
        var: Var,
        extent: Expr,
        body: Vec<TStmt>,
        unroll: bool,
        /// Index into [`ScheduleInfo::pipelines`] when this loop is the
        /// steady-state (or degenerate serial form) of a software
        /// pipeline; `None` for ordinary loops. The simulator uses it to
        /// attribute the loop body to that pipeline's copy/compute
        /// stage timeline instead of the flat kernel-wide accumulator.
        pipeline: Option<usize>,
    },
    If {
        cond: Expr,
        then_body: Vec<TStmt>,
        else_body: Vec<TStmt>,
    },
    Copy {
        src: RegionRef,
        dst: RegionRef,
        binding: CopyBinding,
    },
    Gemm {
        a: RegionRef,
        b: RegionRef,
        c: BufferId,
        trans_a: bool,
        trans_b: bool,
        sched: GemmSched,
    },
    Fill {
        buf: BufferId,
        value: f64,
    },
    Reduce {
        src: BufferId,
        dst: BufferId,
        dim: usize,
        kind: ReduceKind,
        clear: bool,
    },
    Dequant {
        src: BufferId,
        dst: BufferId,
        scheme: DequantScheme,
        scale: Option<BufferId>,
        group_size: i64,
    },
    Atomic {
        dst: RegionRef,
        src: BufferId,
        kind: AtomicKind,
    },
    Parallel {
        vars: Vec<Var>,
        extents: Vec<i64>,
        body: Vec<ElemStmt>,
        binding: ParallelBinding,
    },
    /// `__syncthreads()` — block barrier.
    Barrier,
    /// `cp.async.commit_group` — seal the pending async copies.
    AsyncCommit,
    /// `cp.async.wait_group N` — wait until at most N groups in flight.
    AsyncWait(usize),
}

/// Shared-memory allocation in the lowered program.
#[derive(Clone, Debug)]
pub struct SharedAlloc {
    pub buf: BufferId,
    /// Physical cells of ONE slot (layout output size, includes padding).
    pub cells_per_slot: i64,
    /// Multi-buffer slot count (pipeline stages), >= 1.
    pub slots: i64,
    pub elem_bits: u32,
    pub dtype: crate::ir::dtype::DType,
}

impl SharedAlloc {
    pub fn bytes(&self) -> i64 {
        (self.cells_per_slot * self.slots * self.elem_bits as i64 + 7) / 8
    }
}

/// Register allocation for a fragment buffer.
#[derive(Clone, Debug)]
pub struct FragAlloc {
    pub buf: BufferId,
    pub locals_per_thread: i64,
    pub dtype: crate::ir::dtype::DType,
}

/// Pipeline summary for the performance model.
#[derive(Clone, Debug)]
pub struct PipelineSched {
    pub num_stages: usize,
    /// Global->shared bytes moved per iteration.
    pub bytes_per_iter: i64,
    /// Loop trip count (static) or None (dynamic).
    pub trip_count: Option<i64>,
    /// Whether copies were lowered async (cp.async / TMA class).
    pub uses_async: bool,
}

/// Whole-kernel scheduling summary consumed by the simulator.
#[derive(Clone, Debug, Default)]
pub struct ScheduleInfo {
    pub pipelines: Vec<PipelineSched>,
    pub warp_specialized: bool,
    /// Warps dedicated to the producer (copy) role under warp
    /// specialization; `0` when the kernel is not specialized. The
    /// remaining `threads/32 - producer_warps` warps are consumers.
    pub producer_warps: i64,
    /// Total shared memory bytes per block (after multi-buffering).
    pub smem_bytes: i64,
    /// Estimated registers per thread (fragment locals x 32-bit words).
    pub regs_per_thread: i64,
    /// L2 rasterization swizzle enabled.
    pub swizzle_blocks: bool,
}

/// The lowered kernel.
#[derive(Clone, Debug)]
pub struct LoweredProgram {
    pub name: String,
    pub grid: Vec<Expr>,
    pub block_vars: Vec<Var>,
    pub threads: i64,
    pub params: Vec<Buffer>,
    pub shared: Vec<SharedAlloc>,
    pub frags: Vec<FragAlloc>,
    pub layout: LayoutMap,
    pub body: Vec<TStmt>,
    pub schedule: ScheduleInfo,
}

impl LoweredProgram {
    pub fn static_grid(&self) -> Option<Vec<i64>> {
        self.grid.iter().map(|g| g.as_int()).collect()
    }

    pub fn shared_alloc(&self, buf: BufferId) -> &SharedAlloc {
        self.shared
            .iter()
            .find(|s| s.buf == buf)
            .unwrap_or_else(|| panic!("no shared alloc for buffer {}", buf))
    }

    pub fn frag_alloc(&self, buf: BufferId) -> &FragAlloc {
        self.frags
            .iter()
            .find(|s| s.buf == buf)
            .unwrap_or_else(|| panic!("no fragment alloc for buffer {}", buf))
    }

    pub fn param(&self, buf: BufferId) -> &Buffer {
        self.params
            .iter()
            .find(|b| b.id == buf)
            .unwrap_or_else(|| panic!("no param buffer {}", buf))
    }

    /// Walk statements depth-first.
    pub fn visit<'a>(&'a self, f: &mut impl FnMut(&'a TStmt)) {
        fn walk<'a>(stmts: &'a [TStmt], f: &mut impl FnMut(&'a TStmt)) {
            for s in stmts {
                f(s);
                match s {
                    TStmt::For { body, .. } => walk(body, f),
                    TStmt::If {
                        then_body,
                        else_body,
                        ..
                    } => {
                        walk(then_body, f);
                        walk(else_body, f);
                    }
                    _ => {}
                }
            }
        }
        walk(&self.body, f);
    }

    /// Count statements of each major kind (used by pipeline tests and
    /// the compile report).
    pub fn stmt_counts(&self) -> StmtCounts {
        let mut c = StmtCounts::default();
        self.visit(&mut |s| match s {
            TStmt::Copy { binding, .. } => {
                c.copies += 1;
                if binding.is_async {
                    c.async_copies += 1;
                }
            }
            TStmt::Gemm { .. } => c.gemms += 1,
            TStmt::Barrier => c.barriers += 1,
            TStmt::AsyncCommit => c.commits += 1,
            TStmt::AsyncWait(_) => c.waits += 1,
            TStmt::Parallel { .. } => c.parallels += 1,
            _ => {}
        });
        c
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StmtCounts {
    pub copies: usize,
    pub async_copies: usize,
    pub gemms: usize,
    pub barriers: usize,
    pub commits: usize,
    pub waits: usize,
    pub parallels: usize,
}
