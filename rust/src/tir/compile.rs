//! Compiled CPU backend: lower a [`LoweredProgram`] to a fused
//! register-bytecode VM (std-only, unsafe-free).
//!
//! The tree-walking interpreter ([`super::interp`]) re-evaluates offset
//! expressions, hashes buffer ids and allocates index vectors on every
//! element access. This module removes all of that *per-element dispatch*
//! at compile time instead of run time:
//!
//! * **Linear instruction stream** — the grid loop, every `For` (static
//!   after `specialize`), every statically-decidable `If`, and the
//!   async-copy commit/wait queue are unrolled while compiling, so the VM
//!   executes a flat `Vec<Instr>` with no control flow.
//! * **Pre-resolved offsets** — region offsets, multi-buffer slot
//!   indices and layout bases are evaluated to constants at compile time;
//!   global tails are pre-clipped into per-axis `[lo, hi)` guard ranges
//!   (out-of-bounds reads produce `0.0`, stores are dropped — the same
//!   predication the interpreter applies element by element).
//! * **Strength-reduced index arithmetic** — element addresses advance by
//!   per-axis strides (an odometer walk); no expression tree is evaluated
//!   inside a tile loop. Elementwise epilogues compile to constant-folded
//!   postfix tapes over the parallel axes.
//! * **Tile-granular inner ops** — one `Gemm` instruction runs the whole
//!   fma-over-`block_k` accumulation, one `Reduce` the row-max/row-sum of
//!   flash softmax, one `Dequant` the int4/nf4/fp4 unpack+scale.
//!
//! # Oracle contract
//!
//! The interpreter stays the semantic oracle: for every lowered program
//! that the interpreter executes successfully, `CompiledProgram::run`
//! produces **bit-for-bit identical** tensors — the same f32 accumulation
//! order in GEMMs, the same `round_to_dtype` on every store, the same
//! euclidean div/mod in index math, the same async-queue flush points and
//! the same block execution order. (Programs the interpreter *rejects* —
//! ownership violations, aliasing layouts — are reported as compile or
//! run errors here instead; divergence is only possible on programs that
//! are already broken.) `rust/tests/backend_diff.rs` enforces the
//! contract across all six workload families; the VM additionally offers
//! [`CompiledProgram::validate`] (static in-bounds proof of every
//! pre-resolved address), [`CompiledProgram::write_counts`] (a shadow
//! pass counting stores per output element) and
//! [`CompiledProgram::traffic`] (per-tier byte/FLOP movement accounting
//! the interpreter must reproduce dynamically) for property tests.
//!
//! # Example: compile once, match the interpreter bit-for-bit
//!
//! ```
//! use tilelang::ir::dtype::DType;
//! use tilelang::passes::lower::{compile, CompileOptions};
//! use tilelang::sim::device::Device;
//! use tilelang::tir::compile::compile_lowered;
//! use tilelang::tir::interp::{Interp, Tensors};
//! use tilelang::workloads::matmul::{matmul_program, TileConfig};
//!
//! let cfg = TileConfig::default_for(32, 32, 32);
//! let prog = matmul_program(32, 32, 32, DType::F16, &cfg);
//! let lowered = compile(&prog, &Device::h100(), &CompileOptions::default()).unwrap();
//!
//! let vm = compile_lowered(&lowered).unwrap();
//! vm.validate().unwrap();
//!
//! let (a, b, c) = (lowered.params[0].id, lowered.params[1].id, lowered.params[2].id);
//! let mut t_vm: Tensors = Tensors::new();
//! t_vm.insert(a, vec![1.0; 32 * 32]);
//! t_vm.insert(b, vec![0.5; 32 * 32]);
//! let mut t_oracle = t_vm.clone();
//!
//! vm.run(&mut t_vm).unwrap();
//! Interp::new(&lowered).unwrap().run(&mut t_oracle).unwrap();
//! assert_eq!(t_vm[&c], t_oracle[&c]); // bit-for-bit
//! ```
//!
//! # Bytecode format (one block, schematically)
//!
//! ```text
//! ZeroChip                          ; fresh on-chip arena (shared+frag)
//! Copy   g[A+17408 Δ(64,1) ✓]  -> chip[0     Δ(32,1)]    ; tile load
//! Copy   g[B+128   Δ(64,1) ✓]  -> chip[1024  Δ(64,1)]
//! Gemm   m=32 n=32 k=32  a=chip[0] b=chip[1024] c=chip[3072]
//! Elems  32x32 { c_l[i,j] = max(c_l[i,j], 0.0) }         ; fused epilogue
//! Copy   chip[3072 Δ(32,1)]    -> g[C+2048 Δ(64,1) ✓]    ; tile store
//! ```
//!
//! On-chip storage is a single flat f32 arena per block. Shared tiles
//! address it through their inferred physical layout (identity layouts
//! become pure strided walks; padded/swizzled layouts keep one
//! precomputed `logical flat -> physical cell` table lookup per element).
//! Fragments collapse to logical row-major cells: the interpreter keeps
//! one replica per owning thread but every write path writes all replicas
//! with the same value, so replicas are always equal and a single logical
//! cell is value-identical.

use std::collections::HashMap;

use crate::ir::buffer::{BufferId, MemScope};
use crate::ir::dtype::{fp4_e2m1_decode, round_to_dtype, DType, NF4_TABLE};
use crate::ir::expr::{BinOp, Expr, ExprKind, UnOp, VarId};
use crate::ir::program::{AtomicKind, DequantScheme, ElemStmt, ReduceKind};

use crate::obs::traffic::{Tier, Traffic};

use super::interp::Tensors;
use super::{LoweredProgram, RegionRef, TStmt};

// ---------------------------------------------------------------------
// address model
// ---------------------------------------------------------------------

/// Which storage a pre-resolved address points into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Slab {
    /// The per-block on-chip arena (shared tiles + fragment registers).
    Chip,
    /// Global parameter `i` (index into the param table).
    Param(usize),
}

/// One dimension of a strided walk. `lo..hi` is the valid coordinate
/// range after clipping against the underlying buffer (global tails);
/// coordinates outside it read `0.0` / drop stores.
#[derive(Clone, Debug)]
struct AxisWalk {
    extent: i64,
    stride: i64,
    lo: i64,
    hi: i64,
}

impl AxisWalk {
    #[inline]
    fn ok(&self, c: i64) -> bool {
        c >= self.lo && c < self.hi
    }
}

/// A pre-resolved strided view of a slab (one side of a copy/atomic).
#[derive(Clone, Debug)]
struct View {
    slab: Slab,
    /// Arena segment base: chip buffer base + slot offset (0 for params).
    seg: i64,
    /// Constant part of the relative address (global offsets folded in).
    rel0: i64,
    axes: Vec<AxisWalk>,
    /// Non-identity shared layout: index into `CompiledProgram::perms`,
    /// remapping the logical relative address to a physical cell.
    perm: Option<usize>,
    /// Any axis is partially out of bounds (guard checks required).
    guarded: bool,
    /// Contiguous row-major walk (memcpy-able when also unguarded).
    dense: bool,
}

impl View {
    fn count(&self) -> i64 {
        self.axes.iter().map(|a| a.extent).product()
    }
}

/// Odometer over a `View`'s axes: tracks the relative address and the
/// number of currently out-of-range axes incrementally (no per-element
/// index vector, no re-multiplication).
struct Cursor {
    cnt: Vec<i64>,
    rel: i64,
    oob: i64,
}

impl Cursor {
    fn new(v: &View) -> Cursor {
        Cursor {
            cnt: vec![0; v.axes.len()],
            rel: v.rel0,
            oob: v.axes.iter().filter(|a| !a.ok(0)).count() as i64,
        }
    }

    #[inline]
    fn valid(&self) -> bool {
        self.oob == 0
    }

    /// Advance to the next element in row-major order.
    #[inline]
    fn step(&mut self, axes: &[AxisWalk]) {
        let mut d = axes.len();
        while d > 0 {
            d -= 1;
            let a = &axes[d];
            let old = self.cnt[d];
            if old + 1 < a.extent {
                self.cnt[d] = old + 1;
                self.rel += a.stride;
                self.oob += a.ok(old) as i64 - a.ok(old + 1) as i64;
                return;
            }
            self.cnt[d] = 0;
            self.rel -= a.stride * (a.extent - 1);
            self.oob += a.ok(old) as i64 - a.ok(0) as i64;
        }
    }
}

/// A GEMM operand: `value(r, k)` at `seg + perm(rel0 + r*rs + k*ks)`,
/// valid when `r` in `[r_lo, r_hi)` and `k` in `[k_lo, k_hi)`.
#[derive(Clone, Debug)]
struct Mat {
    slab: Slab,
    seg: i64,
    rel0: i64,
    rs: i64,
    ks: i64,
    perm: Option<usize>,
    r_lo: i64,
    r_hi: i64,
    k_lo: i64,
    k_hi: i64,
    guarded: bool,
}

// ---------------------------------------------------------------------
// instruction set
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
struct CopyOp {
    src: View,
    dst: View,
    /// Destination storage dtype (rounded on every store).
    dtype: DType,
    count: i64,
}

#[derive(Clone, Debug)]
struct GemmOp {
    m: i64,
    n: i64,
    k: i64,
    a: Mat,
    b: Mat,
    /// Accumulator fragment: chip base + row stride (f32, unrounded).
    c_seg: i64,
    c_rs: i64,
}

#[derive(Clone, Debug)]
struct ReduceOp {
    out_extents: Vec<i64>,
    /// Source stride per output axis (0 on the kept dummy dim).
    src_strides: Vec<i64>,
    dst_seg: i64,
    src_seg: i64,
    red_extent: i64,
    red_stride: i64,
    kind: ReduceKind,
    clear: bool,
    dtype: DType,
}

#[derive(Clone, Debug)]
struct ScaleRef {
    seg: i64,
    s0: i64,
    s1: i64,
    perm: Option<usize>,
}

#[derive(Clone, Debug)]
struct DequantOp {
    rows: i64,
    cols: i64,
    src_seg: i64,
    src_s0: i64,
    src_s1: i64,
    src_perm: Option<usize>,
    scale: Option<ScaleRef>,
    dst_seg: i64,
    scheme: DequantScheme,
    bits: u32,
    epb: i64,
    group: i64,
    dtype: DType,
}

#[derive(Clone, Debug)]
struct AtomicOp {
    src: View,
    dst: View,
    kind: AtomicKind,
    dtype: DType,
    count: i64,
}

/// Integer postfix tape mirroring `Expr::eval_int` (euclidean div/mod).
#[derive(Clone, Debug)]
enum IOp {
    Const(i64),
    /// Parallel axis `k`'s current coordinate.
    Axis(usize),
    Bin(BinOp),
    Un(UnOp),
    /// Pops else, then, cond.
    Select,
}

/// Float postfix tape mirroring `Interp::eval_value` (all-f32 math).
#[derive(Clone, Debug)]
enum FOp {
    Const(f32),
    Axis(usize),
    /// Pushes the value of `ElemWrite::loads[i]`.
    Load(usize),
    Bin(BinOp),
    Un(UnOp),
    /// Pops else, then, cond (branches are pure — value-identical to the
    /// interpreter's lazy select).
    Select,
    Cast(DType),
}

#[derive(Clone, Debug)]
enum LSrc {
    Chip {
        seg: i64,
        strides: Vec<i64>,
        perm: Option<usize>,
        /// Logical cell count (reads outside it yield 0.0 defensively).
        cells: i64,
    },
    Global {
        param: usize,
        shape: Vec<i64>,
    },
}

#[derive(Clone, Debug)]
struct LoadRef {
    idx: Vec<Vec<IOp>>,
    src: LSrc,
}

#[derive(Clone, Debug)]
enum Dst {
    Chip {
        seg: i64,
        strides: Vec<i64>,
        perm: Option<usize>,
        cells: i64,
    },
    Global {
        param: usize,
        shape: Vec<i64>,
    },
}

#[derive(Clone, Debug)]
struct ElemWrite {
    idx: Vec<Vec<IOp>>,
    value: Vec<FOp>,
    loads: Vec<LoadRef>,
    dst: Dst,
    dtype: DType,
}

#[derive(Clone, Debug)]
struct ElemsOp {
    extents: Vec<i64>,
    stmts: Vec<ElemWrite>,
}

#[derive(Clone, Debug)]
enum Instr {
    /// Zero the on-chip arena (block start).
    ZeroChip,
    Copy(Box<CopyOp>),
    Gemm(Box<GemmOp>),
    Fill { seg: i64, len: i64, value: f32 },
    Reduce(Box<ReduceOp>),
    Dequant(Box<DequantOp>),
    Atomic(Box<AtomicOp>),
    Elems(Box<ElemsOp>),
}

// ---------------------------------------------------------------------
// compiled program
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
struct ParamMeta {
    id: BufferId,
    name: String,
    shape: Vec<i64>,
    len: usize,
}

/// An on-chip buffer's slice of the arena.
#[derive(Clone, Debug)]
struct ChipBuf {
    base: i64,
    /// Addressable cells per multi-buffer slot (physical for shared).
    cells: i64,
    slots: i64,
    /// Logical shape (layout input shape / fragment shape).
    shape: Vec<i64>,
    dtype: DType,
    scope: MemScope,
    perm: Option<usize>,
}

/// A [`LoweredProgram`] lowered to the bytecode VM. Built once by
/// [`compile_lowered`]; [`CompiledProgram::run`] then executes the whole
/// grid with the same tensor-map interface as `Interp::run`.
#[derive(Clone, Debug)]
pub struct CompiledProgram {
    name: String,
    instrs: Vec<Instr>,
    perms: Vec<Vec<i64>>,
    params: Vec<ParamMeta>,
    chip_len: usize,
    /// Arena tier map: `(base, end, scope)` per on-chip buffer, sorted
    /// by base. A pre-resolved chip segment never straddles buffers, so
    /// one lookup classifies it as shared memory or fragment registers
    /// for the [`CompiledProgram::traffic`] shadow pass.
    chip_spans: Vec<(i64, i64, MemScope)>,
}

/// Reused evaluation scratch (no per-element allocation).
struct Scratch {
    f: Vec<f32>,
    i: Vec<i64>,
}

fn row_major(shape: &[i64]) -> Vec<i64> {
    let mut s = vec![1i64; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * shape[d + 1];
    }
    s
}

/// Is the axis walk a contiguous row-major range?
fn is_dense(axes: &[AxisWalk]) -> bool {
    let mut expect = 1i64;
    for a in axes.iter().rev() {
        if a.stride != expect {
            return false;
        }
        expect *= a.extent;
    }
    true
}

// ---------------------------------------------------------------------
// compile-time expression evaluation (mirrors Expr::eval_int, but
// returns errors where the interpreter would panic)
// ---------------------------------------------------------------------

fn ibin_checked(op: BinOp, a: i64, b: i64) -> Result<i64, String> {
    Ok(match op {
        BinOp::FloorDiv => {
            if b == 0 {
                return Err("division by zero in static expression".into());
            }
            a.div_euclid(b)
        }
        BinOp::FloorMod => {
            if b == 0 {
                return Err("mod by zero in static expression".into());
            }
            a.rem_euclid(b)
        }
        _ => ibin(op, a, b),
    })
}

/// Integer binop with the interpreter's semantics; div/mod by zero yield
/// 0 (only reachable from eagerly-evaluated untaken select branches).
#[inline]
fn ibin(op: BinOp, a: i64, b: i64) -> i64 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::FloorDiv => {
            if b == 0 {
                0
            } else {
                a.div_euclid(b)
            }
        }
        BinOp::FloorMod => {
            if b == 0 {
                0
            } else {
                a.rem_euclid(b)
            }
        }
        BinOp::Min => a.min(b),
        BinOp::Max => a.max(b),
        BinOp::BitXor => a ^ b,
        BinOp::BitAnd => a & b,
        BinOp::Shl => a << b,
        BinOp::Shr => a >> b,
        BinOp::Lt => (a < b) as i64,
        BinOp::Le => (a <= b) as i64,
        BinOp::Eq => (a == b) as i64,
        BinOp::And => (a != 0 && b != 0) as i64,
        BinOp::Or => (a != 0 || b != 0) as i64,
    }
}

/// Static integer evaluation under the compile-time environment.
fn ceval(e: &Expr, env: &HashMap<VarId, i64>) -> Result<i64, String> {
    Ok(match e.kind() {
        ExprKind::Var(v) => *env
            .get(&v.id)
            .ok_or_else(|| format!("unbound var {} in static expression", v.name))?,
        ExprKind::Int(v) => *v,
        ExprKind::Float(_) => return Err("float in integer expression".into()),
        ExprKind::Bin(op, a, b) => ibin_checked(*op, ceval(a, env)?, ceval(b, env)?)?,
        ExprKind::Un(op, a) => {
            let x = ceval(a, env)?;
            match op {
                UnOp::Neg => -x,
                UnOp::Abs => x.abs(),
                UnOp::Not => (x == 0) as i64,
                _ => return Err("float intrinsic in integer expression".into()),
            }
        }
        ExprKind::Select(c, t, f) => {
            if ceval(c, env)? != 0 {
                ceval(t, env)?
            } else {
                ceval(f, env)?
            }
        }
        ExprKind::Cast(_, a) => ceval(a, env)?,
        ExprKind::Load(..) => return Err("load in address expression".into()),
    })
}

/// Static float evaluation (mirrors `Interp::eval_value` on load-free
/// expressions) — used to constant-fold axis-independent subtrees.
fn feval(e: &Expr, env: &HashMap<VarId, i64>) -> Result<f32, String> {
    Ok(match e.kind() {
        ExprKind::Var(v) => *env
            .get(&v.id)
            .ok_or_else(|| format!("unbound var {} in value", v.name))? as f32,
        ExprKind::Int(v) => *v as f32,
        ExprKind::Float(v) => *v as f32,
        ExprKind::Bin(op, a, b) => fbin(*op, feval(a, env)?, feval(b, env)?)?,
        ExprKind::Un(op, a) => fun(*op, feval(a, env)?),
        ExprKind::Select(c, t, f) => {
            if feval(c, env)? != 0.0 {
                feval(t, env)?
            } else {
                feval(f, env)?
            }
        }
        ExprKind::Cast(dt, a) => round_to_dtype(feval(a, env)?, *dt),
        ExprKind::Load(..) => return Err("load in constant value".into()),
    })
}

#[inline]
fn fbin(op: BinOp, x: f32, y: f32) -> Result<f32, String> {
    Ok(match op {
        BinOp::Add => x + y,
        BinOp::Sub => x - y,
        BinOp::Mul => x * y,
        BinOp::FloorDiv => (x / y).floor(),
        BinOp::FloorMod => x - (x / y).floor() * y,
        BinOp::Min => x.min(y),
        BinOp::Max => x.max(y),
        BinOp::Lt => (x < y) as i32 as f32,
        BinOp::Le => (x <= y) as i32 as f32,
        BinOp::Eq => (x == y) as i32 as f32,
        BinOp::And => ((x != 0.0) && (y != 0.0)) as i32 as f32,
        BinOp::Or => ((x != 0.0) || (y != 0.0)) as i32 as f32,
        BinOp::BitXor | BinOp::BitAnd | BinOp::Shl | BinOp::Shr => {
            return Err("bitwise op in float value".into())
        }
    })
}

#[inline]
fn fun(op: UnOp, x: f32) -> f32 {
    match op {
        UnOp::Neg => -x,
        UnOp::Exp => x.exp(),
        UnOp::Exp2 => x.exp2(),
        UnOp::Log => x.ln(),
        UnOp::Sqrt => x.sqrt(),
        UnOp::Rsqrt => 1.0 / x.sqrt(),
        UnOp::Abs => x.abs(),
        UnOp::Tanh => x.tanh(),
        UnOp::Not => (x == 0.0) as i32 as f32,
    }
}

fn uses_axis(e: &Expr, axes: &HashMap<VarId, usize>) -> bool {
    match e.kind() {
        ExprKind::Var(v) => axes.contains_key(&v.id),
        ExprKind::Int(_) | ExprKind::Float(_) => false,
        ExprKind::Bin(_, a, b) => uses_axis(a, axes) || uses_axis(b, axes),
        ExprKind::Un(_, a) => uses_axis(a, axes),
        ExprKind::Select(c, t, f) => {
            uses_axis(c, axes) || uses_axis(t, axes) || uses_axis(f, axes)
        }
        ExprKind::Cast(_, a) => uses_axis(a, axes),
        ExprKind::Load(_, idx) => idx.iter().any(|e| uses_axis(e, axes)),
    }
}

fn has_load(e: &Expr) -> bool {
    match e.kind() {
        ExprKind::Var(_) | ExprKind::Int(_) | ExprKind::Float(_) => false,
        ExprKind::Bin(_, a, b) => has_load(a) || has_load(b),
        ExprKind::Un(_, a) => has_load(a),
        ExprKind::Select(c, t, f) => has_load(c) || has_load(t) || has_load(f),
        ExprKind::Cast(_, a) => has_load(a),
        ExprKind::Load(..) => true,
    }
}

// ---------------------------------------------------------------------
// compiler
// ---------------------------------------------------------------------

/// Lower `prog` to bytecode. Fails (rather than miscompiling) on
/// programs the interpreter could not execute either: dynamic grids,
/// non-static loop extents, out-of-range on-chip regions.
pub fn compile_lowered(prog: &LoweredProgram) -> Result<CompiledProgram, String> {
    Compiler::new(prog)?.compile()
}

struct Compiler<'p> {
    prog: &'p LoweredProgram,
    chip: HashMap<BufferId, ChipBuf>,
    perms: Vec<Vec<i64>>,
    params: Vec<ParamMeta>,
    pidx: HashMap<BufferId, usize>,
    chip_len: i64,
    instrs: Vec<Instr>,
    /// Async-copy queue, mirrored at compile time: uncommitted copies,
    /// then committed groups in FIFO order.
    current: Vec<Instr>,
    pending: Vec<Vec<Instr>>,
}

impl<'p> Compiler<'p> {
    fn new(prog: &'p LoweredProgram) -> Result<Compiler<'p>, String> {
        let mut params = Vec::new();
        let mut pidx = HashMap::new();
        for b in &prog.params {
            let shape = b
                .static_shape()
                .ok_or_else(|| format!("param {} must be static for execution", b.name))?;
            pidx.insert(b.id, params.len());
            params.push(ParamMeta {
                id: b.id,
                name: b.name.clone(),
                len: shape.iter().product::<i64>() as usize,
                shape,
            });
        }
        let mut chip = HashMap::new();
        let mut perms: Vec<Vec<i64>> = Vec::new();
        let mut chip_len = 0i64;
        for s in &prog.shared {
            let l = prog.layout.shared_layout(s.buf);
            let shape = l.input_shape();
            let table = l.table();
            let logical: i64 = shape.iter().product();
            if table.len() as i64 != logical {
                return Err(format!(
                    "shared layout table for buffer {} covers {} cells, expected {}",
                    s.buf,
                    table.len(),
                    logical
                ));
            }
            let identity = table.iter().enumerate().all(|(i, &p)| p == i as i64);
            let perm = if identity {
                None
            } else {
                if table.iter().any(|&p| p < 0 || p >= s.cells_per_slot) {
                    return Err(format!(
                        "shared layout for buffer {} maps outside its {} physical cells",
                        s.buf, s.cells_per_slot
                    ));
                }
                perms.push(table);
                Some(perms.len() - 1)
            };
            chip.insert(
                s.buf,
                ChipBuf {
                    base: chip_len,
                    cells: s.cells_per_slot,
                    slots: s.slots,
                    shape,
                    dtype: s.dtype,
                    scope: MemScope::Shared,
                    perm,
                },
            );
            chip_len += s.cells_per_slot * s.slots;
        }
        for f in &prog.frags {
            let fr = prog.layout.fragment(f.buf).to_table();
            let cells: i64 = fr.shape.iter().product();
            chip.insert(
                f.buf,
                ChipBuf {
                    base: chip_len,
                    cells,
                    slots: 1,
                    shape: fr.shape.clone(),
                    dtype: f.dtype,
                    scope: MemScope::Fragment,
                    perm: None,
                },
            );
            chip_len += cells;
        }
        Ok(Compiler {
            prog,
            chip,
            perms,
            params,
            pidx,
            chip_len,
            instrs: Vec::new(),
            current: Vec::new(),
            pending: Vec::new(),
        })
    }

    fn compile(mut self) -> Result<CompiledProgram, String> {
        let grid = self
            .prog
            .static_grid()
            .ok_or("grid must be static for execution (specialize first)")?;
        let total: i64 = grid.iter().product();
        for flat in 0..total {
            let mut rem = flat;
            let mut env: HashMap<VarId, i64> = HashMap::new();
            for (d, v) in self.prog.block_vars.iter().enumerate() {
                env.insert(v.id, rem % grid[d]);
                rem /= grid[d];
            }
            self.instrs.push(Instr::ZeroChip);
            let body = self.prog.body.clone();
            self.walk(&body, &mut env)?;
            // epilogue flush: committed groups execute, uncommitted
            // copies are dropped (exactly the interpreter's block end)
            while let Some(g) = (!self.pending.is_empty()).then(|| self.pending.remove(0)) {
                self.instrs.extend(g);
            }
            self.current.clear();
        }
        let mut chip_spans: Vec<(i64, i64, MemScope)> = self
            .chip
            .values()
            .map(|c| (c.base, c.base + c.cells * c.slots, c.scope))
            .collect();
        chip_spans.sort_by_key(|&(base, _, _)| base);
        Ok(CompiledProgram {
            name: self.prog.name.clone(),
            instrs: self.instrs,
            perms: self.perms,
            params: self.params,
            chip_len: self.chip_len as usize,
            chip_spans,
        })
    }

    fn cb(&self, buf: BufferId) -> Result<&ChipBuf, String> {
        self.chip
            .get(&buf)
            .ok_or_else(|| format!("buffer {} is not on-chip", buf))
    }

    fn dtype_of(&self, buf: BufferId) -> DType {
        if let Some(c) = self.chip.get(&buf) {
            return c.dtype;
        }
        if let Some(&p) = self.pidx.get(&buf) {
            return self
                .prog
                .param(self.params[p].id)
                .dtype;
        }
        DType::F32
    }

    fn walk(&mut self, stmts: &[TStmt], env: &mut HashMap<VarId, i64>) -> Result<(), String> {
        for s in stmts {
            self.emit(s, env)?;
        }
        Ok(())
    }

    fn emit(&mut self, s: &TStmt, env: &mut HashMap<VarId, i64>) -> Result<(), String> {
        match s {
            TStmt::For {
                var, extent, body, ..
            } => {
                let e = ceval(extent, env)?;
                for i in 0..e {
                    env.insert(var.id, i);
                    self.walk(body, env)?;
                }
                env.remove(&var.id);
                Ok(())
            }
            TStmt::If {
                cond,
                then_body,
                else_body,
            } => {
                if ceval(cond, env)? != 0 {
                    self.walk(then_body, env)
                } else {
                    self.walk(else_body, env)
                }
            }
            TStmt::Copy { src, dst, binding } => {
                let ins = self.copy_instr(src, dst, env)?;
                if binding.is_async {
                    self.current.push(ins);
                } else {
                    self.instrs.push(ins);
                }
                Ok(())
            }
            TStmt::AsyncCommit => {
                let g = std::mem::take(&mut self.current);
                self.pending.push(g);
                Ok(())
            }
            TStmt::AsyncWait(n) => {
                while self.pending.len() > *n {
                    let g = self.pending.remove(0);
                    self.instrs.extend(g);
                }
                Ok(())
            }
            TStmt::Barrier => Ok(()), // lockstep execution: no-op numerically
            TStmt::Fill { buf, value } => {
                let c = self.cb(*buf)?;
                self.instrs.push(Instr::Fill {
                    seg: c.base,
                    len: c.cells * c.slots,
                    value: round_to_dtype(*value as f32, c.dtype),
                });
                Ok(())
            }
            TStmt::Gemm {
                a,
                b,
                c,
                trans_a,
                trans_b,
                ..
            } => {
                let ins = self.gemm_instr(a, b, *c, *trans_a, *trans_b, env)?;
                self.instrs.push(ins);
                Ok(())
            }
            TStmt::Reduce {
                src,
                dst,
                dim,
                kind,
                clear,
            } => {
                let ins = self.reduce_instr(*src, *dst, *dim, *kind, *clear)?;
                self.instrs.push(ins);
                Ok(())
            }
            TStmt::Dequant {
                src,
                dst,
                scheme,
                scale,
                group_size,
            } => {
                let ins = self.dequant_instr(*src, *dst, *scheme, *scale, *group_size)?;
                self.instrs.push(ins);
                Ok(())
            }
            TStmt::Atomic { dst, src, kind } => {
                let ins = self.atomic_instr(dst, *src, *kind, env)?;
                self.instrs.push(ins);
                Ok(())
            }
            TStmt::Parallel {
                vars,
                extents,
                body,
                ..
            } => {
                let ins = self.parallel_instr(vars, extents, body, env)?;
                self.instrs.push(ins);
                Ok(())
            }
        }
    }

    /// Resolve a region reference into a strided `View`.
    fn view(&self, r: &RegionRef, env: &HashMap<VarId, i64>) -> Result<View, String> {
        if let Some(&p) = self.pidx.get(&r.buf) {
            let meta = &self.params[p];
            if r.offsets.len() != meta.shape.len() || r.shape.len() != meta.shape.len() {
                return Err(format!(
                    "region rank {} does not match param {} rank {}",
                    r.shape.len(),
                    meta.name,
                    meta.shape.len()
                ));
            }
            let strides = row_major(&meta.shape);
            let mut rel0 = 0i64;
            let mut axes = Vec::with_capacity(r.shape.len());
            let mut guarded = false;
            for d in 0..r.shape.len() {
                let o = ceval(&r.offsets[d], env)?;
                rel0 += o * strides[d];
                let extent = r.shape[d];
                let lo = (-o).clamp(0, extent);
                let hi = (meta.shape[d] - o).clamp(lo, extent);
                if lo > 0 || hi < extent {
                    guarded = true;
                }
                axes.push(AxisWalk {
                    extent,
                    stride: strides[d],
                    lo,
                    hi,
                });
            }
            let dense = !guarded && is_dense(&axes);
            return Ok(View {
                slab: Slab::Param(p),
                seg: 0,
                rel0,
                axes,
                perm: None,
                guarded,
                dense,
            });
        }
        let c = self.cb(r.buf)?;
        if r.offsets.len() != c.shape.len() || r.shape.len() != c.shape.len() {
            return Err(format!(
                "region rank {} does not match on-chip buffer {} rank {}",
                r.shape.len(),
                r.buf,
                c.shape.len()
            ));
        }
        let slot = ceval(&r.slot, env)?;
        if slot < 0 || slot >= c.slots {
            return Err(format!(
                "slot {} out of range for buffer {} ({} slots)",
                slot, r.buf, c.slots
            ));
        }
        let strides = row_major(&c.shape);
        let mut rel0 = 0i64;
        let mut axes = Vec::with_capacity(r.shape.len());
        for d in 0..r.shape.len() {
            let o = ceval(&r.offsets[d], env)?;
            if o < 0 || o + r.shape[d] > c.shape[d] {
                return Err(format!(
                    "on-chip region [{}..{}) exceeds buffer {} dim {} extent {}",
                    o,
                    o + r.shape[d],
                    r.buf,
                    d,
                    c.shape[d]
                ));
            }
            rel0 += o * strides[d];
            axes.push(AxisWalk {
                extent: r.shape[d],
                stride: strides[d],
                lo: 0,
                hi: r.shape[d],
            });
        }
        let dense = c.perm.is_none() && rel0 == 0 && is_dense(&axes);
        Ok(View {
            slab: Slab::Chip,
            seg: c.base + slot * c.cells,
            rel0,
            axes,
            perm: c.perm,
            guarded: false,
            dense,
        })
    }

    fn copy_instr(
        &self,
        src: &RegionRef,
        dst: &RegionRef,
        env: &HashMap<VarId, i64>,
    ) -> Result<Instr, String> {
        let sv = self.view(src, env)?;
        let dv = self.view(dst, env)?;
        let count = dv.count();
        if sv.count() != count {
            return Err(format!(
                "copy cell count mismatch: src {} vs dst {}",
                sv.count(),
                count
            ));
        }
        Ok(Instr::Copy(Box::new(CopyOp {
            dtype: self.dtype_of(dst.buf),
            src: sv,
            dst: dv,
            count,
        })))
    }

    fn gemm_instr(
        &self,
        a: &RegionRef,
        b: &RegionRef,
        c: BufferId,
        trans_a: bool,
        trans_b: bool,
        env: &HashMap<VarId, i64>,
    ) -> Result<Instr, String> {
        let (sa, sb) = (&a.shape, &b.shape);
        if sa.len() != 2 || sb.len() != 2 {
            return Err("gemm operands must be rank-2 regions".into());
        }
        let (m, k) = if trans_a {
            (sa[1], sa[0])
        } else {
            (sa[0], sa[1])
        };
        let n = if trans_b { sb[0] } else { sb[1] };
        let av = self.view(a, env)?;
        let bv = self.view(b, env)?;
        // map (row r, reduction kk) onto the region's (dim0, dim1):
        // a indexes [i, kk] (transposed: [kk, i]), b indexes [kk, j]
        // (transposed: [j, kk])
        let a_mat = mat_of(&av, !trans_a);
        let b_mat = mat_of(&bv, trans_b);
        let cb = self.cb(c)?;
        if cb.scope != MemScope::Fragment {
            return Err("gemm accumulator must be a fragment".into());
        }
        if cb.shape.len() != 2 || m > cb.shape[0] || n > cb.shape[1] {
            return Err(format!(
                "gemm {}x{} accumulator exceeds fragment shape {:?}",
                m, n, cb.shape
            ));
        }
        Ok(Instr::Gemm(Box::new(GemmOp {
            m,
            n,
            k,
            a: a_mat,
            b: b_mat,
            c_seg: cb.base,
            c_rs: cb.shape[1],
        })))
    }

    fn reduce_instr(
        &self,
        src: BufferId,
        dst: BufferId,
        dim: usize,
        kind: ReduceKind,
        clear: bool,
    ) -> Result<Instr, String> {
        let sc = self.cb(src)?;
        let dc = self.cb(dst)?;
        if sc.scope != MemScope::Fragment || dc.scope != MemScope::Fragment {
            return Err("reduce src/dst must be fragments".into());
        }
        let out = dc.shape.clone();
        let ss = row_major(&sc.shape);
        if dim >= sc.shape.len() {
            return Err(format!("reduce dim {} out of range for {:?}", dim, sc.shape));
        }
        let src_strides: Vec<i64> = if sc.shape.len() == out.len() {
            // dst kept a dummy dim
            (0..out.len())
                .map(|d| if d == dim { 0 } else { ss[d] })
                .collect()
        } else if sc.shape.len() == out.len() + 1 {
            (0..out.len())
                .map(|d| ss[if d < dim { d } else { d + 1 }])
                .collect()
        } else {
            return Err(format!(
                "reduce rank mismatch: src {:?} dst {:?}",
                sc.shape, out
            ));
        };
        for d in 0..out.len() {
            let sd = if sc.shape.len() == out.len() {
                d
            } else if d < dim {
                d
            } else {
                d + 1
            };
            if sd != dim && out[d] > sc.shape[sd] {
                return Err(format!(
                    "reduce output {:?} exceeds source {:?}",
                    out, sc.shape
                ));
            }
        }
        Ok(Instr::Reduce(Box::new(ReduceOp {
            src_strides,
            out_extents: out,
            dst_seg: dc.base,
            src_seg: sc.base,
            red_extent: sc.shape[dim],
            red_stride: ss[dim],
            kind,
            clear,
            dtype: dc.dtype,
        })))
    }

    fn dequant_instr(
        &self,
        src: BufferId,
        dst: BufferId,
        scheme: DequantScheme,
        scale: Option<BufferId>,
        group_size: i64,
    ) -> Result<Instr, String> {
        let dc = self.cb(dst)?;
        if dc.scope != MemScope::Fragment || dc.shape.len() != 2 {
            return Err("dequant dst must be a rank-2 fragment".into());
        }
        let sc = self.cb(src)?;
        if sc.shape.len() != 2 {
            return Err("dequant src must be a rank-2 on-chip buffer".into());
        }
        let (rows, cols) = (dc.shape[0], dc.shape[1]);
        let bits = match scheme {
            DequantScheme::UintAffine { .. } => {
                // bits derivable from shape ratio
                let epb = dc.shape[1] / sc.shape[1];
                if epb <= 0 || 8 % epb != 0 {
                    return Err(format!(
                        "dequant shape ratio {} does not give a byte-packable width",
                        epb
                    ));
                }
                (8 / epb) as u32
            }
            DequantScheme::Nf4Lut | DequantScheme::Fp4E2m1 => 4,
        };
        let epb = (8 / bits) as i64;
        if rows > sc.shape[0] || (cols - 1) / epb >= sc.shape[1] {
            return Err(format!(
                "dequant dst {:?} reads outside packed src {:?}",
                dc.shape, sc.shape
            ));
        }
        let scale_ref = match scale {
            Some(s) => {
                let b = self.cb(s)?;
                if b.shape.len() != 2 {
                    return Err("dequant scale must be a rank-2 on-chip buffer".into());
                }
                if group_size <= 0 {
                    return Err("dequant group_size must be positive".into());
                }
                if rows > b.shape[0] || (cols - 1) / group_size >= b.shape[1] {
                    return Err(format!(
                        "dequant dst {:?} reads outside scale {:?}",
                        dc.shape, b.shape
                    ));
                }
                Some(ScaleRef {
                    seg: b.base,
                    s0: b.shape[1],
                    s1: 1,
                    perm: b.perm,
                })
            }
            None => None,
        };
        Ok(Instr::Dequant(Box::new(DequantOp {
            rows,
            cols,
            src_seg: sc.base,
            src_s0: sc.shape[1],
            src_s1: 1,
            src_perm: sc.perm,
            scale: scale_ref,
            dst_seg: dc.base,
            scheme,
            bits,
            epb,
            group: group_size,
            dtype: dc.dtype,
        })))
    }

    fn atomic_instr(
        &self,
        dst: &RegionRef,
        src: BufferId,
        kind: AtomicKind,
        env: &HashMap<VarId, i64>,
    ) -> Result<Instr, String> {
        let dv = self.view(dst, env)?;
        if !matches!(dv.slab, Slab::Param(_)) {
            return Err("atomic destination must be a global param".into());
        }
        // source cells are read over the destination's cell domain
        let src_region = if let Some(c) = self.chip.get(&src) {
            if c.shape != dst.shape {
                return Err(format!(
                    "atomic src shape {:?} differs from dst region {:?}",
                    c.shape, dst.shape
                ));
            }
            RegionRef::whole(src, c.shape.clone())
        } else if let Some(&p) = self.pidx.get(&src) {
            if self.params[p].shape != dst.shape {
                return Err(format!(
                    "atomic src shape {:?} differs from dst region {:?}",
                    self.params[p].shape, dst.shape
                ));
            }
            RegionRef::whole(src, self.params[p].shape.clone())
        } else {
            return Err(format!("atomic src buffer {} unknown", src));
        };
        let sv = self.view(&src_region, env)?;
        let count = dv.count();
        Ok(Instr::Atomic(Box::new(AtomicOp {
            src: sv,
            dtype: self.dtype_of(dst.buf),
            dst: dv,
            kind,
            count,
        })))
    }

    fn parallel_instr(
        &self,
        vars: &[crate::ir::expr::Var],
        extents: &[i64],
        body: &[ElemStmt],
        env: &HashMap<VarId, i64>,
    ) -> Result<Instr, String> {
        let axes: HashMap<VarId, usize> =
            vars.iter().enumerate().map(|(i, v)| (v.id, i)).collect();
        let mut stmts = Vec::with_capacity(body.len());
        for es in body {
            let dst = if let Some(&p) = self.pidx.get(&es.dst) {
                Dst::Global {
                    param: p,
                    shape: self.params[p].shape.clone(),
                }
            } else {
                let c = self.cb(es.dst)?;
                Dst::Chip {
                    seg: c.base,
                    strides: row_major(&c.shape),
                    perm: c.perm,
                    cells: c.shape.iter().product(),
                }
            };
            let idx = es
                .indices
                .iter()
                .map(|e| self.itape(e, env, &axes))
                .collect::<Result<Vec<_>, String>>()?;
            let mut loads = Vec::new();
            let mut value = Vec::new();
            self.ftape(&es.value, env, &axes, &mut value, &mut loads)?;
            stmts.push(ElemWrite {
                idx,
                value,
                loads,
                dst,
                dtype: self.dtype_of(es.dst),
            });
        }
        Ok(Instr::Elems(Box::new(ElemsOp {
            extents: extents.to_vec(),
            stmts,
        })))
    }

    /// Build an integer tape; axis-free subtrees constant-fold.
    fn itape(
        &self,
        e: &Expr,
        env: &HashMap<VarId, i64>,
        axes: &HashMap<VarId, usize>,
    ) -> Result<Vec<IOp>, String> {
        let mut out = Vec::new();
        self.itape_into(e, env, axes, &mut out)?;
        Ok(out)
    }

    fn itape_into(
        &self,
        e: &Expr,
        env: &HashMap<VarId, i64>,
        axes: &HashMap<VarId, usize>,
        out: &mut Vec<IOp>,
    ) -> Result<(), String> {
        if !uses_axis(e, axes) {
            out.push(IOp::Const(ceval(e, env)?));
            return Ok(());
        }
        match e.kind() {
            ExprKind::Var(v) => out.push(IOp::Axis(axes[&v.id])),
            ExprKind::Bin(op, a, b) => {
                self.itape_into(a, env, axes, out)?;
                self.itape_into(b, env, axes, out)?;
                out.push(IOp::Bin(*op));
            }
            ExprKind::Un(op, a) => {
                if !matches!(op, UnOp::Neg | UnOp::Abs | UnOp::Not) {
                    return Err("float intrinsic in integer expression".into());
                }
                self.itape_into(a, env, axes, out)?;
                out.push(IOp::Un(*op));
            }
            ExprKind::Select(c, t, f) => {
                self.itape_into(c, env, axes, out)?;
                self.itape_into(t, env, axes, out)?;
                self.itape_into(f, env, axes, out)?;
                out.push(IOp::Select);
            }
            ExprKind::Cast(_, a) => self.itape_into(a, env, axes, out)?,
            ExprKind::Int(_) | ExprKind::Float(_) | ExprKind::Load(..) => {
                return Err("invalid node in address expression".into())
            }
        }
        Ok(())
    }

    /// Build a float tape; axis-free load-free subtrees constant-fold.
    fn ftape(
        &self,
        e: &Expr,
        env: &HashMap<VarId, i64>,
        axes: &HashMap<VarId, usize>,
        out: &mut Vec<FOp>,
        loads: &mut Vec<LoadRef>,
    ) -> Result<(), String> {
        if !uses_axis(e, axes) && !has_load(e) {
            out.push(FOp::Const(feval(e, env)?));
            return Ok(());
        }
        match e.kind() {
            ExprKind::Var(v) => out.push(FOp::Axis(axes[&v.id])),
            ExprKind::Int(v) => out.push(FOp::Const(*v as f32)),
            ExprKind::Float(v) => out.push(FOp::Const(*v as f32)),
            ExprKind::Load(buf, idx) => {
                let idx_tapes = idx
                    .iter()
                    .map(|x| self.itape(x, env, axes))
                    .collect::<Result<Vec<_>, String>>()?;
                let src = if let Some(&p) = self.pidx.get(buf) {
                    LSrc::Global {
                        param: p,
                        shape: self.params[p].shape.clone(),
                    }
                } else {
                    let c = self.cb(*buf)?;
                    LSrc::Chip {
                        seg: c.base,
                        strides: row_major(&c.shape),
                        perm: c.perm,
                        cells: c.shape.iter().product(),
                    }
                };
                loads.push(LoadRef {
                    idx: idx_tapes,
                    src,
                });
                out.push(FOp::Load(loads.len() - 1));
            }
            ExprKind::Bin(op, a, b) => {
                if matches!(
                    op,
                    BinOp::BitXor | BinOp::BitAnd | BinOp::Shl | BinOp::Shr
                ) {
                    return Err("bitwise op in float value".into());
                }
                self.ftape(a, env, axes, out, loads)?;
                self.ftape(b, env, axes, out, loads)?;
                out.push(FOp::Bin(*op));
            }
            ExprKind::Un(op, a) => {
                self.ftape(a, env, axes, out, loads)?;
                out.push(FOp::Un(*op));
            }
            ExprKind::Select(c, t, f) => {
                // fold a static condition to preserve lazy-branch
                // semantics where possible
                if !uses_axis(c, axes) && !has_load(c) {
                    if feval(c, env)? != 0.0 {
                        self.ftape(t, env, axes, out, loads)?;
                    } else {
                        self.ftape(f, env, axes, out, loads)?;
                    }
                } else {
                    self.ftape(c, env, axes, out, loads)?;
                    self.ftape(t, env, axes, out, loads)?;
                    self.ftape(f, env, axes, out, loads)?;
                    out.push(FOp::Select);
                }
            }
            ExprKind::Cast(dt, a) => {
                self.ftape(a, env, axes, out, loads)?;
                out.push(FOp::Cast(*dt));
            }
        }
        Ok(())
    }
}

/// Count the arithmetic tape ops and surviving loads of an elementwise
/// value expression, mirroring `ftape`'s constant folding *exactly*: an
/// axis-free, load-free subtree folds to one constant (zero ops), a
/// select whose condition is static keeps only the taken branch, and
/// every surviving `Bin`/`Un`/`Select`/`Cast` costs one op. The
/// interpreter calls this once per executed `Parallel` statement so its
/// dynamic traffic counters agree bit-exactly with the compiled static
/// shadow ([`CompiledProgram::traffic`]); any change here must move in
/// lockstep with `ftape`.
pub(crate) fn elem_value_cost(
    e: &Expr,
    env: &HashMap<VarId, i64>,
    axes: &HashMap<VarId, usize>,
    loads: &mut Vec<BufferId>,
) -> Result<u64, String> {
    if !uses_axis(e, axes) && !has_load(e) {
        return Ok(0); // folds to one FOp::Const
    }
    Ok(match e.kind() {
        ExprKind::Var(_) | ExprKind::Int(_) | ExprKind::Float(_) => 0,
        ExprKind::Load(buf, _) => {
            // index tapes are integer address math, not f32 ops
            loads.push(*buf);
            0
        }
        ExprKind::Bin(_, a, b) => {
            elem_value_cost(a, env, axes, loads)? + elem_value_cost(b, env, axes, loads)? + 1
        }
        ExprKind::Un(_, a) => elem_value_cost(a, env, axes, loads)? + 1,
        ExprKind::Select(c, t, f) => {
            if !uses_axis(c, axes) && !has_load(c) {
                // static condition: only the taken branch is compiled
                if feval(c, env)? != 0.0 {
                    elem_value_cost(t, env, axes, loads)?
                } else {
                    elem_value_cost(f, env, axes, loads)?
                }
            } else {
                elem_value_cost(c, env, axes, loads)?
                    + elem_value_cost(t, env, axes, loads)?
                    + elem_value_cost(f, env, axes, loads)?
                    + 1
            }
        }
        ExprKind::Cast(_, a) => elem_value_cost(a, env, axes, loads)? + 1,
    })
}

/// Map a rank-2 view onto GEMM (row, reduction) coordinates.
/// `row_is_dim0`: the row index selects dim 0 (else dim 1).
fn mat_of(v: &View, row_is_dim0: bool) -> Mat {
    let (r, k) = if row_is_dim0 {
        (&v.axes[0], &v.axes[1])
    } else {
        (&v.axes[1], &v.axes[0])
    };
    Mat {
        slab: v.slab,
        seg: v.seg,
        rel0: v.rel0,
        rs: r.stride,
        ks: k.stride,
        perm: v.perm,
        r_lo: r.lo,
        r_hi: r.hi,
        k_lo: k.lo,
        k_hi: k.hi,
        guarded: v.guarded,
    }
}

// ---------------------------------------------------------------------
// execution
// ---------------------------------------------------------------------

impl CompiledProgram {
    /// Kernel name (from the lowered program).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Total instructions in the (fully unrolled) stream.
    pub fn instr_count(&self) -> usize {
        self.instrs.len()
    }

    /// On-chip arena cells per block.
    pub fn chip_cells(&self) -> usize {
        self.chip_len
    }

    /// Execute the whole grid. Same interface and same results as
    /// `Interp::run`: `tensors` maps every global param id to row-major
    /// f32 contents (created zero-filled if missing).
    pub fn run(&self, tensors: &mut Tensors) -> Result<(), String> {
        let mut globals: Vec<Vec<f32>> = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let t = tensors
                .remove(&p.id)
                .unwrap_or_else(|| vec![0.0; p.len]);
            if t.len() != p.len {
                let msg = format!(
                    "tensor for {} has {} elements, expected {}",
                    p.name,
                    t.len(),
                    p.len
                );
                tensors.insert(p.id, t);
                for (q, v) in self.params.iter().zip(globals.drain(..)) {
                    tensors.insert(q.id, v);
                }
                return Err(msg);
            }
            globals.push(t);
        }
        let mut chip = vec![0.0f32; self.chip_len];
        let mut scratch = Scratch {
            f: Vec::with_capacity(16),
            i: Vec::with_capacity(16),
        };
        let mut res = Ok(());
        for ins in &self.instrs {
            res = self.exec(ins, &mut chip, &mut globals, &mut scratch);
            if res.is_err() {
                break;
            }
        }
        for (p, v) in self.params.iter().zip(globals.into_iter()) {
            tensors.insert(p.id, v);
        }
        res
    }

    fn exec(
        &self,
        ins: &Instr,
        chip: &mut [f32],
        globals: &mut [Vec<f32>],
        scratch: &mut Scratch,
    ) -> Result<(), String> {
        match ins {
            Instr::ZeroChip => {
                chip.fill(0.0);
                Ok(())
            }
            Instr::Fill { seg, len, value } => {
                let s = *seg as usize;
                chip[s..s + *len as usize].fill(*value);
                Ok(())
            }
            Instr::Copy(c) => self.exec_copy(c, chip, globals),
            Instr::Gemm(g) => {
                self.exec_gemm(g, chip, globals);
                Ok(())
            }
            Instr::Reduce(r) => {
                exec_reduce(r, chip);
                Ok(())
            }
            Instr::Dequant(d) => {
                self.exec_dequant(d, chip);
                Ok(())
            }
            Instr::Atomic(a) => {
                self.exec_atomic(a, chip, globals);
                Ok(())
            }
            Instr::Elems(e) => self.exec_elems(e, chip, globals, scratch),
        }
    }

    #[inline]
    fn addr(&self, v: &View, rel: i64) -> usize {
        match v.perm {
            Some(p) => (v.seg + self.perms[p][rel as usize]) as usize,
            None => (v.seg + rel) as usize,
        }
    }

    fn exec_copy(
        &self,
        c: &CopyOp,
        chip: &mut [f32],
        globals: &mut [Vec<f32>],
    ) -> Result<(), String> {
        let n = c.count as usize;
        // dense f32 fast path: straight slice copy when both sides are
        // contiguous, fully in-bounds, and storage applies no rounding
        if c.src.dense && c.dst.dense && c.dtype == DType::F32 {
            let s0 = self.addr(&c.src, c.src.rel0);
            let d0 = self.addr(&c.dst, c.dst.rel0);
            match (c.src.slab, c.dst.slab) {
                (Slab::Chip, Slab::Chip) if s0 + n <= d0 || d0 + n <= s0 => {
                    chip.copy_within(s0..s0 + n, d0);
                    return Ok(());
                }
                (Slab::Param(p), Slab::Chip) => {
                    chip[d0..d0 + n].copy_from_slice(&globals[p][s0..s0 + n]);
                    return Ok(());
                }
                (Slab::Chip, Slab::Param(p)) => {
                    globals[p][d0..d0 + n].copy_from_slice(&chip[s0..s0 + n]);
                    return Ok(());
                }
                (Slab::Param(p), Slab::Param(q))
                    if p != q || s0 + n <= d0 || d0 + n <= s0 =>
                {
                    if p == q {
                        globals[p].copy_within(s0..s0 + n, d0);
                    } else {
                        let (src, dst) = two_params(globals, p, q);
                        dst[d0..d0 + n].copy_from_slice(&src[s0..s0 + n]);
                    }
                    return Ok(());
                }
                _ => {} // overlapping: element order matters, fall through
            }
        }
        let mut sc = Cursor::new(&c.src);
        let mut dc = Cursor::new(&c.dst);
        for _ in 0..c.count {
            let v = if sc.valid() {
                let a = self.addr(&c.src, sc.rel);
                match c.src.slab {
                    Slab::Chip => chip[a],
                    Slab::Param(p) => globals[p][a],
                }
            } else {
                0.0 // out-of-bounds read: predicated off
            };
            if dc.valid() {
                let a = self.addr(&c.dst, dc.rel);
                let v = round_to_dtype(v, c.dtype);
                match c.dst.slab {
                    Slab::Chip => chip[a] = v,
                    Slab::Param(p) => globals[p][a] = v,
                }
            }
            sc.step(&c.src.axes);
            dc.step(&c.dst.axes);
        }
        Ok(())
    }

    fn exec_gemm(&self, g: &GemmOp, chip: &mut [f32], globals: &[Vec<f32>]) {
        // hot path: both operands on-chip, identity layout, in-bounds —
        // a branch-free fma-over-block_k inner loop
        if g.a.slab == Slab::Chip
            && g.b.slab == Slab::Chip
            && g.a.perm.is_none()
            && g.b.perm.is_none()
            && !g.a.guarded
            && !g.b.guarded
        {
            for i in 0..g.m {
                let a_row = g.a.seg + g.a.rel0 + i * g.a.rs;
                let c_row = g.c_seg + i * g.c_rs;
                for j in 0..g.n {
                    let caddr = (c_row + j) as usize;
                    let mut acc = chip[caddr];
                    let b_col = g.b.seg + g.b.rel0 + j * g.b.rs;
                    let mut ai = a_row;
                    let mut bi = b_col;
                    for _ in 0..g.k {
                        acc += chip[ai as usize] * chip[bi as usize];
                        ai += g.a.ks;
                        bi += g.b.ks;
                    }
                    chip[caddr] = acc; // unrounded f32 accumulator
                }
            }
            return;
        }
        for i in 0..g.m {
            let c_row = g.c_seg + i * g.c_rs;
            for j in 0..g.n {
                let caddr = (c_row + j) as usize;
                let mut acc = chip[caddr];
                for kk in 0..g.k {
                    acc += self.mat_read(&g.a, i, kk, chip, globals)
                        * self.mat_read(&g.b, j, kk, chip, globals);
                }
                chip[caddr] = acc;
            }
        }
    }

    #[inline]
    fn mat_read(&self, m: &Mat, r: i64, k: i64, chip: &[f32], globals: &[Vec<f32>]) -> f32 {
        if m.guarded && !(r >= m.r_lo && r < m.r_hi && k >= m.k_lo && k < m.k_hi) {
            return 0.0;
        }
        let rel = m.rel0 + r * m.rs + k * m.ks;
        let a = match m.perm {
            Some(p) => (m.seg + self.perms[p][rel as usize]) as usize,
            None => (m.seg + rel) as usize,
        };
        match m.slab {
            Slab::Chip => chip[a],
            Slab::Param(p) => globals[p][a],
        }
    }

    fn exec_dequant(&self, d: &DequantOp, chip: &mut [f32]) {
        let mask = (1u32 << d.bits) - 1;
        for i in 0..d.rows {
            for j in 0..d.cols {
                let rel = i * d.src_s0 + (j / d.epb) * d.src_s1;
                let a = match d.src_perm {
                    Some(p) => (d.src_seg + self.perms[p][rel as usize]) as usize,
                    None => (d.src_seg + rel) as usize,
                };
                let byte = chip[a] as u32;
                let code = (byte >> (((j % d.epb) as u32) * d.bits)) & mask;
                let base = match d.scheme {
                    DequantScheme::UintAffine { zero } => code as f32 - zero as f32,
                    DequantScheme::Nf4Lut => NF4_TABLE[code as usize],
                    DequantScheme::Fp4E2m1 => fp4_e2m1_decode(code as u8),
                };
                let s = match &d.scale {
                    Some(sc) => {
                        let rel = i * sc.s0 + (j / d.group) * sc.s1;
                        let a = match sc.perm {
                            Some(p) => (sc.seg + self.perms[p][rel as usize]) as usize,
                            None => (sc.seg + rel) as usize,
                        };
                        chip[a]
                    }
                    None => 1.0,
                };
                chip[(d.dst_seg + i * d.cols + j) as usize] =
                    round_to_dtype(base * s, d.dtype);
            }
        }
    }

    fn exec_atomic(&self, at: &AtomicOp, chip: &mut [f32], globals: &mut [Vec<f32>]) {
        let mut sc = Cursor::new(&at.src);
        let mut dc = Cursor::new(&at.dst);
        for _ in 0..at.count {
            let sv = {
                let a = self.addr(&at.src, sc.rel);
                match at.src.slab {
                    Slab::Chip => chip[a],
                    Slab::Param(p) => globals[p][a],
                }
            };
            if dc.valid() {
                let a = self.addr(&at.dst, dc.rel);
                if let Slab::Param(p) = at.dst.slab {
                    let cur = globals[p][a];
                    globals[p][a] = round_to_dtype(
                        match at.kind {
                            AtomicKind::Add => cur + sv,
                            AtomicKind::Max => cur.max(sv),
                            AtomicKind::Min => cur.min(sv),
                        },
                        at.dtype,
                    );
                }
            }
            sc.step(&at.src.axes);
            dc.step(&at.dst.axes);
        }
    }

    fn exec_elems(
        &self,
        e: &ElemsOp,
        chip: &mut [f32],
        globals: &mut [Vec<f32>],
        scratch: &mut Scratch,
    ) -> Result<(), String> {
        let nd = e.extents.len();
        let mut point = vec![0i64; nd];
        let total: i64 = e.extents.iter().product();
        for _ in 0..total {
            for w in &e.stmts {
                let value = self.eval_ftape(w, &point, chip, globals, scratch)?;
                match &w.dst {
                    Dst::Chip {
                        seg,
                        strides,
                        perm,
                        cells,
                    } => {
                        let mut rel = 0i64;
                        for (t, s) in w.idx.iter().zip(strides) {
                            rel += eval_itape(t, &point, &mut scratch.i) * s;
                        }
                        if rel < 0 || rel >= *cells {
                            return Err(format!(
                                "{}: elementwise store outside on-chip buffer",
                                self.name
                            ));
                        }
                        let a = match perm {
                            Some(p) => (seg + self.perms[*p][rel as usize]) as usize,
                            None => (seg + rel) as usize,
                        };
                        chip[a] = round_to_dtype(value, w.dtype);
                    }
                    Dst::Global { param, shape } => {
                        let mut addr = 0i64;
                        let mut ok = true;
                        for (t, &s) in w.idx.iter().zip(shape.iter()) {
                            let i = eval_itape(t, &point, &mut scratch.i);
                            if i < 0 || i >= s {
                                ok = false; // out-of-bounds: predicated off
                                break;
                            }
                            addr = addr * s + i;
                        }
                        if ok {
                            globals[*param][addr as usize] = round_to_dtype(value, w.dtype);
                        }
                    }
                }
            }
            // row-major odometer over the parallel domain
            let mut d = nd;
            while d > 0 {
                d -= 1;
                point[d] += 1;
                if point[d] < e.extents[d] {
                    break;
                }
                point[d] = 0;
            }
        }
        Ok(())
    }

    fn eval_ftape(
        &self,
        w: &ElemWrite,
        point: &[i64],
        chip: &[f32],
        globals: &[Vec<f32>],
        scratch: &mut Scratch,
    ) -> Result<f32, String> {
        scratch.f.clear();
        for op in &w.value {
            match op {
                FOp::Const(v) => scratch.f.push(*v),
                FOp::Axis(k) => scratch.f.push(point[*k] as f32),
                FOp::Load(i) => {
                    let l = &w.loads[*i];
                    let v = match &l.src {
                        LSrc::Chip {
                            seg,
                            strides,
                            perm,
                            cells,
                        } => {
                            let mut rel = 0i64;
                            for (t, s) in l.idx.iter().zip(strides) {
                                rel += eval_itape(t, point, &mut scratch.i) * s;
                            }
                            if rel < 0 || rel >= *cells {
                                0.0 // defensively predicated (eager select branch)
                            } else {
                                let a = match perm {
                                    Some(p) => (seg + self.perms[*p][rel as usize]) as usize,
                                    None => (seg + rel) as usize,
                                };
                                chip[a]
                            }
                        }
                        LSrc::Global { param, shape } => {
                            let mut addr = 0i64;
                            let mut ok = true;
                            for (t, &s) in l.idx.iter().zip(shape.iter()) {
                                let i = eval_itape(t, point, &mut scratch.i);
                                if i < 0 || i >= s {
                                    ok = false;
                                    break;
                                }
                                addr = addr * s + i;
                            }
                            if ok {
                                globals[*param][addr as usize]
                            } else {
                                0.0
                            }
                        }
                    };
                    scratch.f.push(v);
                }
                FOp::Bin(op) => {
                    let y = scratch.f.pop().unwrap();
                    let x = scratch.f.pop().unwrap();
                    scratch.f.push(fbin(*op, x, y)?);
                }
                FOp::Un(op) => {
                    let x = scratch.f.pop().unwrap();
                    scratch.f.push(fun(*op, x));
                }
                FOp::Select => {
                    let f = scratch.f.pop().unwrap();
                    let t = scratch.f.pop().unwrap();
                    let c = scratch.f.pop().unwrap();
                    scratch.f.push(if c != 0.0 { t } else { f });
                }
                FOp::Cast(dt) => {
                    let x = scratch.f.pop().unwrap();
                    scratch.f.push(round_to_dtype(x, *dt));
                }
            }
        }
        Ok(scratch.f.pop().unwrap_or(0.0))
    }
}

fn eval_itape(tape: &[IOp], point: &[i64], stack: &mut Vec<i64>) -> i64 {
    stack.clear();
    for op in tape {
        match op {
            IOp::Const(v) => stack.push(*v),
            IOp::Axis(k) => stack.push(point[*k]),
            IOp::Bin(op) => {
                let b = stack.pop().unwrap();
                let a = stack.pop().unwrap();
                stack.push(ibin(*op, a, b));
            }
            IOp::Un(op) => {
                let a = stack.pop().unwrap();
                stack.push(match op {
                    UnOp::Neg => -a,
                    UnOp::Abs => a.abs(),
                    UnOp::Not => (a == 0) as i64,
                    _ => unreachable!("checked at tape build"),
                });
            }
            IOp::Select => {
                let f = stack.pop().unwrap();
                let t = stack.pop().unwrap();
                let c = stack.pop().unwrap();
                stack.push(if c != 0 { t } else { f });
            }
        }
    }
    stack.pop().unwrap_or(0)
}

fn exec_reduce(r: &ReduceOp, chip: &mut [f32]) {
    let init = match r.kind {
        ReduceKind::Sum => 0.0f32,
        ReduceKind::Max => f32::NEG_INFINITY,
        ReduceKind::Min => f32::INFINITY,
        ReduceKind::AbsMax => 0.0,
    };
    let nd = r.out_extents.len();
    let mut cnt = vec![0i64; nd];
    let mut src_rel = 0i64;
    let total: i64 = r.out_extents.iter().product();
    for flat in 0..total {
        let daddr = (r.dst_seg + flat) as usize;
        let mut acc = if r.clear { init } else { chip[daddr] };
        let mut rel = src_rel;
        for _ in 0..r.red_extent {
            let v = chip[(r.src_seg + rel) as usize];
            acc = match r.kind {
                ReduceKind::Sum => acc + v,
                ReduceKind::Max => acc.max(v),
                ReduceKind::Min => acc.min(v),
                ReduceKind::AbsMax => acc.max(v.abs()),
            };
            rel += r.red_stride;
        }
        chip[daddr] = round_to_dtype(acc, r.dtype);
        let mut d = nd;
        while d > 0 {
            d -= 1;
            cnt[d] += 1;
            src_rel += r.src_strides[d];
            if cnt[d] < r.out_extents[d] {
                break;
            }
            src_rel -= r.src_strides[d] * cnt[d];
            cnt[d] = 0;
        }
    }
}

/// Split-borrow two distinct parameter tensors (src read, dst write).
fn two_params(globals: &mut [Vec<f32>], src: usize, dst: usize) -> (&[f32], &mut [f32]) {
    debug_assert_ne!(src, dst);
    if src < dst {
        let (a, b) = globals.split_at_mut(dst);
        (&a[src], &mut b[0])
    } else {
        let (a, b) = globals.split_at_mut(src);
        (&b[0], &mut a[dst])
    }
}

// ---------------------------------------------------------------------
// property checks (static in-bounds proof + shadow write counting)
// ---------------------------------------------------------------------

impl CompiledProgram {
    /// Prove every pre-resolved address in the instruction stream stays
    /// inside its slab: strided walks are checked by their coordinate
    /// extremes, permutation tables by their value range, elementwise
    /// on-chip stores by sweeping the (small) parallel domain.
    /// Runtime-guarded global accesses are exempt by design — they clip,
    /// not trap.
    pub fn validate(&self) -> Result<(), String> {
        for (pi, perm) in self.perms.iter().enumerate() {
            if perm.iter().any(|&v| v < 0) {
                return Err(format!("perm table {} holds a negative cell", pi));
            }
        }
        let mut stack = Vec::new();
        for (n, ins) in self.instrs.iter().enumerate() {
            let at = |msg: String| format!("instr {}: {}", n, msg);
            match ins {
                Instr::ZeroChip => {}
                Instr::Fill { seg, len, value: _ } => {
                    if *seg < 0 || (*seg + *len) as usize > self.chip_len {
                        return Err(at(format!("fill [{}, {}) outside arena", seg, seg + len)));
                    }
                }
                Instr::Copy(c) => {
                    self.check_view(&c.src, false).map_err(&at)?;
                    self.check_view(&c.dst, true).map_err(&at)?;
                }
                Instr::Atomic(a) => {
                    self.check_view(&a.src, false).map_err(&at)?;
                    self.check_view(&a.dst, true).map_err(&at)?;
                }
                Instr::Gemm(g) => {
                    self.check_mat(&g.a, g.m, g.k).map_err(&at)?;
                    self.check_mat(&g.b, g.n, g.k).map_err(&at)?;
                    let hi = g.c_seg + (g.m - 1) * g.c_rs + (g.n - 1);
                    if g.c_seg < 0 || hi as usize >= self.chip_len {
                        return Err(at("gemm accumulator outside arena".into()));
                    }
                }
                Instr::Reduce(r) => {
                    let out: i64 = r.out_extents.iter().product();
                    if r.dst_seg < 0 || (r.dst_seg + out) as usize > self.chip_len {
                        return Err(at("reduce dst outside arena".into()));
                    }
                    let span: i64 = r
                        .out_extents
                        .iter()
                        .zip(&r.src_strides)
                        .map(|(e, s)| (e - 1) * s)
                        .sum::<i64>()
                        + (r.red_extent - 1) * r.red_stride;
                    if r.src_seg < 0 || (r.src_seg + span) as usize >= self.chip_len {
                        return Err(at("reduce src outside arena".into()));
                    }
                }
                Instr::Dequant(d) => {
                    let src_hi =
                        d.src_seg + (d.rows - 1) * d.src_s0 + ((d.cols - 1) / d.epb) * d.src_s1;
                    let src_hi = match d.src_perm {
                        Some(p) => d.src_seg + max_perm(&self.perms[p]),
                        None => src_hi,
                    };
                    if src_hi as usize >= self.chip_len {
                        return Err(at("dequant src outside arena".into()));
                    }
                    if let Some(sc) = &d.scale {
                        let hi = match sc.perm {
                            Some(p) => sc.seg + max_perm(&self.perms[p]),
                            None => {
                                sc.seg + (d.rows - 1) * sc.s0 + ((d.cols - 1) / d.group) * sc.s1
                            }
                        };
                        if hi as usize >= self.chip_len {
                            return Err(at("dequant scale outside arena".into()));
                        }
                    }
                    let dst_hi = d.dst_seg + d.rows * d.cols;
                    if d.dst_seg < 0 || dst_hi as usize > self.chip_len {
                        return Err(at("dequant dst outside arena".into()));
                    }
                }
                Instr::Elems(e) => {
                    // sweep the parallel domain: on-chip stores must
                    // never leave their buffer
                    let total: i64 = e.extents.iter().product();
                    let nd = e.extents.len();
                    let mut point = vec![0i64; nd];
                    for _ in 0..total {
                        for w in &e.stmts {
                            if let Dst::Chip { strides, cells, .. } = &w.dst {
                                let mut rel = 0i64;
                                for (t, s) in w.idx.iter().zip(strides) {
                                    rel += eval_itape(t, &point, &mut stack) * s;
                                }
                                if rel < 0 || rel >= *cells {
                                    return Err(at(format!(
                                        "elementwise store at {:?} leaves its buffer",
                                        point
                                    )));
                                }
                            }
                        }
                        let mut d = nd;
                        while d > 0 {
                            d -= 1;
                            point[d] += 1;
                            if point[d] < e.extents[d] {
                                break;
                            }
                            point[d] = 0;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn slab_len(&self, slab: Slab) -> usize {
        match slab {
            Slab::Chip => self.chip_len,
            Slab::Param(p) => self.params[p].len,
        }
    }

    fn check_view(&self, v: &View, _is_dst: bool) -> Result<(), String> {
        // axes with an empty valid range never dereference
        if v.axes.iter().any(|a| a.lo >= a.hi) {
            return Ok(());
        }
        let min_rel: i64 = v.rel0 + v.axes.iter().map(|a| a.lo * a.stride).sum::<i64>();
        let max_rel: i64 = v.rel0 + v.axes.iter().map(|a| (a.hi - 1) * a.stride).sum::<i64>();
        match v.perm {
            Some(p) => {
                let table = &self.perms[p];
                if min_rel < 0 || max_rel as usize >= table.len() {
                    return Err(format!(
                        "view rel range [{}, {}] outside perm table ({})",
                        min_rel,
                        max_rel,
                        table.len()
                    ));
                }
                let hi = v.seg + max_perm(table);
                if v.seg < 0 || hi as usize >= self.slab_len(v.slab) {
                    return Err("permuted view outside slab".into());
                }
            }
            None => {
                let (lo, hi) = (v.seg + min_rel, v.seg + max_rel);
                if lo < 0 || hi as usize >= self.slab_len(v.slab) {
                    return Err(format!(
                        "view addr range [{}, {}] outside slab of {} cells",
                        lo,
                        hi,
                        self.slab_len(v.slab)
                    ));
                }
            }
        }
        Ok(())
    }

    fn check_mat(&self, m: &Mat, rows: i64, ks: i64) -> Result<(), String> {
        let (r_lo, r_hi) = (m.r_lo.max(0), m.r_hi.min(rows));
        let (k_lo, k_hi) = (m.k_lo.max(0), m.k_hi.min(ks));
        if r_lo >= r_hi || k_lo >= k_hi {
            return Ok(()); // fully guarded off
        }
        let min_rel = m.rel0 + r_lo * m.rs + k_lo * m.ks;
        let max_rel = m.rel0 + (r_hi - 1) * m.rs + (k_hi - 1) * m.ks;
        match m.perm {
            Some(p) => {
                let table = &self.perms[p];
                if min_rel < 0 || max_rel as usize >= table.len() {
                    return Err("gemm operand rel range outside perm table".into());
                }
                if m.seg < 0 || (m.seg + max_perm(table)) as usize >= self.slab_len(m.slab) {
                    return Err("gemm operand outside slab".into());
                }
            }
            None => {
                if m.seg + min_rel < 0
                    || (m.seg + max_rel) as usize >= self.slab_len(m.slab)
                {
                    return Err("gemm operand outside slab".into());
                }
            }
        }
        Ok(())
    }

    /// Shadow pass: count how many times each element of global param
    /// `buf` is written across the whole instruction stream, without
    /// executing any arithmetic (index tapes never load tensor data, so
    /// the result is input-independent). The canonical property for
    /// every default artifact: the output tensor's counts are all 1.
    pub fn write_counts(&self, buf: BufferId) -> Result<Vec<u64>, String> {
        let target = self
            .params
            .iter()
            .position(|p| p.id == buf)
            .ok_or_else(|| format!("buffer {} is not a global param", buf))?;
        let mut counts = vec![0u64; self.params[target].len];
        let mut stack = Vec::new();
        for ins in &self.instrs {
            match ins {
                Instr::Copy(c) => {
                    if c.dst.slab == Slab::Param(target) {
                        count_view(&c.dst, &mut counts);
                    }
                }
                Instr::Atomic(a) => {
                    if a.dst.slab == Slab::Param(target) {
                        count_view(&a.dst, &mut counts);
                    }
                }
                Instr::Elems(e) => {
                    let total: i64 = e.extents.iter().product();
                    let nd = e.extents.len();
                    let mut point = vec![0i64; nd];
                    for _ in 0..total {
                        for w in &e.stmts {
                            if let Dst::Global { param, shape } = &w.dst {
                                if *param != target {
                                    continue;
                                }
                                let mut addr = 0i64;
                                let mut ok = true;
                                for (t, &s) in w.idx.iter().zip(shape.iter()) {
                                    let i = eval_itape(t, &point, &mut stack);
                                    if i < 0 || i >= s {
                                        ok = false;
                                        break;
                                    }
                                    addr = addr * s + i;
                                }
                                if ok {
                                    counts[addr as usize] += 1;
                                }
                            }
                        }
                        let mut d = nd;
                        while d > 0 {
                            d -= 1;
                            point[d] += 1;
                            if point[d] < e.extents[d] {
                                break;
                            }
                            point[d] = 0;
                        }
                    }
                }
                _ => {}
            }
        }
        Ok(counts)
    }
}

/// Static per-instruction-class execution counters for one VM run.
///
/// Computed from the instruction stream alone (like
/// [`CompiledProgram::write_counts`]: index tapes never load tensor
/// data), so the numbers are tensor-independent — executing the same
/// program twice reports identical counts regardless of inputs. The
/// observability layer emits these as `vm.*` counters per node
/// execution, and `tilelang profile` sums them per kernel next to the
/// cost model's predictions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// Copy instructions executed (tile loads/stores + atomics).
    pub copy_tiles: u64,
    pub gemm_tiles: u64,
    pub reduce_tiles: u64,
    pub dequant_tiles: u64,
    /// Elementwise sweeps executed (fused epilogues, masks, softmax).
    pub elems_tiles: u64,
    /// f32 arithmetic operations (2·m·n·k per GEMM tile, one combine
    /// per reduced element, one tape op per elementwise evaluation).
    pub f32_ops: u64,
    /// Bytes read + written through the arena and the global params.
    pub bytes_moved: u64,
}

impl OpCounts {
    pub fn merge(&mut self, other: &OpCounts) {
        self.copy_tiles += other.copy_tiles;
        self.gemm_tiles += other.gemm_tiles;
        self.reduce_tiles += other.reduce_tiles;
        self.dequant_tiles += other.dequant_tiles;
        self.elems_tiles += other.elems_tiles;
        self.f32_ops += other.f32_ops;
        self.bytes_moved += other.bytes_moved;
    }

    /// `(counter name, value)` pairs in the `vm.*` namespace the
    /// recorder stores them under.
    pub fn items(&self) -> [(&'static str, u64); 7] {
        [
            ("vm.copy_tiles", self.copy_tiles),
            ("vm.gemm_tiles", self.gemm_tiles),
            ("vm.reduce_tiles", self.reduce_tiles),
            ("vm.dequant_tiles", self.dequant_tiles),
            ("vm.elems_tiles", self.elems_tiles),
            ("vm.f32_ops", self.f32_ops),
            ("vm.bytes_moved", self.bytes_moved),
        ]
    }

    /// Tiles across every instruction class.
    pub fn total_tiles(&self) -> u64 {
        self.copy_tiles + self.gemm_tiles + self.reduce_tiles + self.dequant_tiles
            + self.elems_tiles
    }
}

impl CompiledProgram {
    /// Per-instruction-class counters for one full-grid execution —
    /// a shadow pass over the instruction stream, input-independent by
    /// construction (see [`OpCounts`]). O(instructions), no domain
    /// sweeps: element counts come from extents, never from walking
    /// addresses.
    pub fn op_counts(&self) -> OpCounts {
        let mut oc = OpCounts::default();
        for ins in &self.instrs {
            match ins {
                Instr::ZeroChip => {
                    oc.bytes_moved += 4 * self.chip_len as u64;
                }
                Instr::Fill { len, .. } => {
                    oc.bytes_moved += 4 * *len as u64;
                }
                Instr::Copy(c) => {
                    oc.copy_tiles += 1;
                    // read + write four bytes per element
                    oc.bytes_moved += 8 * c.count as u64;
                }
                Instr::Atomic(a) => {
                    // an atomic is a copy with a combine: read src,
                    // read-modify-write dst
                    oc.copy_tiles += 1;
                    oc.f32_ops += a.count as u64;
                    oc.bytes_moved += 12 * a.count as u64;
                }
                Instr::Gemm(g) => {
                    oc.gemm_tiles += 1;
                    let (m, n, k) = (g.m as u64, g.n as u64, g.k as u64);
                    oc.f32_ops += 2 * m * n * k;
                    oc.bytes_moved += 4 * (m * k + n * k + 2 * m * n);
                }
                Instr::Reduce(r) => {
                    oc.reduce_tiles += 1;
                    let out: u64 = r.out_extents.iter().map(|&e| e as u64).product();
                    let red = r.red_extent as u64;
                    oc.f32_ops += out * red;
                    oc.bytes_moved += 4 * (out * red + out);
                }
                Instr::Dequant(d) => {
                    oc.dequant_tiles += 1;
                    let elems = (d.rows * d.cols) as u64;
                    let packed = (d.rows * d.cols.div_ceil(d.epb)) as u64;
                    let scales = match &d.scale {
                        Some(_) => (d.rows * d.cols.div_ceil(d.group)) as u64,
                        None => 0,
                    };
                    oc.f32_ops += elems;
                    oc.bytes_moved += 4 * (elems + packed + scales);
                }
                Instr::Elems(e) => {
                    oc.elems_tiles += 1;
                    let total: u64 = e.extents.iter().map(|&x| x as u64).product();
                    for w in &e.stmts {
                        let tape_ops = w
                            .value
                            .iter()
                            .filter(|op| {
                                matches!(
                                    op,
                                    FOp::Bin(_) | FOp::Un(_) | FOp::Select | FOp::Cast(_)
                                )
                            })
                            .count() as u64;
                        oc.f32_ops += total * tape_ops.max(1);
                        // every load read + the store written
                        oc.bytes_moved += total * 4 * (w.loads.len() as u64 + 1);
                    }
                }
            }
        }
        oc
    }

    /// Which tier an arena segment lives in (shared tile vs fragment
    /// registers), from the compile-time buffer layout.
    fn chip_tier(&self, seg: i64) -> Tier {
        for &(base, end, scope) in &self.chip_spans {
            if seg >= base && seg < end {
                return match scope {
                    MemScope::Fragment => Tier::Fragment,
                    _ => Tier::Shared,
                };
            }
        }
        // an empty segment (zero-cell buffer) cannot carry traffic
        Tier::Shared
    }

    fn view_tier(&self, v: &View) -> Tier {
        match v.slab {
            Slab::Param(_) => Tier::Dram,
            Slab::Chip => self.chip_tier(v.seg),
        }
    }

    fn mat_tier(&self, m: &Mat) -> Tier {
        match m.slab {
            Slab::Param(_) => Tier::Dram,
            Slab::Chip => self.chip_tier(m.seg),
        }
    }

    /// Per-tier data-movement shadow pass: exact DRAM/shared/fragment
    /// read+write bytes and FLOPs for one full-grid execution, computed
    /// from the instruction stream's pre-resolved shapes alone — no
    /// domain sweeps, input-independent by construction. Follows the
    /// logical-extent conventions documented in [`crate::obs::traffic`];
    /// the interpreter counts the identical quantities dynamically
    /// (`Interp::run_traffic`), and `rust/tests/traffic.rs` pins the two
    /// bit-exactly across every default artifact.
    pub fn traffic(&self) -> Traffic {
        let mut t = Traffic::default();
        for ins in &self.instrs {
            match ins {
                // block-start arena zeroing is allocation, not movement
                Instr::ZeroChip => {}
                Instr::Fill { seg, len, .. } => {
                    t.add_wr(self.chip_tier(*seg), 4 * *len as u64);
                }
                Instr::Copy(c) => {
                    let bytes = 4 * c.count as u64;
                    t.add_rd(self.view_tier(&c.src), bytes);
                    t.add_wr(self.view_tier(&c.dst), bytes);
                }
                Instr::Atomic(a) => {
                    // read src, read-modify-write dst
                    let bytes = 4 * a.count as u64;
                    t.add_rd(self.view_tier(&a.src), bytes);
                    t.add_rd(self.view_tier(&a.dst), bytes);
                    t.add_wr(self.view_tier(&a.dst), bytes);
                    t.flops += a.count as u64;
                }
                Instr::Gemm(g) => {
                    let (m, n, k) = (g.m as u64, g.n as u64, g.k as u64);
                    t.add_rd(self.mat_tier(&g.a), 4 * m * k);
                    t.add_rd(self.mat_tier(&g.b), 4 * n * k);
                    // the accumulator is read-modify-written in place
                    t.frag_rd_bytes += 4 * m * n;
                    t.frag_wr_bytes += 4 * m * n;
                    t.flops += 2 * m * n * k;
                }
                Instr::Reduce(r) => {
                    let out: u64 = r.out_extents.iter().map(|&e| e as u64).product();
                    let red = r.red_extent as u64;
                    t.frag_rd_bytes += 4 * out * red;
                    if !r.clear {
                        // accumulating into live values reads them first
                        t.frag_rd_bytes += 4 * out;
                    }
                    t.frag_wr_bytes += 4 * out;
                    t.flops += out * red;
                }
                Instr::Dequant(d) => {
                    let elems = (d.rows * d.cols) as u64;
                    let packed = (d.rows * d.cols.div_ceil(d.epb)) as u64;
                    t.add_rd(self.chip_tier(d.src_seg), 4 * packed);
                    if let Some(s) = &d.scale {
                        let scales = (d.rows * d.cols.div_ceil(d.group)) as u64;
                        t.add_rd(self.chip_tier(s.seg), 4 * scales);
                    }
                    t.frag_wr_bytes += 4 * elems;
                    t.flops += elems;
                }
                Instr::Elems(e) => {
                    let total: u64 = e.extents.iter().map(|&x| x as u64).product();
                    for w in &e.stmts {
                        for l in &w.loads {
                            let tier = match &l.src {
                                LSrc::Global { .. } => Tier::Dram,
                                LSrc::Chip { seg, .. } => self.chip_tier(*seg),
                            };
                            t.add_rd(tier, 4 * total);
                        }
                        let dst_tier = match &w.dst {
                            Dst::Global { .. } => Tier::Dram,
                            Dst::Chip { seg, .. } => self.chip_tier(*seg),
                        };
                        t.add_wr(dst_tier, 4 * total);
                        let tape_ops = w
                            .value
                            .iter()
                            .filter(|op| {
                                matches!(
                                    op,
                                    FOp::Bin(_) | FOp::Un(_) | FOp::Select | FOp::Cast(_)
                                )
                            })
                            .count() as u64;
                        t.flops += total * tape_ops;
                    }
                }
            }
        }
        t
    }
}

fn count_view(v: &View, counts: &mut [u64]) {
    let mut cur = Cursor::new(v);
    let n: i64 = v.count();
    for _ in 0..n {
        if cur.valid() {
            counts[cur.rel as usize] += 1;
        }
        cur.step(&v.axes);
    }
}

fn max_perm(table: &[i64]) -> i64 {
    table.iter().copied().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::dtype::DType;
    use crate::passes::lower::{compile, CompileOptions};
    use crate::sim::device::Device;
    use crate::workloads::matmul::{matmul_program, test_data, TileConfig};

    fn lowered_matmul(m: i64, n: i64, k: i64) -> LoweredProgram {
        let cfg = TileConfig::default_for(m, n, k);
        let prog = matmul_program(m, n, k, DType::F16, &cfg);
        compile(&prog, &Device::h100(), &CompileOptions::default()).unwrap()
    }

    #[test]
    fn matmul_matches_interp_bit_for_bit() {
        let lowered = lowered_matmul(64, 64, 64);
        let vm = compile_lowered(&lowered).unwrap();
        assert!(vm.instr_count() > 0);
        let (a, b, c) = (
            lowered.params[0].id,
            lowered.params[1].id,
            lowered.params[2].id,
        );
        let mut tv: Tensors = Tensors::new();
        tv.insert(a, test_data(64 * 64, 0xC0));
        tv.insert(b, test_data(64 * 64, 0xC1));
        let mut ti = tv.clone();
        vm.run(&mut tv).unwrap();
        super::super::interp::Interp::new(&lowered)
            .unwrap()
            .run(&mut ti)
            .unwrap();
        assert_eq!(tv[&c], ti[&c], "compiled and interp outputs diverge");
        assert!(tv[&c].iter().any(|&x| x != 0.0), "output all zero");
    }

    #[test]
    fn validate_and_write_counts_hold_for_matmul() {
        let lowered = lowered_matmul(64, 64, 64);
        let vm = compile_lowered(&lowered).unwrap();
        vm.validate().unwrap();
        let c = lowered.params[2].id;
        let counts = vm.write_counts(c).unwrap();
        assert_eq!(counts.len(), 64 * 64);
        assert!(
            counts.iter().all(|&n| n == 1),
            "every output element must be written exactly once"
        );
        // operands are never written
        let a = lowered.params[0].id;
        assert!(vm.write_counts(a).unwrap().iter().all(|&n| n == 0));
    }

    #[test]
    fn dynamic_m_tail_matches_interp_and_writes_once() {
        use crate::ir::program::specialize;
        use crate::workloads::matmul::matmul_program_dyn;
        let cfg = TileConfig {
            block_m: 64,
            block_n: 32,
            block_k: 32,
            num_stages: 2,
            threads: 128,
            policy: crate::ir::program::GemmWarpPolicy::Square,
            rasterize: true,
            specialize: None,
        };
        let (n, k, m) = (64i64, 64i64, 33i64);
        let (prog, mvar) = matmul_program_dyn(n, k, DType::F16, &cfg);
        let mut bind = HashMap::new();
        bind.insert(mvar.id, m);
        let sp = specialize(&prog, &bind);
        let lowered = compile(&sp, &Device::h100(), &CompileOptions::default()).unwrap();
        let vm = compile_lowered(&lowered).unwrap();
        vm.validate().unwrap();
        let (a, b, c) = (
            lowered.params[0].id,
            lowered.params[1].id,
            lowered.params[2].id,
        );
        let mut tv: Tensors = Tensors::new();
        tv.insert(a, test_data(m * k, 0xD0));
        tv.insert(b, test_data(k * n, 0xD1));
        let mut ti = tv.clone();
        vm.run(&mut tv).unwrap();
        super::super::interp::Interp::new(&lowered)
            .unwrap()
            .run(&mut ti)
            .unwrap();
        assert_eq!(tv[&c], ti[&c], "dyn-M tail diverges from interp");
        let counts = vm.write_counts(c).unwrap();
        assert!(counts.iter().all(|&x| x == 1), "tail rows double- or un-written");
    }

    #[test]
    fn op_counts_are_static_and_track_the_gemm_volume() {
        let lowered = lowered_matmul(64, 64, 64);
        let vm = compile_lowered(&lowered).unwrap();
        let oc = vm.op_counts();
        // tensor-independent: the shadow pass never reads data
        assert_eq!(oc, vm.op_counts());
        assert!(oc.gemm_tiles > 0, "a matmul must execute gemm tiles");
        assert!(oc.copy_tiles > 0, "tiles are loaded and stored via copies");
        // the grid tiles 64x64x64 exactly, so gemm flops cover at least
        // the full 2*M*N*K mac volume
        assert!(
            oc.f32_ops >= 2 * 64 * 64 * 64,
            "gemm flops {} below the 2MNK volume",
            oc.f32_ops
        );
        // every output element is written once through a copy/elems
        // path, so at least out reads+writes move through memory
        let c = lowered.params[2].id;
        let writes: u64 = vm.write_counts(c).unwrap().iter().sum();
        assert_eq!(writes, 64 * 64);
        assert!(
            oc.bytes_moved >= 8 * writes,
            "bytes_moved {} below the output write volume",
            oc.bytes_moved
        );
        assert_eq!(oc.total_tiles(), oc.copy_tiles + oc.gemm_tiles + oc.elems_tiles
            + oc.reduce_tiles + oc.dequant_tiles);
        // counter names are stable (the obs layer keys on them)
        let items = oc.items();
        assert_eq!(items[1].0, "vm.gemm_tiles");
        assert_eq!(items[1].1, oc.gemm_tiles);
    }

    #[test]
    fn traffic_shadow_matches_the_interpreters_dynamic_count() {
        let lowered = lowered_matmul(64, 64, 64);
        let vm = compile_lowered(&lowered).unwrap();
        let shadow = vm.traffic();
        // input-independent and repeatable
        assert_eq!(shadow, vm.traffic());
        let (a, b) = (lowered.params[0].id, lowered.params[1].id);
        let mut t: Tensors = Tensors::new();
        t.insert(a, test_data(64 * 64, 0xC0));
        t.insert(b, test_data(64 * 64, 0xC1));
        let dynamic = super::super::interp::Interp::new(&lowered)
            .unwrap()
            .run_traffic(&mut t)
            .unwrap();
        assert_eq!(
            shadow, dynamic,
            "static traffic shadow diverges from the interpreter's dynamic count"
        );
        // a tiled matmul stages operands DRAM -> shared -> fragments:
        // every tier must see movement, and GEMM flops dominate
        assert!(shadow.dram_rd_bytes >= 4 * 2 * 64 * 64, "operand loads");
        assert!(shadow.dram_wr_bytes >= 4 * 64 * 64, "output store");
        assert!(shadow.shared_rd_bytes > 0 && shadow.shared_wr_bytes > 0);
        assert!(shadow.frag_rd_bytes > 0 && shadow.frag_wr_bytes > 0);
        assert!(shadow.flops >= 2 * 64 * 64 * 64);
        assert!(shadow.arith_intensity() > 0.0);
    }
}
