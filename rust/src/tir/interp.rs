//! Interpreter for lowered programs — the semantic oracle, and the
//! always-available execution backend of the serving layer
//! (`runtime::ExecBackend::Interp` executes requests through this
//! module, so deployments work in an offline, dependency-free build).
//!
//! Executes a `LoweredProgram` block-by-block on the CPU with:
//! * physical shared memory (accesses go through the inferred layouts, so
//!   an aliasing layout corrupts results),
//! * per-thread register files for fragments (reads check *ownership*:
//!   if layout inference failed to replicate a broadcast operand, the
//!   reading thread does not own the cell and execution errors — the
//!   Fig. 7 invariant, enforced dynamically),
//! * async-copy queue semantics (`commit`/`wait` groups): a mis-scheduled
//!   pipeline reads stale tiles and produces wrong numbers,
//! * dtype rounding on every store (fp16/bf16 storage effects).

use std::collections::HashMap;

use crate::ir::buffer::{BufferId, MemScope};
use crate::ir::dtype::{fp4_e2m1_decode, round_to_dtype, DType, NF4_TABLE};
use crate::ir::expr::{BinOp, Expr, ExprKind, UnOp, VarId};
use crate::ir::program::{AtomicKind, DequantScheme, ReduceKind};
use crate::layout::fragment::Fragment;
use crate::layout::layout::domain_iter;
use crate::obs::traffic::{Tier, Traffic};

use super::compile::elem_value_cost;
use super::{LoweredProgram, RegionRef, TStmt};

/// Dense tensor storage for interpreter runs: logical row-major f32
/// (sub-byte packed buffers hold their *byte* codes as values 0..255).
pub type Tensors = HashMap<BufferId, Vec<f32>>;

struct BlockState {
    /// physical shared storage: buf -> values (slots * cells_per_slot)
    shared: HashMap<BufferId, Vec<f32>>,
    /// fragment registers: buf -> values (num_threads * locals)
    regs: HashMap<BufferId, Vec<f32>>,
    /// pending async copy groups (stmt clone + env snapshot)
    pending: Vec<Vec<(TStmt, HashMap<VarId, i64>)>>,
    current_group: Vec<(TStmt, HashMap<VarId, i64>)>,
    /// dynamic data-movement counters, one add per executed op on its
    /// logical extents — must agree bit-exactly with the compiled
    /// static shadow (`CompiledProgram::traffic`)
    traffic: Traffic,
}

/// Cached per-buffer metadata.
struct Meta {
    scope: MemScope,
    dtype: DType,
    shape: Vec<i64>,
    frag: Option<Fragment>,
    /// dense physical-address table for shared layouts (hot path)
    layout_table: Option<Vec<i64>>,
    slots_cells: i64,
    locals: i64,
    frag_threads: i64,
}

impl Meta {
    #[inline]
    fn phys(&self, idx: &[i64]) -> i64 {
        let mut flat = 0i64;
        for (d, &i) in idx.iter().enumerate() {
            flat = flat * self.shape[d] + i;
        }
        self.layout_table.as_ref().unwrap()[flat as usize]
    }
}

pub struct Interp<'a> {
    prog: &'a LoweredProgram,
    meta: HashMap<BufferId, Meta>,
}

impl<'a> Interp<'a> {
    pub fn new(prog: &'a LoweredProgram) -> Result<Interp<'a>, String> {
        let mut meta = HashMap::new();
        for b in &prog.params {
            let shape = b
                .static_shape()
                .ok_or_else(|| format!("param {} must be static for execution", b.name))?;
            meta.insert(
                b.id,
                Meta {
                    scope: MemScope::Global,
                    dtype: b.dtype,
                    shape,
                    frag: None,
                    layout_table: None,
                    slots_cells: 0,
                    locals: 0,
                    frag_threads: 0,
                },
            );
        }
        for s in &prog.shared {
            let l = prog.layout.shared_layout(s.buf).clone();
            meta.insert(
                s.buf,
                Meta {
                    scope: MemScope::Shared,
                    dtype: dtype_of(prog, s.buf),
                    shape: l.input_shape(),
                    layout_table: Some(l.table()),
                    frag: None,
                    slots_cells: s.cells_per_slot * s.slots,
                    locals: 0,
                    frag_threads: 0,
                },
            );
        }
        for f in &prog.frags {
            let fr = prog.layout.fragment(f.buf).to_table();
            meta.insert(
                f.buf,
                Meta {
                    scope: MemScope::Fragment,
                    dtype: dtype_of(prog, f.buf),
                    shape: fr.shape.clone(),
                    frag: Some(fr.clone()),
                    layout_table: None,
                    slots_cells: 0,
                    locals: f.locals_per_thread,
                    frag_threads: fr.num_threads,
                },
            );
        }
        Ok(Interp { prog, meta })
    }

    fn m(&self, buf: BufferId) -> &Meta {
        self.meta
            .get(&buf)
            .unwrap_or_else(|| panic!("no metadata for buffer {}", buf))
    }

    /// Which traffic tier a buffer's storage lives in.
    fn tier_of(&self, buf: BufferId) -> Tier {
        match self.m(buf).scope {
            MemScope::Global => Tier::Dram,
            MemScope::Shared | MemScope::SharedDyn => Tier::Shared,
            MemScope::Fragment => Tier::Fragment,
            MemScope::Local => unreachable!("locals are not addressable buffers"),
        }
    }

    /// Execute the whole grid. `tensors` maps every global param id to
    /// row-major f32 contents (created if missing, zero-filled).
    pub fn run(&self, tensors: &mut Tensors) -> Result<(), String> {
        self.run_traffic(tensors).map(|_| ())
    }

    /// [`Interp::run`] returning the run's dynamically counted
    /// data-movement accounting: per-tier read/write bytes and FLOPs on
    /// the logical extents of every executed op (the conventions in
    /// [`crate::obs::traffic`]). For any program the compiler accepts,
    /// this equals `CompiledProgram::traffic()` bit-exactly.
    pub fn run_traffic(&self, tensors: &mut Tensors) -> Result<Traffic, String> {
        let grid = self
            .prog
            .static_grid()
            .ok_or("grid must be static for execution (specialize first)")?;
        for b in &self.prog.params {
            let n = self.m(b.id).shape.iter().product::<i64>() as usize;
            let t = tensors.entry(b.id).or_insert_with(|| vec![0.0; n]);
            if t.len() != n {
                return Err(format!(
                    "tensor for {} has {} elements, expected {}",
                    b.name,
                    t.len(),
                    n
                ));
            }
        }
        let total: i64 = grid.iter().product();
        let mut traffic = Traffic::default();
        for flat in 0..total {
            let mut rem = flat;
            let mut env: HashMap<VarId, i64> = HashMap::new();
            for (d, v) in self.prog.block_vars.iter().enumerate() {
                let e = grid[d];
                env.insert(v.id, rem % e);
                rem /= e;
            }
            let mut st = BlockState {
                shared: self
                    .prog
                    .shared
                    .iter()
                    .map(|s| (s.buf, vec![0.0f32; (s.cells_per_slot * s.slots) as usize]))
                    .collect(),
                regs: self
                    .prog
                    .frags
                    .iter()
                    .map(|f| {
                        let m = self.m(f.buf);
                        (
                            f.buf,
                            vec![0.0f32; (m.frag_threads * f.locals_per_thread) as usize],
                        )
                    })
                    .collect(),
                pending: Vec::new(),
                current_group: Vec::new(),
                traffic: Traffic::default(),
            };
            self.exec_stmts(&self.prog.body, &mut env, &mut st, tensors)?;
            // flush any remaining async copies (epilogue safety)
            self.drain_async(0, &mut st, tensors)?;
            traffic.merge(&st.traffic);
        }
        Ok(traffic)
    }

    fn exec_stmts(
        &self,
        stmts: &[TStmt],
        env: &mut HashMap<VarId, i64>,
        st: &mut BlockState,
        tensors: &mut Tensors,
    ) -> Result<(), String> {
        for s in stmts {
            self.exec_stmt(s, env, st, tensors)?;
        }
        Ok(())
    }

    fn exec_stmt(
        &self,
        s: &TStmt,
        env: &mut HashMap<VarId, i64>,
        st: &mut BlockState,
        tensors: &mut Tensors,
    ) -> Result<(), String> {
        match s {
            TStmt::For {
                var, extent, body, ..
            } => {
                let e = extent.eval_int(env);
                for i in 0..e {
                    env.insert(var.id, i);
                    self.exec_stmts(body, env, st, tensors)?;
                }
                env.remove(&var.id);
                Ok(())
            }
            TStmt::If {
                cond,
                then_body,
                else_body,
            } => {
                if cond.eval_int(env) != 0 {
                    self.exec_stmts(then_body, env, st, tensors)
                } else {
                    self.exec_stmts(else_body, env, st, tensors)
                }
            }
            TStmt::Copy { binding, .. } => {
                if binding.is_async {
                    st.current_group.push((s.clone(), env.clone()));
                    Ok(())
                } else {
                    self.exec_copy(s, env, st, tensors)
                }
            }
            TStmt::AsyncCommit => {
                let g = std::mem::take(&mut st.current_group);
                st.pending.push(g);
                Ok(())
            }
            TStmt::AsyncWait(n) => self.drain_async(*n, st, tensors),
            TStmt::Barrier => Ok(()), // lockstep execution: no-op numerically
            TStmt::Fill { buf, value } => {
                let m = self.m(*buf);
                // whole-storage write: cells*slots for shared tiles,
                // logical cells for fragments (matching the compiled
                // Fill's `len` exactly)
                let len: u64 = match m.scope {
                    MemScope::Fragment => m.shape.iter().product::<i64>() as u64,
                    _ => m.slots_cells as u64,
                };
                st.traffic.add_wr(self.tier_of(*buf), 4 * len);
                let v = round_to_dtype(*value as f32, m.dtype);
                match m.scope {
                    MemScope::Fragment => {
                        for x in st.regs.get_mut(buf).unwrap().iter_mut() {
                            *x = v;
                        }
                    }
                    _ => {
                        for x in st.shared.get_mut(buf).unwrap().iter_mut() {
                            *x = v;
                        }
                    }
                }
                Ok(())
            }
            TStmt::Gemm {
                a,
                b,
                c,
                trans_a,
                trans_b,
                ..
            } => self.exec_gemm(a, b, *c, *trans_a, *trans_b, env, st, tensors),
            TStmt::Reduce {
                src,
                dst,
                dim,
                kind,
                clear,
            } => self.exec_reduce(*src, *dst, *dim, *kind, *clear, st),
            TStmt::Dequant {
                src,
                dst,
                scheme,
                scale,
                group_size,
            } => self.exec_dequant(*src, *dst, *scheme, *scale, *group_size, st),
            TStmt::Atomic { dst, src, kind } => self.exec_atomic(dst, *src, *kind, env, st, tensors),
            TStmt::Parallel {
                vars,
                extents,
                body,
                ..
            } => self.exec_parallel(vars, extents, body, env, st, tensors),
        }
    }

    fn drain_async(
        &self,
        keep: usize,
        st: &mut BlockState,
        tensors: &mut Tensors,
    ) -> Result<(), String> {
        while st.pending.len() > keep {
            let group = st.pending.remove(0);
            for (stmt, genv) in group {
                let mut env = genv.clone();
                self.exec_copy(&stmt, &mut env, st, tensors)?;
            }
        }
        Ok(())
    }

    // ---- element accessors ------------------------------------------

    fn global_linear(&self, m: &Meta, idx: &[i64]) -> Option<usize> {
        let mut addr = 0i64;
        for (d, &i) in idx.iter().enumerate() {
            if i < 0 || i >= m.shape[d] {
                return None; // out-of-bounds: predicated off
            }
            addr = addr * m.shape[d] + i;
        }
        Some(addr as usize)
    }

    fn read_elem(
        &self,
        buf: BufferId,
        idx: &[i64],
        slot: i64,
        exec_thread: Option<i64>,
        st: &BlockState,
        tensors: &Tensors,
    ) -> Result<f32, String> {
        let m = self.m(buf);
        match m.scope {
            MemScope::Global => Ok(self
                .global_linear(m, idx)
                .map(|a| tensors[&buf][a])
                .unwrap_or(0.0)),
            MemScope::Shared | MemScope::SharedDyn => {
                let phys = m.phys(idx) + slot * (m.slots_cells / self.slots_of(buf));
                Ok(st.shared[&buf][phys as usize])
            }
            MemScope::Fragment => {
                let f = m.frag.as_ref().unwrap();
                let owners = f.owners(idx);
                let (t, l) = match exec_thread {
                    Some(et) => *owners.iter().find(|(t, _)| *t == et).ok_or_else(|| {
                        format!(
                            "thread {} reads cell {:?} of buffer {} it does not own \
                             (owners: {:?}) — layout inference failed to replicate",
                            et, idx, buf, owners
                        )
                    })?,
                    None => owners[0],
                };
                Ok(st.regs[&buf][(t * m.locals + l) as usize])
            }
            MemScope::Local => unreachable!("locals are not addressable buffers"),
        }
    }

    fn slots_of(&self, buf: BufferId) -> i64 {
        self.prog
            .shared
            .iter()
            .find(|s| s.buf == buf)
            .map(|s| s.slots)
            .unwrap_or(1)
    }

    fn write_elem(
        &self,
        buf: BufferId,
        idx: &[i64],
        slot: i64,
        value: f32,
        st: &mut BlockState,
        tensors: &mut Tensors,
    ) {
        let m = self.m(buf);
        let v = round_to_dtype(value, m.dtype);
        match m.scope {
            MemScope::Global => {
                if let Some(a) = self.global_linear(m, idx) {
                    tensors.get_mut(&buf).unwrap()[a] = v;
                }
            }
            MemScope::Shared | MemScope::SharedDyn => {
                let cells = m.slots_cells / self.slots_of(buf);
                let phys = m.phys(idx) + slot * cells;
                st.shared.get_mut(&buf).unwrap()[phys as usize] = v;
            }
            MemScope::Fragment => {
                let f = m.frag.as_ref().unwrap();
                let regs = st.regs.get_mut(&buf).unwrap();
                for (t, l) in f.owners(idx) {
                    regs[(t * m.locals + l) as usize] = v;
                }
            }
            MemScope::Local => unreachable!(),
        }
    }

    // ---- op executors -----------------------------------------------

    fn exec_copy(
        &self,
        s: &TStmt,
        env: &mut HashMap<VarId, i64>,
        st: &mut BlockState,
        tensors: &mut Tensors,
    ) -> Result<(), String> {
        let (src, dst) = match s {
            TStmt::Copy { src, dst, .. } => (src, dst),
            _ => unreachable!(),
        };
        let src_off: Vec<i64> = src.offsets.iter().map(|e| e.eval_int(env)).collect();
        let dst_off: Vec<i64> = dst.offsets.iter().map(|e| e.eval_int(env)).collect();
        let src_slot = src.slot.eval_int(env);
        let dst_slot = dst.slot.eval_int(env);
        let bytes = 4 * dst.shape.iter().product::<i64>() as u64;
        st.traffic.add_rd(self.tier_of(src.buf), bytes);
        st.traffic.add_wr(self.tier_of(dst.buf), bytes);
        // copies are tile-shaped; same cell count, possibly different rank
        for cell in domain_iter(&dst.shape) {
            let flat = flatten(&cell, &dst.shape);
            let scell = unflatten(flat, &src.shape);
            let sidx: Vec<i64> = scell.iter().zip(&src_off).map(|(c, o)| c + o).collect();
            let didx: Vec<i64> = cell.iter().zip(&dst_off).map(|(c, o)| c + o).collect();
            let v = self.read_elem(src.buf, &sidx, src_slot, None, st, tensors)?;
            self.write_elem(dst.buf, &didx, dst_slot, v, st, tensors);
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_gemm(
        &self,
        a: &RegionRef,
        b: &RegionRef,
        c: BufferId,
        trans_a: bool,
        trans_b: bool,
        env: &mut HashMap<VarId, i64>,
        st: &mut BlockState,
        tensors: &mut Tensors,
    ) -> Result<(), String> {
        let (sa, sb) = (&a.shape, &b.shape);
        let (m, k) = if trans_a {
            (sa[1], sa[0])
        } else {
            (sa[0], sa[1])
        };
        let n = if trans_b { sb[0] } else { sb[1] };
        st.traffic.add_rd(self.tier_of(a.buf), 4 * (m * k) as u64);
        st.traffic.add_rd(self.tier_of(b.buf), 4 * (n * k) as u64);
        // the fragment accumulator is read-modify-written in place
        st.traffic.frag_rd_bytes += 4 * (m * n) as u64;
        st.traffic.frag_wr_bytes += 4 * (m * n) as u64;
        st.traffic.flops += 2 * (m * n * k) as u64;
        let a_slot = a.slot.eval_int(env);
        let b_slot = b.slot.eval_int(env);
        let cm = self.m(c);
        let cf = cm.frag.as_ref().expect("gemm accumulator must be a fragment");
        for i in 0..m {
            for j in 0..n {
                let mut acc = self.read_elem(c, &[i, j], 0, None, st, tensors)?;
                for kk in 0..k {
                    let ai = if trans_a { vec![kk, i] } else { vec![i, kk] };
                    let bi = if trans_b { vec![j, kk] } else { vec![kk, j] };
                    let av = self.read_elem(a.buf, &ai, a_slot, None, st, tensors)?;
                    let bv = self.read_elem(b.buf, &bi, b_slot, None, st, tensors)?;
                    acc += av * bv;
                }
                let regs = st.regs.get_mut(&c).unwrap();
                for (t, l) in cf.owners(&[i, j]) {
                    regs[(t * cm.locals + l) as usize] = acc;
                }
            }
        }
        Ok(())
    }

    fn exec_reduce(
        &self,
        src: BufferId,
        dst: BufferId,
        dim: usize,
        kind: ReduceKind,
        clear: bool,
        st: &mut BlockState,
    ) -> Result<(), String> {
        let sm = self.m(src);
        let dm = self.m(dst);
        let sf = sm.frag.as_ref().ok_or("reduce src must be fragment")?;
        let df = dm.frag.as_ref().ok_or("reduce dst must be fragment")?;
        let out_n: u64 = df.shape.iter().product::<i64>() as u64;
        let red_n = sf.shape[dim] as u64;
        st.traffic.frag_rd_bytes += 4 * out_n * red_n;
        if !clear {
            st.traffic.frag_rd_bytes += 4 * out_n;
        }
        st.traffic.frag_wr_bytes += 4 * out_n;
        st.traffic.flops += out_n * red_n;
        for out in domain_iter(&df.shape) {
            let init = if clear {
                match kind {
                    ReduceKind::Sum => 0.0f32,
                    ReduceKind::Max => f32::NEG_INFINITY,
                    ReduceKind::Min => f32::INFINITY,
                    ReduceKind::AbsMax => 0.0,
                }
            } else {
                let (t, l) = df.owners(&out)[0];
                st.regs[&dst][(t * dm.locals + l) as usize]
            };
            let mut acc = init;
            for r in 0..sf.shape[dim] {
                let mut idx = out.clone();
                if sf.ndim() == out.len() {
                    // dst kept a dummy dim
                    idx = out.clone();
                    idx[dim] = r;
                } else {
                    idx.insert(dim, r);
                }
                let (t, l) = sf.owners(&idx)[0];
                let v = st.regs[&src][(t * sm.locals + l) as usize];
                acc = match kind {
                    ReduceKind::Sum => acc + v,
                    ReduceKind::Max => acc.max(v),
                    ReduceKind::Min => acc.min(v),
                    ReduceKind::AbsMax => acc.max(v.abs()),
                };
            }
            let regs = st.regs.get_mut(&dst).unwrap();
            let v = round_to_dtype(acc, dm.dtype);
            for (t, l) in df.owners(&out) {
                regs[(t * dm.locals + l) as usize] = v;
            }
        }
        Ok(())
    }

    fn exec_dequant(
        &self,
        src: BufferId,
        dst: BufferId,
        scheme: DequantScheme,
        scale: Option<BufferId>,
        group_size: i64,
        st: &mut BlockState,
    ) -> Result<(), String> {
        let dm = self.m(dst);
        let df = dm.frag.as_ref().ok_or("dequant dst must be fragment")?;
        let sm = self.m(src);
        let bits = match scheme {
            DequantScheme::UintAffine { .. } => {
                // bits derivable from shape ratio
                let epb = df.shape[1] / sm.shape[1];
                (8 / epb) as u32
            }
            DequantScheme::Nf4Lut | DequantScheme::Fp4E2m1 => 4,
        };
        let epb = (8 / bits) as i64;
        let mask = (1u32 << bits) - 1;
        let (rows, cols) = (df.shape[0], df.shape[1]);
        let elems = (rows * cols) as u64;
        st.traffic
            .add_rd(self.tier_of(src), 4 * (rows * cols.div_ceil(epb)) as u64);
        if let Some(sc) = scale {
            st.traffic
                .add_rd(self.tier_of(sc), 4 * (rows * cols.div_ceil(group_size)) as u64);
        }
        st.traffic.frag_wr_bytes += 4 * elems;
        st.traffic.flops += elems;
        for cell in domain_iter(&df.shape) {
            let (i, j) = (cell[0], cell[1]);
            let byte_idx = vec![i, j / epb];
            let byte = self.frag_or_shared_read(src, &byte_idx, st)? as u32;
            let code = (byte >> (((j % epb) as u32) * bits)) & mask;
            let base = match scheme {
                DequantScheme::UintAffine { zero } => code as f32 - zero as f32,
                DequantScheme::Nf4Lut => NF4_TABLE[code as usize],
                DequantScheme::Fp4E2m1 => fp4_e2m1_decode(code as u8),
            };
            let s = match scale {
                Some(sc) => {
                    let sidx = vec![i, j / group_size];
                    self.frag_or_shared_read(sc, &sidx, st)?
                }
                None => 1.0,
            };
            let v = round_to_dtype(base * s, dm.dtype);
            let regs = st.regs.get_mut(&dst).unwrap();
            for (t, l) in df.owners(&cell) {
                regs[(t * dm.locals + l) as usize] = v;
            }
        }
        Ok(())
    }

    fn frag_or_shared_read(
        &self,
        buf: BufferId,
        idx: &[i64],
        st: &BlockState,
    ) -> Result<f32, String> {
        let m = self.m(buf);
        match m.scope {
            MemScope::Fragment => {
                let f = m.frag.as_ref().unwrap();
                let (t, l) = f.owners(idx)[0];
                Ok(st.regs[&buf][(t * m.locals + l) as usize])
            }
            MemScope::Shared | MemScope::SharedDyn => {
                Ok(st.shared[&buf][m.phys(idx) as usize])
            }
            _ => Err("dequant operand must be on-chip".into()),
        }
    }

    fn exec_atomic(
        &self,
        dst: &RegionRef,
        src: BufferId,
        kind: AtomicKind,
        env: &mut HashMap<VarId, i64>,
        st: &mut BlockState,
        tensors: &mut Tensors,
    ) -> Result<(), String> {
        let off: Vec<i64> = dst.offsets.iter().map(|e| e.eval_int(env)).collect();
        let dm = self.m(dst.buf);
        let count: u64 = dst.shape.iter().product::<i64>() as u64;
        st.traffic.add_rd(self.tier_of(src), 4 * count);
        // destination is read-modify-written
        st.traffic.add_rd(self.tier_of(dst.buf), 4 * count);
        st.traffic.add_wr(self.tier_of(dst.buf), 4 * count);
        st.traffic.flops += count;
        for cell in domain_iter(&dst.shape) {
            let didx: Vec<i64> = cell.iter().zip(&off).map(|(c, o)| c + o).collect();
            let sv = self.read_elem(src, &cell, 0, None, st, tensors)?;
            if let Some(a) = self.global_linear(dm, &didx) {
                let t = tensors.get_mut(&dst.buf).unwrap();
                let cur = t[a];
                t[a] = round_to_dtype(
                    match kind {
                        AtomicKind::Add => cur + sv,
                        AtomicKind::Max => cur.max(sv),
                        AtomicKind::Min => cur.min(sv),
                    },
                    dm.dtype,
                );
            }
        }
        Ok(())
    }

    fn exec_parallel(
        &self,
        vars: &[crate::ir::expr::Var],
        extents: &[i64],
        body: &[crate::ir::program::ElemStmt],
        env: &mut HashMap<VarId, i64>,
        st: &mut BlockState,
        tensors: &mut Tensors,
    ) -> Result<(), String> {
        // Charge traffic once up front from the *logical* extents, using
        // the same constant-folding rules the compiler's value tapes
        // apply (`elem_value_cost`), so both halves count identically.
        // env carries no parallel-var bindings yet — same as emit time.
        let axes: HashMap<VarId, usize> =
            vars.iter().enumerate().map(|(i, v)| (v.id, i)).collect();
        let total: u64 = extents.iter().product::<i64>() as u64;
        for es in body {
            let mut loads = Vec::new();
            let ops = elem_value_cost(&es.value, env, &axes, &mut loads)?;
            for b in loads {
                st.traffic.add_rd(self.tier_of(b), 4 * total);
            }
            st.traffic.add_wr(self.tier_of(es.dst), 4 * total);
            st.traffic.flops += total * ops;
        }
        for point in domain_iter(extents) {
            for (v, &p) in vars.iter().zip(&point) {
                env.insert(v.id, p);
            }
            for es in body {
                let idx: Vec<i64> = es.indices.iter().map(|e| e.eval_int(env)).collect();
                let dm = self.m(es.dst);
                match dm.scope {
                    MemScope::Fragment => {
                        let owners = dm.frag.as_ref().unwrap().owners(&idx);
                        // each owning thread computes the value itself —
                        // its loads must resolve within its own registers
                        let mut vals = Vec::with_capacity(owners.len());
                        for (t, _) in &owners {
                            vals.push(self.eval_value(&es.value, env, Some(*t), st, tensors)?);
                        }
                        let regs = st.regs.get_mut(&es.dst).unwrap();
                        for ((t, l), v) in owners.iter().zip(vals) {
                            regs[(t * dm.locals + l) as usize] = round_to_dtype(v, dm.dtype);
                        }
                    }
                    _ => {
                        let v = self.eval_value(&es.value, env, None, st, tensors)?;
                        self.write_elem(es.dst, &idx, 0, v, st, tensors);
                    }
                }
            }
        }
        for v in vars {
            env.remove(&v.id);
        }
        Ok(())
    }

    /// Evaluate a scalar value expression (element-wise bodies).
    fn eval_value(
        &self,
        e: &Expr,
        env: &HashMap<VarId, i64>,
        exec_thread: Option<i64>,
        st: &BlockState,
        tensors: &Tensors,
    ) -> Result<f32, String> {
        Ok(match e.kind() {
            ExprKind::Var(v) => *env
                .get(&v.id)
                .unwrap_or_else(|| panic!("unbound var {} in value", v.name))
                as f32,
            ExprKind::Int(v) => *v as f32,
            ExprKind::Float(v) => *v as f32,
            ExprKind::Load(buf, idx) => {
                let i: Vec<i64> = idx.iter().map(|x| x.eval_int(env)).collect();
                self.read_elem(*buf, &i, 0, exec_thread, st, tensors)?
            }
            ExprKind::Bin(op, a, b) => {
                let (x, y) = (
                    self.eval_value(a, env, exec_thread, st, tensors)?,
                    self.eval_value(b, env, exec_thread, st, tensors)?,
                );
                match op {
                    BinOp::Add => x + y,
                    BinOp::Sub => x - y,
                    BinOp::Mul => x * y,
                    BinOp::FloorDiv => (x / y).floor(),
                    BinOp::FloorMod => x - (x / y).floor() * y,
                    BinOp::Min => x.min(y),
                    BinOp::Max => x.max(y),
                    BinOp::Lt => (x < y) as i32 as f32,
                    BinOp::Le => (x <= y) as i32 as f32,
                    BinOp::Eq => (x == y) as i32 as f32,
                    BinOp::And => ((x != 0.0) && (y != 0.0)) as i32 as f32,
                    BinOp::Or => ((x != 0.0) || (y != 0.0)) as i32 as f32,
                    BinOp::BitXor | BinOp::BitAnd | BinOp::Shl | BinOp::Shr => {
                        return Err("bitwise op in float value".into())
                    }
                }
            }
            ExprKind::Un(op, a) => {
                let x = self.eval_value(a, env, exec_thread, st, tensors)?;
                match op {
                    UnOp::Neg => -x,
                    UnOp::Exp => x.exp(),
                    UnOp::Exp2 => x.exp2(),
                    UnOp::Log => x.ln(),
                    UnOp::Sqrt => x.sqrt(),
                    UnOp::Rsqrt => 1.0 / x.sqrt(),
                    UnOp::Abs => x.abs(),
                    UnOp::Tanh => x.tanh(),
                    UnOp::Not => (x == 0.0) as i32 as f32,
                }
            }
            ExprKind::Select(c, t, f) => {
                if self.eval_value(c, env, exec_thread, st, tensors)? != 0.0 {
                    self.eval_value(t, env, exec_thread, st, tensors)?
                } else {
                    self.eval_value(f, env, exec_thread, st, tensors)?
                }
            }
            ExprKind::Cast(dt, a) => {
                round_to_dtype(self.eval_value(a, env, exec_thread, st, tensors)?, *dt)
            }
        })
    }
}

fn flatten(idx: &[i64], shape: &[i64]) -> i64 {
    let mut f = 0;
    for (d, &i) in idx.iter().enumerate() {
        f = f * shape[d] + i;
    }
    f
}

fn unflatten(mut flat: i64, shape: &[i64]) -> Vec<i64> {
    let mut idx = vec![0i64; shape.len()];
    for d in (0..shape.len()).rev() {
        idx[d] = flat % shape[d];
        flat /= shape[d];
    }
    idx
}

fn dtype_of(prog: &LoweredProgram, buf: BufferId) -> DType {
    if let Some(b) = prog.params.iter().find(|b| b.id == buf) {
        return b.dtype;
    }
    if let Some(s) = prog.shared.iter().find(|s| s.buf == buf) {
        return s.dtype;
    }
    if let Some(f) = prog.frags.iter().find(|f| f.buf == buf) {
        return f.dtype;
    }
    DType::F32
}
