//! The graph layer: multi-kernel dataflow graphs with epilogue fusion
//! and planned buffer reuse, served end to end.
//!
//! The paper's central claim is that AI kernels are *composable tiled
//! dataflow* — this subsystem makes the composition explicit above the
//! single-kernel layer:
//!
//! * [`ir`] — `KernelGraph`: nodes are workload tile programs (plus a
//!   fused epilogue vocabulary from `workloads::epilogue`) or standalone
//!   element-wise ops, edges are typed f32 tensors; ships builders for
//!   real scenarios (`mlp_block`, `attention_block`,
//!   `dequant_mlp_block`) and a CPU-reference composition oracle.
//! * [`fuse`] — the fusion planner: folds element-wise consumers into
//!   producer-kernel epilogues where the tile shapes admit it, costed by
//!   `sim::simulate_kernel` per node plus a DRAM-traffic + launch term
//!   per materialized edge.
//! * [`memplan`] — liveness-based buffer planning: intermediates with
//!   disjoint live ranges share allocations; the executor allocates
//!   from this plan, so it is enforced, not advisory.
//! * [`exec`] — [`GraphKernel`]: topological execution through the
//!   interp backend, tile configs per node via the persistent tuning
//!   cache.
//!
//! Serving integration lives in `runtime` (manifest `graph=` artifacts
//! load as `GraphKernel`s) and the CLI (`tilelang graph` prints the
//! plan; `serve` accepts graph artifacts).

pub mod exec;
pub mod fuse;
pub mod ir;
pub mod memplan;

pub use exec::GraphKernel;
pub use fuse::FusionPlan;
pub use ir::KernelGraph;
