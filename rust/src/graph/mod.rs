//! The graph layer: multi-kernel dataflow graphs with epilogue fusion
//! and planned buffer reuse, served end to end.
//!
//! The paper's central claim is that AI kernels are *composable tiled
//! dataflow* — this subsystem makes the composition explicit above the
//! single-kernel layer:
//!
//! * [`ir`] — `KernelGraph`: nodes are workload tile programs (plus a
//!   fused epilogue vocabulary from `workloads::epilogue`) or standalone
//!   element-wise ops, edges are typed f32 tensors; ships builders for
//!   real scenarios (`mlp_block`, `attention_block`, `dequant_mlp_block`
//!   and the KV-cache `decode_block`) and a CPU-reference composition
//!   oracle.
//! * [`fuse`] — the fusion planner: folds element-wise consumers into
//!   producer epilogues where the tile shapes admit it — GEMM-family
//!   accumulators take the full vocabulary, attention-family O tiles the
//!   element-wise subset (e.g. a block residual folded into the flash
//!   kernel's O epilogue) — costed by `sim::simulate_kernel` per node
//!   plus a DRAM-traffic + launch term per materialized edge.
//! * [`memplan`] — liveness-based buffer planning: intermediates with
//!   disjoint live ranges share allocations; the executor allocates
//!   from this plan, so it is enforced, not advisory.
//! * [`exec`] — [`GraphKernel`]: topological execution through the
//!   interp backend, tile configs per node via the persistent tuning
//!   cache.
//!
//! Serving integration lives in `runtime` (manifest `graph=` artifacts
//! load as `GraphKernel`s — or, on the sharded backend, as
//! `shard::graph::ShardedGraphKernel`s running the fused block per
//! shard) and the CLI (`tilelang graph` prints the plan; `serve` accepts
//! graph artifacts at any shard count). See `docs/SERVING.md` for the
//! operator flows.
//!
//! The whole load-plan-execute loop, against the reference oracle:
//!
//! ```
//! use tilelang::graph::GraphKernel;
//! use tilelang::graph::ir::mlp_block;
//! use tilelang::runtime::InterpOptions;
//! use tilelang::workloads::matmul::test_data;
//!
//! let g = mlp_block(32, 32, 32);
//! let opts = InterpOptions { tune: false, ..Default::default() };
//! let kernel = GraphKernel::prepare(&g, &opts, &std::env::temp_dir()).unwrap();
//! assert!(!kernel.fusions().is_empty()); // biases + GELU fold into the GEMMs
//!
//! let inputs = vec![
//!     test_data(32 * 32, 1), // X
//!     test_data(32 * 32, 2), // W1
//!     test_data(32, 3),      // B1
//!     test_data(32 * 32, 4), // W2
//!     test_data(32, 5),      // B2
//! ];
//! let got = kernel.execute(&inputs).unwrap();
//! let want = g.reference_execute(&inputs).unwrap();
//! for (g_, w) in got.iter().zip(&want) {
//!     assert!((g_ - w).abs() < 0.06 + 0.02 * w.abs());
//! }
//! ```

pub mod exec;
pub mod fuse;
pub mod ir;
pub mod memplan;

pub use exec::GraphKernel;
pub use fuse::FusionPlan;
pub use ir::KernelGraph;
