//! Graph execution on the interp backend: topological node order, tile
//! configs per node selected through the persistent tuning cache, and
//! intermediates placed by the liveness [`crate::graph::memplan`] so
//! disjoint live ranges share allocations.
//!
//! [`GraphKernel`] is the graph analogue of the interp backend's
//! per-artifact kernel: `prepare` runs the fusion planner, builds one
//! lowered program per kernel node (through the same tuning-cache ->
//! builder -> `passes::lower` path single-kernel artifacts use), and
//! computes the buffer plan; `execute` walks the nodes, feeding each
//! node's output into its assigned pool buffer via
//! `InterpKernel::execute_into` — the reuse is physical, so a broken
//! plan fails the differential tests instead of mis-reporting a number.

use std::path::Path;

use crate::error::Result;
use crate::graph::fuse::{self, FusedEdge};
use crate::graph::ir::{GraphNode, KernelGraph, NodeOp, ValueRef};
use crate::graph::memplan::{self, MemPlan};
use crate::ir::program::TileProgram;
use crate::obs::{Recorder, Traffic};
use crate::runtime::interp_backend::{
    attention_config, decode_config, dequant_config, gemm_config, paged_decode_config,
    InterpKernel,
};
use crate::runtime::{ArtifactSpec, InterpOptions, WorkloadKind};
use crate::sim::device::Device;
use crate::sim::model::{simulate_kernel, Penalties};
use crate::workloads::attention::{
    flash_attention_program_ep, flash_decode_paged_program, flash_decode_program,
};
use crate::workloads::dequant::dequant_matmul_program_ep;
use crate::workloads::epilogue::reference_apply;
use crate::workloads::matmul::matmul_program_ep;
use crate::workloads::shapes::AttnShape;
use crate::{anyhow, bail};

/// Build the tile program a kernel node executes: workload builder +
/// fused epilogues, tile config through the tuning cache (or the static
/// defaults when `opts.tune` is off).
pub(crate) fn node_program(
    node: &GraphNode,
    dev: &Device,
    opts: &InterpOptions,
    dir: &Path,
) -> Result<TileProgram> {
    let kind = match &node.op {
        NodeOp::Kernel(kind) => kind,
        NodeOp::Elementwise(op) => {
            bail!("{}: element-wise node {} has no tile program", node.name, op.describe())
        }
    };
    if node.epilogues.is_empty() {
        // no epilogues: reuse the exact artifact path (validation + all
        // five families, chunk kernels included)
        let spec = node_spec(node, kind);
        return crate::runtime::interp_backend::build_program(kind, &spec, dev, opts, dir);
    }
    match kind {
        WorkloadKind::Gemm => {
            let (a, b) = (&node.in_shapes[0], &node.in_shapes[1]);
            let (m, k, n) = (a[0], a[1], b[1]);
            let cfg = gemm_config(m, n, k, dev, opts, dir)
                .map_err(|e| anyhow!("{}: {}", node.name, e))?;
            // the builder asserts tileability; graphs with sub-tile
            // shapes must surface as errors, not panics
            if m % cfg.block_m != 0 || n % cfg.block_n != 0 || k % cfg.block_k != 0 {
                bail!(
                    "{}: gemm {}x{}x{} is not tileable by {}x{}x{}",
                    node.name, m, n, k, cfg.block_m, cfg.block_n, cfg.block_k
                );
            }
            Ok(matmul_program_ep(
                m,
                n,
                k,
                crate::ir::dtype::DType::F16,
                &cfg,
                &node.epilogues,
            ))
        }
        WorkloadKind::Dequant { fmt, group } => {
            let a = &node.in_shapes[0];
            let (m, k) = (a[0], a[1]);
            let n = node.in_shapes[1][0];
            let cfg = dequant_config(m, n, k, *fmt, *group, dev, opts, dir)
                .map_err(|e| anyhow!("{}: {}", node.name, e))?;
            if m % cfg.block_m != 0 || n % cfg.block_n != 0 || k % cfg.block_k != 0 {
                bail!(
                    "{}: dequant {}x{}x{} is not tileable by {}x{}x{}",
                    node.name, m, n, k, cfg.block_m, cfg.block_n, cfg.block_k
                );
            }
            Ok(dequant_matmul_program_ep(m, n, k, *fmt, &cfg, &node.epilogues))
        }
        WorkloadKind::FlashAttention { causal } => {
            let q = &node.in_shapes[0];
            let (bh, seq, d) = (q[0], q[1], q[2]);
            let shape = AttnShape {
                name: "graph-node",
                batch: 1,
                heads: bh,
                seq_len: seq,
                head_dim: d,
                causal: *causal,
            };
            let cfg = attention_config(shape, dev, opts, dir)
                .map_err(|e| anyhow!("{}: {}", node.name, e))?;
            Ok(flash_attention_program_ep(
                bh,
                seq,
                d,
                *causal,
                &cfg,
                &node.epilogues,
            ))
        }
        WorkloadKind::FlashDecode => {
            let q = &node.in_shapes[0];
            let (b, h, d) = (q[0], q[1], q[2]);
            let kv = node.in_shapes[1][1];
            let cfg = decode_config(b, h, kv, d, dev, opts, dir)
                .map_err(|e| anyhow!("{}: {}", node.name, e))?;
            Ok(flash_decode_program(b, h, kv, d, &cfg, &node.epilogues))
        }
        WorkloadKind::FlashDecodePaged => {
            let q = &node.in_shapes[0];
            let (b, h, d) = (q[0], q[1], q[2]);
            let kv = node.in_shapes[1][1];
            // pinned config — never tuned, never shape-adaptive, so a
            // stream's output is invariant under cache-view padding (the
            // serial-vs-batched bit-exactness the serving tests assert)
            let cfg =
                paged_decode_config(h, kv, d).map_err(|e| anyhow!("{}: {}", node.name, e))?;
            Ok(flash_decode_paged_program(b, h, kv, d, &cfg, &node.epilogues))
        }
        other => bail!(
            "{}: {} kernels take no fused epilogues",
            node.name,
            other.tag()
        ),
    }
}

/// Data-movement accounting for an element-wise node: `reference_apply`
/// streams every input once from DRAM, writes the output once, and
/// spends one flop per output element. One fixed formula used by both
/// the static shadow ([`GraphKernel::node_traffic`]) and the dynamic
/// recording in `execute_all_refs_rec`, so the two agree by
/// construction (kernel nodes get the real static-vs-dynamic cross
/// check from `tir`).
pub(crate) fn elementwise_traffic(node: &GraphNode) -> Traffic {
    let mut t = Traffic::default();
    for s in &node.in_shapes {
        t.dram_rd_bytes += 4 * s.iter().product::<i64>() as u64;
    }
    t.dram_wr_bytes += 4 * node.out_len() as u64;
    t.flops += node.out_len() as u64;
    t
}

/// A kernel node viewed as a single-kernel artifact spec (shape
/// contract checks reuse the interp backend's).
fn node_spec(node: &GraphNode, kind: &WorkloadKind) -> ArtifactSpec {
    ArtifactSpec {
        name: node.name.clone(),
        hlo_path: Path::new("-").to_path_buf(),
        in_shapes: node.in_shapes.clone(),
        out_shape: node.out_shape.clone(),
        workload: Some(kind.tag()),
        graph: None,
    }
}

/// Modeled cost of one node, µs: `sim::simulate_kernel` for kernel
/// nodes (static-default configs — uniform, cache-free costing), DRAM
/// traffic for element-wise nodes (read primary + operand, write out).
pub(crate) fn node_cost_us(node: &GraphNode, dev: &Device) -> Result<f64> {
    match &node.op {
        NodeOp::Kernel(_) => {
            let opts = InterpOptions {
                tune: false,
                ..Default::default()
            };
            let prog = node_program(node, dev, &opts, Path::new("."))?;
            let report = simulate_kernel(&prog, dev, &Penalties::none())
                .map_err(|e| anyhow!("{}: cost model: {}", node.name, e))?;
            Ok(report.time_us)
        }
        NodeOp::Elementwise(_) => {
            let elems: i64 = node
                .in_shapes
                .iter()
                .map(|s| s.iter().product::<i64>())
                .sum::<i64>()
                + node.out_len() as i64;
            // same formula as the model's element-wise helper, so the
            // fold-vs-launch tradeoff stays calibrated to LAUNCH_US
            Ok(crate::sim::model::elemwise_kernel_us(elems, dev))
        }
    }
}

/// A graph artifact resolved to per-node lowered programs plus the
/// fusion decision and buffer plan that connect them.
pub struct GraphKernel {
    graph: KernelGraph,
    fused: Vec<FusedEdge>,
    fused_cost_us: f64,
    unfused_cost_us: f64,
    memplan: MemPlan,
    /// One prepared kernel per kernel node (`None` for element-wise).
    kernels: Vec<Option<InterpKernel>>,
    /// The modeled device the kernels were prepared for (cost column).
    device: Device,
    in_shapes: Vec<Vec<i64>>,
    out_len: usize,
    /// Element counts of the extra outputs, declaration order.
    extra_out_lens: Vec<usize>,
}

impl GraphKernel {
    /// Run the fusion planner, then prepare every kernel node (tile
    /// configs through the tuning cache in `dir`) and the buffer plan.
    pub fn prepare(graph: &KernelGraph, opts: &InterpOptions, dir: &Path) -> Result<GraphKernel> {
        let dev = device(opts)?;
        let fp = fuse::plan(graph, &dev)
            .map_err(|e| anyhow!("{}: fusion planning: {}", graph.name, e))?;
        GraphKernel::from_planned(
            fp.graph,
            fp.fused,
            fp.fused_cost_us,
            fp.unfused_cost_us,
            &dev,
            opts,
            dir,
        )
    }

    /// Prepare without fusing — the unfused baseline of the differential
    /// tests and the CLI's `--no-fuse` view.
    pub fn prepare_unfused(
        graph: &KernelGraph,
        opts: &InterpOptions,
        dir: &Path,
    ) -> Result<GraphKernel> {
        let dev = device(opts)?;
        graph.validate()?;
        let cost = fuse::graph_cost_us(graph, &dev)?;
        GraphKernel::from_planned(graph.clone(), Vec::new(), cost, cost, &dev, opts, dir)
    }

    fn from_planned(
        graph: KernelGraph,
        fused: Vec<FusedEdge>,
        fused_cost_us: f64,
        unfused_cost_us: f64,
        dev: &Device,
        opts: &InterpOptions,
        dir: &Path,
    ) -> Result<GraphKernel> {
        let memplan = memplan::plan(&graph);
        let mut kernels = Vec::with_capacity(graph.nodes.len());
        for node in &graph.nodes {
            kernels.push(match &node.op {
                NodeOp::Kernel(kind) => {
                    let prog = node_program(node, dev, opts, dir)?;
                    Some(InterpKernel::from_program(
                        &prog,
                        &node_spec(node, kind),
                        dev,
                        opts.compiled,
                    )?)
                }
                NodeOp::Elementwise(_) => None,
            });
        }
        Ok(GraphKernel {
            in_shapes: graph.input_shapes(),
            out_len: graph.out_shape()?.iter().product::<i64>() as usize,
            extra_out_lens: graph
                .extra_out_shapes()?
                .iter()
                .map(|s| s.iter().product::<i64>() as usize)
                .collect(),
            graph,
            fused,
            fused_cost_us,
            unfused_cost_us,
            memplan,
            kernels,
            device: dev.clone(),
        })
    }

    /// The graph this kernel executes (post-fusion).
    pub fn graph(&self) -> &KernelGraph {
        &self.graph
    }

    /// Accepted folds from the fusion planner.
    pub fn fusions(&self) -> &[FusedEdge] {
        &self.fused
    }

    /// The buffer-reuse plan the executor allocates from.
    pub fn memplan(&self) -> &MemPlan {
        &self.memplan
    }

    /// Modeled (fused, unfused) graph cost, µs.
    pub fn modeled_cost_us(&self) -> (f64, f64) {
        (self.fused_cost_us, self.unfused_cost_us)
    }

    /// Per-node `(name, modeled µs)` pairs in execution order — the
    /// `model` column of `tilelang profile`. Kernel nodes are costed on
    /// their *prepared* lowered program (tuned config included);
    /// element-wise nodes use the fusion planner's DRAM-traffic model.
    /// `None` marks a node the simulator cannot cost.
    pub fn node_modeled_us(&self) -> Vec<(String, Option<f64>)> {
        self.graph
            .nodes
            .iter()
            .zip(&self.kernels)
            .map(|(node, kernel)| {
                let us = match kernel {
                    Some(k) => k.modeled_time_us(&self.device),
                    None => node_cost_us(node, &self.device).ok(),
                };
                (node.name.clone(), us)
            })
            .collect()
    }

    /// Static VM counters summed over every compiled kernel node (all
    /// zeros when the graph was prepared for the tree-walking interp).
    pub fn op_counts(&self) -> crate::tir::compile::OpCounts {
        let mut oc = crate::tir::compile::OpCounts::default();
        for kernel in self.kernels.iter().flatten() {
            if let Some(k) = kernel.op_counts() {
                oc.merge(&k);
            }
        }
        oc
    }

    /// Per-node static data-movement shadow, execution order — fused
    /// epilogues are attributed to their producer node because they
    /// execute inside its lowered program. Kernel nodes carry their
    /// `CompiledProgram::traffic` shadow (`None` on the tree-walking
    /// interp, which counts dynamically instead); element-wise nodes use
    /// the fixed [`elementwise_traffic`] formula.
    pub fn node_traffic(&self) -> Vec<(String, Option<Traffic>)> {
        self.graph
            .nodes
            .iter()
            .zip(&self.kernels)
            .map(|(node, kernel)| {
                let t = match kernel {
                    Some(k) => k.traffic(),
                    None => Some(elementwise_traffic(node)),
                };
                (node.name.clone(), t)
            })
            .collect()
    }

    /// Model-side op/byte counters per node: kernel nodes go through
    /// [`crate::sim::model::modeled_traffic`] (the lowered program's
    /// static shadow), element-wise nodes through the fixed
    /// [`elementwise_traffic`] formula. This is the quantity the
    /// differential guardrail pins against the dynamic counters.
    pub fn modeled_node_traffic_exact(&self) -> Vec<(String, Option<Traffic>)> {
        self.graph
            .nodes
            .iter()
            .zip(&self.kernels)
            .map(|(node, kernel)| {
                let t = match kernel {
                    Some(k) => k.modeled_traffic_exact(),
                    None => Some(elementwise_traffic(node)),
                };
                (node.name.clone(), t)
            })
            .collect()
    }

    /// Whole-graph modeled traffic (sum of
    /// [`GraphKernel::modeled_node_traffic_exact`] rows), `None` when a
    /// kernel node fails to compile to the VM.
    pub fn modeled_traffic_exact(&self) -> Option<Traffic> {
        let mut t = Traffic::default();
        for (_, node) in self.modeled_node_traffic_exact() {
            t.merge(&node?);
        }
        Some(t)
    }

    /// Whole-graph static data-movement shadow: the sum of every
    /// resolvable [`GraphKernel::node_traffic`] row. On the compiled
    /// backend this equals the `traffic.*` counters one recorded
    /// execution adds.
    pub fn traffic(&self) -> Traffic {
        let mut t = Traffic::default();
        for (_, node) in self.node_traffic() {
            if let Some(nt) = node {
                t.merge(&nt);
            }
        }
        t
    }

    /// Per-node `(name, modeled DRAM bytes)` predictions from the cost
    /// model — the denominators of `tilelang roofline`'s calibration
    /// column. Element-wise nodes use the fusion planner's streaming
    /// model (every input read once, the output written once).
    pub fn node_modeled_bytes(&self) -> Vec<(String, Option<f64>)> {
        self.graph
            .nodes
            .iter()
            .zip(&self.kernels)
            .map(|(node, kernel)| {
                let b = match kernel {
                    Some(k) => k.modeled_dram_bytes(&self.device),
                    None => {
                        let t = elementwise_traffic(node);
                        Some((t.dram_rd_bytes + t.dram_wr_bytes) as f64)
                    }
                };
                (node.name.clone(), b)
            })
            .collect()
    }

    /// Whether batched *row* serving is sound for this graph (every
    /// output row depends only on the matching row of input 0 — see
    /// [`KernelGraph::row_batchable`]). The coordinator's model workers
    /// refuse artifacts where this is false.
    pub fn row_batchable(&self) -> bool {
        self.graph.row_batchable()
    }

    /// One-line summary for serve output and logs.
    pub fn describe(&self) -> String {
        let kernels = self.kernels.iter().filter(|k| k.is_some()).count();
        format!(
            "{}: {} node(s) ({} kernel(s)), {} fusion(s), modeled {:.1} us fused vs {:.1} us \
             unfused, planned peak {} B vs {} B materialized",
            self.graph.name,
            self.graph.nodes.len(),
            kernels,
            self.fused.len(),
            self.fused_cost_us,
            self.unfused_cost_us,
            self.memplan.peak_bytes,
            self.memplan.intermediate_bytes
        )
    }

    /// Execute the graph on f32 inputs (manifest order).
    pub fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        self.execute_refs(&refs)
    }

    /// Like [`GraphKernel::execute`], over borrowed slices — the sharded
    /// graph backend shares replicated weight tensors across shard
    /// threads without copying them per shard. Returns the primary
    /// output only.
    pub fn execute_refs(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        Ok(self.execute_all_refs(inputs)?.swap_remove(0))
    }

    /// [`GraphKernel::execute_refs`] with spans recorded per node.
    pub fn execute_refs_rec(&self, inputs: &[&[f32]], rec: &Recorder) -> Result<Vec<f32>> {
        Ok(self.execute_all_refs_rec(inputs, rec)?.swap_remove(0))
    }

    /// Execute and return every surfaced tensor: the primary output
    /// first, then the extra outputs in declaration order — the serving
    /// engine reads a decode step's new K/V rows from here.
    pub fn execute_all_refs(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.execute_all_refs_rec(inputs, &Recorder::disabled())
    }

    /// [`GraphKernel::execute_all_refs`] under a [`Recorder`]: one
    /// `graph` span per node (annotated with the node's fused epilogue
    /// chain and memplan buffer id) plus the node's static VM counters.
    pub fn execute_all_refs_rec(
        &self,
        inputs: &[&[f32]],
        rec: &Recorder,
    ) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.in_shapes.len() {
            bail!(
                "graph {} expects {} inputs, got {}",
                self.graph.name,
                self.in_shapes.len(),
                inputs.len()
            );
        }
        for (i, (data, shape)) in inputs.iter().zip(&self.in_shapes).enumerate() {
            let want = shape.iter().product::<i64>() as usize;
            if data.len() != want {
                bail!(
                    "graph input {} length {} != shape {:?}",
                    i,
                    data.len(),
                    shape
                );
            }
        }
        let mut pool: Vec<Vec<f32>> = self.memplan.pool_bytes.iter().map(|_| Vec::new()).collect();
        let mut dedicated: Vec<Option<Vec<f32>>> = vec![None; self.graph.nodes.len()];
        for (i, node) in self.graph.nodes.iter().enumerate() {
            // take this node's output storage *before* borrowing the
            // operands: the memplan guarantees the assigned buffer holds
            // no live operand of this node
            let storage = match self.memplan.slots[i].buffer {
                Some(b) => std::mem::take(&mut pool[b]),
                None => Vec::new(),
            };
            let mut ops: Vec<&[f32]> = Vec::with_capacity(node.inputs.len());
            for v in &node.inputs {
                ops.push(match v {
                    ValueRef::Input(k) => inputs[*k],
                    ValueRef::Node(j) => match self.memplan.slots[*j].buffer {
                        Some(b) => pool[b].as_slice(),
                        None => dedicated[*j]
                            .as_ref()
                            .ok_or_else(|| {
                                anyhow!("{}: operand node {} not materialized", node.name, j)
                            })?
                            .as_slice(),
                    },
                });
            }
            let sp = rec.span_with("graph", &node.name, || {
                let mut args = vec![("graph".to_string(), self.graph.name.clone())];
                if !node.epilogues.is_empty() {
                    let eps: Vec<String> = node.epilogues.iter().map(|e| e.describe()).collect();
                    args.push(("epilogues".to_string(), eps.join("+")));
                }
                if let Some(b) = self.memplan.slots[i].buffer {
                    args.push(("buffer".to_string(), b.to_string()));
                }
                args
            });
            let (out, traffic) = match (&self.kernels[i], &node.op) {
                (Some(kernel), _) => kernel
                    .execute_into_traffic(&ops, storage)
                    .map_err(|e| anyhow!("{}: {}", node.name, e))?,
                (None, NodeOp::Elementwise(op)) => {
                    let mut out = storage;
                    out.clear();
                    out.extend_from_slice(ops[0]);
                    reference_apply(op, &mut out, ops.get(1).copied(), &node.out_shape)
                        .map_err(|e| anyhow!("{}: {}", node.name, e))?;
                    (out, elementwise_traffic(node))
                }
                (None, NodeOp::Kernel(_)) => {
                    bail!("{}: kernel node was not prepared", node.name)
                }
            };
            sp.finish_us();
            if rec.is_enabled() {
                if let Some(kernel) = &self.kernels[i] {
                    if let Some(oc) = kernel.op_counts() {
                        for (name, v) in oc.items() {
                            rec.add(name, v);
                        }
                    }
                }
                for (name, v) in traffic.items() {
                    rec.add(name, v);
                }
            }
            drop(ops);
            match self.memplan.slots[i].buffer {
                Some(b) => pool[b] = out,
                None => dedicated[i] = Some(out),
            }
        }
        // validation forbids duplicate output refs, so each surfaced
        // value can be moved out of its storage exactly once
        let mut fetch = |v: ValueRef| -> Result<Vec<f32>> {
            Ok(match v {
                ValueRef::Input(i) => inputs[i].to_vec(),
                ValueRef::Node(j) => match self.memplan.slots[j].buffer {
                    Some(b) => std::mem::take(&mut pool[b]),
                    None => dedicated[j]
                        .take()
                        .ok_or_else(|| anyhow!("graph output node {} was not materialized", j))?,
                },
            })
        };
        let out = fetch(self.graph.output)?;
        if out.len() != self.out_len {
            bail!(
                "graph output has {} values, manifest expects {}",
                out.len(),
                self.out_len
            );
        }
        let mut outs = vec![out];
        for (i, &e) in self.graph.extra_outputs.iter().enumerate() {
            let extra = fetch(e)?;
            if extra.len() != self.extra_out_lens[i] {
                bail!(
                    "graph extra output {} has {} values, expected {}",
                    i,
                    extra.len(),
                    self.extra_out_lens[i]
                );
            }
            outs.push(extra);
        }
        Ok(outs)
    }
}

fn device(opts: &InterpOptions) -> Result<Device> {
    Device::by_name(&opts.device)
        .ok_or_else(|| anyhow!("graph backend: unknown modeled device {:?}", opts.device))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::mlp_block;
    use crate::workloads::matmul::test_data;

    fn fast_opts() -> InterpOptions {
        InterpOptions {
            tune: false,
            ..Default::default()
        }
    }

    #[test]
    fn fused_mlp_matches_the_reference_composition() {
        let (m, dm, dh) = (64i64, 64, 128);
        let g = mlp_block(m, dm, dh);
        let inputs = vec![
            test_data(m * dm, 0x51),
            test_data(dm * dh, 0x52),
            test_data(dh, 0x53),
            test_data(dh * dm, 0x54),
            test_data(dm, 0x55),
        ];
        let want = g.reference_execute(&inputs).expect("reference");
        let dir = std::env::temp_dir().join(format!("tilelang-graph-exec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let fused = GraphKernel::prepare(&g, &fast_opts(), &dir).expect("prepare fused");
        assert!(!fused.fusions().is_empty());
        let got = fused.execute(&inputs).expect("fused execution");
        assert_eq!(got.len(), want.len());
        for (i, (g_, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g_ - w).abs() < 0.06 + 0.02 * w.abs(),
                "idx {}: fused {} vs reference {}",
                i,
                g_,
                w
            );
        }
        // unfused execution agrees too (kernel f16 rounding is shared)
        let unfused = GraphKernel::prepare_unfused(&g, &fast_opts(), &dir).expect("unfused");
        assert!(unfused.fusions().is_empty());
        let got_u = unfused.execute(&inputs).expect("unfused execution");
        for (g_, u) in got.iter().zip(&got_u) {
            assert!((g_ - u).abs() < 0.06, "fused {} vs unfused {}", g_, u);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn paged_decode_graph_surfaces_extras() {
        use crate::graph::ir::decode_block_paged;
        let (slots, heads, dh, max_kv) = (16i64, 16, 16, 32);
        let d_model = heads * dh;
        let g = decode_block_paged(slots, heads, dh, max_kv);
        let lens: Vec<f32> = (0..slots)
            .map(|i| if i == 2 { 0.0 } else { (16 + (i % 3) * 5) as f32 })
            .collect();
        let inputs = vec![
            test_data(slots * d_model, 0x81),
            test_data(d_model * d_model, 0x82),
            test_data(slots * max_kv * dh, 0x83),
            test_data(slots * max_kv * dh, 0x84),
            lens,
            test_data(d_model * dh, 0x85),
            test_data(d_model * dh, 0x86),
            test_data(d_model * d_model, 0x87),
            test_data(d_model, 0x88),
        ];
        let want = g.reference_execute_all(&inputs).expect("reference");
        let dir = std::env::temp_dir().join(format!("tilelang-graph-paged-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let k = GraphKernel::prepare_unfused(&g, &fast_opts(), &dir).expect("prepare");
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let outs = k.execute_all_refs(&refs).expect("execute");
        assert_eq!(outs.len(), 3);
        for (which, (got, want)) in outs.iter().zip(&want).enumerate() {
            assert_eq!(got.len(), want.len(), "output {}", which);
            for (i, (g_, w)) in got.iter().zip(want).enumerate() {
                assert!(
                    (g_ - w).abs() < 0.06 + 0.02 * w.abs(),
                    "output {} idx {}: {} vs {}",
                    which,
                    i,
                    g_,
                    w
                );
            }
        }
        // the primary-only path returns the same tensor
        assert_eq!(k.execute_refs(&refs).unwrap(), outs[0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kernel_count_and_describe() {
        let g = mlp_block(64, 64, 128);
        let dir = std::env::temp_dir().join(format!("tilelang-graph-desc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let k = GraphKernel::prepare(&g, &fast_opts(), &dir).expect("prepare");
        let d = k.describe();
        assert!(d.contains("fusion"), "{}", d);
        assert!(k.memplan().peak_bytes > 0);
        let (fused_us, unfused_us) = k.modeled_cost_us();
        assert!(fused_us > 0.0 && fused_us < unfused_us);
        // wrong input counts and lengths error instead of panicking
        assert!(k.execute(&[]).is_err());
        let mut bad = vec![vec![0.0; 1]; 5];
        bad[0] = vec![0.0; 64 * 64];
        assert!(k.execute(&bad).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
