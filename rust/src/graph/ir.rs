//! Dataflow-graph IR: multi-kernel computations over the workload
//! families, with typed edges carrying shapes/dtypes.
//!
//! A [`KernelGraph`] is a DAG in topological node order: every node's
//! operands reference graph inputs or *earlier* nodes. Nodes are either
//! kernel nodes (one of the `runtime::WorkloadKind` families, built by
//! the `workloads::*` tile-program builders, optionally carrying a fused
//! epilogue list) or element-wise nodes (one `EpilogueOp` applied to a
//! tensor — the unfused form that `graph::fuse` folds into producers).
//!
//! Edges are f32 wire tensors. A node may view an operand under a
//! different shape when the element counts match (row-major reshape,
//! e.g. a `[seq, d]` GEMM output feeding a `[1, seq, d]` attention
//! input); the declared per-operand `in_shapes` make that explicit.
//!
//! [`KernelGraph::reference_execute`] composes the f32 CPU references
//! node by node — the oracle for goldens and the differential tests.
//!
//! Ships builders for the paper-motivated scenarios: [`mlp_block`]
//! (GEMM+bias+GELU -> GEMM+bias+residual), [`attention_block`]
//! (QKV GEMMs -> flash attention -> output-proj+residual),
//! [`dequant_mlp_block`] (GEMM+bias+GELU -> dequant-GEMM+bias),
//! [`decode_block`] (autoregressive decode against a KV cache:
//! Q projection -> flash decode + residual-in-O -> out-proj + bias) and
//! [`decode_block_paged`] (the continuous-batching variant: masked
//! paged attention over gathered cache pages, with this step's new K/V
//! rows surfaced as *extra outputs* for the in-graph cache append).

use std::fs;
use std::path::Path;

use crate::error::{Context, Result};
use crate::ir::dtype::DType;
use crate::runtime::WorkloadKind;
use crate::util::json::Json;
use crate::workloads::attention::{
    reference_attention, reference_flash_decode, reference_flash_decode_paged,
};
use crate::workloads::dequant::{reference_dequant_matmul, WeightFormat};
use crate::workloads::epilogue::{reference_apply, Activation, EpilogueOp};
use crate::workloads::linear_attention::{reference_chunk_scan, reference_chunk_state};
use crate::workloads::matmul::reference_matmul;
use crate::{anyhow, bail};

/// A value flowing along a graph edge: a graph input or a node output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValueRef {
    Input(usize),
    Node(usize),
}

impl ValueRef {
    fn encode(&self) -> String {
        match self {
            ValueRef::Input(i) => format!("in:{}", i),
            ValueRef::Node(i) => format!("node:{}", i),
        }
    }

    fn decode(s: &str) -> Option<ValueRef> {
        if let Some(i) = s.strip_prefix("in:") {
            return Some(ValueRef::Input(i.parse().ok()?));
        }
        if let Some(i) = s.strip_prefix("node:") {
            return Some(ValueRef::Node(i.parse().ok()?));
        }
        None
    }
}

/// A graph input tensor (typed edge source).
#[derive(Clone, Debug)]
pub struct GraphInput {
    pub name: String,
    pub shape: Vec<i64>,
    /// Wire dtype. Graphs currently move f32 tensors end to end (the
    /// runtime's request format); compute dtypes live inside the tile
    /// programs.
    pub dtype: DType,
}

/// What a node computes.
#[derive(Clone, Debug)]
pub enum NodeOp {
    /// One workload-family kernel (tile program).
    Kernel(WorkloadKind),
    /// One element-wise operator over the primary input — the unfused
    /// form of an epilogue.
    Elementwise(EpilogueOp),
}

/// One graph node. `inputs` lists operands in program-parameter order:
/// for kernel nodes the workload's operands first, then one operand per
/// fused epilogue op that consumes a tensor; for element-wise nodes the
/// primary tensor and (for bias/residual) the operand.
#[derive(Clone, Debug)]
pub struct GraphNode {
    pub name: String,
    pub op: NodeOp,
    pub inputs: Vec<ValueRef>,
    /// The shape the node's program expects for each operand. May be a
    /// row-major reshape of the producer's shape (same element count).
    pub in_shapes: Vec<Vec<i64>>,
    /// Fused epilogue ops (kernel nodes only; populated by
    /// `graph::fuse`, or pre-seeded by a builder).
    pub epilogues: Vec<EpilogueOp>,
    pub out_shape: Vec<i64>,
    /// Wire dtype of the output edge.
    pub dtype: DType,
}

impl GraphNode {
    pub fn out_len(&self) -> usize {
        self.out_shape.iter().product::<i64>() as usize
    }

    /// One-line description for plans and the CLI.
    pub fn describe(&self) -> String {
        let op = match &self.op {
            NodeOp::Kernel(k) => k.tag(),
            NodeOp::Elementwise(e) => format!("ew:{}", e.describe()),
        };
        let eps = if self.epilogues.is_empty() {
            String::new()
        } else {
            format!(
                " + {}",
                self.epilogues
                    .iter()
                    .map(|e| e.describe())
                    .collect::<Vec<_>>()
                    .join(" + ")
            )
        };
        format!("{}: {}{} -> {:?}", self.name, op, eps, self.out_shape)
    }
}

/// A multi-kernel dataflow graph. `output` is the primary output tensor
/// (the runtime artifact contract: one request tensor out per execute).
/// `extra_outputs` names additional node values the executor must also
/// surface — e.g. a paged decode block's freshly projected K/V rows, so
/// the serving layer's cache append consumes in-graph values instead of
/// re-deriving them. Extras never replace the primary output; they ride
/// alongside it via `GraphKernel::execute_all_refs`.
#[derive(Clone, Debug)]
pub struct KernelGraph {
    pub name: String,
    pub inputs: Vec<GraphInput>,
    pub nodes: Vec<GraphNode>,
    pub output: ValueRef,
    pub extra_outputs: Vec<ValueRef>,
}

/// Number of primary (non-epilogue) operands a workload kernel takes.
pub fn kernel_input_count(kind: &WorkloadKind) -> usize {
    match kind {
        WorkloadKind::Gemm => 2,
        WorkloadKind::FlashAttention { .. } | WorkloadKind::FlashDecode => 3,
        // Q gather, K gather, V gather, per-stream lengths
        WorkloadKind::FlashDecodePaged => 4,
        WorkloadKind::Dequant { .. } => 3,
        WorkloadKind::ChunkState | WorkloadKind::ChunkScan => 3,
    }
}

impl KernelGraph {
    /// Shape of a value (input or node output).
    pub fn value_shape(&self, v: ValueRef) -> Result<&[i64]> {
        match v {
            ValueRef::Input(i) => Ok(&self
                .inputs
                .get(i)
                .ok_or_else(|| anyhow!("graph references unknown input {}", i))?
                .shape),
            ValueRef::Node(i) => Ok(&self
                .nodes
                .get(i)
                .ok_or_else(|| anyhow!("graph references unknown node {}", i))?
                .out_shape),
        }
    }

    fn value_elems(&self, v: ValueRef) -> Result<i64> {
        Ok(self.value_shape(v)?.iter().product())
    }

    /// The graph's output shape.
    pub fn out_shape(&self) -> Result<&[i64]> {
        self.value_shape(self.output)
    }

    /// Shapes of the graph inputs (manifest `in=` order).
    pub fn input_shapes(&self) -> Vec<Vec<i64>> {
        self.inputs.iter().map(|i| i.shape.clone()).collect()
    }

    /// How many node operands (plus the graph outputs, primary and
    /// extra) read this value.
    pub fn fan_out(&self, v: ValueRef) -> usize {
        let mut n = 0;
        for node in &self.nodes {
            n += node.inputs.iter().filter(|&&i| i == v).count();
        }
        if self.output == v {
            n += 1;
        }
        n += self.extra_outputs.iter().filter(|&&e| e == v).count();
        n
    }

    /// Is `v` surfaced by the executor — the primary output or one of
    /// the extras? Such values must keep dedicated storage (no pool
    /// reuse) and must not be folded away by fusion.
    pub fn is_output(&self, v: ValueRef) -> bool {
        self.output == v || self.extra_outputs.contains(&v)
    }

    /// Shapes of the extra outputs, in declaration order.
    pub fn extra_out_shapes(&self) -> Result<Vec<Vec<i64>>> {
        self.extra_outputs
            .iter()
            .map(|&v| Ok(self.value_shape(v)?.to_vec()))
            .collect()
    }

    /// Structural + shape validation: topological operand order, operand
    /// counts per node kind, element-count-compatible reshapes, epilogue
    /// operand shapes, and a reachable output.
    pub fn validate(&self) -> Result<()> {
        // shapes must be positive everywhere: a zero/negative dim would
        // pass element-count products and reach builder asserts
        for gi in &self.inputs {
            check_positive(&gi.name, &gi.shape)?;
        }
        for node in &self.nodes {
            check_positive(&node.name, &node.out_shape)?;
            for s in &node.in_shapes {
                check_positive(&node.name, s)?;
            }
        }
        // node names are identifiers in every plan, error and fusion
        // memo: duplicates would silently skip folds and misattribute
        // diagnostics
        for (i, node) in self.nodes.iter().enumerate() {
            if self.nodes[..i].iter().any(|n| n.name == node.name) {
                bail!("duplicate node name {:?}", node.name);
            }
        }
        for (i, node) in self.nodes.iter().enumerate() {
            if node.inputs.len() != node.in_shapes.len() {
                bail!(
                    "{}: {} operands but {} declared shapes",
                    node.name,
                    node.inputs.len(),
                    node.in_shapes.len()
                );
            }
            for &v in &node.inputs {
                if let ValueRef::Node(j) = v {
                    if j >= i {
                        bail!(
                            "{}: operand references node {} out of topological order",
                            node.name,
                            j
                        );
                    }
                }
            }
            for (k, (v, shape)) in node.inputs.iter().zip(&node.in_shapes).enumerate() {
                let have = self.value_elems(*v).with_context(|| node.name.clone())?;
                let want: i64 = shape.iter().product();
                if have != want {
                    bail!(
                        "{}: operand {} has {} elements, program expects {:?} ({})",
                        node.name,
                        k,
                        have,
                        shape,
                        want
                    );
                }
            }
            match &node.op {
                NodeOp::Kernel(kind) => {
                    let primary = kernel_input_count(kind);
                    let operands: usize = node
                        .epilogues
                        .iter()
                        .filter(|e| e.takes_operand())
                        .count();
                    if node.inputs.len() != primary + operands {
                        bail!(
                            "{}: {} kernel expects {} primary + {} epilogue operands, got {}",
                            node.name,
                            kind.tag(),
                            primary,
                            operands,
                            node.inputs.len()
                        );
                    }
                    // primary operand ranks per family: the program
                    // builders index dims positionally, so a wrong-rank
                    // shape from a hand-edited graph file must fail here
                    // rather than panic inside `node_program`
                    let ranks: &[usize] = match kind {
                        WorkloadKind::Gemm => &[2, 2],
                        WorkloadKind::FlashAttention { .. } | WorkloadKind::FlashDecode => {
                            &[3, 3, 3]
                        }
                        WorkloadKind::FlashDecodePaged => &[3, 3, 3, 1],
                        WorkloadKind::Dequant { .. } => &[2, 2, 2],
                        WorkloadKind::ChunkState | WorkloadKind::ChunkScan => &[3, 3, 2],
                    };
                    for (idx, want) in ranks.iter().enumerate() {
                        if node.in_shapes[idx].len() != *want {
                            bail!(
                                "{}: {} operand {} must be rank {}, got {:?}",
                                node.name,
                                kind.tag(),
                                idx,
                                want,
                                node.in_shapes[idx]
                            );
                        }
                    }
                    if let WorkloadKind::Gemm = kind {
                        if node.in_shapes[1][0] != node.in_shapes[0][1] {
                            bail!(
                                "{}: gemm K mismatch (A {:?}, B {:?})",
                                node.name,
                                node.in_shapes[0],
                                node.in_shapes[1]
                            );
                        }
                    }
                    let mut next = primary;
                    for ep in &node.epilogues {
                        check_epilogue_dim(&node.name, ep, &node.out_shape)?;
                        if let Some(want) = ep.operand_shape(&node.out_shape) {
                            let got = &node.in_shapes[next];
                            if *got != want {
                                bail!(
                                    "{}: epilogue {} operand shape {:?}, expected {:?}",
                                    node.name,
                                    ep.describe(),
                                    got,
                                    want
                                );
                            }
                            next += 1;
                        }
                    }
                }
                NodeOp::Elementwise(op) => {
                    if !node.epilogues.is_empty() {
                        bail!("{}: element-wise nodes carry no fused epilogues", node.name);
                    }
                    check_epilogue_dim(&node.name, op, &node.out_shape)?;
                    let want_operands = 1 + op.takes_operand() as usize;
                    if node.inputs.len() != want_operands {
                        bail!(
                            "{}: {} expects {} operand(s), got {}",
                            node.name,
                            op.describe(),
                            want_operands,
                            node.inputs.len()
                        );
                    }
                    if node.in_shapes[0] != node.out_shape {
                        bail!(
                            "{}: element-wise output {:?} != primary input {:?}",
                            node.name,
                            node.out_shape,
                            node.in_shapes[0]
                        );
                    }
                    if let Some(want) = op.operand_shape(&node.out_shape) {
                        if node.in_shapes[1] != want {
                            bail!(
                                "{}: {} operand shape {:?}, expected {:?}",
                                node.name,
                                op.describe(),
                                node.in_shapes[1],
                                want
                            );
                        }
                    }
                }
            }
        }
        self.value_shape(self.output).context("graph output")?;
        for (i, &e) in self.extra_outputs.iter().enumerate() {
            self.value_shape(e)
                .with_context(|| format!("graph extra output {}", i))?;
            if e == self.output {
                bail!("graph extra output {} duplicates the primary output", i);
            }
            if self.extra_outputs[..i].contains(&e) {
                bail!("graph extra output {} listed twice ({:?})", i, e);
            }
        }
        Ok(())
    }

    /// Conservative row-independence analysis for batched row serving:
    /// true only when every output row provably depends on just the
    /// matching row of graph input 0. Tracks which values carry the
    /// request rows along their dim 0: input 0 does; a GEMM propagates
    /// it when its A operand does (same leading extent, B not
    /// row-carrying), as do row-independent epilogues / element-wise ops
    /// (feature-dim bias, activation, scale, residual against another
    /// row-carrying value). Anything else — attention (mixes across the
    /// sequence), the transposed dequant output, chunk kernels, dim-0
    /// bias — stops the chain, so the coordinator refuses to micro-batch
    /// the artifact instead of serving rows computed from co-batched
    /// strangers.
    pub fn row_batchable(&self) -> bool {
        // multi-output graphs carry side-channel tensors (e.g. new K/V
        // rows) the row-serving reply format cannot return
        if !self.extra_outputs.is_empty() {
            return false;
        }
        let batch = match self.inputs.first() {
            Some(gi) => gi.shape[0],
            None => return false,
        };
        let mut carries = vec![false; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            let primary = carries_rows(&node.inputs[0], &carries);
            // a reshape that moves the row dimension breaks tracking
            let rows_intact = node.in_shapes[0].first() == Some(&batch)
                && node.out_shape.first() == Some(&batch);
            carries[i] = match &node.op {
                NodeOp::Kernel(WorkloadKind::Gemm) => {
                    primary
                        && rows_intact
                        && !carries_rows(&node.inputs[1], &carries)
                        && epilogues_row_independent(node, &carries)
                }
                // flash decode attends each stream (= request row) only
                // against its own row of the Q tensor and the cache
                // operands; as long as the caches are weight tensors (not
                // row-carrying values), output rows stay independent
                NodeOp::Kernel(WorkloadKind::FlashDecode) => {
                    primary
                        && rows_intact
                        && !carries_rows(&node.inputs[1], &carries)
                        && !carries_rows(&node.inputs[2], &carries)
                        && epilogues_row_independent(node, &carries)
                }
                NodeOp::Elementwise(op) => {
                    primary
                        && rows_intact
                        && ep_row_independent(op, node.inputs.get(1), &carries)
                }
                NodeOp::Kernel(_) => false,
            };
        }
        carries_rows(&self.output, &carries)
    }

    /// Execute the graph on the f32 CPU references, node by node with
    /// every edge materialized — the semantic oracle for goldens and the
    /// fused-vs-unfused differential tests. Returns the primary output.
    pub fn reference_execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let mut outs = self.reference_execute_all(inputs)?;
        Ok(outs.swap_remove(0))
    }

    /// Like [`KernelGraph::reference_execute`] but returns every
    /// surfaced tensor: the primary output first, then the extra
    /// outputs in declaration order.
    pub fn reference_execute_all(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.validate()?;
        if inputs.len() != self.inputs.len() {
            bail!(
                "graph {} expects {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        for (i, (data, gi)) in inputs.iter().zip(&self.inputs).enumerate() {
            let want = gi.shape.iter().product::<i64>() as usize;
            if data.len() != want {
                bail!(
                    "graph input {} has {} values, shape {:?} wants {}",
                    i,
                    data.len(),
                    gi.shape,
                    want
                );
            }
        }
        let mut values: Vec<Vec<f32>> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let ops: Vec<&[f32]> = node
                .inputs
                .iter()
                .map(|v| match v {
                    ValueRef::Input(i) => inputs[*i].as_slice(),
                    ValueRef::Node(j) => values[*j].as_slice(),
                })
                .collect();
            let mut out = match &node.op {
                NodeOp::Kernel(kind) => {
                    reference_kernel(kind, &node.in_shapes, &node.out_shape, &ops)
                        .with_context(|| node.name.clone())?
                }
                NodeOp::Elementwise(op) => {
                    let mut out = ops[0].to_vec();
                    reference_apply(op, &mut out, ops.get(1).copied(), &node.out_shape)
                        .map_err(|e| anyhow!("{}: {}", node.name, e))?;
                    out
                }
            };
            // fused epilogues run on the kernel result in graph order
            if let NodeOp::Kernel(kind) = &node.op {
                let mut next = kernel_input_count(kind);
                for ep in &node.epilogues {
                    let op_data = if ep.takes_operand() {
                        let d = ops[next];
                        next += 1;
                        Some(d)
                    } else {
                        None
                    };
                    reference_apply(ep, &mut out, op_data, &node.out_shape)
                        .map_err(|e| anyhow!("{}: {}", node.name, e))?;
                }
            }
            drop(ops);
            values.push(out);
        }
        let fetch = |v: ValueRef| match v {
            ValueRef::Input(i) => inputs[i].clone(),
            ValueRef::Node(j) => values[j].clone(),
        };
        let mut outs = vec![fetch(self.output)];
        outs.extend(self.extra_outputs.iter().map(|&e| fetch(e)));
        Ok(outs)
    }

    // ---- serialization (graph artifacts) -----------------------------

    pub fn to_json(&self) -> Json {
        let inputs = self
            .inputs
            .iter()
            .map(|i| {
                Json::Obj(vec![
                    ("name".into(), Json::Str(i.name.clone())),
                    ("shape".into(), shape_json(&i.shape)),
                    ("dtype".into(), Json::Str(i.dtype.to_string())),
                ])
            })
            .collect();
        let nodes = self
            .nodes
            .iter()
            .map(|n| {
                let mut fields = vec![("name".into(), Json::Str(n.name.clone()))];
                match &n.op {
                    NodeOp::Kernel(k) => {
                        fields.push(("kernel".into(), Json::Str(k.tag())));
                    }
                    NodeOp::Elementwise(e) => {
                        fields.push(("elementwise".into(), e.to_json()));
                    }
                }
                fields.push((
                    "inputs".into(),
                    Json::Arr(n.inputs.iter().map(|v| Json::Str(v.encode())).collect()),
                ));
                fields.push((
                    "in_shapes".into(),
                    Json::Arr(n.in_shapes.iter().map(|s| shape_json(s)).collect()),
                ));
                if !n.epilogues.is_empty() {
                    fields.push((
                        "epilogues".into(),
                        Json::Arr(n.epilogues.iter().map(|e| e.to_json()).collect()),
                    ));
                }
                fields.push(("out".into(), shape_json(&n.out_shape)));
                fields.push(("dtype".into(), Json::Str(n.dtype.to_string())));
                Json::Obj(fields)
            })
            .collect();
        let mut fields = vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("inputs".into(), Json::Arr(inputs)),
            ("nodes".into(), Json::Arr(nodes)),
            ("output".into(), Json::Str(self.output.encode())),
        ];
        // only written when present, so single-output artifacts keep
        // their pre-multi-output byte layout
        if !self.extra_outputs.is_empty() {
            fields.push((
                "extra_outputs".into(),
                Json::Arr(
                    self.extra_outputs
                        .iter()
                        .map(|v| Json::Str(v.encode()))
                        .collect(),
                ),
            ));
        }
        Json::Obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<KernelGraph> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("graph json missing name"))?
            .to_string();
        let mut inputs = Vec::new();
        for i in v
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("graph json missing inputs"))?
        {
            inputs.push(GraphInput {
                name: i
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("graph input missing name"))?
                    .to_string(),
                shape: i
                    .get("shape")
                    .and_then(Json::as_i64_arr)
                    .ok_or_else(|| anyhow!("graph input missing shape"))?,
                dtype: parse_wire_dtype(i.get("dtype").and_then(Json::as_str))?,
            });
        }
        let mut nodes = Vec::new();
        for n in v
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("graph json missing nodes"))?
        {
            let nname = n
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("graph node missing name"))?
                .to_string();
            let op = if let Some(tag) = n.get("kernel").and_then(Json::as_str) {
                NodeOp::Kernel(WorkloadKind::parse(tag)?)
            } else if let Some(e) = n.get("elementwise") {
                NodeOp::Elementwise(
                    EpilogueOp::from_json(e)
                        .ok_or_else(|| anyhow!("{}: bad elementwise op", nname))?,
                )
            } else {
                bail!("{}: node is neither kernel nor elementwise", nname);
            };
            let mut refs = Vec::new();
            for s in n
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{}: missing inputs", nname))?
            {
                let s = s.as_str().ok_or_else(|| anyhow!("{}: bad input ref", nname))?;
                refs.push(
                    ValueRef::decode(s).ok_or_else(|| anyhow!("{}: bad input ref {:?}", nname, s))?,
                );
            }
            let mut in_shapes = Vec::new();
            for s in n
                .get("in_shapes")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{}: missing in_shapes", nname))?
            {
                in_shapes.push(
                    s.as_i64_arr()
                        .ok_or_else(|| anyhow!("{}: bad in_shape", nname))?,
                );
            }
            let mut epilogues = Vec::new();
            if let Some(eps) = n.get("epilogues").and_then(Json::as_arr) {
                for e in eps {
                    epilogues.push(
                        EpilogueOp::from_json(e)
                            .ok_or_else(|| anyhow!("{}: bad epilogue", nname))?,
                    );
                }
            }
            nodes.push(GraphNode {
                name: nname.clone(),
                op,
                inputs: refs,
                in_shapes,
                epilogues,
                out_shape: n
                    .get("out")
                    .and_then(Json::as_i64_arr)
                    .ok_or_else(|| anyhow!("{}: missing out shape", nname))?,
                dtype: parse_wire_dtype(n.get("dtype").and_then(Json::as_str))?,
            });
        }
        let output = v
            .get("output")
            .and_then(Json::as_str)
            .and_then(ValueRef::decode)
            .ok_or_else(|| anyhow!("graph json missing output"))?;
        let mut extra_outputs = Vec::new();
        if let Some(extras) = v.get("extra_outputs").and_then(Json::as_arr) {
            for e in extras {
                let s = e
                    .as_str()
                    .ok_or_else(|| anyhow!("graph json: bad extra output ref"))?;
                extra_outputs.push(
                    ValueRef::decode(s)
                        .ok_or_else(|| anyhow!("graph json: bad extra output ref {:?}", s))?,
                );
            }
        }
        let g = KernelGraph {
            name,
            inputs,
            nodes,
            output,
            extra_outputs,
        };
        g.validate()?;
        Ok(g)
    }

    /// Read + validate a graph artifact file (`<name>.graph.json`).
    pub fn load(path: impl AsRef<Path>) -> Result<KernelGraph> {
        let path = path.as_ref();
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading graph artifact {:?}", path))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow!("parsing graph artifact {:?}: {}", path, e))?;
        KernelGraph::from_json(&v)
    }

    /// Write the graph artifact file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        fs::write(path, self.to_json().dump())
            .with_context(|| format!("writing graph artifact {:?}", path))
    }
}

fn shape_json(s: &[i64]) -> Json {
    Json::Arr(s.iter().map(|&d| Json::Num(d as f64)).collect())
}

fn check_positive(name: &str, shape: &[i64]) -> Result<()> {
    if shape.is_empty() || shape.iter().any(|&d| d <= 0) {
        bail!("{}: malformed shape {:?} (dims must be positive)", name, shape);
    }
    Ok(())
}

/// Does `v` carry the request rows along its dim 0? The single source
/// of truth for `row_batchable`'s tracking: graph input 0 does; node
/// outputs per the propagation table.
fn carries_rows(v: &ValueRef, carries: &[bool]) -> bool {
    match v {
        ValueRef::Input(i) => *i == 0,
        ValueRef::Node(j) => carries[*j],
    }
}

/// Are all of a kernel node's fused epilogues row-independent?
fn epilogues_row_independent(node: &GraphNode, carries: &[bool]) -> bool {
    let kind = match &node.op {
        NodeOp::Kernel(kind) => kind,
        NodeOp::Elementwise(_) => return true,
    };
    let mut next = kernel_input_count(kind);
    for ep in &node.epilogues {
        let operand = if ep.takes_operand() {
            let v = node.inputs.get(next);
            next += 1;
            v
        } else {
            None
        };
        if !ep_row_independent(ep, operand, carries) {
            return false;
        }
    }
    true
}

/// Is one epilogue / element-wise op independent across output rows?
/// Feature-dim bias, activation and scale are; a residual is when its
/// operand also carries the request rows; a dim-0 bias ties values to
/// absolute batch slots, which rotated request rows would scramble.
fn ep_row_independent(op: &EpilogueOp, operand: Option<&ValueRef>, carries: &[bool]) -> bool {
    match op {
        EpilogueOp::BiasAdd { dim } => *dim == 1,
        EpilogueOp::Activation(_) | EpilogueOp::Scale(_) => true,
        EpilogueOp::ResidualAdd => operand.map(|v| carries_rows(v, carries)).unwrap_or(false),
    }
}

/// A bias must index a real dimension of a rank-2 output — anything
/// else would sail past `operand_shape` (which returns `None` for an
/// out-of-range dim) and panic inside the builder asserts instead of
/// failing the load.
fn check_epilogue_dim(name: &str, op: &EpilogueOp, out_shape: &[i64]) -> Result<()> {
    if let EpilogueOp::BiasAdd { dim } = op {
        if out_shape.len() != 2 || *dim >= 2 {
            bail!(
                "{}: bias_add dim {} invalid for output {:?} (rank-2, dim < 2 required)",
                name,
                dim,
                out_shape
            );
        }
    }
    Ok(())
}

fn parse_wire_dtype(s: Option<&str>) -> Result<DType> {
    match s {
        None | Some("f32") => Ok(DType::F32),
        Some(other) => bail!("unsupported wire dtype {:?} (graphs move f32 tensors)", other),
    }
}

/// Execute one workload kernel on the CPU references. `ops` holds the
/// primary operand slices (flat f32) in program order.
fn reference_kernel(
    kind: &WorkloadKind,
    in_shapes: &[Vec<i64>],
    out_shape: &[i64],
    ops: &[&[f32]],
) -> Result<Vec<f32>> {
    match kind {
        WorkloadKind::Gemm => {
            let (a, b) = (&in_shapes[0], &in_shapes[1]);
            Ok(reference_matmul(ops[0], ops[1], a[0], b[1], a[1]))
        }
        WorkloadKind::FlashAttention { causal } => {
            let q = &in_shapes[0];
            Ok(reference_attention(
                ops[0], ops[1], ops[2], q[0], q[1], q[2], *causal,
            ))
        }
        WorkloadKind::FlashDecode => {
            let (q, k) = (&in_shapes[0], &in_shapes[1]);
            if k[0] != q[0] || k[2] != q[2] || in_shapes[2] != *k {
                bail!(
                    "flash_decode cache {:?}/{:?} does not match Q {:?}",
                    k,
                    in_shapes[2],
                    q
                );
            }
            Ok(reference_flash_decode(
                ops[0], ops[1], ops[2], q[0], q[1], k[1], q[2],
            ))
        }
        WorkloadKind::FlashDecodePaged => {
            let (q, k) = (&in_shapes[0], &in_shapes[1]);
            if k[0] != q[0] || k[2] != q[2] || in_shapes[2] != *k || in_shapes[3] != [q[0]] {
                bail!(
                    "flash_decode_paged cache {:?}/{:?} or lens {:?} does not match Q {:?}",
                    k,
                    in_shapes[2],
                    in_shapes[3],
                    q
                );
            }
            Ok(reference_flash_decode_paged(
                ops[0], ops[1], ops[2], ops[3], q[0], q[1], k[1], q[2],
            ))
        }
        WorkloadKind::Dequant { fmt, group } => {
            let (a, s) = (&in_shapes[0], &in_shapes[2]);
            let (m, k) = (a[0], a[1]);
            let n = in_shapes[1][0];
            if s[1] * group != k {
                bail!("dequant scales {:?} do not cover k {} at group {}", s, k, group);
            }
            Ok(reference_dequant_matmul(
                ops[0], ops[1], ops[2], m, n, k, *fmt, *group,
            ))
        }
        WorkloadKind::ChunkState => {
            let b = &in_shapes[0];
            let (bh, seq, n_state) = (b[0], b[1], b[2]);
            let p = in_shapes[1][2];
            let nchunks = out_shape[0] / bh;
            if nchunks <= 0 || seq % nchunks != 0 {
                bail!("chunk_state output {:?} does not tile seq {}", out_shape, seq);
            }
            Ok(reference_chunk_state(
                ops[0],
                ops[1],
                ops[2],
                bh,
                seq,
                n_state,
                p,
                seq / nchunks,
            ))
        }
        WorkloadKind::ChunkScan => {
            let c = &in_shapes[0];
            let (bh, seq, n_state) = (c[0], c[1], c[2]);
            let p = in_shapes[1][2];
            let nchunks = in_shapes[1][0] / bh;
            if nchunks <= 0 || seq % nchunks != 0 {
                bail!("chunk_scan state {:?} does not tile seq {}", in_shapes[1], seq);
            }
            Ok(reference_chunk_scan(
                ops[0],
                ops[1],
                ops[2],
                bh,
                seq,
                n_state,
                p,
                seq / nchunks,
            ))
        }
    }
}

// ---- scenario builders ---------------------------------------------

/// Transformer MLP block: `Y = X + B2 + gelu(X W1 + B1) W2` over a row
/// batch `X [m, d_model]`. Built *unfused* — one node per kernel and one
/// per element-wise op — so the fusion planner's folds are observable,
/// testable decisions.
pub fn mlp_block(m: i64, d_model: i64, d_hidden: i64) -> KernelGraph {
    let f32s = DType::F32;
    let inputs = vec![
        GraphInput { name: "X".into(), shape: vec![m, d_model], dtype: f32s },
        GraphInput { name: "W1".into(), shape: vec![d_model, d_hidden], dtype: f32s },
        GraphInput { name: "B1".into(), shape: vec![d_hidden], dtype: f32s },
        GraphInput { name: "W2".into(), shape: vec![d_hidden, d_model], dtype: f32s },
        GraphInput { name: "B2".into(), shape: vec![d_model], dtype: f32s },
    ];
    let nodes = vec![
        GraphNode {
            name: "ffn1".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Input(0), ValueRef::Input(1)],
            in_shapes: vec![vec![m, d_model], vec![d_model, d_hidden]],
            epilogues: vec![],
            out_shape: vec![m, d_hidden],
            dtype: f32s,
        },
        GraphNode {
            name: "bias1".into(),
            op: NodeOp::Elementwise(EpilogueOp::BiasAdd { dim: 1 }),
            inputs: vec![ValueRef::Node(0), ValueRef::Input(2)],
            in_shapes: vec![vec![m, d_hidden], vec![d_hidden]],
            epilogues: vec![],
            out_shape: vec![m, d_hidden],
            dtype: f32s,
        },
        GraphNode {
            name: "gelu".into(),
            op: NodeOp::Elementwise(EpilogueOp::Activation(Activation::Gelu)),
            inputs: vec![ValueRef::Node(1)],
            in_shapes: vec![vec![m, d_hidden]],
            epilogues: vec![],
            out_shape: vec![m, d_hidden],
            dtype: f32s,
        },
        GraphNode {
            name: "ffn2".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Node(2), ValueRef::Input(3)],
            in_shapes: vec![vec![m, d_hidden], vec![d_hidden, d_model]],
            epilogues: vec![],
            out_shape: vec![m, d_model],
            dtype: f32s,
        },
        GraphNode {
            name: "bias2".into(),
            op: NodeOp::Elementwise(EpilogueOp::BiasAdd { dim: 1 }),
            inputs: vec![ValueRef::Node(3), ValueRef::Input(4)],
            in_shapes: vec![vec![m, d_model], vec![d_model]],
            epilogues: vec![],
            out_shape: vec![m, d_model],
            dtype: f32s,
        },
        GraphNode {
            name: "residual".into(),
            op: NodeOp::Elementwise(EpilogueOp::ResidualAdd),
            inputs: vec![ValueRef::Node(4), ValueRef::Input(0)],
            in_shapes: vec![vec![m, d_model], vec![m, d_model]],
            epilogues: vec![],
            out_shape: vec![m, d_model],
            dtype: f32s,
        },
    ];
    KernelGraph {
        name: format!("mlp_block_{}x{}x{}", m, d_model, d_hidden),
        inputs,
        nodes,
        output: ValueRef::Node(5),
        extra_outputs: vec![],
    }
}

/// Single-head attention block: Q/K/V projections of `X [seq, d]`,
/// flash attention over the `[1, seq, d]` view, output projection with
/// a residual back to `X`. The rank-2 -> rank-3 operand reshapes are the
/// typed-edge case the graph IR makes explicit.
pub fn attention_block(seq: i64, d: i64, causal: bool) -> KernelGraph {
    let f32s = DType::F32;
    let proj = |name: &str, w: usize| GraphNode {
        name: name.into(),
        op: NodeOp::Kernel(WorkloadKind::Gemm),
        inputs: vec![ValueRef::Input(0), ValueRef::Input(w)],
        in_shapes: vec![vec![seq, d], vec![d, d]],
        epilogues: vec![],
        out_shape: vec![seq, d],
        dtype: f32s,
    };
    let inputs = vec![
        GraphInput { name: "X".into(), shape: vec![seq, d], dtype: f32s },
        GraphInput { name: "Wq".into(), shape: vec![d, d], dtype: f32s },
        GraphInput { name: "Wk".into(), shape: vec![d, d], dtype: f32s },
        GraphInput { name: "Wv".into(), shape: vec![d, d], dtype: f32s },
        GraphInput { name: "Wo".into(), shape: vec![d, d], dtype: f32s },
    ];
    let nodes = vec![
        proj("q_proj", 1),
        proj("k_proj", 2),
        proj("v_proj", 3),
        GraphNode {
            name: "attention".into(),
            op: NodeOp::Kernel(WorkloadKind::FlashAttention { causal }),
            inputs: vec![ValueRef::Node(0), ValueRef::Node(1), ValueRef::Node(2)],
            // [seq, d] projections viewed as single-head [1, seq, d];
            // the kernel's output keeps the rank-3 view and the output
            // projection reshapes it back — both sides of the typed-edge
            // reshape rule
            in_shapes: vec![vec![1, seq, d]; 3],
            epilogues: vec![],
            out_shape: vec![1, seq, d],
            dtype: f32s,
        },
        GraphNode {
            name: "out_proj".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Node(3), ValueRef::Input(4)],
            in_shapes: vec![vec![seq, d], vec![d, d]],
            epilogues: vec![],
            out_shape: vec![seq, d],
            dtype: f32s,
        },
        GraphNode {
            name: "residual".into(),
            op: NodeOp::Elementwise(EpilogueOp::ResidualAdd),
            inputs: vec![ValueRef::Node(4), ValueRef::Input(0)],
            in_shapes: vec![vec![seq, d], vec![seq, d]],
            epilogues: vec![],
            out_shape: vec![seq, d],
            dtype: f32s,
        },
    ];
    KernelGraph {
        name: format!("attention_block_{}x{}", seq, d),
        inputs,
        nodes,
        output: ValueRef::Node(5),
        extra_outputs: vec![],
    }
}

/// Dequant MLP: fp16 GEMM + bias + GELU feeding a weight-only-quantized
/// second layer (`Ct[n_out, m] = dequant(W2) @ h^T`) with a bias over
/// the transposed output's feature rows (dim 0).
pub fn dequant_mlp_block(
    m: i64,
    d_model: i64,
    d_hidden: i64,
    d_out: i64,
    fmt: WeightFormat,
    group: i64,
) -> KernelGraph {
    let f32s = DType::F32;
    let epb = fmt.elems_per_byte();
    let inputs = vec![
        GraphInput { name: "X".into(), shape: vec![m, d_model], dtype: f32s },
        GraphInput { name: "W1".into(), shape: vec![d_model, d_hidden], dtype: f32s },
        GraphInput { name: "B1".into(), shape: vec![d_hidden], dtype: f32s },
        GraphInput {
            name: "W2_packed".into(),
            shape: vec![d_out, d_hidden / epb],
            dtype: f32s,
        },
        GraphInput {
            name: "W2_scales".into(),
            shape: vec![d_out, d_hidden / group],
            dtype: f32s,
        },
        GraphInput { name: "B2".into(), shape: vec![d_out], dtype: f32s },
    ];
    let nodes = vec![
        GraphNode {
            name: "ffn1".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Input(0), ValueRef::Input(1)],
            in_shapes: vec![vec![m, d_model], vec![d_model, d_hidden]],
            epilogues: vec![],
            out_shape: vec![m, d_hidden],
            dtype: f32s,
        },
        GraphNode {
            name: "bias1".into(),
            op: NodeOp::Elementwise(EpilogueOp::BiasAdd { dim: 1 }),
            inputs: vec![ValueRef::Node(0), ValueRef::Input(2)],
            in_shapes: vec![vec![m, d_hidden], vec![d_hidden]],
            epilogues: vec![],
            out_shape: vec![m, d_hidden],
            dtype: f32s,
        },
        GraphNode {
            name: "gelu".into(),
            op: NodeOp::Elementwise(EpilogueOp::Activation(Activation::Gelu)),
            inputs: vec![ValueRef::Node(1)],
            in_shapes: vec![vec![m, d_hidden]],
            epilogues: vec![],
            out_shape: vec![m, d_hidden],
            dtype: f32s,
        },
        GraphNode {
            name: "ffn2_dequant".into(),
            op: NodeOp::Kernel(WorkloadKind::Dequant { fmt, group }),
            inputs: vec![ValueRef::Node(2), ValueRef::Input(3), ValueRef::Input(4)],
            in_shapes: vec![
                vec![m, d_hidden],
                vec![d_out, d_hidden / epb],
                vec![d_out, d_hidden / group],
            ],
            epilogues: vec![],
            out_shape: vec![d_out, m],
            dtype: f32s,
        },
        GraphNode {
            name: "bias2".into(),
            op: NodeOp::Elementwise(EpilogueOp::BiasAdd { dim: 0 }),
            inputs: vec![ValueRef::Node(3), ValueRef::Input(5)],
            in_shapes: vec![vec![d_out, m], vec![d_out]],
            epilogues: vec![],
            out_shape: vec![d_out, m],
            dtype: f32s,
        },
    ];
    KernelGraph {
        name: format!("dequant_mlp_{}x{}x{}", m, d_model, d_hidden),
        inputs,
        nodes,
        output: ValueRef::Node(4),
        extra_outputs: vec![],
    }
}

/// Autoregressive decode block over a KV cache: a micro-batch of
/// `streams` decode positions `X [streams, d_model]` runs
/// `Y = (X + MQA(X Wq, K_cache, V_cache)) Wo + Bo`, where every stream's
/// `heads = d_model / head_dim` query heads attend its own cached
/// keys/values (`[streams, past, head_dim]`, MQA-style shared cache per
/// stream; the serving layer appends/rolls the cache between steps — see
/// `rust/tests/graph_sharding.rs` for the two-step lifecycle).
///
/// Built *unfused*: the residual is a standalone element-wise node on
/// the attention output (the fusion planner folds it into the flash
/// kernel's O epilogue — the attention-family fold), and the output bias
/// folds into the out-projection GEMM. The `[streams, d_model]` <->
/// `[streams, heads, head_dim]` views on both sides of the attention
/// node are row-major reshapes along the typed edges.
pub fn decode_block(streams: i64, heads: i64, head_dim: i64, past: i64) -> KernelGraph {
    let f32s = DType::F32;
    let d_model = heads * head_dim;
    let inputs = vec![
        GraphInput { name: "X".into(), shape: vec![streams, d_model], dtype: f32s },
        GraphInput { name: "Wq".into(), shape: vec![d_model, d_model], dtype: f32s },
        GraphInput {
            name: "K_cache".into(),
            shape: vec![streams, past, head_dim],
            dtype: f32s,
        },
        GraphInput {
            name: "V_cache".into(),
            shape: vec![streams, past, head_dim],
            dtype: f32s,
        },
        GraphInput { name: "Wo".into(), shape: vec![d_model, d_model], dtype: f32s },
        GraphInput { name: "Bo".into(), shape: vec![d_model], dtype: f32s },
    ];
    let nodes = vec![
        GraphNode {
            name: "q_proj".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Input(0), ValueRef::Input(1)],
            in_shapes: vec![vec![streams, d_model], vec![d_model, d_model]],
            epilogues: vec![],
            out_shape: vec![streams, d_model],
            dtype: f32s,
        },
        GraphNode {
            name: "attn".into(),
            op: NodeOp::Kernel(WorkloadKind::FlashDecode),
            inputs: vec![ValueRef::Node(0), ValueRef::Input(2), ValueRef::Input(3)],
            // the projection's [streams, d_model] rows view as
            // [streams, heads, head_dim] query heads (row-major reshape)
            in_shapes: vec![
                vec![streams, heads, head_dim],
                vec![streams, past, head_dim],
                vec![streams, past, head_dim],
            ],
            epilogues: vec![],
            out_shape: vec![streams, heads, head_dim],
            dtype: f32s,
        },
        GraphNode {
            name: "attn_res".into(),
            op: NodeOp::Elementwise(EpilogueOp::ResidualAdd),
            // X viewed under the attention output's rank-3 shape — the
            // fold target for the flash kernel's O epilogue
            inputs: vec![ValueRef::Node(1), ValueRef::Input(0)],
            in_shapes: vec![
                vec![streams, heads, head_dim],
                vec![streams, heads, head_dim],
            ],
            epilogues: vec![],
            out_shape: vec![streams, heads, head_dim],
            dtype: f32s,
        },
        GraphNode {
            name: "out_proj".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Node(2), ValueRef::Input(4)],
            in_shapes: vec![vec![streams, d_model], vec![d_model, d_model]],
            epilogues: vec![],
            out_shape: vec![streams, d_model],
            dtype: f32s,
        },
        GraphNode {
            name: "bias_o".into(),
            op: NodeOp::Elementwise(EpilogueOp::BiasAdd { dim: 1 }),
            inputs: vec![ValueRef::Node(3), ValueRef::Input(5)],
            in_shapes: vec![vec![streams, d_model], vec![d_model]],
            epilogues: vec![],
            out_shape: vec![streams, d_model],
            dtype: f32s,
        },
    ];
    KernelGraph {
        name: format!("decode_block_{}x{}x{}", streams, d_model, past),
        inputs,
        nodes,
        output: ValueRef::Node(4),
        extra_outputs: vec![],
    }
}

/// Paged-cache decode block: the continuous-batching serving engine's
/// per-step graph. Like [`decode_block`], but (a) attention runs the
/// *masked* paged kernel — the K/V operands are gather buffers padded to
/// `max_kv` rows with a per-stream `Lens` vector masking the tail, so
/// slots at different sequence lengths co-batch in one launch — and (b)
/// the graph also projects this step's new K/V rows (`X Wk`, `X Wv`) and
/// surfaces them as extra outputs, so the engine appends cache rows from
/// in-graph values instead of re-deriving them host-side.
///
/// `slots` is the engine's fixed batch dimension (dead slots run with
/// `lens = 0` and produce exactly-zero attention output); `max_kv` is
/// the gather buffer's padded row count (multiple of 16).
pub fn decode_block_paged(slots: i64, heads: i64, head_dim: i64, max_kv: i64) -> KernelGraph {
    let f32s = DType::F32;
    let d_model = heads * head_dim;
    let inputs = vec![
        GraphInput { name: "X".into(), shape: vec![slots, d_model], dtype: f32s },
        GraphInput { name: "Wq".into(), shape: vec![d_model, d_model], dtype: f32s },
        GraphInput {
            name: "K_gather".into(),
            shape: vec![slots, max_kv, head_dim],
            dtype: f32s,
        },
        GraphInput {
            name: "V_gather".into(),
            shape: vec![slots, max_kv, head_dim],
            dtype: f32s,
        },
        GraphInput { name: "Lens".into(), shape: vec![slots], dtype: f32s },
        GraphInput { name: "Wk".into(), shape: vec![d_model, head_dim], dtype: f32s },
        GraphInput { name: "Wv".into(), shape: vec![d_model, head_dim], dtype: f32s },
        GraphInput { name: "Wo".into(), shape: vec![d_model, d_model], dtype: f32s },
        GraphInput { name: "Bo".into(), shape: vec![d_model], dtype: f32s },
    ];
    let nodes = vec![
        GraphNode {
            name: "q_proj".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Input(0), ValueRef::Input(1)],
            in_shapes: vec![vec![slots, d_model], vec![d_model, d_model]],
            epilogues: vec![],
            out_shape: vec![slots, d_model],
            dtype: f32s,
        },
        GraphNode {
            name: "attn".into(),
            op: NodeOp::Kernel(WorkloadKind::FlashDecodePaged),
            inputs: vec![
                ValueRef::Node(0),
                ValueRef::Input(2),
                ValueRef::Input(3),
                ValueRef::Input(4),
            ],
            in_shapes: vec![
                vec![slots, heads, head_dim],
                vec![slots, max_kv, head_dim],
                vec![slots, max_kv, head_dim],
                vec![slots],
            ],
            epilogues: vec![],
            out_shape: vec![slots, heads, head_dim],
            dtype: f32s,
        },
        GraphNode {
            name: "attn_res".into(),
            op: NodeOp::Elementwise(EpilogueOp::ResidualAdd),
            inputs: vec![ValueRef::Node(1), ValueRef::Input(0)],
            in_shapes: vec![
                vec![slots, heads, head_dim],
                vec![slots, heads, head_dim],
            ],
            epilogues: vec![],
            out_shape: vec![slots, heads, head_dim],
            dtype: f32s,
        },
        GraphNode {
            name: "out_proj".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Node(2), ValueRef::Input(7)],
            in_shapes: vec![vec![slots, d_model], vec![d_model, d_model]],
            epilogues: vec![],
            out_shape: vec![slots, d_model],
            dtype: f32s,
        },
        GraphNode {
            name: "bias_o".into(),
            op: NodeOp::Elementwise(EpilogueOp::BiasAdd { dim: 1 }),
            inputs: vec![ValueRef::Node(3), ValueRef::Input(8)],
            in_shapes: vec![vec![slots, d_model], vec![d_model]],
            epilogues: vec![],
            out_shape: vec![slots, d_model],
            dtype: f32s,
        },
        GraphNode {
            name: "k_new".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Input(0), ValueRef::Input(5)],
            in_shapes: vec![vec![slots, d_model], vec![d_model, head_dim]],
            epilogues: vec![],
            out_shape: vec![slots, head_dim],
            dtype: f32s,
        },
        GraphNode {
            name: "v_new".into(),
            op: NodeOp::Kernel(WorkloadKind::Gemm),
            inputs: vec![ValueRef::Input(0), ValueRef::Input(6)],
            in_shapes: vec![vec![slots, d_model], vec![d_model, head_dim]],
            epilogues: vec![],
            out_shape: vec![slots, head_dim],
            dtype: f32s,
        },
    ];
    KernelGraph {
        name: format!("decode_block_paged_{}x{}x{}", slots, d_model, max_kv),
        inputs,
        nodes,
        output: ValueRef::Node(4),
        extra_outputs: vec![ValueRef::Node(5), ValueRef::Node(6)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::matmul::test_data;

    #[test]
    fn builders_validate() {
        for g in [
            mlp_block(64, 64, 128),
            attention_block(128, 64, false),
            attention_block(128, 64, true),
            dequant_mlp_block(32, 64, 64, 64, WeightFormat::Int4, 32),
            decode_block(64, 16, 16, 64),
        ] {
            g.validate().unwrap_or_else(|e| panic!("{}: {}", g.name, e));
            assert!(g.out_shape().is_ok());
        }
    }

    #[test]
    fn json_round_trip_preserves_structure() {
        let g = mlp_block(64, 64, 128);
        let text = g.to_json().dump();
        let back = KernelGraph::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.name, g.name);
        assert_eq!(back.inputs.len(), g.inputs.len());
        assert_eq!(back.nodes.len(), g.nodes.len());
        assert_eq!(back.output, g.output);
        for (a, b) in back.nodes.iter().zip(&g.nodes) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.in_shapes, b.in_shapes);
            assert_eq!(a.out_shape, b.out_shape);
            assert_eq!(a.epilogues, b.epilogues);
        }
        // attention's rank-3 reshapes survive too
        let g = attention_block(128, 64, true);
        let back =
            KernelGraph::from_json(&Json::parse(&g.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.nodes[3].in_shapes[0], vec![1, 128, 64]);
    }

    #[test]
    fn validation_rejects_malformed_graphs() {
        // forward reference
        let mut g = mlp_block(64, 64, 128);
        g.nodes[0].inputs[0] = ValueRef::Node(3);
        assert!(g.validate().is_err());
        // element-count mismatch
        let mut g = mlp_block(64, 64, 128);
        g.nodes[0].in_shapes[0] = vec![64, 32];
        assert!(g.validate().is_err());
        // epilogue operand shape mismatch
        let mut g = mlp_block(64, 64, 128);
        g.nodes[1].in_shapes[1] = vec![64];
        assert!(g.validate().is_err());
    }

    #[test]
    fn reference_execute_composes_the_mlp() {
        let (m, dm, dh) = (8i64, 8i64, 16i64);
        let g = mlp_block(m, dm, dh);
        let x = test_data(m * dm, 1);
        let w1 = test_data(dm * dh, 2);
        let b1 = test_data(dh, 3);
        let w2 = test_data(dh * dm, 4);
        let b2 = test_data(dm, 5);
        let out = g
            .reference_execute(&[x.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone()])
            .unwrap();
        // hand-composed oracle
        let mut h = reference_matmul(&x, &w1, m, dh, dm);
        for i in 0..m as usize {
            for j in 0..dh as usize {
                h[i * dh as usize + j] += b1[j];
                h[i * dh as usize + j] = Activation::Gelu.reference(h[i * dh as usize + j]);
            }
        }
        let mut y = reference_matmul(&h, &w2, m, dm, dh);
        for i in 0..m as usize {
            for j in 0..dm as usize {
                y[i * dm as usize + j] += b2[j] + x[i * dm as usize + j];
            }
        }
        for (g_, w_) in out.iter().zip(&y) {
            assert!((g_ - w_).abs() < 1e-5, "{} vs {}", g_, w_);
        }
    }

    #[test]
    fn decode_block_composes_the_reference_decode() {
        use crate::workloads::attention::reference_flash_decode;
        let (streams, heads, dh, past) = (16i64, 16i64, 16i64, 32i64);
        let d_model = heads * dh;
        let g = decode_block(streams, heads, dh, past);
        let x = test_data(streams * d_model, 0x61);
        let wq = test_data(d_model * d_model, 0x62);
        let kc = test_data(streams * past * dh, 0x63);
        let vc = test_data(streams * past * dh, 0x64);
        let wo = test_data(d_model * d_model, 0x65);
        let bo = test_data(d_model, 0x66);
        let out = g
            .reference_execute(&[
                x.clone(),
                wq.clone(),
                kc.clone(),
                vc.clone(),
                wo.clone(),
                bo.clone(),
            ])
            .unwrap();
        // hand-composed oracle: y = (x + mqa(x wq, cache)) wo + bo
        let q = reference_matmul(&x, &wq, streams, d_model, d_model);
        let mut h = reference_flash_decode(&q, &kc, &vc, streams, heads, past, dh);
        for (hv, xv) in h.iter_mut().zip(&x) {
            *hv += xv;
        }
        let mut y = reference_matmul(&h, &wo, streams, d_model, d_model);
        for i in 0..streams as usize {
            for j in 0..d_model as usize {
                y[i * d_model as usize + j] += bo[j];
            }
        }
        for (g_, w) in out.iter().zip(&y) {
            assert!((g_ - w).abs() < 1e-4, "{} vs {}", g_, w);
        }
        // the decode block keeps request rows independent end to end
        assert!(g.row_batchable());
    }

    #[test]
    fn fan_out_counts_every_consumer() {
        let g = mlp_block(64, 64, 128);
        // X feeds ffn1 and the residual
        assert_eq!(g.fan_out(ValueRef::Input(0)), 2);
        assert_eq!(g.fan_out(ValueRef::Node(0)), 1);
        assert_eq!(g.fan_out(ValueRef::Node(5)), 1); // the graph output
    }

    #[test]
    fn paged_decode_block_validates_with_extras() {
        let g = decode_block_paged(16, 16, 16, 32);
        g.validate().unwrap();
        assert_eq!(g.out_shape().unwrap(), &[16, 256]);
        assert_eq!(
            g.extra_out_shapes().unwrap(),
            vec![vec![16, 16], vec![16, 16]]
        );
        // extras pin their producers' storage and count as consumers
        assert!(g.is_output(ValueRef::Node(4)));
        assert!(g.is_output(ValueRef::Node(5)));
        assert!(g.is_output(ValueRef::Node(6)));
        assert!(!g.is_output(ValueRef::Node(0)));
        assert_eq!(g.fan_out(ValueRef::Node(5)), 1);
        // the reply format can't carry the extra K/V tensors
        assert!(!g.row_batchable());
        // an extra referencing a missing node fails validation
        let mut bad = decode_block_paged(16, 16, 16, 32);
        bad.extra_outputs.push(ValueRef::Node(99));
        assert!(bad.validate().is_err());
        let mut dup = decode_block_paged(16, 16, 16, 32);
        dup.extra_outputs.push(ValueRef::Node(5));
        assert!(dup.validate().is_err());
    }

    #[test]
    fn extra_outputs_survive_json_round_trip() {
        let g = decode_block_paged(16, 16, 16, 32);
        let back = KernelGraph::from_json(&Json::parse(&g.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.output, g.output);
        assert_eq!(back.extra_outputs, g.extra_outputs);
        // single-output graphs keep the old artifact layout
        let text = mlp_block(8, 8, 16).to_json().dump();
        assert!(!text.contains("extra_outputs"));
    }

    #[test]
    fn reference_execute_all_returns_primary_then_extras() {
        use crate::workloads::attention::reference_flash_decode_paged;
        let (slots, heads, dh, max_kv) = (16i64, 16i64, 16i64, 32i64);
        let d_model = heads * dh;
        let g = decode_block_paged(slots, heads, dh, max_kv);
        let x = test_data(slots * d_model, 0x71);
        let wq = test_data(d_model * d_model, 0x72);
        let kg = test_data(slots * max_kv * dh, 0x73);
        let vg = test_data(slots * max_kv * dh, 0x74);
        // staggered live lengths, one dead slot
        let lens: Vec<f32> = (0..slots)
            .map(|i| if i == 3 { 0.0 } else { (8 + (i % 4) * 7) as f32 })
            .collect();
        let wk = test_data(d_model * dh, 0x75);
        let wv = test_data(d_model * dh, 0x76);
        let wo = test_data(d_model * d_model, 0x77);
        let bo = test_data(d_model, 0x78);
        let inputs = vec![
            x.clone(),
            wq.clone(),
            kg.clone(),
            vg.clone(),
            lens.clone(),
            wk.clone(),
            wv.clone(),
            wo.clone(),
            bo.clone(),
        ];
        let outs = g.reference_execute_all(&inputs).unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0].len(), (slots * d_model) as usize);
        // extras are exactly the K/V projections of X
        let k_new = reference_matmul(&x, &wk, slots, dh, d_model);
        let v_new = reference_matmul(&x, &wv, slots, dh, d_model);
        assert_eq!(outs[1], k_new);
        assert_eq!(outs[2], v_new);
        // primary composes the masked decode oracle
        let q = reference_matmul(&x, &wq, slots, d_model, d_model);
        let mut h =
            reference_flash_decode_paged(&q, &kg, &vg, &lens, slots, heads, max_kv, dh);
        for (hv, xv) in h.iter_mut().zip(&x) {
            *hv += xv;
        }
        let mut y = reference_matmul(&h, &wo, slots, d_model, d_model);
        for i in 0..slots as usize {
            for j in 0..d_model as usize {
                y[i * d_model as usize + j] += bo[j];
            }
        }
        for (g_, w) in outs[0].iter().zip(&y) {
            assert!((g_ - w).abs() < 1e-4, "{} vs {}", g_, w);
        }
        // reference_execute still returns just the primary
        assert_eq!(g.reference_execute(&inputs).unwrap(), outs[0]);
    }
}
