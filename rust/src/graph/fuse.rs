//! Epilogue-fusion planner: fold element-wise consumer nodes into their
//! producer kernels' epilogues when the tile shapes admit it and the
//! model says it pays.
//!
//! The decision is costed, not assumed: each kernel node is scored by
//! `sim::simulate_kernel` on the program it would actually execute
//! (with or without the folded epilogue), and each element-wise node by
//! the DRAM traffic it materializes (read primary + operand, write
//! output, at the modeled device's HBM bandwidth). A fold is accepted
//! only when `sim(kernel + op) < sim(kernel) + traffic(elementwise)` —
//! so fused-vs-unfused is a modeled, testable decision, and a fold whose
//! fused program fails to compile (shared-memory pressure, layout
//! infeasibility) is rejected with a reason instead of crashing serving.
//!
//! Admissibility mirrors the builders: only the GEMM families take
//! epilogues (`matmul_program_ep`, `dequant_matmul_program_ep`), a bias
//! must broadcast along the family's feature dimension, and the folded
//! operands must be defined before the producer so topological order
//! survives the rewrite.

use std::collections::HashMap;

use crate::error::Result;
use crate::graph::exec::node_cost_us;
use crate::graph::ir::{GraphNode, KernelGraph, NodeOp, ValueRef};
use crate::runtime::WorkloadKind;
use crate::sim::device::Device;
use crate::workloads::epilogue::EpilogueOp;

/// Per-plan node-cost memo: a node's modeled cost depends only on its
/// op, operand shapes and epilogue list, and node names are unique
/// (validated), so `name + epilogues` keys the sim result. Folding
/// candidates re-cost the same producer repeatedly without this.
fn memo_cost(
    node: &GraphNode,
    dev: &Device,
    memo: &mut HashMap<String, f64>,
) -> Result<f64> {
    let key = format!("{}|{:?}", node.name, node.epilogues);
    if let Some(&us) = memo.get(&key) {
        return Ok(us);
    }
    let us = node_cost_us(node, dev)?;
    memo.insert(key, us);
    Ok(us)
}

/// One accepted fold, for plan printing and tests.
#[derive(Clone, Debug)]
pub struct FusedEdge {
    /// Kernel node that absorbed the op.
    pub producer: String,
    /// Element-wise node that disappeared.
    pub folded: String,
    pub op: EpilogueOp,
    /// Modeled saving (unfused minus fused cost of the pair), µs.
    pub saved_us: f64,
}

/// The fusion decision for one graph.
#[derive(Clone, Debug)]
pub struct FusionPlan {
    /// The rewritten graph (kernel nodes carry fused epilogues).
    pub graph: KernelGraph,
    pub fused: Vec<FusedEdge>,
    /// Folds considered and rejected, with reasons.
    pub rejected: Vec<(String, String)>,
    /// Modeled cost of the rewritten graph, µs.
    pub fused_cost_us: f64,
    /// Modeled cost had nothing been folded, µs.
    pub unfused_cost_us: f64,
}

/// Can `op` fold into a `kind` kernel's epilogue? The GEMM families
/// accept any epilogue on their rank-2 outputs with the bias indexing
/// the family's feature dimension (1 for row-major GEMM, 0 for the
/// transposed dequant output). The attention families accept the
/// element-wise subset on their rank-3 O tiles (activation, scale,
/// residual — e.g. a block residual folded into the flash kernel's O
/// epilogue); a bias has no rank-2 feature dim to broadcast along there.
pub fn admits(kind: &WorkloadKind, op: &EpilogueOp, out_shape: &[i64]) -> Result<(), String> {
    let feature_dim = match kind {
        WorkloadKind::Gemm => 1usize,
        WorkloadKind::Dequant { .. } => 0usize,
        WorkloadKind::FlashAttention { .. }
        | WorkloadKind::FlashDecode
        | WorkloadKind::FlashDecodePaged => {
            if out_shape.len() != 3 {
                return Err(format!(
                    "attention epilogues need the rank-3 O tile, got {:?}",
                    out_shape
                ));
            }
            return match op {
                EpilogueOp::BiasAdd { .. } => Err(format!(
                    "no feature-dim bias on {}'s rank-3 output",
                    kind.tag()
                )),
                _ => Ok(()),
            };
        }
        other => {
            return Err(format!("{} kernels take no fused epilogues", other.tag()));
        }
    };
    if out_shape.len() != 2 {
        return Err(format!("epilogues need a rank-2 output, got {:?}", out_shape));
    }
    if let EpilogueOp::BiasAdd { dim } = op {
        if *dim != feature_dim {
            return Err(format!(
                "bias over dim {} cannot broadcast along {}'s feature dim {}",
                dim,
                kind.tag(),
                feature_dim
            ));
        }
    }
    Ok(())
}

/// Sum of per-node modeled costs (kernel sim + element-wise traffic).
pub fn graph_cost_us(g: &KernelGraph, dev: &Device) -> Result<f64> {
    let mut total = 0f64;
    for node in &g.nodes {
        total += node_cost_us(node, dev)?;
    }
    Ok(total)
}

/// Plan epilogue fusion for `g` on the modeled device. Folds greedily to
/// a fixpoint (a bias and the activation behind it both land on the same
/// producer), never rewrites when the model says the fold loses, and
/// records every rejection.
pub fn plan(g: &KernelGraph, dev: &Device) -> Result<FusionPlan> {
    g.validate()?;
    let mut memo: HashMap<String, f64> = HashMap::new();
    let mut unfused_cost_us = 0f64;
    for node in &g.nodes {
        unfused_cost_us += memo_cost(node, dev, &mut memo)?;
    }
    let mut graph = g.clone();
    let mut fused = Vec::new();
    let mut rejected: Vec<(String, String)> = Vec::new();
    'outer: loop {
        for e in 0..graph.nodes.len() {
            let ew = &graph.nodes[e];
            let op = match &ew.op {
                NodeOp::Elementwise(op) => *op,
                NodeOp::Kernel(_) => continue,
            };
            if rejected.iter().any(|(n, _)| *n == ew.name) {
                continue;
            }
            // candidate producer: the primary input must be a kernel
            // node consumed only here
            let p = match ew.inputs[0] {
                ValueRef::Node(p) => p,
                ValueRef::Input(_) => continue,
            };
            let kind = match &graph.nodes[p].op {
                NodeOp::Kernel(kind) => kind.clone(),
                NodeOp::Elementwise(_) => continue,
            };
            let reason = check_fold(&graph, p, e, &kind, &op);
            match reason {
                Err(why) => {
                    rejected.push((graph.nodes[e].name.clone(), why));
                    continue;
                }
                Ok(()) => {}
            }
            // modeled decision: kernel+op vs kernel + materialized edge
            let producer_before = memo_cost(&graph.nodes[p], dev, &mut memo)?;
            let ew_cost = memo_cost(&graph.nodes[e], dev, &mut memo)?;
            let candidate = fold(&graph, p, e);
            let producer_after = match memo_cost(&candidate.nodes[p], dev, &mut memo) {
                Ok(us) => us,
                Err(why) => {
                    // fused program does not compile (smem pressure,
                    // layout infeasibility): keep the unfused node
                    rejected.push((
                        graph.nodes[e].name.clone(),
                        format!("fused program rejected: {}", why),
                    ));
                    continue;
                }
            };
            let saved_us = producer_before + ew_cost - producer_after;
            if saved_us <= 0.0 {
                rejected.push((
                    graph.nodes[e].name.clone(),
                    format!(
                        "model prefers unfused ({:.2} vs {:.2} us)",
                        producer_before + ew_cost,
                        producer_after
                    ),
                ));
                continue;
            }
            fused.push(FusedEdge {
                producer: graph.nodes[p].name.clone(),
                folded: graph.nodes[e].name.clone(),
                op,
                saved_us,
            });
            graph = candidate;
            continue 'outer; // indices shifted: restart the scan
        }
        break;
    }
    graph.validate()?;
    let mut fused_cost_us = 0f64;
    for node in &graph.nodes {
        fused_cost_us += memo_cost(node, dev, &mut memo)?;
    }
    Ok(FusionPlan {
        graph,
        fused,
        rejected,
        fused_cost_us,
        unfused_cost_us,
    })
}

/// Structural admissibility of folding element-wise node `e` into kernel
/// node `p`.
fn check_fold(
    g: &KernelGraph,
    p: usize,
    e: usize,
    kind: &WorkloadKind,
    op: &EpilogueOp,
) -> Result<(), String> {
    admits(kind, op, &g.nodes[p].out_shape)?;
    if g.fan_out(ValueRef::Node(p)) != 1 {
        return Err(format!(
            "{} has {} consumers; its output must materialize",
            g.nodes[p].name,
            g.fan_out(ValueRef::Node(p))
        ));
    }
    if g.is_output(ValueRef::Node(p)) {
        return Err(format!(
            "{} is a graph output (primary or extra)",
            g.nodes[p].name
        ));
    }
    // the element-wise view must be the producer's own shape (no fused
    // reshape), and epilogue operands must already be defined before p
    if g.nodes[e].in_shapes[0] != g.nodes[p].out_shape {
        return Err(format!(
            "{} views the edge as {:?}, producer writes {:?}",
            g.nodes[e].name, g.nodes[e].in_shapes[0], g.nodes[p].out_shape
        ));
    }
    for v in &g.nodes[e].inputs[1..] {
        if let ValueRef::Node(j) = v {
            if *j >= p {
                return Err(format!(
                    "operand node {} is defined after producer {}",
                    g.nodes[*j].name, g.nodes[p].name
                ));
            }
        }
    }
    Ok(())
}

/// Rewrite: fold element-wise node `e` into kernel node `p` (`p < e`),
/// rewiring every consumer of `e` to `p` and compacting node indices.
fn fold(g: &KernelGraph, p: usize, e: usize) -> KernelGraph {
    debug_assert!(p < e);
    let mut nodes = g.nodes.clone();
    let ew = nodes[e].clone();
    let op = match &ew.op {
        NodeOp::Elementwise(op) => *op,
        NodeOp::Kernel(_) => unreachable!("fold target is element-wise"),
    };
    nodes[p].epilogues.push(op);
    nodes[p].inputs.extend_from_slice(&ew.inputs[1..]);
    nodes[p].in_shapes.extend_from_slice(&ew.in_shapes[1..]);
    nodes.remove(e);
    let remap = |v: ValueRef| match v {
        ValueRef::Node(j) if j == e => ValueRef::Node(p),
        ValueRef::Node(j) if j > e => ValueRef::Node(j - 1),
        other => other,
    };
    for n in nodes.iter_mut() {
        for v in n.inputs.iter_mut() {
            *v = remap(*v);
        }
    }
    KernelGraph {
        name: g.name.clone(),
        inputs: g.inputs.clone(),
        nodes,
        output: remap(g.output),
        extra_outputs: g.extra_outputs.iter().map(|&v| remap(v)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::{attention_block, dequant_mlp_block, mlp_block};
    use crate::workloads::dequant::WeightFormat;
    use crate::workloads::epilogue::Activation;

    fn h100() -> Device {
        Device::h100()
    }

    #[test]
    fn mlp_block_folds_every_elementwise_node() {
        let g = mlp_block(64, 64, 128);
        let p = plan(&g, &h100()).expect("fusion plan");
        // bias1 + gelu fold into ffn1; bias2 + residual into ffn2
        assert_eq!(p.fused.len(), 4, "fused: {:?}", p.fused);
        assert_eq!(p.graph.nodes.len(), 2);
        assert_eq!(p.graph.nodes[0].epilogues.len(), 2);
        assert_eq!(p.graph.nodes[1].epilogues.len(), 2);
        assert!(
            p.fused_cost_us < p.unfused_cost_us,
            "fused {:.2} vs unfused {:.2}",
            p.fused_cost_us,
            p.unfused_cost_us
        );
        // epilogue operands landed behind the gemm operands
        assert_eq!(p.graph.nodes[0].inputs.len(), 3); // X, W1, B1
        assert_eq!(p.graph.nodes[1].inputs.len(), 4); // h, W2, B2, X
        assert_eq!(p.graph.output, ValueRef::Node(1));
        p.graph.validate().expect("rewritten graph is well-formed");
    }

    #[test]
    fn attention_block_folds_only_the_residual() {
        let g = attention_block(128, 64, false);
        let p = plan(&g, &h100()).expect("fusion plan");
        assert_eq!(p.fused.len(), 1, "fused: {:?}", p.fused);
        assert_eq!(p.fused[0].producer, "out_proj");
        assert_eq!(p.fused[0].op, EpilogueOp::ResidualAdd);
        // q/k/v gemms and the attention kernel survive
        assert_eq!(p.graph.nodes.len(), 5);
        p.graph.validate().unwrap();
    }

    #[test]
    fn dequant_block_takes_a_dim0_bias() {
        let g = dequant_mlp_block(32, 64, 64, 64, WeightFormat::Int4, 32);
        let p = plan(&g, &h100()).expect("fusion plan");
        // bias1 + gelu into ffn1, dim-0 bias2 into the dequant kernel
        assert_eq!(p.fused.len(), 3, "fused: {:?}", p.fused);
        assert_eq!(p.graph.nodes.len(), 2);
        let dq = &p.graph.nodes[1];
        assert_eq!(dq.epilogues, vec![EpilogueOp::BiasAdd { dim: 0 }]);
        p.graph.validate().unwrap();
    }

    #[test]
    fn decode_block_folds_residual_into_the_flash_o_epilogue() {
        let g = crate::graph::ir::decode_block(64, 16, 16, 64);
        let p = plan(&g, &h100()).expect("fusion plan");
        // attn_res folds into the flash decode kernel's O epilogue,
        // bias_o into the out-projection GEMM
        assert_eq!(p.fused.len(), 2, "fused: {:?}", p.fused);
        let attn_fold = p
            .fused
            .iter()
            .find(|f| f.producer == "attn")
            .expect("residual folds into the attention producer");
        assert_eq!(attn_fold.op, EpilogueOp::ResidualAdd);
        assert!(p.fused.iter().any(|f| f.producer == "out_proj"));
        assert_eq!(p.graph.nodes.len(), 3);
        // the attention node absorbed the residual operand (Q, K, V, X)
        let attn = &p.graph.nodes[1];
        assert_eq!(attn.epilogues, vec![EpilogueOp::ResidualAdd]);
        assert_eq!(attn.inputs.len(), 4);
        p.graph.validate().unwrap();
    }

    #[test]
    fn paged_decode_folds_track_extra_outputs_through_the_rewrite() {
        let g = crate::graph::ir::decode_block_paged(16, 16, 16, 32);
        let p = plan(&g, &h100()).expect("fusion plan");
        p.graph.validate().unwrap();
        // whatever folded, the extras must still point at the K/V
        // projection nodes after index compaction
        assert_eq!(p.graph.extra_outputs.len(), 2);
        for (extra, want) in p.graph.extra_outputs.iter().zip(["k_new", "v_new"]) {
            match extra {
                ValueRef::Node(j) => assert_eq!(p.graph.nodes[*j].name, want),
                other => panic!("extra output {:?} is not a node", other),
            }
        }
        // the residual still folds into the paged attention kernel and
        // the bias into the out-projection, as in the contiguous block
        assert_eq!(p.fused.len(), 2, "fused: {:?}", p.fused);
        assert!(p.fused.iter().any(|f| f.producer == "attn"));
        assert!(p.fused.iter().any(|f| f.producer == "out_proj"));
    }

    #[test]
    fn extra_outputs_block_folding_their_producer() {
        // mark ffn1's output as an extra: the gelu consumer behind its
        // bias may no longer fold the producer away
        let mut g = mlp_block(64, 64, 128);
        g.extra_outputs.push(ValueRef::Node(0));
        let p = plan(&g, &h100()).expect("plan");
        p.graph.validate().unwrap();
        assert!(
            p.rejected
                .iter()
                .any(|(n, why)| n == "bias1" && why.contains("consumers")),
            "rejected: {:?}",
            p.rejected
        );
        assert!(p.fused.iter().all(|f| f.producer != "ffn1"));
    }

    #[test]
    fn attention_rejects_bias_folds_with_a_reason() {
        // a (contrived) dim-1 bias behind the flash decode node must be
        // rejected: rank-3 O tiles have no rank-2 feature dim. BiasAdd
        // validation itself requires rank-2 outputs, so model the case
        // through admits() directly.
        let err = admits(
            &WorkloadKind::FlashDecode,
            &EpilogueOp::BiasAdd { dim: 1 },
            &[64, 16, 16],
        )
        .unwrap_err();
        assert!(err.contains("bias"), "{}", err);
        // the element-wise subset is admissible
        assert!(admits(
            &WorkloadKind::FlashAttention { causal: false },
            &EpilogueOp::ResidualAdd,
            &[2, 128, 64],
        )
        .is_ok());
        assert!(admits(
            &WorkloadKind::FlashDecode,
            &EpilogueOp::Scale(0.5),
            &[64, 16, 16],
        )
        .is_ok());
    }

    #[test]
    fn inadmissible_folds_are_rejected_with_reasons() {
        // a bias over the wrong dim cannot fold into a gemm
        let mut g = mlp_block(64, 64, 128);
        g.nodes[1].op = NodeOp::Elementwise(EpilogueOp::BiasAdd { dim: 0 });
        g.nodes[1].in_shapes[1] = vec![64];
        g.nodes[1].inputs[1] = ValueRef::Input(4); // B2 is [d_model] = [64]
        let p = plan(&g, &h100()).expect("plan");
        assert!(
            p.rejected.iter().any(|(n, why)| n == "bias1" && why.contains("feature dim")),
            "rejected: {:?}",
            p.rejected
        );
        // the gelu behind the unfolded bias has an element-wise
        // producer, so it cannot fold either; ffn2's pair still does
        assert!(p.fused.iter().all(|f| f.producer == "ffn2"));
        p.graph.validate().unwrap();
    }

    #[test]
    fn fan_out_blocks_fusion() {
        // make the first gemm's output feed both the bias and the
        // residual: it must materialize, so nothing folds into ffn1
        let mut g = mlp_block(64, 64, 128);
        // residual reads node 0 instead of X (same [64, 64]... shapes
        // differ: node0 is [64,128]) — use an activation consumer on
        // node 0 instead
        g.nodes[2].inputs = vec![ValueRef::Node(0)];
        g.nodes[2].in_shapes = vec![vec![64, 128]];
        g.nodes[2].op = NodeOp::Elementwise(EpilogueOp::Activation(Activation::Relu));
        let p = plan(&g, &h100()).expect("plan");
        assert!(
            p.rejected.iter().any(|(_, why)| why.contains("consumers")),
            "rejected: {:?}",
            p.rejected
        );
        p.graph.validate().unwrap();
    }
}
