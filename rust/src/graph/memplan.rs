//! Liveness-based buffer planning for graph execution: intermediates
//! whose live ranges are disjoint share one allocation, so a block's
//! peak "DRAM" footprint is the planned pool, not the sum of every edge
//! tensor.
//!
//! The plan is computed once per prepared graph and then *used* by the
//! executor (`graph::exec`): each node writes its output into its
//! assigned pool buffer via `InterpKernel::execute_into`, so a plan that
//! wrongly shared a live buffer would corrupt the differential tests,
//! not just an accounting number.

use crate::graph::ir::{KernelGraph, ValueRef};

/// One pooled intermediate: which buffer a node's output occupies and
/// its live range `[def, last_use]` in node indices.
#[derive(Clone, Debug)]
pub struct SlotAssign {
    /// Pool buffer index; `None` for the graph outputs — primary and
    /// extras alike get dedicated allocations, since they leave the
    /// pool with the request reply.
    pub buffer: Option<usize>,
    /// Node index that defines the tensor.
    pub def: usize,
    /// Last node index that reads it (== `def` for dead or output-only
    /// tensors; `usize::MAX` never occurs — the output is dedicated).
    pub last_use: usize,
    /// Tensor bytes (f32 wire format).
    pub bytes: i64,
}

/// The buffer-reuse plan for one graph.
#[derive(Clone, Debug)]
pub struct MemPlan {
    /// Per node (same order as `graph.nodes`).
    pub slots: Vec<SlotAssign>,
    /// Planned pool buffer sizes, bytes.
    pub pool_bytes: Vec<i64>,
    /// Peak planned bytes: the whole pool is live at once in the worst
    /// case, so this is the pool sum (graph output excluded).
    pub peak_bytes: i64,
    /// What materializing every intermediate would cost (graph output
    /// excluded) — the number the pool must beat.
    pub intermediate_bytes: i64,
}

impl MemPlan {
    /// Human lines for the CLI plan printout.
    pub fn describe(&self, g: &KernelGraph) -> Vec<String> {
        let mut out = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            let buf = match s.buffer {
                Some(b) => format!("pool[{}]", b),
                None => "output".to_string(),
            };
            out.push(format!(
                "  {:<24} {:>8} B  {:<9} live [{}, {}]",
                g.nodes[i].name, s.bytes, buf, s.def, s.last_use
            ));
        }
        out.push(format!(
            "  peak planned: {} B across {} pooled buffer(s); materializing every \
             intermediate would take {} B",
            self.peak_bytes,
            self.pool_bytes.len(),
            self.intermediate_bytes
        ));
        out
    }
}

/// Plan buffer reuse for `g` (fused or unfused). Greedy linear scan in
/// topological order: allocate the defining node's output first (so a
/// node never aliases its own operands), then return operands whose
/// last consumer was this node to the free pool.
pub fn plan(g: &KernelGraph) -> MemPlan {
    let n = g.nodes.len();
    // last consuming node per node output
    let mut last_use: Vec<usize> = (0..n).collect();
    for (i, node) in g.nodes.iter().enumerate() {
        for v in &node.inputs {
            if let ValueRef::Node(j) = v {
                last_use[*j] = last_use[*j].max(i);
            }
        }
    }
    let mut pool_bytes: Vec<i64> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut slots: Vec<SlotAssign> = Vec::with_capacity(n);
    let mut intermediate_bytes = 0i64;
    for (i, node) in g.nodes.iter().enumerate() {
        let bytes = node.out_len() as i64 * 4;
        let buffer = if g.is_output(ValueRef::Node(i)) {
            None
        } else {
            intermediate_bytes += bytes;
            // best fit: the smallest free buffer that holds the tensor;
            // otherwise grow the largest free buffer (still reuse);
            // otherwise open a new one
            let fit = free
                .iter()
                .copied()
                .filter(|&b| pool_bytes[b] >= bytes)
                .min_by_key(|&b| pool_bytes[b]);
            let chosen = match fit {
                Some(b) => b,
                None => match free.iter().copied().max_by_key(|&b| pool_bytes[b]) {
                    Some(b) => {
                        pool_bytes[b] = bytes;
                        b
                    }
                    None => {
                        pool_bytes.push(bytes);
                        pool_bytes.len() - 1
                    }
                },
            };
            free.retain(|&b| b != chosen);
            Some(chosen)
        };
        slots.push(SlotAssign {
            buffer,
            def: i,
            last_use: last_use[i],
            bytes,
        });
        // operands that die here go back to the pool — strictly after
        // this node's own allocation, so input/output never alias
        // (j == i frees a never-consumed output immediately)
        for j in 0..=i {
            if last_use[j] == i {
                if let Some(b) = slots[j].buffer {
                    if !free.contains(&b) {
                        free.push(b);
                    }
                }
            }
        }
    }
    MemPlan {
        peak_bytes: pool_bytes.iter().sum(),
        pool_bytes,
        slots,
        intermediate_bytes,
    }
}

/// Check the no-aliasing invariant: two tensors sharing a pool buffer
/// must have disjoint live ranges, with the later tensor defined
/// strictly after the earlier one's last use. Returns the offending
/// pair when violated (test + debug helper).
pub fn find_live_overlap(plan: &MemPlan) -> Option<(usize, usize)> {
    for i in 0..plan.slots.len() {
        for j in (i + 1)..plan.slots.len() {
            let (a, b) = (&plan.slots[i], &plan.slots[j]);
            if a.buffer.is_some() && a.buffer == b.buffer && b.def <= a.last_use {
                return Some((i, j));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::{attention_block, decode_block_paged, dequant_mlp_block, mlp_block};
    use crate::workloads::dequant::WeightFormat;

    #[test]
    fn chain_graph_reuses_buffers() {
        // unfused MLP: a 6-node chain — consecutive intermediates are
        // dead after one hop, so the pool stays tiny
        let g = mlp_block(64, 64, 128);
        let p = plan(&g);
        assert_eq!(p.slots.len(), 6);
        assert!(p.slots[5].buffer.is_none(), "output is dedicated");
        assert!(
            p.peak_bytes < p.intermediate_bytes,
            "peak {} must beat materializing all {} intermediate bytes",
            p.peak_bytes,
            p.intermediate_bytes
        );
        // the chain needs at most two live tensors at a time
        assert!(p.pool_bytes.len() <= 2, "pool {:?}", p.pool_bytes);
        assert!(find_live_overlap(&p).is_none());
    }

    #[test]
    fn attention_graph_reuses_after_the_attention_node() {
        let g = attention_block(128, 64, false);
        let p = plan(&g);
        // q/k/v all stay live until attention consumes them; the
        // attention output can then reuse one of their buffers
        assert!(p.pool_bytes.len() >= 3);
        assert!(p.peak_bytes < p.intermediate_bytes);
        assert!(find_live_overlap(&p).is_none());
        // q, k, v must not share buffers with each other
        let (q, k, v) = (&p.slots[0], &p.slots[1], &p.slots[2]);
        assert_ne!(q.buffer, k.buffer);
        assert_ne!(q.buffer, v.buffer);
        assert_ne!(k.buffer, v.buffer);
    }

    #[test]
    fn extra_outputs_get_dedicated_storage() {
        let g = decode_block_paged(16, 16, 16, 32);
        let p = plan(&g);
        // primary (bias_o, node 4) and both extras (k_new 5, v_new 6)
        // must never land in the shared pool
        for i in [4, 5, 6] {
            assert!(p.slots[i].buffer.is_none(), "node {} pooled", i);
        }
        // true intermediates still pool
        assert!(p.slots[0].buffer.is_some());
        assert!(find_live_overlap(&p).is_none());
    }

    #[test]
    fn no_two_live_intermediates_share_a_buffer() {
        for g in [
            mlp_block(64, 64, 128),
            attention_block(128, 64, true),
            dequant_mlp_block(32, 64, 64, 64, WeightFormat::Int4, 32),
        ] {
            let p = plan(&g);
            if let Some((i, j)) = find_live_overlap(&p) {
                panic!(
                    "{}: nodes {} and {} share a buffer while both live",
                    g.name, i, j
                );
            }
        }
    }
}
