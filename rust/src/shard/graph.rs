//! Graph sharding: plan and execute one whole [`KernelGraph`] across N
//! parallel executors — scatter once, run the fused block per shard,
//! gather once.
//!
//! The single-kernel planner ([`crate::shard::plan`]) partitions one
//! kernel's tile grid; this module lifts the same decision to a *block*:
//! one partition axis is chosen for the entire graph, every shard
//! receives a sliced sub-graph (same nodes, scaled shapes), and
//! intermediates stay shard-local — they are produced, fused and
//! buffer-pooled inside each shard's [`GraphKernel`] and never cross the
//! interconnect. Only the graph inputs scatter and the single graph
//! output gathers.
//!
//! ## The partition axis
//!
//! A [`batch-axis analysis`](plan_graph) (a generalization of
//! `KernelGraph::row_batchable`) tracks where dim 0 of graph input 0 —
//! the block's batch axis — lives in every value:
//!
//! * a GEMM propagates it from its A rows to its output rows (B must be
//!   a replicated weight);
//! * a dequant-GEMM moves it to dim 1 of its transposed output;
//! * flash attention / flash decode carry it through the `batch*heads`
//!   grid axis, and *demand* that their K/V operands slice identically
//!   (a KV cache is per-stream state, so it scatters with the streams);
//! * element-wise ops pass it through (a residual operand must carry it
//!   the same way; a feature-dim bias replicates).
//!
//! Row-major reshapes along typed edges keep the axis when it stays
//! leading (`[m, h*d] -> [m*h', 1, d]`-style views); anything that moves
//! the batch off the leading dimension — e.g. `attention_block`'s
//! `[seq, d] -> [1, seq, d]` single-head view, whose rows the flash
//! kernel then mixes — rejects the strategy with a reason.
//!
//! The strategy is reported as `row_parallel` when only GEMM-family
//! nodes ride the axis (MLP blocks: data-parallel rows) and
//! `head_parallel` when an attention-family node does (decode blocks:
//! the axis is the flash grid's batch*heads dimension).
//!
//! ## Cost and feasibility
//!
//! Each candidate partition is costed like the single-kernel planner:
//! the *fused* per-shard graph cost from `graph::fuse::plan` (which
//! builds every node's real tile program, so planner feasibility equals
//! execution feasibility — an over-split shard whose GEMM rows or decode
//! heads fall below the hardware tile is rejected here with the
//! builder's reason), taken over the slowest distinct sub-shape, plus
//! one scatter + one gather communication term over the modeled
//! NVLink-class link.
//!
//! ## Execution
//!
//! [`ShardedGraphKernel`] prepares one [`GraphKernel`] per *distinct*
//! shard sub-shape (uniform splits share one kernel — and its fusion
//! decision, tuned per-node tile configs and buffer memplan — across all
//! shard threads), scatters request inputs per the plan's
//! [`InputSlice`]s (replicated weights are borrowed, not copied),
//! executes every shard on its own `std::thread::scope` thread, and
//! concatenates the shard outputs along the output's batch dimension.
//!
//! ```
//! use tilelang::graph::ir::mlp_block;
//! use tilelang::runtime::InterpOptions;
//! use tilelang::shard::graph::{plan_graph, GraphStrategy, ShardedGraphKernel};
//! use tilelang::sim::device::Device;
//! use tilelang::workloads::matmul::test_data;
//!
//! // plan a whole MLP block across 2 executors...
//! let g = mlp_block(32, 32, 32);
//! let plan = plan_graph(&g, 2, &Device::h100()).unwrap();
//! assert_eq!(plan.shards(), 2);
//! assert_eq!(plan.strategy, GraphStrategy::RowParallel);
//!
//! // ...execute it sharded, and compare to the reference oracle
//! let opts = InterpOptions { tune: false, ..Default::default() };
//! let kernel = ShardedGraphKernel::from_plan(&g, plan, &opts, std::env::temp_dir()).unwrap();
//! let inputs = vec![
//!     test_data(32 * 32, 1), // X
//!     test_data(32 * 32, 2), // W1
//!     test_data(32, 3),      // B1
//!     test_data(32 * 32, 4), // W2
//!     test_data(32, 5),      // B2
//! ];
//! let got = kernel.execute(&inputs).unwrap();
//! let want = g.reference_execute(&inputs).unwrap();
//! for (g_, w) in got.iter().zip(&want) {
//!     assert!((g_ - w).abs() < 0.06 + 0.02 * w.abs());
//! }
//! ```

use std::borrow::Cow;
use std::fmt;
use std::path::Path;
use std::time::Instant;

use crate::error::Result;
use crate::graph::exec::GraphKernel;
use crate::obs::{Recorder, Traffic};
use crate::graph::fuse;
use crate::graph::ir::{kernel_input_count, KernelGraph, NodeOp, ValueRef};
use crate::runtime::{InterpOptions, WorkloadKind};
use crate::shard::exec::{slice_tensor, ShardedOptions};
use crate::shard::plan::{link_gbps, split_spans, InputSlice};
use crate::sim::device::Device;
use crate::workloads::epilogue::EpilogueOp;
use crate::{anyhow, bail};

/// How the block partitions, named by what rides the axis: pure
/// GEMM-family graphs split their data rows, graphs with an
/// attention-family node on the axis split the flash grid's batch*heads
/// dimension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphStrategy {
    RowParallel,
    HeadParallel,
}

impl fmt::Display for GraphStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            GraphStrategy::RowParallel => "row_parallel",
            GraphStrategy::HeadParallel => "head_parallel",
        })
    }
}

/// Where a value carries the block's batch axis: slicing batch units
/// `[s0, s1)` slices the value's `dim` at `[s0 * unit, s1 * unit)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Axis {
    dim: usize,
    unit: i64,
}

/// One shard's slice of the block.
#[derive(Clone, Debug)]
pub struct GraphShardPart {
    pub index: usize,
    /// Per graph input (manifest order): slice or replicate.
    pub inputs: Vec<InputSlice>,
    /// The sliced sub-graph this shard executes (same nodes and fusion
    /// opportunities, scaled shapes).
    pub graph: KernelGraph,
}

/// A complete sharding decision for one dataflow graph.
#[derive(Clone, Debug)]
pub struct GraphShardPlan {
    pub graph_name: String,
    pub strategy: GraphStrategy,
    /// Batch extent (rows of graph input 0) being partitioned.
    pub batch: i64,
    /// `(start, len)` of each shard's batch span, in input-0 rows.
    pub spans: Vec<(i64, i64)>,
    pub parts: Vec<GraphShardPart>,
    /// Output dimension the shard outputs concatenate along (0 for
    /// row-major leading concat; 1 for the transposed dequant output).
    pub concat_dim: usize,
    /// Modeled *fused* graph time of the slowest shard, microseconds
    /// (shards run in parallel).
    pub kernel_us: f64,
    /// Modeled scatter + gather communication time, microseconds.
    pub comm_us: f64,
}

impl GraphShardPlan {
    pub fn shards(&self) -> usize {
        self.parts.len()
    }

    /// Total modeled time the planner minimizes.
    pub fn cost_us(&self) -> f64 {
        self.kernel_us + self.comm_us
    }

    /// One-line human description for CLI / serve output.
    pub fn describe(&self) -> String {
        format!(
            "{} x{} (spans {:?}, gather concat dim {}), modeled {:.1} us slowest shard \
             + {:.1} us comm",
            self.strategy,
            self.shards(),
            self.spans,
            self.concat_dim,
            self.kernel_us,
            self.comm_us
        )
    }
}

/// The per-value batch-axis assignment of one graph (see module docs).
struct BatchFlow {
    /// Per graph input: `Some` = sliced along the axis, `None` =
    /// replicated to every shard.
    inputs: Vec<Option<Axis>>,
    /// Per node output.
    nodes: Vec<Option<Axis>>,
    /// Per node, per operand: the axis in the operand's *view*
    /// coordinates (`in_shapes[k]`), for sub-graph shape scaling.
    views: Vec<Vec<Option<Axis>>>,
    /// Whether any attention-family node rides the axis.
    attention_on_axis: bool,
    /// Minimum batch-span granule (input-0 rows) so every per-shard
    /// kernel keeps whole hardware tiles.
    granule: i64,
}

fn gcd(a: i64, b: i64) -> i64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: i64, b: i64) -> i64 {
    a / gcd(a, b) * b
}

/// Translate a producer-side axis through a row-major reshape into the
/// consumer's view coordinates. Identity views keep the axis; a real
/// reshape only preserves it when it stays on the leading dimension.
fn view_axis(
    producer: Option<Axis>,
    producer_shape: &[i64],
    view: &[i64],
    batch: i64,
) -> Result<Option<Axis>, String> {
    if view == producer_shape {
        return Ok(producer);
    }
    match producer {
        None => Ok(None),
        Some(Axis { dim: 0, .. }) => {
            if view[0] % batch != 0 {
                return Err(format!(
                    "reshape {:?} -> {:?} moves the batch axis off the leading dim",
                    producer_shape, view
                ));
            }
            Ok(Some(Axis {
                dim: 0,
                unit: view[0] / batch,
            }))
        }
        Some(Axis { dim, .. }) => Err(format!(
            "batch axis lives on dim {} of {:?}; reshaped views are only \
             supported for a leading batch axis",
            dim, producer_shape
        )),
    }
}

/// Require graph input `idx` to scatter along `axis` (or fail on a
/// conflicting earlier decision).
fn require_input_axis(
    flow_inputs: &mut [Option<Axis>],
    denied: &[Option<String>],
    idx: usize,
    axis: Axis,
    why: &str,
) -> Result<(), String> {
    if let Some(user) = &denied[idx] {
        return Err(format!(
            "input {} must scatter with the batch ({}) but {} needs it replicated",
            idx, why, user
        ));
    }
    match flow_inputs[idx] {
        None => {
            flow_inputs[idx] = Some(axis);
            Ok(())
        }
        Some(existing) if existing == axis => Ok(()),
        Some(existing) => Err(format!(
            "input {} is sliced two different ways ({:?} vs {:?})",
            idx, existing, axis
        )),
    }
}

/// Record that graph input `idx` must be replicated (weights); fails if
/// it was already required to scatter.
fn deny_input_axis(
    flow_inputs: &[Option<Axis>],
    denied: &mut [Option<String>],
    idx: usize,
    why: &str,
) -> Result<(), String> {
    if flow_inputs[idx].is_some() {
        return Err(format!(
            "input {} carries the batch axis but {} needs it replicated",
            idx, why
        ));
    }
    if denied[idx].is_none() {
        denied[idx] = Some(why.to_string());
    }
    Ok(())
}

/// The axis of one operand value (input or earlier node), translated
/// into the operand's view shape. For *input* operands whose axis is not
/// yet decided, `demand` assigns it (attention caches, sliced residuals).
#[allow(clippy::too_many_arguments)]
fn operand_axis(
    g: &KernelGraph,
    flow_inputs: &mut [Option<Axis>],
    flow_nodes: &[Option<Axis>],
    denied: &[Option<String>],
    v: ValueRef,
    view: &[i64],
    batch: i64,
    demand: Option<(Axis, &str)>,
) -> Result<Option<Axis>, String> {
    let (current, shape): (Option<Axis>, &[i64]) = match v {
        ValueRef::Input(i) => (flow_inputs[i], &g.inputs[i].shape),
        ValueRef::Node(j) => (flow_nodes[j], &g.nodes[j].out_shape),
    };
    let viewed = view_axis(current, shape, view, batch)?;
    match (viewed, demand) {
        (Some(a), _) => Ok(Some(a)),
        (None, Some((want, why))) => {
            // only undecided *inputs* can still be assigned; a node that
            // does not carry the axis cannot be re-sliced
            let ValueRef::Input(i) = v else {
                return Err(format!(
                    "{} needs a batch-sliced operand, but the value does not carry \
                     the batch axis",
                    why
                ));
            };
            if view != shape {
                return Err(format!(
                    "{} needs input {} sliced, but it is consumed through a reshape",
                    why, i
                ));
            }
            if shape[0] % batch != 0 || shape[0] / batch != want.unit || want.dim != 0 {
                return Err(format!(
                    "{} needs input {} sliced as {:?}, which its shape {:?} cannot \
                     satisfy over batch {}",
                    why, i, want, shape, batch
                ));
            }
            require_input_axis(flow_inputs, denied, i, want, why)?;
            Ok(Some(want))
        }
        (None, None) => Ok(None),
    }
}

/// Walk one node's epilogue list (pre-seeded graphs), applying the
/// element-wise operand rules against the node's output axis. Returns
/// the view axes of the epilogue operands (aligned with
/// `inputs[base..]`).
#[allow(clippy::too_many_arguments)]
fn epilogue_axes(
    g: &KernelGraph,
    flow_inputs: &mut [Option<Axis>],
    flow_nodes: &[Option<Axis>],
    denied: &mut [Option<String>],
    node_idx: usize,
    base: usize,
    out_axis: Option<Axis>,
    batch: i64,
) -> Result<Vec<Option<Axis>>, String> {
    let node = &g.nodes[node_idx];
    let mut views = Vec::new();
    let mut next = base;
    for ep in &node.epilogues {
        if !ep.takes_operand() {
            continue;
        }
        let v = node.inputs[next];
        let view = &node.in_shapes[next];
        let axis = ep_operand_axis(
            g,
            flow_inputs,
            flow_nodes,
            denied,
            ep,
            v,
            view,
            out_axis,
            batch,
            &node.name,
        )?;
        views.push(axis);
        next += 1;
    }
    Ok(views)
}

/// The element-wise operand rule shared by standalone element-wise nodes
/// and fused epilogues: a residual scatters exactly like the output; a
/// bias replicates unless it indexes the batch-carrying dim, in which
/// case it slices.
#[allow(clippy::too_many_arguments)]
fn ep_operand_axis(
    g: &KernelGraph,
    flow_inputs: &mut [Option<Axis>],
    flow_nodes: &[Option<Axis>],
    denied: &mut [Option<String>],
    ep: &EpilogueOp,
    v: ValueRef,
    view: &[i64],
    out_axis: Option<Axis>,
    batch: i64,
    node_name: &str,
) -> Result<Option<Axis>, String> {
    match ep {
        EpilogueOp::ResidualAdd => match out_axis {
            Some(a) => {
                let why = format!("{}'s residual operand", node_name);
                let got = operand_axis(
                    g,
                    flow_inputs,
                    flow_nodes,
                    denied,
                    v,
                    view,
                    batch,
                    Some((a, why.as_str())),
                )?;
                if got != Some(a) {
                    return Err(format!(
                        "{}: residual operand axis {:?} does not match the output's {:?}",
                        node_name, got, a
                    ));
                }
                Ok(got)
            }
            None => {
                let got =
                    operand_axis(g, flow_inputs, flow_nodes, denied, v, view, batch, None)?;
                if got.is_some() {
                    return Err(format!(
                        "{}: residual operand carries the batch axis but the node's \
                         output is replicated",
                        node_name
                    ));
                }
                Ok(None)
            }
        },
        EpilogueOp::BiasAdd { dim } => {
            match out_axis {
                Some(a) if a.dim == *dim => {
                    // bias over the batch-carrying dim: slice it with the
                    // same unit (rank-1 operand, so its dim 0)
                    let want = Axis { dim: 0, unit: a.unit };
                    let why = format!("{}'s batch-dim bias", node_name);
                    operand_axis(
                        g,
                        flow_inputs,
                        flow_nodes,
                        denied,
                        v,
                        view,
                        batch,
                        Some((want, why.as_str())),
                    )
                }
                _ => {
                    // feature-dim bias: a replicated weight
                    if let ValueRef::Input(i) = v {
                        let why = format!("{}'s feature bias", node_name);
                        deny_input_axis(flow_inputs, denied, i, &why)?;
                    }
                    Ok(None)
                }
            }
        }
        EpilogueOp::Activation(_) | EpilogueOp::Scale(_) => Ok(None),
    }
}

/// Run the batch-axis analysis (module docs) over `g`.
fn analyze(g: &KernelGraph) -> Result<BatchFlow, String> {
    if g.inputs.is_empty() {
        return Err("graph has no inputs to partition".to_string());
    }
    if !g.extra_outputs.is_empty() {
        // the collective only reassembles the primary output; extras
        // (e.g. a paged decode block's new K/V rows) would be dropped
        return Err(format!(
            "graph carries {} extra output(s); sharded execution returns only \
             the primary output",
            g.extra_outputs.len()
        ));
    }
    let batch = g.inputs[0].shape[0];
    let mut flow_inputs: Vec<Option<Axis>> = vec![None; g.inputs.len()];
    let mut denied: Vec<Option<String>> = vec![None; g.inputs.len()];
    // the partition axis is *defined* as dim 0 of graph input 0
    flow_inputs[0] = Some(Axis { dim: 0, unit: 1 });
    let mut flow_nodes: Vec<Option<Axis>> = vec![None; g.nodes.len()];
    let mut views: Vec<Vec<Option<Axis>>> = Vec::with_capacity(g.nodes.len());
    let mut attention_on_axis = false;
    let mut granule = 1i64;

    for (i, node) in g.nodes.iter().enumerate() {
        let mut node_views: Vec<Option<Axis>> = vec![None; node.inputs.len()];
        let out_axis: Option<Axis> = match &node.op {
            NodeOp::Kernel(kind) => {
                let base = kernel_input_count(kind);
                let primary = operand_axis(
                    g,
                    &mut flow_inputs,
                    &flow_nodes,
                    &denied,
                    node.inputs[0],
                    &node.in_shapes[0],
                    batch,
                    None,
                )?;
                let out = match kind {
                    WorkloadKind::Gemm => {
                        if let ValueRef::Input(bi) = node.inputs[1] {
                            deny_input_axis(
                                &flow_inputs,
                                &mut denied,
                                bi,
                                &format!("{}'s weight operand", node.name),
                            )?;
                        } else if let ValueRef::Node(bj) = node.inputs[1] {
                            if flow_nodes[bj].is_some() {
                                return Err(format!(
                                    "{}: the B operand carries the batch axis",
                                    node.name
                                ));
                            }
                        }
                        match primary {
                            Some(a @ Axis { dim: 0, unit }) => {
                                node_views[0] = Some(a);
                                // per-shard GEMM rows must stay whole
                                // 16-row hardware tiles
                                granule = lcm(granule, 16 / gcd(16, unit));
                                Some(Axis { dim: 0, unit })
                            }
                            Some(a) => {
                                return Err(format!(
                                    "{}: gemm rows carry the batch on dim {} (only a \
                                     leading batch axis is splittable)",
                                    node.name, a.dim
                                ))
                            }
                            None => None,
                        }
                    }
                    WorkloadKind::Dequant { .. } => {
                        for (k, what) in [(1usize, "packed weights"), (2, "scales")] {
                            if let ValueRef::Input(bi) = node.inputs[k] {
                                deny_input_axis(
                                    &flow_inputs,
                                    &mut denied,
                                    bi,
                                    &format!("{}'s {}", node.name, what),
                                )?;
                            } else if let ValueRef::Node(bj) = node.inputs[k] {
                                if flow_nodes[bj].is_some() {
                                    return Err(format!(
                                        "{}: the {} operand carries the batch axis",
                                        node.name, what
                                    ));
                                }
                            }
                        }
                        match primary {
                            Some(a @ Axis { dim: 0, unit }) => {
                                node_views[0] = Some(a);
                                granule = lcm(granule, 16 / gcd(16, unit));
                                // the dequant output is transposed:
                                // activations' rows land on dim 1
                                Some(Axis { dim: 1, unit })
                            }
                            Some(a) => {
                                return Err(format!(
                                    "{}: dequant activations carry the batch on dim {}",
                                    node.name, a.dim
                                ))
                            }
                            None => None,
                        }
                    }
                    WorkloadKind::FlashAttention { .. } | WorkloadKind::FlashDecode => {
                        match primary {
                            Some(a @ Axis { dim: 0, unit }) => {
                                node_views[0] = Some(a);
                                attention_on_axis = true;
                                // K/V must scatter with Q's batch*heads
                                // rows: same extent on their dim 0
                                for k in [1usize, 2] {
                                    let why = format!("{}'s KV operand", node.name);
                                    let got = operand_axis(
                                        g,
                                        &mut flow_inputs,
                                        &flow_nodes,
                                        &denied,
                                        node.inputs[k],
                                        &node.in_shapes[k],
                                        batch,
                                        Some((a, why.as_str())),
                                    )?;
                                    if got != Some(a) {
                                        return Err(format!(
                                            "{}: KV operand {} axis {:?} does not \
                                             match Q's {:?}",
                                            node.name, k, got, a
                                        ));
                                    }
                                    node_views[k] = got;
                                }
                                Some(Axis { dim: 0, unit })
                            }
                            Some(a) => {
                                return Err(format!(
                                    "{}: attention batch*heads carry the batch on dim {}",
                                    node.name, a.dim
                                ))
                            }
                            None => {
                                // a fully replicated attention node: K/V
                                // must not carry either
                                for k in [1usize, 2] {
                                    let got = operand_axis(
                                        g,
                                        &mut flow_inputs,
                                        &flow_nodes,
                                        &denied,
                                        node.inputs[k],
                                        &node.in_shapes[k],
                                        batch,
                                        None,
                                    )?;
                                    if got.is_some() {
                                        return Err(format!(
                                            "{}: KV operand carries the batch axis but \
                                             Q is replicated",
                                            node.name
                                        ));
                                    }
                                }
                                None
                            }
                        }
                    }
                    WorkloadKind::FlashDecodePaged
                    | WorkloadKind::ChunkState
                    | WorkloadKind::ChunkScan => {
                        return Err(format!(
                            "{}: {} nodes are not graph-shardable yet",
                            node.name,
                            kind.tag()
                        ))
                    }
                };
                // fused epilogue operands (pre-seeded graphs)
                let ep_views = epilogue_axes(
                    g,
                    &mut flow_inputs,
                    &flow_nodes,
                    &mut denied,
                    i,
                    base,
                    out,
                    batch,
                )?;
                for (off, a) in ep_views.into_iter().enumerate() {
                    node_views[base + off] = a;
                }
                out
            }
            NodeOp::Elementwise(op) => {
                let primary = operand_axis(
                    g,
                    &mut flow_inputs,
                    &flow_nodes,
                    &denied,
                    node.inputs[0],
                    &node.in_shapes[0],
                    batch,
                    None,
                )?;
                node_views[0] = primary;
                if let (Some(v), Some(view)) = (node.inputs.get(1), node.in_shapes.get(1)) {
                    node_views[1] = ep_operand_axis(
                        g,
                        &mut flow_inputs,
                        &flow_nodes,
                        &mut denied,
                        op,
                        *v,
                        view,
                        primary,
                        batch,
                        &node.name,
                    )?;
                }
                // element-wise outputs keep the primary's shape and axis
                primary
            }
        };
        flow_nodes[i] = out_axis;
        views.push(node_views);
    }
    // the gathered output must carry the axis, or there is nothing to
    // concatenate back
    let out_axis = match g.output {
        ValueRef::Input(i) => flow_inputs[i],
        ValueRef::Node(j) => flow_nodes[j],
    };
    if out_axis.is_none() {
        return Err("the graph output does not carry the partition axis".to_string());
    }
    Ok(BatchFlow {
        inputs: flow_inputs,
        nodes: flow_nodes,
        views,
        attention_on_axis,
        granule,
    })
}

/// Build the sliced sub-graph for one batch span (`start`, `len` in
/// input-0 rows): every axis-carrying shape scales its batch dim, all
/// other shapes stay intact.
fn slice_graph(g: &KernelGraph, flow: &BatchFlow, len: i64) -> KernelGraph {
    let mut sub = g.clone();
    for (gi, axis) in sub.inputs.iter_mut().zip(&flow.inputs) {
        if let Some(a) = axis {
            gi.shape[a.dim] = len * a.unit;
        }
    }
    for (ni, node) in sub.nodes.iter_mut().enumerate() {
        if let Some(a) = flow.nodes[ni] {
            node.out_shape[a.dim] = len * a.unit;
        }
        for (k, view_axis) in flow.views[ni].iter().enumerate() {
            if let Some(a) = view_axis {
                node.in_shapes[k][a.dim] = len * a.unit;
            }
        }
    }
    sub
}

/// Plan how `g` partitions across `shards` executors: run the batch-axis
/// analysis, split the batch into granule-aligned spans, build + cost the
/// per-shard sub-graphs (fused cost of the slowest distinct sub-shape +
/// scatter/gather comm). Errors carry the structural or feasibility
/// reason the block cannot shard.
pub fn plan_graph(g: &KernelGraph, shards: usize, dev: &Device) -> Result<GraphShardPlan> {
    g.validate()?;
    let flow = analyze(g)
        .map_err(|e| anyhow!("{}: graph sharding does not apply: {}", g.name, e))?;
    let batch = g.inputs[0].shape[0];
    let s = shards.max(1) as i64;
    let spans = split_spans("batch rows", batch, s, flow.granule)
        .map_err(|e| anyhow!("{}: {}", g.name, e))?;
    let out_axis = match g.output {
        ValueRef::Input(i) => flow.inputs[i],
        ValueRef::Node(j) => flow.nodes[j],
    }
    .expect("analyze() guarantees an output axis");

    let mut parts = Vec::with_capacity(spans.len());
    for (i, &(start, len)) in spans.iter().enumerate() {
        let sub = slice_graph(g, &flow, len);
        sub.validate()
            .map_err(|e| anyhow!("{}: shard {} sub-graph invalid: {}", g.name, i, e))?;
        let inputs = flow
            .inputs
            .iter()
            .map(|axis| match axis {
                Some(a) => InputSlice::along(a.dim, start * a.unit, len * a.unit),
                None => InputSlice::full(),
            })
            .collect();
        parts.push(GraphShardPart {
            index: i,
            inputs,
            graph: sub,
        });
    }

    // feasibility + cost: the fused program of every distinct sub-shape
    // must build (the same builder path the executor runs), and the
    // compute phase is the slowest shard
    let mut kernel_us = 0f64;
    let mut seen: Vec<i64> = Vec::new();
    for (&(_, len), part) in spans.iter().zip(&parts) {
        if seen.contains(&len) {
            continue;
        }
        seen.push(len);
        let fp = fuse::plan(&part.graph, dev).map_err(|e| {
            anyhow!(
                "{}: shard of {} batch row(s) is infeasible: {}",
                g.name,
                len,
                e
            )
        })?;
        kernel_us = kernel_us.max(fp.fused_cost_us);
    }
    let comm_us = graph_comm_us(g, &flow, dev, spans.len() as f64);

    Ok(GraphShardPlan {
        graph_name: g.name.clone(),
        strategy: if flow.attention_on_axis {
            GraphStrategy::HeadParallel
        } else {
            GraphStrategy::RowParallel
        },
        batch,
        spans,
        parts,
        concat_dim: out_axis.dim,
        kernel_us,
        comm_us,
    })
}

/// All feasible graph partitions for `shards` executors (for the
/// `tilelang plan` strategy table). One partition axis exists today —
/// the block's batch axis — so this returns zero or one plan; the
/// enumeration shape matches the single-kernel planner so more axes can
/// slot in.
pub fn enumerate_graph(g: &KernelGraph, shards: usize, dev: &Device) -> Vec<GraphShardPlan> {
    plan_graph(g, shards, dev).ok().into_iter().collect()
}

/// Scatter + gather byte model over f32 wire tensors (mirrors the
/// single-kernel planner's: sliced tensors move once in total,
/// replicated weights once per shard, the concatenated output once).
fn graph_comm_us(g: &KernelGraph, flow: &BatchFlow, dev: &Device, nparts: f64) -> f64 {
    let mut bytes = 0f64;
    for (gi, axis) in g.inputs.iter().zip(&flow.inputs) {
        let full: i64 = gi.shape.iter().product();
        bytes += full as f64 * 4.0 * if axis.is_none() { nparts } else { 1.0 };
    }
    if let Ok(out) = g.out_shape() {
        bytes += out.iter().product::<i64>() as f64 * 4.0;
    }
    bytes / (link_gbps(dev) * 1e3)
}

/// A graph artifact resolved to per-shard [`GraphKernel`]s plus the
/// scatter/gather plan connecting them — the graph analogue of
/// [`crate::shard::exec::ShardedKernel`].
pub struct ShardedGraphKernel {
    plan: GraphShardPlan,
    /// Distinct prepared graph kernels (uniform splits share one; each
    /// carries its own fusion decision, tuned configs and memplan).
    kernels: Vec<GraphKernel>,
    /// Part index -> index into `kernels`.
    part_kernel: Vec<usize>,
    in_shapes: Vec<Vec<i64>>,
    out_shape: Vec<i64>,
    out_len: usize,
    row_batchable: bool,
}

impl ShardedGraphKernel {
    /// Plan the partition on the modeled device and prepare the
    /// per-shard graph kernels.
    pub fn prepare(
        graph: &KernelGraph,
        opts: &ShardedOptions,
        dir: impl AsRef<Path>,
    ) -> Result<ShardedGraphKernel> {
        let dev = Device::by_name(&opts.interp.device).ok_or_else(|| {
            anyhow!(
                "sharded graph backend: unknown modeled device {:?}",
                opts.interp.device
            )
        })?;
        let plan = plan_graph(graph, opts.shards, &dev)?;
        ShardedGraphKernel::from_plan(graph, plan, &opts.interp, dir)
    }

    /// Prepare per-shard kernels for an explicit plan (differential
    /// tests pin partitions through this). Each *distinct* shard
    /// sub-shape gets one [`GraphKernel`] — fusion planned, per-node
    /// tile configs through the persistent tuning cache in `dir` (keyed
    /// with the shard count), memplan enforced — shared across the
    /// threads of identical shards.
    pub fn from_plan(
        graph: &KernelGraph,
        plan: GraphShardPlan,
        interp: &InterpOptions,
        dir: impl AsRef<Path>,
    ) -> Result<ShardedGraphKernel> {
        let dir = dir.as_ref();
        let mut interp = interp.clone();
        interp.shards = plan.shards();
        let mut kernels: Vec<GraphKernel> = Vec::new();
        let mut kernel_lens: Vec<i64> = Vec::new();
        let mut part_kernel = Vec::with_capacity(plan.shards());
        for (&(_, len), part) in plan.spans.iter().zip(&plan.parts) {
            let ki = match kernel_lens.iter().position(|&l| l == len) {
                Some(ki) => ki,
                None => {
                    kernels.push(
                        GraphKernel::prepare(&part.graph, &interp, dir)
                            .map_err(|e| anyhow!("shard {}: {}", part.index, e))?,
                    );
                    kernel_lens.push(len);
                    kernels.len() - 1
                }
            };
            part_kernel.push(ki);
        }
        Ok(ShardedGraphKernel {
            in_shapes: graph.input_shapes(),
            out_shape: graph.out_shape()?.to_vec(),
            out_len: graph.out_shape()?.iter().product::<i64>() as usize,
            row_batchable: graph.row_batchable(),
            plan,
            kernels,
            part_kernel,
        })
    }

    /// The partition this kernel executes.
    pub fn plan(&self) -> &GraphShardPlan {
        &self.plan
    }

    /// Whether batched *row* serving is sound for the underlying graph
    /// (see `KernelGraph::row_batchable`).
    pub fn row_batchable(&self) -> bool {
        self.row_batchable
    }

    /// Per-lane static data-movement shadows, `("shard<i>", traffic)`
    /// rows in part order: each lane sums its sub-graph's per-node
    /// shadows ([`GraphKernel::node_traffic`]). A lane is `None` when
    /// any of its kernel nodes was prepared for the tree-walking interp
    /// (dynamic `traffic.*` counters still record).
    pub fn shard_traffic(&self) -> Vec<(String, Option<Traffic>)> {
        self.part_kernel
            .iter()
            .enumerate()
            .map(|(si, &ki)| {
                let mut t = Traffic::default();
                let mut complete = true;
                for (_, node) in self.kernels[ki].node_traffic() {
                    match node {
                        Some(nt) => t.merge(&nt),
                        None => complete = false,
                    }
                }
                (format!("shard{}", si), complete.then_some(t))
            })
            .collect()
    }

    /// Whole-request static shadow: the sum over every lane, `None` when
    /// any lane is incomplete. On the compiled backend this equals the
    /// `traffic.*` counters one recorded execution adds.
    pub fn traffic(&self) -> Option<Traffic> {
        let mut t = Traffic::default();
        for (_, lane) in self.shard_traffic() {
            t.merge(&lane?);
        }
        Some(t)
    }

    /// Per-lane modeled DRAM bytes (`tilelang roofline` calibration
    /// denominators): each lane sums its sub-graph's per-node
    /// predictions, `None` when any node is uncostable.
    pub fn shard_modeled_bytes(&self) -> Vec<(String, Option<f64>)> {
        self.part_kernel
            .iter()
            .enumerate()
            .map(|(si, &ki)| {
                let mut total = 0f64;
                let mut complete = true;
                for (_, b) in self.kernels[ki].node_modeled_bytes() {
                    match b {
                        Some(b) => total += b,
                        None => complete = false,
                    }
                }
                (format!("shard{}", si), complete.then_some(total))
            })
            .collect()
    }

    /// One-line summary for serve output and logs (plan + the shared
    /// per-shard kernel's fusion/memplan description).
    pub fn describe(&self) -> String {
        format!(
            "{}: sharded {}; per-shard {}",
            self.plan.graph_name,
            self.plan.describe(),
            self.kernels[self.part_kernel[0]].describe()
        )
    }

    /// Scatter -> parallel per-shard graph execution -> concat gather.
    pub fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        self.execute_rec(inputs, &Recorder::disabled())
    }

    /// [`ShardedGraphKernel::execute`] under a [`Recorder`]: scatter /
    /// per-shard compute / gather spans, with each shard thread
    /// recording through a forked [`crate::obs::ThreadBuf`]. The
    /// per-shard [`GraphKernel`] adds its own per-node `graph` spans on
    /// the shard thread's lane.
    pub fn execute_rec(&self, inputs: &[Vec<f32>], rec: &Recorder) -> Result<Vec<f32>> {
        if inputs.len() != self.in_shapes.len() {
            bail!(
                "sharded graph expects {} inputs, got {}",
                self.in_shapes.len(),
                inputs.len()
            );
        }
        for (i, (data, shape)) in inputs.iter().zip(&self.in_shapes).enumerate() {
            let want = shape.iter().product::<i64>() as usize;
            if data.len() != want {
                bail!(
                    "sharded graph input {} length {} != shape {:?}",
                    i,
                    data.len(),
                    shape
                );
            }
        }
        // scatter: slice the batch-carrying tensors, borrow the rest
        let scatter_sp = rec.span_with("shard", "scatter", || {
            vec![
                ("graph".to_string(), self.plan.graph_name.clone()),
                ("strategy".to_string(), self.plan.strategy.to_string()),
                ("shards".to_string(), self.plan.shards().to_string()),
            ]
        });
        let mut shard_inputs: Vec<Vec<Cow<'_, [f32]>>> = Vec::with_capacity(self.plan.shards());
        for part in &self.plan.parts {
            let mut ins = Vec::with_capacity(inputs.len());
            for (i, slice) in part.inputs.iter().enumerate() {
                ins.push(match slice.dim {
                    None => Cow::Borrowed(inputs[i].as_slice()),
                    Some(d) => Cow::Owned(slice_tensor(
                        &inputs[i],
                        &self.in_shapes[i],
                        d,
                        slice.start,
                        slice.len,
                    )),
                });
            }
            shard_inputs.push(ins);
        }
        scatter_sp.finish_us();
        // one thread per shard; identical shards share a prepared kernel
        let outs: Vec<Result<Vec<f32>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .part_kernel
                .iter()
                .zip(shard_inputs.iter())
                .enumerate()
                .map(|(si, (&ki, ins))| {
                    let kernel = &self.kernels[ki];
                    let rec = rec.clone();
                    scope.spawn(move || {
                        let mut tb = rec.fork();
                        let t0 = Instant::now();
                        let refs: Vec<&[f32]> = ins.iter().map(|c| c.as_ref()).collect();
                        let out = kernel.execute_refs_rec(&refs, &rec);
                        tb.span_with("shard", "compute", t0, || {
                            vec![("shard".to_string(), si.to_string())]
                        });
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("graph shard thread panicked")))
                })
                .collect()
        });
        let mut parts_data = Vec::with_capacity(outs.len());
        let gather_sp = rec.span("shard", "gather");
        for (i, r) in outs.into_iter().enumerate() {
            parts_data.push(r.map_err(|e| anyhow!("shard {}: {}", i, e))?);
        }
        let gathered = self.gather(parts_data);
        gather_sp.finish_us();
        gathered
    }

    /// Concatenate shard outputs along `plan.concat_dim` in shard order.
    fn gather(&self, parts: Vec<Vec<f32>>) -> Result<Vec<f32>> {
        let dim = self.plan.concat_dim;
        if dim == 0 {
            // leading-dim bands are contiguous in row-major order
            let mut out = Vec::with_capacity(self.out_len);
            for p in parts {
                out.extend_from_slice(&p);
            }
            if out.len() != self.out_len {
                bail!(
                    "gathered graph output has {} elements, artifact expects {}",
                    out.len(),
                    self.out_len
                );
            }
            return Ok(out);
        }
        // inner-dim concat (the transposed dequant output): interleave
        // each shard's band into every outer row
        let outer: i64 = self.out_shape[..dim].iter().product();
        let inner: i64 = self.out_shape[dim + 1..].iter().product();
        let full_extent = self.out_shape[dim];
        let mut out = vec![0f32; self.out_len];
        let mut offset = 0i64;
        for (pi, (part, part_graph)) in
            parts.iter().zip(self.plan.parts.iter().map(|p| &p.graph)).enumerate()
        {
            let extent = part_graph
                .out_shape()
                .map_err(|e| anyhow!("shard {}: {}", pi, e))?[dim];
            if part.len() as i64 != outer * extent * inner {
                bail!(
                    "shard {} output has {} elements, its sub-graph expects {}",
                    pi,
                    part.len(),
                    outer * extent * inner
                );
            }
            for o in 0..outer {
                let src = (o * extent * inner) as usize;
                let dst = ((o * full_extent + offset) * inner) as usize;
                let n = (extent * inner) as usize;
                out[dst..dst + n].copy_from_slice(&part[src..src + n]);
            }
            offset += extent;
        }
        if offset != full_extent {
            bail!(
                "gathered bands cover {} of dim {} extent {}",
                offset,
                dim,
                full_extent
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::{attention_block, decode_block, dequant_mlp_block, mlp_block};
    use crate::workloads::dequant::WeightFormat;

    fn h100() -> Device {
        Device::h100()
    }

    #[test]
    fn mlp_block_plans_row_parallel() {
        let g = mlp_block(64, 64, 128);
        let p = plan_graph(&g, 2, &h100()).expect("plan");
        assert_eq!(p.strategy, GraphStrategy::RowParallel);
        assert_eq!(p.spans, vec![(0, 32), (32, 32)]);
        assert_eq!(p.concat_dim, 0);
        // X slices, all four weights replicate
        assert_eq!(p.parts[1].inputs[0], InputSlice::along(0, 32, 32));
        for w in 1..5 {
            assert_eq!(p.parts[1].inputs[w], InputSlice::full(), "input {}", w);
        }
        // the sub-graph is the same block at half the rows
        assert_eq!(p.parts[0].graph.nodes.len(), g.nodes.len());
        assert_eq!(p.parts[0].graph.inputs[0].shape, vec![32, 64]);
        assert_eq!(p.parts[0].graph.out_shape().unwrap(), &[32, 64]);
        assert!(p.kernel_us > 0.0 && p.comm_us > 0.0);
        // uneven remainder spans hand out whole 16-row tiles
        let p3 = plan_graph(&g, 3, &h100()).expect("plan x3");
        assert_eq!(p3.spans, vec![(0, 32), (32, 16), (48, 16)]);
    }

    #[test]
    fn decode_block_plans_head_parallel_with_scattered_caches() {
        let g = decode_block(64, 16, 16, 64);
        let p = plan_graph(&g, 2, &h100()).expect("plan");
        assert_eq!(p.strategy, GraphStrategy::HeadParallel);
        assert_eq!(p.concat_dim, 0);
        // X and both caches scatter with the streams; weights replicate
        assert_eq!(p.parts[1].inputs[0], InputSlice::along(0, 32, 32));
        assert_eq!(p.parts[1].inputs[2], InputSlice::along(0, 32, 32));
        assert_eq!(p.parts[1].inputs[3], InputSlice::along(0, 32, 32));
        assert_eq!(p.parts[1].inputs[1], InputSlice::full());
        assert_eq!(p.parts[1].inputs[4], InputSlice::full());
        assert_eq!(p.parts[1].inputs[5], InputSlice::full());
        // the per-shard attention keeps all 16 heads over 32 streams
        let sub = &p.parts[0].graph;
        assert_eq!(sub.nodes[1].in_shapes[0], vec![32, 16, 16]);
        assert_eq!(sub.nodes[1].in_shapes[1], vec![32, 64, 16]);
    }

    #[test]
    fn dequant_block_concatenates_along_dim_1() {
        let g = dequant_mlp_block(64, 64, 64, 64, WeightFormat::Int4, 32);
        let p = plan_graph(&g, 2, &h100()).expect("plan");
        assert_eq!(p.strategy, GraphStrategy::RowParallel);
        // the transposed dequant output carries the batch on dim 1
        assert_eq!(p.concat_dim, 1);
        assert_eq!(p.parts[0].graph.out_shape().unwrap(), &[64, 32]);
        // packed weights, scales and the dim-0 bias replicate
        assert_eq!(p.parts[1].inputs[3], InputSlice::full());
        assert_eq!(p.parts[1].inputs[4], InputSlice::full());
        assert_eq!(p.parts[1].inputs[5], InputSlice::full());
    }

    #[test]
    fn attention_block_is_rejected_with_a_reason() {
        // the single-head [seq, d] -> [1, seq, d] view moves the batch
        // rows off the leading dim (and the flash kernel mixes them)
        let g = attention_block(128, 64, false);
        let err = plan_graph(&g, 2, &h100()).unwrap_err().to_string();
        assert!(
            err.contains("does not apply") && err.contains("leading"),
            "{}",
            err
        );
        assert!(enumerate_graph(&g, 2, &h100()).is_empty());
    }

    #[test]
    fn over_split_blocks_are_rejected() {
        // 64 rows = 4 gemm tiles: 5 shards cannot each hold one
        let g = mlp_block(64, 64, 128);
        let err = plan_graph(&g, 5, &h100()).unwrap_err().to_string();
        assert!(err.contains("fewer than 5 shards"), "{}", err);
    }
}
