//! The sharding planner: choose how one workload partitions across N
//! executors.
//!
//! A [`ShardPlan`] is a pure description — which slice of every input
//! each shard receives (or whether it is replicated), the shape of each
//! per-shard sub-problem, and the [`Collective`] that recombines the
//! shard outputs. Plans are chosen by cost: the analytical device model
//! scores the per-shard kernel (`sim::simulate_kernel` on the sub-shape,
//! via the same `build_program` path the interpreter backend executes,
//! so planner feasibility equals execution feasibility) and a simple
//! bandwidth model scores the scatter/gather communication.
//!
//! Strategies per workload family:
//!
//! | family                  | strategies                          |
//! |-------------------------|-------------------------------------|
//! | gemm / linear           | row-parallel (split M), split-K     |
//! | flash attention         | head-parallel (split batch*heads)   |
//! | dequant-GEMM            | row-parallel (split output rows N)  |
//! | chunk_state / chunk_scan| chunk-parallel (split batch*heads)  |
//!
//! Splits need not be even: shard counts that do not divide the
//! partitioned dimension get remainder spans (whole hardware tiles,
//! distributed over the leading shards), and the compute phase is
//! costed as the slowest shard.

use std::fmt;
use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::runtime::interp_backend::build_program;
use crate::runtime::{ArtifactSpec, InterpOptions, WorkloadKind};
use crate::sim::device::Device;
use crate::sim::model::{simulate_kernel, Penalties};
use crate::{anyhow, bail};

/// How one workload is partitioned across shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Split the output rows: GEMM M (data-parallel over the batch/row
    /// dimension) or dequant-GEMM output rows N. Shards are independent;
    /// outputs concatenate.
    RowParallel,
    /// Split the GEMM reduction dimension K; every shard produces a
    /// full-size partial product and the collective sums them.
    SplitK,
    /// Split the flattened batch*heads dimension of attention; heads
    /// never mix, so shards are independent and outputs concatenate.
    HeadParallel,
    /// Split the flattened batch*heads dimension of the Mamba-2 chunk
    /// kernels; per-head chunk blocks are independent.
    ChunkParallel,
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Strategy::RowParallel => "row_parallel",
            Strategy::SplitK => "split_k",
            Strategy::HeadParallel => "head_parallel",
            Strategy::ChunkParallel => "chunk_parallel",
        })
    }
}

/// How shard outputs recombine into the full output tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Collective {
    /// Concatenate along the leading output dimension (row-major, so a
    /// flat concatenation in shard order).
    Concat,
    /// [`Collective::Concat`] along the batch*heads dimension — kept as
    /// its own variant so plans read as what they are semantically.
    HeadConcat,
    /// Element-wise sum of full-size partial outputs (split-K).
    SumReduce,
}

impl fmt::Display for Collective {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Collective::Concat => "concat",
            Collective::HeadConcat => "head_concat",
            Collective::SumReduce => "sum_reduce",
        })
    }
}

/// How one shard obtains one input tensor from the full tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InputSlice {
    /// Dimension the input is sliced along; `None` replicates the full
    /// tensor to every shard.
    pub dim: Option<usize>,
    /// Start offset along `dim` (0 when replicated).
    pub start: i64,
    /// Extent along `dim` (0 when replicated).
    pub len: i64,
}

impl InputSlice {
    /// Replicate the full tensor to this shard.
    pub fn full() -> InputSlice {
        InputSlice {
            dim: None,
            start: 0,
            len: 0,
        }
    }

    /// Slice `len` elements starting at `start` along `dim`.
    pub fn along(dim: usize, start: i64, len: i64) -> InputSlice {
        InputSlice {
            dim: Some(dim),
            start,
            len,
        }
    }
}

/// One shard's sub-problem: input slices and sub-shapes.
#[derive(Clone, Debug)]
pub struct ShardSpec {
    pub index: usize,
    /// Per input (manifest order): slice or replicate.
    pub inputs: Vec<InputSlice>,
    /// The shard's input shapes (after slicing).
    pub in_shapes: Vec<Vec<i64>>,
    /// The shard's output shape (a partial for [`Collective::SumReduce`],
    /// a band of the full output otherwise).
    pub out_shape: Vec<i64>,
}

impl ShardSpec {
    /// Number of output elements this shard produces.
    pub fn out_len(&self) -> usize {
        self.out_shape.iter().product::<i64>() as usize
    }
}

/// A complete sharding decision for one workload.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub workload: WorkloadKind,
    pub strategy: Strategy,
    pub parts: Vec<ShardSpec>,
    pub collective: Collective,
    /// Modeled kernel time of the *slowest* shard (shards run in
    /// parallel, so this is the whole compute phase), microseconds.
    pub kernel_us: f64,
    /// Modeled scatter + gather communication time, microseconds.
    pub comm_us: f64,
}

impl ShardPlan {
    pub fn shards(&self) -> usize {
        self.parts.len()
    }

    /// Total modeled time the planner minimizes.
    pub fn cost_us(&self) -> f64 {
        self.kernel_us + self.comm_us
    }

    /// One-line human description for CLI / serve output.
    pub fn describe(&self) -> String {
        format!(
            "{} x{} ({}), modeled {:.1} us kernel + {:.1} us comm",
            self.strategy,
            self.shards(),
            self.collective,
            self.kernel_us,
            self.comm_us
        )
    }
}

/// The strategies that can apply to a workload family.
pub fn strategies_for(kind: &WorkloadKind) -> &'static [Strategy] {
    match kind {
        WorkloadKind::Gemm => &[Strategy::RowParallel, Strategy::SplitK],
        WorkloadKind::FlashAttention { .. } | WorkloadKind::FlashDecode => {
            &[Strategy::HeadParallel]
        }
        // the paged kernel's gather buffers are views into the serving
        // engine's shared KV pool: slicing them per shard would deep-copy
        // the pool (defeating paging), so it has no shard strategies —
        // continuous batching scales by co-batching streams instead
        WorkloadKind::FlashDecodePaged => &[],
        WorkloadKind::Dequant { .. } => &[Strategy::RowParallel],
        WorkloadKind::ChunkState | WorkloadKind::ChunkScan => &[Strategy::ChunkParallel],
    }
}

/// Resolve the workload family of a manifest artifact (tag, then
/// name-prefix fallback).
pub fn resolve_kind(spec: &ArtifactSpec) -> Result<WorkloadKind> {
    WorkloadKind::for_spec(spec)
}

/// Choose the cheapest feasible plan for `shards` executors.
pub fn plan(
    kind: &WorkloadKind,
    in_shapes: &[Vec<i64>],
    out_shape: &[i64],
    shards: usize,
    dev: &Device,
) -> Result<ShardPlan> {
    let mut best: Option<ShardPlan> = None;
    let mut errors = Vec::new();
    for &st in strategies_for(kind) {
        match plan_with_strategy(kind, in_shapes, out_shape, shards, st, dev) {
            Ok(p) => {
                let better = match &best {
                    None => true,
                    Some(b) => p.cost_us() < b.cost_us(),
                };
                if better {
                    best = Some(p);
                }
            }
            Err(e) => errors.push(format!("{}: {}", st, e)),
        }
    }
    best.ok_or_else(|| {
        anyhow!(
            "no feasible sharding strategy for {} across {} shards ({})",
            kind.tag(),
            shards,
            errors.join("; ")
        )
    })
}

/// All feasible plans for `shards` executors, costed (for `tilelang
/// plan` output and planner tests). Infeasible strategies are skipped.
pub fn enumerate(
    kind: &WorkloadKind,
    in_shapes: &[Vec<i64>],
    out_shape: &[i64],
    shards: usize,
    dev: &Device,
) -> Vec<ShardPlan> {
    strategies_for(kind)
        .iter()
        .filter_map(|&st| plan_with_strategy(kind, in_shapes, out_shape, shards, st, dev).ok())
        .collect()
}

/// Build and cost the plan for one specific strategy (differential tests
/// pin strategies through this; `plan` ranks the feasible ones).
pub fn plan_with_strategy(
    kind: &WorkloadKind,
    in_shapes: &[Vec<i64>],
    out_shape: &[i64],
    shards: usize,
    strategy: Strategy,
    dev: &Device,
) -> Result<ShardPlan> {
    let s = shards.max(1) as i64;
    let (parts, collective): (Vec<ShardSpec>, Collective) = match (kind, strategy) {
        (WorkloadKind::Gemm, Strategy::RowParallel) => {
            let (m, k, n) = gemm_dims(in_shapes, out_shape)?;
            let spans = split_spans("M", m, s, 16)?;
            let parts = spans
                .iter()
                .enumerate()
                .map(|(i, &(start, len))| ShardSpec {
                    index: i,
                    inputs: vec![InputSlice::along(0, start, len), InputSlice::full()],
                    in_shapes: vec![vec![len, k], vec![k, n]],
                    out_shape: vec![len, n],
                })
                .collect();
            (parts, Collective::Concat)
        }
        (WorkloadKind::Gemm, Strategy::SplitK) => {
            let (m, k, n) = gemm_dims(in_shapes, out_shape)?;
            let spans = split_spans("K", k, s, 16)?;
            let parts = spans
                .iter()
                .enumerate()
                .map(|(i, &(start, len))| ShardSpec {
                    index: i,
                    inputs: vec![
                        InputSlice::along(1, start, len),
                        InputSlice::along(0, start, len),
                    ],
                    in_shapes: vec![vec![m, len], vec![len, n]],
                    out_shape: vec![m, n],
                })
                .collect();
            (parts, Collective::SumReduce)
        }
        (WorkloadKind::FlashAttention { .. }, Strategy::HeadParallel) => {
            if in_shapes.len() != 3 || in_shapes.iter().any(|sh| sh != &in_shapes[0]) {
                bail!("attention expects 3 identical rank-3 inputs, got {:?}", in_shapes);
            }
            if in_shapes[0].len() != 3 || out_shape != in_shapes[0].as_slice() {
                bail!(
                    "attention output {:?} must match Q {:?}",
                    out_shape,
                    in_shapes[0]
                );
            }
            let (bh, seq, d) = (in_shapes[0][0], in_shapes[0][1], in_shapes[0][2]);
            let spans = split_spans("batch*heads", bh, s, 1)?;
            let parts = spans
                .iter()
                .enumerate()
                .map(|(i, &(start, len))| ShardSpec {
                    index: i,
                    inputs: vec![InputSlice::along(0, start, len); 3],
                    in_shapes: vec![vec![len, seq, d]; 3],
                    out_shape: vec![len, seq, d],
                })
                .collect();
            (parts, Collective::HeadConcat)
        }
        (WorkloadKind::FlashDecode, Strategy::HeadParallel) => {
            // Q: [b, heads, d] (one query per stream*head), K/V cache:
            // [b, kv, d] shared by each stream's heads — the sliceable
            // axis is the stream batch, which is the flash grid's
            // batch*heads analogue (heads never mix across streams)
            if in_shapes.len() != 3 || in_shapes.iter().any(|sh| sh.len() != 3) {
                bail!("flash_decode expects 3 rank-3 inputs, got {:?}", in_shapes);
            }
            let q = &in_shapes[0];
            let (b, h, d) = (q[0], q[1], q[2]);
            let kv = in_shapes[1][1];
            if in_shapes[1] != vec![b, kv, d]
                || in_shapes[2] != in_shapes[1]
                || out_shape != q.as_slice()
            {
                bail!(
                    "inconsistent flash_decode shapes (Q {:?}, K {:?}, V {:?}, out {:?})",
                    q,
                    in_shapes[1],
                    in_shapes[2],
                    out_shape
                );
            }
            let spans = split_spans("streams", b, s, 1)?;
            let parts = spans
                .iter()
                .enumerate()
                .map(|(i, &(start, len))| ShardSpec {
                    index: i,
                    inputs: vec![InputSlice::along(0, start, len); 3],
                    in_shapes: vec![vec![len, h, d], vec![len, kv, d], vec![len, kv, d]],
                    out_shape: vec![len, h, d],
                })
                .collect();
            (parts, Collective::HeadConcat)
        }
        (WorkloadKind::Dequant { .. }, Strategy::RowParallel) => {
            if in_shapes.len() != 3 || in_shapes.iter().any(|sh| sh.len() != 2) {
                bail!("dequant expects 3 rank-2 inputs, got {:?}", in_shapes);
            }
            // A: [m, k], packed B: [n, k/epb], scales: [n, k/group],
            // output Ct: [n, m] — split the output rows N
            let (m, k) = (in_shapes[0][0], in_shapes[0][1]);
            let n = in_shapes[1][0];
            let spans = split_spans("N", n, s, 16)?;
            let (kb, kg) = (in_shapes[1][1], in_shapes[2][1]);
            let parts = spans
                .iter()
                .enumerate()
                .map(|(i, &(start, len))| ShardSpec {
                    index: i,
                    inputs: vec![
                        InputSlice::full(),
                        InputSlice::along(0, start, len),
                        InputSlice::along(0, start, len),
                    ],
                    in_shapes: vec![vec![m, k], vec![len, kb], vec![len, kg]],
                    out_shape: vec![len, m],
                })
                .collect();
            (parts, Collective::Concat)
        }
        (WorkloadKind::ChunkState, Strategy::ChunkParallel) => {
            if in_shapes.len() != 3 || out_shape.len() != 3 {
                bail!("chunk_state expects 3 inputs + rank-3 output");
            }
            // B: [bh, seq, N], X: [bh, seq, P], W: [bh, seq],
            // output S: [bh * nchunks, N, P]
            let bh = in_shapes[0][0];
            if bh <= 0 || out_shape[0] % bh != 0 {
                bail!("state rows {} do not tile batch*heads {}", out_shape[0], bh);
            }
            let nchunks = out_shape[0] / bh;
            let spans = split_spans("batch*heads", bh, s, 1)?;
            let parts = spans
                .iter()
                .enumerate()
                .map(|(i, &(start, len))| ShardSpec {
                    index: i,
                    inputs: vec![InputSlice::along(0, start, len); 3],
                    in_shapes: in_shapes
                        .iter()
                        .map(|sh| {
                            let mut sub = sh.clone();
                            sub[0] = len;
                            sub
                        })
                        .collect(),
                    out_shape: vec![len * nchunks, out_shape[1], out_shape[2]],
                })
                .collect();
            (parts, Collective::Concat)
        }
        (WorkloadKind::ChunkScan, Strategy::ChunkParallel) => {
            if in_shapes.len() != 3 || out_shape.len() != 3 {
                bail!("chunk_scan expects 3 inputs + rank-3 output");
            }
            // C: [bh, seq, N], S: [bh * nchunks, N, P], W2: [bh, seq],
            // output Y: [bh, seq, P]
            let bh = in_shapes[0][0];
            if bh <= 0 || in_shapes[1][0] % bh != 0 {
                bail!(
                    "state rows {} do not tile batch*heads {}",
                    in_shapes[1][0],
                    bh
                );
            }
            let nchunks = in_shapes[1][0] / bh;
            let spans = split_spans("batch*heads", bh, s, 1)?;
            let parts = spans
                .iter()
                .enumerate()
                .map(|(i, &(start, len))| ShardSpec {
                    index: i,
                    inputs: vec![
                        InputSlice::along(0, start, len),
                        InputSlice::along(0, start * nchunks, len * nchunks),
                        InputSlice::along(0, start, len),
                    ],
                    in_shapes: vec![
                        vec![len, in_shapes[0][1], in_shapes[0][2]],
                        vec![len * nchunks, in_shapes[1][1], in_shapes[1][2]],
                        vec![len, in_shapes[2][1]],
                    ],
                    out_shape: vec![len, out_shape[1], out_shape[2]],
                })
                .collect();
            (parts, Collective::Concat)
        }
        (kind, strategy) => bail!("strategy {} does not apply to {}", strategy, kind.tag()),
    };
    // shards run in parallel, so the compute phase is the *slowest*
    // shard; uneven splits make parts non-uniform, so cost each
    // distinct sub-shape (uniform plans still cost one kernel)
    let mut kernel_us = 0f64;
    let mut seen: Vec<&Vec<Vec<i64>>> = Vec::new();
    for part in &parts {
        if seen.contains(&&part.in_shapes) {
            continue;
        }
        seen.push(&part.in_shapes);
        kernel_us = kernel_us.max(shard_kernel_us(kind, part, dev)?);
    }
    let comm_us = comm_us(in_shapes, out_shape, &parts, collective, dev);
    Ok(ShardPlan {
        workload: kind.clone(),
        strategy,
        parts,
        collective,
        kernel_us,
        comm_us,
    })
}

fn gemm_dims(in_shapes: &[Vec<i64>], out_shape: &[i64]) -> Result<(i64, i64, i64)> {
    if in_shapes.len() != 2 || in_shapes.iter().any(|sh| sh.len() != 2) || out_shape.len() != 2 {
        bail!("gemm expects 2 rank-2 inputs + rank-2 output, got {:?}", in_shapes);
    }
    let (m, k, n) = (in_shapes[0][0], in_shapes[0][1], in_shapes[1][1]);
    if in_shapes[1][0] != k || out_shape != [m, n] {
        bail!(
            "inconsistent gemm shapes (A {:?}, B {:?}, out {:?})",
            in_shapes[0],
            in_shapes[1],
            out_shape
        );
    }
    Ok((m, k, n))
}

/// Divide `extent` into `s` contiguous spans of `granule`-aligned
/// sizes, distributing the remainder one granule at a time over the
/// leading shards — so shard counts that do not divide the extent stop
/// being rejected. The granule is the hardware tile the per-shard
/// kernel needs (16 rows for GEMM dims — sub-16 shards pad back up to
/// the instruction tile and just recompute the full problem; 1 for
/// head/chunk dims). Returns `(start, len)` per shard.
pub(crate) fn split_spans(
    name: &str,
    extent: i64,
    s: i64,
    granule: i64,
) -> Result<Vec<(i64, i64)>> {
    if extent % granule != 0 {
        bail!(
            "{} = {} is not a multiple of the {}-wide hardware tile",
            name,
            extent,
            granule
        );
    }
    let granules = extent / granule;
    if granules < s {
        bail!(
            "{} = {} has only {} tile(s) of {}, fewer than {} shards",
            name,
            extent,
            granules,
            granule,
            s
        );
    }
    let base = granules / s;
    let rem = granules % s;
    let mut spans = Vec::with_capacity(s as usize);
    let mut start = 0i64;
    for i in 0..s {
        let len = (base + i64::from(i < rem)) * granule;
        spans.push((start, len));
        start += len;
    }
    debug_assert_eq!(start, extent);
    Ok(spans)
}

/// Score one shard's kernel with the analytical device model, through
/// the exact program-construction path the interpreter backend executes.
fn shard_kernel_us(kind: &WorkloadKind, part: &ShardSpec, dev: &Device) -> Result<f64> {
    let spec = ArtifactSpec {
        name: format!("shard-plan.{}", kind.tag()),
        hlo_path: PathBuf::from("-"),
        in_shapes: part.in_shapes.clone(),
        out_shape: part.out_shape.clone(),
        workload: Some(kind.tag()),
        graph: None,
    };
    let opts = InterpOptions {
        tune: false, // static default configs: uniform, cache-free costing
        ..Default::default()
    };
    let prog = build_program(kind, &spec, dev, &opts, Path::new("."))?;
    // mirror InterpKernel::prepare's parameter-contract check: a program
    // whose padded shapes (sub-16 GEMM dims) differ from the shard spec
    // cannot execute, so the planner must reject it identically
    if prog.params.len() != spec.in_shapes.len() + 1 {
        bail!(
            "workload program has {} params for {} shard inputs",
            prog.params.len(),
            spec.in_shapes.len()
        );
    }
    for (i, shape) in spec.in_shapes.iter().enumerate() {
        if prog.params[i].static_shape().as_deref() != Some(shape.as_slice()) {
            bail!(
                "shard input {} shape {:?} does not match the workload program ({:?}): \
                 padded sub-tile dims cannot execute",
                i,
                shape,
                prog.params[i].static_shape()
            );
        }
    }
    let out = prog.params.last().expect("checked non-empty above");
    if out.static_shape().as_deref() != Some(part.out_shape.as_slice()) {
        bail!(
            "shard output shape {:?} does not match the workload program ({:?})",
            part.out_shape,
            out.static_shape()
        );
    }
    let report = simulate_kernel(&prog, dev, &Penalties::none())
        .map_err(|e| anyhow!("shard cost model: {}", e))?;
    Ok(report.time_us)
}

/// Modeled executor-interconnect bandwidth: NVLink-class links run at
/// roughly 1/8 of the device's HBM bandwidth.
pub(crate) fn link_gbps(dev: &Device) -> f64 {
    (dev.dram_gbps / 8.0).max(1.0)
}

/// Scatter + gather byte model over f32 wire tensors: sliced inputs move
/// once in total, replicated inputs move once *per shard*; concat
/// gathers move the output once, sum-reduce gathers move a full-size
/// partial per shard.
fn comm_us(
    in_shapes: &[Vec<i64>],
    out_shape: &[i64],
    parts: &[ShardSpec],
    collective: Collective,
    dev: &Device,
) -> f64 {
    let nparts = parts.len() as f64;
    let mut bytes = 0f64;
    for (i, shape) in in_shapes.iter().enumerate() {
        let full: i64 = shape.iter().product();
        let replicated = parts[0]
            .inputs
            .get(i)
            .map(|sl| sl.dim.is_none())
            .unwrap_or(true);
        bytes += full as f64 * 4.0 * if replicated { nparts } else { 1.0 };
    }
    let out: i64 = out_shape.iter().product();
    let gather_copies = match collective {
        Collective::SumReduce => nparts,
        Collective::Concat | Collective::HeadConcat => 1.0,
    };
    bytes += out as f64 * 4.0 * gather_copies;
    // GB/s == bytes/ns * 1e-3 -> bytes / (gbps * 1e3) is microseconds
    bytes / (link_gbps(dev) * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h100() -> Device {
        Device::h100()
    }

    #[test]
    fn gemm_row_parallel_parts_tile_the_problem() {
        let p = plan_with_strategy(
            &WorkloadKind::Gemm,
            &[vec![64, 64], vec![64, 64]],
            &[64, 64],
            4,
            Strategy::RowParallel,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.parts.len(), 4);
        assert_eq!(p.collective, Collective::Concat);
        for (i, part) in p.parts.iter().enumerate() {
            assert_eq!(part.in_shapes[0], vec![16, 64]);
            assert_eq!(part.inputs[0], InputSlice::along(0, 16 * i as i64, 16));
            assert_eq!(part.inputs[1], InputSlice::full());
            assert_eq!(part.out_shape, vec![16, 64]);
        }
        assert!(p.kernel_us > 0.0 && p.comm_us > 0.0);
    }

    #[test]
    fn split_k_produces_full_size_partials() {
        let p = plan_with_strategy(
            &WorkloadKind::Gemm,
            &[vec![64, 64], vec![64, 64]],
            &[64, 64],
            2,
            Strategy::SplitK,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.collective, Collective::SumReduce);
        for part in &p.parts {
            assert_eq!(part.out_shape, vec![64, 64]);
            assert_eq!(part.in_shapes[0], vec![64, 32]);
            assert_eq!(part.in_shapes[1], vec![32, 64]);
        }
    }

    #[test]
    fn uneven_splits_distribute_whole_tiles() {
        // 64 rows across 3 shards: 4 row tiles of 16 -> 32, 16, 16
        let p = plan_with_strategy(
            &WorkloadKind::Gemm,
            &[vec![64, 64], vec![64, 64]],
            &[64, 64],
            3,
            Strategy::RowParallel,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.parts.len(), 3);
        assert_eq!(p.parts[0].inputs[0], InputSlice::along(0, 0, 32));
        assert_eq!(p.parts[1].inputs[0], InputSlice::along(0, 32, 16));
        assert_eq!(p.parts[2].inputs[0], InputSlice::along(0, 48, 16));
        assert_eq!(p.parts[0].out_shape, vec![32, 64]);
        assert_eq!(p.parts[2].out_shape, vec![16, 64]);
        assert!(p.kernel_us > 0.0);
        // heads: 4 across 3 shards -> 2, 1, 1
        let p = plan_with_strategy(
            &WorkloadKind::FlashAttention { causal: false },
            &[vec![4, 128, 64]; 3],
            &[4, 128, 64],
            3,
            Strategy::HeadParallel,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.parts[0].out_shape, vec![2, 128, 64]);
        assert_eq!(p.parts[2].inputs[0], InputSlice::along(0, 3, 1));
        // split-K remainder: K = 64 across 3 shards -> 32, 16, 16 deep
        let p = plan_with_strategy(
            &WorkloadKind::Gemm,
            &[vec![64, 64], vec![64, 64]],
            &[64, 64],
            3,
            Strategy::SplitK,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.parts[0].in_shapes[0], vec![64, 32]);
        assert_eq!(p.parts[1].inputs[1], InputSlice::along(0, 32, 16));
    }

    #[test]
    fn indivisible_or_degenerate_splits_are_errors() {
        // 32 rows across 4 shards: only 2 row tiles of 16 for 4 shards
        assert!(plan_with_strategy(
            &WorkloadKind::Gemm,
            &[vec![32, 64], vec![64, 64]],
            &[32, 64],
            4,
            Strategy::RowParallel,
            &h100(),
        )
        .is_err());
        // strategy / family mismatch
        assert!(plan_with_strategy(
            &WorkloadKind::Gemm,
            &[vec![64, 64], vec![64, 64]],
            &[64, 64],
            2,
            Strategy::HeadParallel,
            &h100(),
        )
        .is_err());
        // no strategy at all -> plan() reports every failure: M = 16 is
        // a single row tile (cannot split 3 ways) and K = 62 is not
        // 16-tile aligned for split-K
        let err = plan(
            &WorkloadKind::Gemm,
            &[vec![16, 62], vec![62, 64]],
            &[16, 64],
            3,
            &h100(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("no feasible sharding strategy"), "{}", err);
    }

    #[test]
    fn decode_gemv_prefers_split_k() {
        // m = 16 (the padded decode-GEMV class): the row dimension cannot
        // split further, so the planner must choose split-K
        let p = plan(
            &WorkloadKind::Gemm,
            &[vec![16, 16384], vec![16384, 16384]],
            &[16, 16384],
            2,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.strategy, Strategy::SplitK);
        // m = 1 pads to the 16-row tile inside the workload program, which
        // the executor rejects — the planner must reject it identically
        // (planner feasibility == execution feasibility)
        assert!(plan(
            &WorkloadKind::Gemm,
            &[vec![1, 16384], vec![16384, 16384]],
            &[1, 16384],
            2,
            &h100(),
        )
        .is_err());
    }

    #[test]
    fn shallow_k_prefers_row_parallel() {
        // K = 16: split-K shards would fall below the 16-deep minimum
        let p = plan(
            &WorkloadKind::Gemm,
            &[vec![4096, 16], vec![16, 1024]],
            &[4096, 1024],
            2,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.strategy, Strategy::RowParallel);
    }

    #[test]
    fn attention_and_chunk_families_shard_over_heads() {
        let p = plan(
            &WorkloadKind::FlashAttention { causal: false },
            &[vec![4, 128, 64], vec![4, 128, 64], vec![4, 128, 64]],
            &[4, 128, 64],
            2,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.strategy, Strategy::HeadParallel);
        assert_eq!(p.collective, Collective::HeadConcat);
        assert_eq!(p.parts[1].inputs[2], InputSlice::along(0, 2, 2));

        // chunk_scan: the state tensor slices by whole per-head chunk runs
        let p = plan(
            &WorkloadKind::ChunkScan,
            &[vec![4, 128, 32], vec![8, 32, 32], vec![4, 128]],
            &[4, 128, 32],
            2,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.strategy, Strategy::ChunkParallel);
        // bh = 4, nchunks = 2: shard 1 takes state rows 4..8
        assert_eq!(p.parts[1].inputs[1], InputSlice::along(0, 4, 4));
        assert_eq!(p.parts[1].out_shape, vec![2, 128, 32]);
    }

    #[test]
    fn flash_decode_shards_over_the_stream_batch() {
        let p = plan(
            &WorkloadKind::FlashDecode,
            &[vec![4, 16, 16], vec![4, 64, 16], vec![4, 64, 16]],
            &[4, 16, 16],
            2,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.strategy, Strategy::HeadParallel);
        assert_eq!(p.collective, Collective::HeadConcat);
        assert_eq!(p.parts[1].inputs[1], InputSlice::along(0, 2, 2));
        assert_eq!(p.parts[1].in_shapes[0], vec![2, 16, 16]);
        assert_eq!(p.parts[1].out_shape, vec![2, 16, 16]);
        // more shards than streams: clean rejection, not a panic
        assert!(plan(
            &WorkloadKind::FlashDecode,
            &[vec![2, 16, 16], vec![2, 64, 16], vec![2, 64, 16]],
            &[2, 16, 16],
            3,
            &h100(),
        )
        .is_err());
    }

    #[test]
    fn dequant_shards_over_output_rows() {
        use crate::workloads::dequant::WeightFormat;
        let kind = WorkloadKind::Dequant {
            fmt: WeightFormat::Int4,
            group: 32,
        };
        // A: [16, 128], B packed: [128, 64], scales: [128, 4], out [128, 16]
        let p = plan(
            &kind,
            &[vec![16, 128], vec![128, 64], vec![128, 4]],
            &[128, 16],
            2,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.strategy, Strategy::RowParallel);
        assert_eq!(p.parts[1].inputs[0], InputSlice::full());
        assert_eq!(p.parts[1].inputs[1], InputSlice::along(0, 64, 64));
        assert_eq!(p.parts[1].out_shape, vec![64, 16]);
    }

    #[test]
    fn single_shard_plans_are_trivial_but_valid() {
        let p = plan(
            &WorkloadKind::Gemm,
            &[vec![64, 64], vec![64, 64]],
            &[64, 64],
            1,
            &h100(),
        )
        .unwrap();
        assert_eq!(p.parts.len(), 1);
        assert_eq!(p.parts[0].out_shape, vec![64, 64]);
    }
}
