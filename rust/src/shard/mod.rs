//! Multi-executor sharding: plan and execute one workload — a single
//! kernel or a whole dataflow graph — across N parallel
//! devices/workers.
//!
//! The paper's thesis is that tiled dataflow makes kernel partitioning
//! explicit and schedulable; this subsystem lifts the same idea one
//! level up and partitions a workload's *tile grid* across executors:
//!
//! * [`plan`] — the single-kernel sharding planner. Given a workload
//!   family, its tensor shapes and a shard count, it enumerates the
//!   partition strategies that apply to the family (row/data-parallel,
//!   split-K with sum-reduction, head-parallel, chunk-parallel), costs
//!   each one with the analytical device model (`sim::simulate_kernel`
//!   on the per-shard sub-problem) plus a simple communication term, and
//!   picks the cheapest — a [`plan::ShardPlan`] describing how every
//!   input is scattered and how shard outputs recombine
//!   ([`plan::Collective`]: concat, head-concat or sum-reduce).
//! * [`exec`] — the sharded execution backend. A
//!   [`exec::ShardedKernel`] holds one prepared interpreter kernel per
//!   shard (each tuned for its own sub-shape through the persistent
//!   tuning cache, keyed by shard count), scatters request inputs
//!   according to the plan, executes all shards on parallel `std`
//!   threads, and applies the gather/reduce collective.
//! * [`graph`] — the graph analogue: [`graph::plan_graph`] picks one
//!   partition axis for a whole `KernelGraph` (data-parallel rows for
//!   MLP-style blocks, the flash grid's batch*heads axis for
//!   attention/decode blocks) by tracking the batch axis through every
//!   node, and [`graph::ShardedGraphKernel`] runs the fused block per
//!   shard — scatter once, compute the whole block shard-locally
//!   (intermediates never cross the interconnect), gather once.
//!
//! The runtime surfaces all of this as `ExecBackend::Sharded` (single
//! kernels *and* graph artifacts), the coordinator as
//! `Coordinator::start_sharded`, and the CLI as `serve --shards N`,
//! `tilelang plan` and `tilelang graph --shards N`. See
//! `docs/ARCHITECTURE.md` ("Sharding layer", "Graph sharding") and
//! `docs/SERVING.md` for the operator flows.

pub mod exec;
pub mod graph;
pub mod plan;
