//! Sharded execution: run one workload's [`ShardPlan`] across N
//! parallel interpreter executors.
//!
//! A [`ShardedKernel`] is the sharded analogue of the interp backend's
//! per-artifact kernel: `prepare` plans the partition (or accepts a
//! pinned plan), then builds one interpreter kernel per *distinct*
//! shard sub-shape (today's strategies are shape-uniform, so all shards
//! share one kernel) — resolved through the same workload-program path
//! and tuned for the sub-shape through the persistent tuning cache (the
//! shard count is part of the cache key, so sharded and unsharded
//! configs never collide). `execute` scatters the request inputs per the
//! plan's [`plan::InputSlice`]s, runs every shard on its own `std` thread
//! (expression trees are `Arc`-backed, so lowered programs are shared
//! across threads without copying), and applies the gather collective.

use std::borrow::Cow;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::error::Result;
use crate::obs::{Recorder, Traffic};
use crate::runtime::interp_backend::InterpKernel;
use crate::runtime::{ArtifactSpec, InterpOptions};
use crate::shard::plan::{self, Collective, ShardPlan};
use crate::sim::device::Device;
use crate::{anyhow, bail};

/// Configuration of the sharded execution backend.
#[derive(Clone, Debug)]
pub struct ShardedOptions {
    /// Number of parallel executors to partition each workload across.
    pub shards: usize,
    /// Per-shard interpreter configuration (modeled device, tuning
    /// cache). Its `shards` field is overwritten from the plan.
    pub interp: InterpOptions,
}

impl ShardedOptions {
    pub fn new(shards: usize) -> ShardedOptions {
        ShardedOptions {
            shards: shards.max(1),
            interp: InterpOptions::default(),
        }
    }
}

impl Default for ShardedOptions {
    fn default() -> Self {
        ShardedOptions::new(2)
    }
}

/// A manifest artifact resolved to per-shard interpreter kernels plus
/// the scatter/gather plan connecting them.
pub struct ShardedKernel {
    plan: ShardPlan,
    /// Distinct prepared kernels: even splits are shape-uniform (one
    /// kernel shared by all shard threads); uneven remainder splits
    /// compile one kernel per distinct sub-shape (typically two).
    kernels: Vec<InterpKernel>,
    /// Part index -> index into `kernels`.
    part_kernel: Vec<usize>,
    in_shapes: Vec<Vec<i64>>,
    out_len: usize,
}

impl ShardedKernel {
    /// Plan the partition for `spec` (cheapest feasible strategy on the
    /// modeled device) and prepare the per-shard kernels.
    pub fn prepare(
        spec: &ArtifactSpec,
        opts: &ShardedOptions,
        dir: &Path,
    ) -> Result<ShardedKernel> {
        let kind = plan::resolve_kind(spec)?;
        let dev = Device::by_name(&opts.interp.device).ok_or_else(|| {
            anyhow!("sharded backend: unknown modeled device {:?}", opts.interp.device)
        })?;
        let plan = plan::plan(&kind, &spec.in_shapes, &spec.out_shape, opts.shards, &dev)
            .map_err(|e| anyhow!("{}: sharding plan failed: {}", spec.name, e))?;
        ShardedKernel::prepare_with_plan(spec, plan, opts, dir)
    }

    /// Prepare per-shard kernels for an explicit plan (differential
    /// tests pin strategies through this).
    pub fn prepare_with_plan(
        spec: &ArtifactSpec,
        plan: ShardPlan,
        opts: &ShardedOptions,
        dir: &Path,
    ) -> Result<ShardedKernel> {
        let mut interp = opts.interp.clone();
        interp.shards = plan.shards();
        // prepare one kernel per *distinct* sub-shape: uniform splits
        // compile once and share the kernel across shard threads;
        // remainder splits add one more for the wider leading shards
        let mut kernels: Vec<InterpKernel> = Vec::new();
        let mut kernel_shapes: Vec<(Vec<Vec<i64>>, Vec<i64>)> = Vec::new();
        let mut part_kernel = Vec::with_capacity(plan.shards());
        for part in &plan.parts {
            let ki = match kernel_shapes
                .iter()
                .position(|(ins, out)| *ins == part.in_shapes && *out == part.out_shape)
            {
                Some(ki) => ki,
                None => {
                    let sub = ArtifactSpec {
                        name: format!("{}.shard{}", spec.name, part.index),
                        hlo_path: PathBuf::from("-"),
                        in_shapes: part.in_shapes.clone(),
                        out_shape: part.out_shape.clone(),
                        workload: Some(plan.workload.tag()),
                        graph: None,
                    };
                    kernels.push(InterpKernel::prepare(&sub, &interp, dir)?);
                    kernel_shapes.push((part.in_shapes.clone(), part.out_shape.clone()));
                    kernels.len() - 1
                }
            };
            part_kernel.push(ki);
        }
        Ok(ShardedKernel {
            in_shapes: spec.in_shapes.clone(),
            out_len: spec.out_len(),
            plan,
            kernels,
            part_kernel,
        })
    }

    /// The partition this kernel executes.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Per-lane static data-movement shadows, `("shard<i>", traffic)`
    /// rows in part order. `None` lanes mean the per-shard kernels were
    /// prepared for the tree-walking interp (no compiled shadow; the
    /// dynamic `traffic.*` counters still record).
    pub fn shard_traffic(&self) -> Vec<(String, Option<Traffic>)> {
        self.part_kernel
            .iter()
            .enumerate()
            .map(|(si, &ki)| (format!("shard{}", si), self.kernels[ki].traffic()))
            .collect()
    }

    /// Whole-request static shadow: the sum over every lane, or `None`
    /// when any lane has no compiled shadow. On the compiled backend
    /// this equals the `traffic.*` counters one recorded execution adds.
    pub fn traffic(&self) -> Option<Traffic> {
        let mut t = Traffic::default();
        for (_, lane) in self.shard_traffic() {
            t.merge(&lane?);
        }
        Some(t)
    }

    /// Per-lane modeled DRAM bytes from the cost model (`tilelang
    /// roofline`'s calibration denominators), part order.
    pub fn shard_modeled_bytes(&self, dev: &Device) -> Vec<(String, Option<f64>)> {
        self.part_kernel
            .iter()
            .enumerate()
            .map(|(si, &ki)| {
                (format!("shard{}", si), self.kernels[ki].modeled_dram_bytes(dev))
            })
            .collect()
    }

    /// Scatter -> parallel shard execution -> gather/reduce.
    pub fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        self.execute_rec(inputs, &Recorder::disabled())
    }

    /// [`ShardedKernel::execute`] under a [`Recorder`]: a `shard`
    /// scatter span, one compute span per shard thread (recorded
    /// through a forked [`crate::obs::ThreadBuf`], so shard imbalance
    /// shows as lanes of different length) and a gather span.
    pub fn execute_rec(&self, inputs: &[Vec<f32>], rec: &Recorder) -> Result<Vec<f32>> {
        if inputs.len() != self.in_shapes.len() {
            bail!(
                "sharded kernel expects {} inputs, got {}",
                self.in_shapes.len(),
                inputs.len()
            );
        }
        for (i, (data, shape)) in inputs.iter().zip(&self.in_shapes).enumerate() {
            let want = shape.iter().product::<i64>() as usize;
            if data.len() != want {
                bail!("sharded input {} length {} != shape {:?}", i, data.len(), shape);
            }
        }
        // scatter: materialize only the sliced tensors; replicated
        // inputs are borrowed by every shard instead of copied per shard
        let scatter_sp = rec.span_with("shard", "scatter", || {
            vec![
                ("strategy".to_string(), self.plan.strategy.to_string()),
                ("shards".to_string(), self.plan.shards().to_string()),
            ]
        });
        let mut shard_inputs: Vec<Vec<Cow<'_, [f32]>>> = Vec::with_capacity(self.plan.shards());
        for part in &self.plan.parts {
            let mut ins = Vec::with_capacity(inputs.len());
            for (i, slice) in part.inputs.iter().enumerate() {
                ins.push(match slice.dim {
                    None => Cow::Borrowed(inputs[i].as_slice()),
                    Some(d) => Cow::Owned(slice_tensor(
                        &inputs[i],
                        &self.in_shapes[i],
                        d,
                        slice.start,
                        slice.len,
                    )),
                });
            }
            shard_inputs.push(ins);
        }
        scatter_sp.finish_us();
        // execute every shard on its own thread
        let outs: Vec<Result<Vec<f32>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .part_kernel
                .iter()
                .zip(shard_inputs.iter())
                .enumerate()
                .map(|(si, (&ki, ins))| {
                    let kernel = &self.kernels[ki];
                    let mut tb = rec.fork();
                    scope.spawn(move || {
                        let t0 = Instant::now();
                        let refs: Vec<&[f32]> = ins.iter().map(|c| c.as_ref()).collect();
                        let out = kernel.execute_refs_traffic(&refs);
                        tb.span_with("shard", "compute", t0, || {
                            vec![("shard".to_string(), si.to_string())]
                        });
                        if let Some(oc) = kernel.op_counts() {
                            for (name, v) in oc.items() {
                                tb.add(name, v);
                            }
                        }
                        match out {
                            Ok((out, traffic)) => {
                                for (name, v) in traffic.items() {
                                    tb.add(name, v);
                                }
                                Ok(out)
                            }
                            Err(e) => Err(e),
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("shard worker thread panicked")))
                })
                .collect()
        });
        // gather
        let gather_sp = rec.span("shard", "gather");
        let gathered = match self.plan.collective {
            Collective::Concat | Collective::HeadConcat => {
                let mut out = Vec::with_capacity(self.out_len);
                for (i, r) in outs.into_iter().enumerate() {
                    let o = r.map_err(|e| anyhow!("shard {}: {}", i, e))?;
                    out.extend_from_slice(&o);
                }
                if out.len() != self.out_len {
                    bail!(
                        "gathered output has {} elements, artifact expects {}",
                        out.len(),
                        self.out_len
                    );
                }
                Ok(out)
            }
            Collective::SumReduce => {
                let mut out = vec![0f32; self.out_len];
                for (i, r) in outs.into_iter().enumerate() {
                    let o = r.map_err(|e| anyhow!("shard {}: {}", i, e))?;
                    if o.len() != self.out_len {
                        bail!(
                            "shard {} partial has {} elements, artifact expects {}",
                            i,
                            o.len(),
                            self.out_len
                        );
                    }
                    for (acc, v) in out.iter_mut().zip(&o) {
                        *acc += v;
                    }
                }
                Ok(out)
            }
        };
        gather_sp.finish_us();
        gathered
    }
}

/// Slice a row-major tensor along one dimension: the scatter primitive.
/// Contiguous for `dim == 0`, strided gather otherwise.
pub fn slice_tensor(data: &[f32], shape: &[i64], dim: usize, start: i64, len: i64) -> Vec<f32> {
    assert!(dim < shape.len(), "slice dim {} out of rank {}", dim, shape.len());
    assert!(
        start >= 0 && len > 0 && start + len <= shape[dim],
        "slice {}..{} out of extent {}",
        start,
        start + len,
        shape[dim]
    );
    let outer: i64 = shape[..dim].iter().product();
    let inner: i64 = shape[dim + 1..].iter().product();
    let extent = shape[dim];
    let mut out = Vec::with_capacity((outer * len * inner) as usize);
    for o in 0..outer {
        let base = ((o * extent + start) * inner) as usize;
        out.extend_from_slice(&data[base..base + (len * inner) as usize]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_tensor_dim0_is_contiguous() {
        let data: Vec<f32> = (0..24).map(|v| v as f32).collect();
        // shape [4, 6], rows 1..3
        let s = slice_tensor(&data, &[4, 6], 0, 1, 2);
        assert_eq!(s, (6..18).map(|v| v as f32).collect::<Vec<_>>());
    }

    #[test]
    fn slice_tensor_inner_dim_gathers_strided() {
        let data: Vec<f32> = (0..12).map(|v| v as f32).collect();
        // shape [3, 4], columns 1..3
        let s = slice_tensor(&data, &[3, 4], 1, 1, 2);
        assert_eq!(s, vec![1.0, 2.0, 5.0, 6.0, 9.0, 10.0]);
        // rank-3 middle dim
        let data: Vec<f32> = (0..24).map(|v| v as f32).collect();
        let s = slice_tensor(&data, &[2, 3, 4], 1, 2, 1);
        assert_eq!(s, vec![8.0, 9.0, 10.0, 11.0, 20.0, 21.0, 22.0, 23.0]);
    }

    #[test]
    #[should_panic(expected = "out of extent")]
    fn slice_tensor_rejects_out_of_range() {
        let data = vec![0f32; 8];
        let _ = slice_tensor(&data, &[2, 4], 0, 1, 2);
    }
}
