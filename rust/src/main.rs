//! tilelang CLI — leader entrypoint.
//!
//! Subcommands:
//!   devices                         list modeled devices
//!   artifacts [--dir D]             list AOT artifacts + golden check
//!   compile --kernel K --device D   compile a workload, print report
//!   simulate --kernel K --device D  compile + simulate across baselines
//!   run --artifact NAME [--dir D]   execute an artifact via PJRT
//!
//! (Hand-rolled argument parsing: the offline environment has no clap.)

use std::collections::HashMap;

use tilelang::ir::dtype::DType;
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::report::fmt_us;
use tilelang::runtime::Runtime;
use tilelang::sim::device::Device;
use tilelang::sim::model::{estimate, Penalties};
use tilelang::workloads::attention::{flash_attention_program, AttnConfig};
use tilelang::workloads::dequant::{dequant_matmul_program, DequantConfig, WeightFormat};
use tilelang::workloads::matmul::{matmul_program, TileConfig};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn build_kernel(name: &str, flags: &HashMap<String, String>) -> tilelang::ir::program::TileProgram {
    let get = |k: &str, d: i64| -> i64 {
        flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    };
    match name {
        "gemm" => {
            let (m, n, k) = (get("m", 4096), get("n", 4096), get("k", 4096));
            matmul_program(m, n, k, DType::F16, &TileConfig::default_for(m, n, k))
        }
        "flash_attention" => {
            let (bh, s, d) = (get("bh", 32), get("seq", 1024), get("d", 128));
            flash_attention_program(
                bh,
                s,
                d,
                flags.contains_key("causal"),
                &AttnConfig::default_for(s),
            )
        }
        "dequant" => {
            let (m, n, k) = (get("m", 16), get("n", 4096), get("k", 4096));
            dequant_matmul_program(m, n, k, WeightFormat::Int4, &DequantConfig::default())
        }
        other => {
            eprintln!("unknown kernel {} (gemm|flash_attention|dequant)", other);
            std::process::exit(2);
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&argv[1.min(argv.len())..]);
    let dir = flags
        .get("dir")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());

    match cmd {
        "devices" => {
            for d in ["rtx4090", "a100", "h100", "mi300x", "rtx3090"] {
                let dev = Device::by_name(d).unwrap();
                println!(
                    "{:<10} arch={:?} sms={} bw={}GB/s tensor={}TFLOPS",
                    dev.name,
                    dev.arch,
                    dev.sms,
                    dev.dram_gbps,
                    dev.peak_tensor_tflops()
                );
            }
        }
        "artifacts" => match Runtime::new(&dir) {
            Ok(rt) => {
                for name in rt.artifact_names() {
                    let spec = rt.spec(&name).unwrap().clone();
                    match rt.golden_check(&name) {
                        Ok(err) => println!(
                            "{:<28} out={:?} golden max_err={:.2e}",
                            name, spec.out_shape, err
                        ),
                        Err(e) => println!("{:<28} ERROR: {}", name, e),
                    }
                }
            }
            Err(e) => {
                eprintln!("{}", e);
                std::process::exit(1);
            }
        },
        "compile" | "simulate" => {
            let kernel = flags.get("kernel").map(|s| s.as_str()).unwrap_or("gemm");
            let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
                .unwrap_or_else(|| {
                    eprintln!("unknown device");
                    std::process::exit(2);
                });
            let prog = build_kernel(kernel, &flags);
            let lowered = match compile(&prog, &dev, &CompileOptions::default()) {
                Ok(l) => l,
                Err(e) => {
                    eprintln!("compile error: {}", e);
                    std::process::exit(1);
                }
            };
            let c = lowered.stmt_counts();
            println!("kernel {} on {}:", prog.name, dev.name);
            println!(
                "  grid={:?} threads={} smem={}B regs/thread={}",
                lowered.static_grid(),
                lowered.threads,
                lowered.schedule.smem_bytes,
                lowered.schedule.regs_per_thread
            );
            println!(
                "  stmts: {} copies ({} async), {} gemms, {} barriers, {} commits, {} waits",
                c.copies, c.async_copies, c.gemms, c.barriers, c.commits, c.waits
            );
            println!(
                "  pipeline stages={:?} warp_specialized={}",
                lowered
                    .schedule
                    .pipelines
                    .iter()
                    .map(|p| p.num_stages)
                    .collect::<Vec<_>>(),
                lowered.schedule.warp_specialized
            );
            if cmd == "simulate" {
                for (label, pen) in [
                    ("tilelang", Penalties::none()),
                    ("triton-like", Penalties::triton_like()),
                    ("torch-like", Penalties::torch_like()),
                ] {
                    let r = estimate(&lowered, &dev, &pen);
                    println!(
                        "  {:<12} {:>10}  {:>7.1} TFLOPS  bound={:?}  occ={:.2}",
                        label,
                        fmt_us(r.time_us),
                        r.tflops,
                        r.bound,
                        r.occupancy
                    );
                }
            }
        }
        "run" => {
            let name = flags
                .get("artifact")
                .cloned()
                .unwrap_or_else(|| "matmul_128".to_string());
            let res = Runtime::new(&dir).and_then(|rt| {
                let inputs = rt.example_inputs(&name)?;
                let t0 = std::time::Instant::now();
                let out = rt.execute(&name, &inputs)?;
                Ok((out, t0.elapsed()))
            });
            match res {
                Ok((out, dt)) => {
                    println!(
                        "{}: {} outputs in {:?} (first: {:?})",
                        name,
                        out.len(),
                        dt,
                        &out[..4.min(out.len())]
                    );
                }
                Err(e) => {
                    eprintln!("run failed: {}", e);
                    std::process::exit(1);
                }
            }
        }
        _ => {
            println!(
                "tilelang {} — composable tiled programming model (reproduction)\n\
                 usage: tilelang <devices|artifacts|compile|simulate|run> [--flags]\n\
                 examples:\n\
                 \u{20}  tilelang simulate --kernel gemm --device a100 --m 4096 --n 4096 --k 4096\n\
                 \u{20}  tilelang artifacts --dir artifacts\n\
                 \u{20}  tilelang run --artifact transformer_block",
                tilelang::version()
            );
        }
    }
}
