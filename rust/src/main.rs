//! tilelang CLI — leader entrypoint.
//!
//! Subcommands:
//!   devices                         list modeled devices
//!   artifacts [--dir D] [--force]   generate (if missing) + golden-check
//!                                   the artifact directory
//!   compile --kernel K --device D   compile a workload, print report
//!   simulate --kernel K --device D  compile + simulate across baselines
//!   schedule --kernel K --device D  rank the schedule candidates (tiles x
//!            [--top N]              stages x specialization) and print
//!                                   each one's per-pipeline copy/compute
//!                                   stage timeline plus a specialized-vs-
//!                                   unspecialized head-to-head
//!   tune --kernel K --device D      autotune a workload (persistent cache)
//!   run --artifact NAME [--dir D]   execute one artifact end to end
//!       [--backend interp|compiled]
//!   serve [--artifact NAME]         micro-batched row serving demo
//!         [--shards N]              through the coordinator; N >= 2
//!         [--backend interp|compiled] partitions the artifact across N
//!                                   parallel executors (single kernels
//!                                   *and* graph artifacts — a graph
//!                                   runs the whole fused block per
//!                                   shard). The compiled bytecode VM is
//!                                   the default engine; --backend interp
//!                                   selects the tree-walking oracle.
//!   serve --continuous              continuous-batching decode through
//!         [--streams N] [--steps S] the shared paged KV-cache pool and
//!         [--prefill P] [--slots K] the multi-output decode graph;
//!         [--pool-pages G]          prints per-phase p50/p99 latency
//!         [--page-rows R] [--verify] and pool occupancy. --verify
//!         [--backend interp|compiled] bit-compares every stream
//!                                   against the serial decode oracle.
//!   bench [--quick] [--out F]       run the fig12–15 kernel set plus
//!                                   serve/graph/sharded scenarios on
//!                                   both backends, write a BENCH_*.json
//!                                   perf record (--quick = CI-sized)
//!   bench-check --baseline A        relative-speedup regression gate
//!               --current B [--tol T] between two bench records; also
//!                                   enforces the <2% disabled-tracing
//!                                   overhead ceiling and downgrades
//!                                   regressions to warnings when the
//!                                   baseline is estimated, not measured
//!   profile [--artifact NAME]       run artifacts (default: all, plus a
//!           [--iters N] [--device D] sharded config and the continuous
//!           [--shards N]            serve engine) under tracing and
//!                                   print measured span times next to
//!                                   the cost model's per-kernel
//!                                   predictions (ratio column; ! marks
//!                                   rows off by >3x from the run-wide
//!                                   calibration)
//!   roofline [--artifact NAME]      run artifacts (default: all, plus a
//!            [--iters N] [--device D] sharded lane config and the
//!            [--shards N]            continuous serve engine) with the
//!                                   traffic counters on; print per-unit
//!                                   arithmetic intensity, achieved-vs-
//!                                   peak rates and a memory-/compute-
//!                                   bound verdict, plus the measured-
//!                                   vs-modeled byte calibration table
//!                                   (>2x deviations flagged !) that
//!                                   sim::model::TrafficCalibration
//!                                   feeds back into the simulator
//!   check-trace --file F            validate a Chrome trace written by
//!                                   --trace using the crate's own
//!                                   reader: parses, counter tracks
//!                                   monotonic, spans nest within each
//!                                   lane (CI smoke via
//!                                   scripts/check_trace)
//!   plan --artifact NAME --shards N enumerate + cost the sharding
//!                                   strategies for an artifact (graph
//!                                   artifacts get the graph-level
//!                                   strategy table)
//!   graph --artifact NAME           print a graph artifact's plan:
//!         [--no-fuse] [--shards N]  nodes, fusion decisions, modeled
//!                                   costs and the buffer-reuse plan
//!                                   (peak planned bytes); --shards N
//!                                   adds the graph sharding plan
//!
//! `artifacts`, `run` and `serve` work fully offline: artifacts are
//! generated by the rust-native generator and executed on the TIR
//! interpreter backend (PJRT takes over when the `pjrt` feature is
//! built in).
//!
//! `compile`/`simulate` accept `--tune` to pick the tile configuration
//! via the autotuner (served from the tuning cache when warm) instead of
//! the static defaults. `--cache PATH` overrides the cache location,
//! `--no-cache` forces a fresh sweep.
//!
//! `run`, `serve` (both modes) and `bench` accept `--trace F` (Chrome
//! trace-event JSON, loadable in chrome://tracing / ui.perfetto.dev)
//! and `--metrics F` (Prometheus-style text dump). Tracing is
//! observability-only: every latency in the printed reports is measured
//! by the same recorder spans whether or not recording is on, and
//! decode outputs are bit-identical either way. See
//! docs/OBSERVABILITY.md for the span taxonomy and file formats.
//!
//! (Hand-rolled argument parsing: the offline environment has no clap.)

use std::collections::HashMap;
use std::path::Path;

use tilelang::autotuner::{tune_cached, TuneResult, Tunable, TuningCache};
use tilelang::coordinator::{BatchPolicy, Coordinator};
use tilelang::graph::ir::KernelGraph;
use tilelang::graph::{fuse as graph_fuse, memplan as graph_memplan};
use tilelang::ir::dtype::DType;
use tilelang::obs::{
    bound_label, read_chrome_counters, read_chrome_trace, write_chrome_trace, write_metrics,
    Recorder, Traffic,
};
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::report::fmt_us;
use tilelang::runtime::{artifacts, ExecBackend, InterpOptions, Runtime};
use tilelang::serve::{Engine, EngineConfig, StreamSpec};
use tilelang::shard::exec::ShardedOptions;
use tilelang::shard::graph as graph_shard;
use tilelang::shard::plan as shard_plan;
use tilelang::util::bench::{compare, BenchReport, BenchScenario};
use tilelang::util::stats::{percentile, percentile_f64};
use tilelang::sim::device::Device;
use tilelang::sim::model::{estimate, simulate_kernel, Penalties, TrafficCalibration};
use tilelang::workloads::attention::{
    flash_attention_program, AttentionTunable, AttnConfig, MlaTunable,
};
use tilelang::workloads::dequant::{dequant_matmul_program, DequantConfig, DequantTunable, WeightFormat};
use tilelang::workloads::linear_attention::{ChunkKind, LinearAttentionTunable};
use tilelang::workloads::matmul::{matmul_program, GemmTunable, TileConfig};
use tilelang::workloads::shapes::{AttnShape, LinAttnShape, MlaShape};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn geti(flags: &HashMap<String, String>, k: &str, d: i64) -> i64 {
    flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn open_cache(flags: &HashMap<String, String>) -> TuningCache {
    if flags.contains_key("no-cache") {
        TuningCache::in_memory()
    } else if let Some(path) = flags.get("cache") {
        TuningCache::open(path)
    } else {
        TuningCache::open_default()
    }
}

fn die(msg: &str) -> ! {
    eprintln!("{}", msg);
    std::process::exit(1)
}

/// Resolve `--backend interp|compiled` (+ `--shards N`) to an execution
/// backend. Compiled is the default; `--shards N >= 2` wraps the choice
/// in the sharded backend, whose per-shard kernels inherit the flag.
fn backend_from_flags(flags: &HashMap<String, String>, shards: usize) -> ExecBackend {
    let choice = flags.get("backend").map(|s| s.as_str()).unwrap_or("compiled");
    let compiled = match choice {
        "compiled" => true,
        "interp" => false,
        other => die(&format!("unknown --backend {} (interp|compiled)", other)),
    };
    if shards >= 2 {
        let mut opts = ShardedOptions::new(shards);
        opts.interp.compiled = compiled;
        ExecBackend::Sharded(opts)
    } else if compiled {
        ExecBackend::compiled()
    } else {
        ExecBackend::interp()
    }
}


/// Tune one workload through the generic driver + cache, printing the
/// decision, and return the program built from the chosen config.
fn tuned_program<T: Tunable>(
    t: &T,
    dev: &Device,
    cache: &mut TuningCache,
) -> tilelang::ir::program::TileProgram {
    match tune_cached(t, dev, &Penalties::none(), cache) {
        Ok(r) => {
            print_tune_result(t.workload(), &r);
            t.build(&r.config)
        }
        Err(e) => die(&format!("tuning failed: {}", e)),
    }
}

fn print_tune_result<C: std::fmt::Debug>(workload: &str, r: &TuneResult<C>) {
    println!(
        "tuned {}: {:?}  ({} in {}; {} candidates evaluated)",
        workload,
        r.config,
        fmt_us(r.report.time_us),
        if r.cache_hit { "cache hit" } else { "fresh sweep" },
        r.evaluated
    );
}

/// Build a workload program; `tune` selects the config via the cached
/// autotuner, otherwise the static defaults are used.
fn build_kernel(
    name: &str,
    flags: &HashMap<String, String>,
    dev: &Device,
    tune: bool,
    cache: &mut TuningCache,
) -> tilelang::ir::program::TileProgram {
    match name {
        "gemm" => {
            let (m, n, k) = (geti(flags, "m", 4096), geti(flags, "n", 4096), geti(flags, "k", 4096));
            if tune {
                tuned_program(&GemmTunable::new(m, n, k, DType::F16), dev, cache)
            } else {
                matmul_program(m, n, k, DType::F16, &TileConfig::default_for(m, n, k))
            }
        }
        "flash_attention" => {
            let (bh, s, d) = (geti(flags, "bh", 32), geti(flags, "seq", 1024), geti(flags, "d", 128));
            let causal = flags.contains_key("causal");
            if tune {
                let shape = AttnShape {
                    name: "cli",
                    batch: 1,
                    heads: bh,
                    seq_len: s,
                    head_dim: d,
                    causal,
                };
                tuned_program(&AttentionTunable { shape }, dev, cache)
            } else {
                flash_attention_program(bh, s, d, causal, &AttnConfig::default_for(s))
            }
        }
        "dequant" => {
            let (m, n, k) = (geti(flags, "m", 16), geti(flags, "n", 4096), geti(flags, "k", 4096));
            if tune {
                tuned_program(&DequantTunable::new(m, n, k, WeightFormat::Int4), dev, cache)
            } else {
                dequant_matmul_program(m.max(16), n, k, WeightFormat::Int4, &DequantConfig::default())
            }
        }
        "mla" => {
            let shape = MlaShape {
                batch: geti(flags, "batch", 64),
                heads: geti(flags, "heads", 128),
                seqlen_kv: geti(flags, "seq-kv", 8192),
                dim: geti(flags, "dim", 512),
                pe_dim: geti(flags, "pe", 64),
            };
            tuned_program(&MlaTunable { shape }, dev, cache)
        }
        "chunk_scan" | "chunk_state" => {
            let shape = LinAttnShape {
                name: "cli",
                batch: geti(flags, "batch", 1),
                nheads: geti(flags, "heads", 64),
                seq_len: geti(flags, "seq", 2048),
                head_dim: geti(flags, "d", 64),
                d_state: geti(flags, "dstate", 128),
            };
            let kind = if name == "chunk_state" {
                ChunkKind::State
            } else {
                ChunkKind::Scan
            };
            tuned_program(&LinearAttentionTunable { kind, shape }, dev, cache)
        }
        other => {
            eprintln!(
                "unknown kernel {} (gemm|flash_attention|dequant|mla|chunk_scan|chunk_state)",
                other
            );
            std::process::exit(2);
        }
    }
}

/// `--trace F` / `--metrics F`: an enabled recorder when either flag is
/// present (bare `--trace`/`--metrics` pick default file names), a
/// disabled no-op recorder otherwise.
fn obs_from_flags(
    flags: &HashMap<String, String>,
) -> (Recorder, Option<String>, Option<String>) {
    let named = |key: &str, default: &str| -> Option<String> {
        flags.get(key).map(|v| {
            if v == "true" {
                default.to_string()
            } else {
                v.clone()
            }
        })
    };
    let trace = named("trace", "trace.json");
    let metrics = named("metrics", "metrics.txt");
    let rec = if trace.is_some() || metrics.is_some() {
        Recorder::enabled()
    } else {
        Recorder::disabled()
    };
    (rec, trace, metrics)
}

/// Write the trace/metrics files a traced run asked for.
fn obs_finish(rec: &Recorder, trace: &Option<String>, metrics: &Option<String>) {
    if let Some(path) = trace {
        match write_chrome_trace(rec, path) {
            Ok(()) => println!(
                "wrote {} ({} spans; load in chrome://tracing or ui.perfetto.dev)",
                path,
                rec.events().len()
            ),
            Err(e) => die(&e),
        }
    }
    if let Some(path) = metrics {
        match write_metrics(rec, path) {
            Ok(()) => println!("wrote {}", path),
            Err(e) => die(&e),
        }
    }
}

/// Time `iters` executions (one warm-up first); returns sorted µs.
fn sample_us(
    mut f: impl FnMut() -> tilelang::error::Result<Vec<f32>>,
    iters: usize,
) -> Result<Vec<f64>, String> {
    f().map_err(|e| e.to_string())?;
    let mut v = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = std::time::Instant::now();
        f().map_err(|e| e.to_string())?;
        v.push(t.elapsed().as_secs_f64() * 1e6);
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(v)
}

fn scenario_from_samples(
    name: &str,
    kind: &str,
    interp: &[f64],
    compiled: &[f64],
    compile_us: f64,
) -> BenchScenario {
    let (ip50, cp50) = (percentile_f64(interp, 50.0), percentile_f64(compiled, 50.0));
    BenchScenario {
        name: name.to_string(),
        kind: kind.to_string(),
        interp_p50_us: ip50,
        interp_p99_us: percentile_f64(interp, 99.0),
        compiled_p50_us: cp50,
        compiled_p99_us: percentile_f64(compiled, 99.0),
        compile_us,
        throughput_per_s: if cp50 > 0.0 { 1e6 / cp50 } else { 0.0 },
        speedup: if cp50 > 0.0 { ip50 / cp50 } else { 0.0 },
        trace_overhead: 0.0,
    }
}

/// Measure one artifact on both backends: same artifact directory, same
/// example inputs, interp as the oracle. Also cross-checks that the two
/// backends agree (bit-for-bit) before timing, so a bench run doubles as
/// a differential smoke.
fn measure_artifact(
    dir: &str,
    name: &str,
    kind: &str,
    iters: usize,
    shards: usize,
    rec: &Recorder,
) -> Result<BenchScenario, String> {
    let (interp_backend, compiled_backend) = if shards >= 2 {
        let mut oi = ShardedOptions::new(shards);
        oi.interp.tune = false;
        let mut oc = ShardedOptions::new(shards);
        oc.interp.tune = false;
        oc.interp.compiled = true;
        (ExecBackend::Sharded(oi), ExecBackend::Sharded(oc))
    } else {
        let opts = InterpOptions {
            tune: false,
            ..Default::default()
        };
        (
            ExecBackend::Interp(opts.clone()),
            ExecBackend::Compiled(opts),
        )
    };
    let mut interp_rt = Runtime::with_backend(dir, interp_backend).map_err(|e| e.to_string())?;
    let mut compiled_rt =
        Runtime::with_backend(dir, compiled_backend).map_err(|e| e.to_string())?;
    // a no-op unless `bench --trace/--metrics` asked for an enabled one
    interp_rt.set_recorder(rec.clone());
    compiled_rt.set_recorder(rec.clone());
    let inputs = interp_rt.example_inputs(name).map_err(|e| e.to_string())?;
    let t0 = std::time::Instant::now();
    let loaded = compiled_rt.load(name).map_err(|e| e.to_string())?;
    let compile_us = t0.elapsed().as_secs_f64() * 1e6;
    let want = interp_rt.execute(name, &inputs).map_err(|e| e.to_string())?;
    let got = loaded.execute_rec(&inputs, rec).map_err(|e| e.to_string())?;
    if got != want {
        return Err(format!(
            "{}: compiled output diverged from the interp oracle",
            name
        ));
    }
    let interp = sample_us(|| interp_rt.execute(name, &inputs), iters)?;
    let compiled = sample_us(|| loaded.execute_rec(&inputs, rec), iters)?;
    let mut s = scenario_from_samples(name, kind, &interp, &compiled, compile_us);
    // traffic fields: one traced run per backend, after the timed
    // samples so the probes cannot perturb them. The counters are
    // defined on logical extents, so the interpreter's dynamic count
    // and the VM's static shadow must agree bit-exactly — a divergence
    // here is an accounting bug, not noise.
    let probe_i = Recorder::enabled();
    interp_rt.set_recorder(probe_i.clone());
    interp_rt.execute(name, &inputs).map_err(|e| e.to_string())?;
    interp_rt.set_recorder(rec.clone());
    let ti = Traffic::from_counters(&probe_i.counters());
    let probe_c = Recorder::enabled();
    loaded.execute_rec(&inputs, &probe_c).map_err(|e| e.to_string())?;
    let tc = Traffic::from_counters(&probe_c.counters());
    if ti != tc {
        return Err(format!(
            "{}: traffic counters diverge across backends: interp {:?} vs compiled {:?}",
            name, ti, tc
        ));
    }
    s.dram_bytes = tc.dram_bytes();
    s.arith_intensity = tc.arith_intensity();
    Ok(s)
}

/// Deterministic stream mix for `serve --continuous` and the bench's
/// decode-throughput scenario: staggered arrivals, prompts of varying
/// length, fixed decode budget per stream.
fn continuous_specs(streams: usize, prefill_max: usize, decode_steps: usize) -> Vec<StreamSpec> {
    (0..streams)
        .map(|i| StreamSpec {
            id: i as u64 + 1,
            arrival_step: i % 4,
            prefill_rows: 1 + (i * 7 + 3) % prefill_max.max(1),
            decode_steps,
        })
        .collect()
}

/// The bench's decode-throughput scenario: whole continuous-batching
/// engine runs (admission, paged gather, multi-output decode graph,
/// in-pool cache appends, retirement) on both backends. Throughput is
/// streams retired per second on the compiled backend.
fn measure_continuous_decode(iters: usize, rec: &Recorder) -> Result<BenchScenario, String> {
    let specs = continuous_specs(8, 12, 3);
    let streams = specs.len();
    let engine_for = |compiled: bool| -> Result<Engine, String> {
        Engine::new(EngineConfig {
            page_rows: 8,
            pool_pages: 64,
            compiled,
            ..Default::default()
        })
        .map_err(|e| e.to_string())
    };
    let mut interp_eng = engine_for(false)?;
    let mut compiled_eng = engine_for(true)?;
    interp_eng.set_recorder(rec.clone());
    compiled_eng.set_recorder(rec.clone());
    // first compiled run is the cold path: graph prepare + bytecode
    // compile for every padded KV length the mix reaches
    let t0 = std::time::Instant::now();
    compiled_eng.run(&specs).map_err(|e| e.to_string())?;
    let compile_us = t0.elapsed().as_secs_f64() * 1e6;
    interp_eng.run(&specs).map_err(|e| e.to_string())?; // interp warm-up
    let mut time_runs = |eng: &mut Engine| -> Result<Vec<f64>, String> {
        let mut v = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = std::time::Instant::now();
            eng.run(&specs).map_err(|e| e.to_string())?;
            v.push(t.elapsed().as_secs_f64() * 1e6);
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(v)
    };
    let interp = time_runs(&mut interp_eng)?;
    let compiled = time_runs(&mut compiled_eng)?;
    let mut s = scenario_from_samples(
        "continuous_decode_8streams",
        "serve",
        &interp,
        &compiled,
        compile_us,
    );
    // report stream throughput, not engine-runs/s
    s.throughput_per_s *= streams as f64;
    // Disabled-tracing overhead for the bench gate: span sites still
    // pay two Instant reads when the recorder is off. Count the
    // recording operations one engine run performs (a probe run with
    // tracing on), time the disabled fast path directly for that many
    // operations, and express the total against the scenario's compiled
    // p50. The probe runs *after* the timed samples, so it cannot
    // perturb them.
    let probe = Recorder::enabled();
    compiled_eng.set_recorder(probe.clone());
    compiled_eng.run(&specs).map_err(|e| e.to_string())?;
    compiled_eng.set_recorder(rec.clone());
    // the probe's traffic counters double as the scenario's roofline
    // fields, with the interp engine as the parity oracle for the whole
    // paged-decode path (prefill appends + every padded decode graph)
    let probe_i = Recorder::enabled();
    interp_eng.set_recorder(probe_i.clone());
    interp_eng.run(&specs).map_err(|e| e.to_string())?;
    interp_eng.set_recorder(rec.clone());
    let ti = Traffic::from_counters(&probe_i.counters());
    let tc = Traffic::from_counters(&probe.counters());
    if ti != tc {
        return Err(format!(
            "continuous decode: traffic counters diverge across backends: \
             interp {:?} vs compiled {:?}",
            ti, tc
        ));
    }
    s.dram_bytes = tc.dram_bytes();
    s.arith_intensity = tc.arith_intensity();
    let ops = probe.events().len()
        + probe.samples().iter().map(|(_, v)| v.len()).sum::<usize>()
        + probe.counters().len();
    let off = Recorder::disabled();
    let t = std::time::Instant::now();
    for _ in 0..ops.max(1) {
        off.span("bench", "probe").finish_us();
    }
    let overhead_us = t.elapsed().as_secs_f64() * 1e6;
    if s.compiled_p50_us > 0.0 {
        s.trace_overhead = 1.0 + overhead_us / s.compiled_p50_us;
    }
    Ok(s)
}

/// The `tilelang bench` subcommand: fig12–15 kernel scenarios plus
/// serve/graph/sharded blocks, both backends, one [`BenchReport`].
fn run_bench(flags: &HashMap<String, String>, dir: &str) {
    let quick = flags.contains_key("quick");
    let iters = geti(flags, "iters", if quick { 3 } else { 10 }).max(1) as usize;
    let out_path = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_current.json".to_string());
    let manifest = Path::new(dir).join("manifest.tsv");
    if !manifest.exists() {
        match artifacts::generate_default_set(dir) {
            Ok(names) => println!("generated {} artifacts in {}/", names.len(), dir),
            Err(e) => die(&format!("artifact generation failed: {}", e)),
        }
    }
    // (name, kind, shards). Quick mode keeps the full scenario set —
    // bench-check treats a baseline scenario missing from the current
    // run as a failure — and only reduces the iteration count.
    let scenarios: &[(&str, &str, usize)] = &[
        ("matmul_64x64x64", "kernel", 1),
        ("flash_attention_2x128x64", "kernel", 1),
        ("flash_attention_causal_2x128x64", "kernel", 1),
        ("flash_decode_4x16x64x16", "kernel", 1),
        ("dequant_int4_32x64x64", "kernel", 1),
        ("chunk_state_2x128", "kernel", 1),
        ("chunk_scan_2x128", "kernel", 1),
        ("linear_64x256x64", "serve", 1),
        ("linear_64x256x64_shards2", "sharded", 2),
        ("mlp_block_64x64x128", "graph", 1),
        ("decode_block_64x256x64", "graph", 1),
    ];
    let (rec, trace, metrics) = obs_from_flags(flags);
    let mut report = BenchReport {
        label: "BENCH_10".to_string(),
        mode: if quick { "quick" } else { "full" }.to_string(),
        provenance: format!(
            "measured: tilelang bench on {}-{}, tune=false static configs, {} iters/backend",
            std::env::consts::ARCH,
            std::env::consts::OS,
            iters
        ),
        provenance_kind: "measured".to_string(),
        scenarios: Vec::new(),
    };
    for (name, kind, shards) in scenarios {
        let artifact = name.strip_suffix("_shards2").unwrap_or(name);
        match measure_artifact(dir, artifact, kind, iters, *shards, &rec) {
            Ok(mut s) => {
                s.name = name.to_string();
                println!(
                    "{:<32} interp p50 {:>10}  compiled p50 {:>10}  speedup {:>6.2}x  \
                     (compile {:>9})",
                    s.name,
                    fmt_us(s.interp_p50_us),
                    fmt_us(s.compiled_p50_us),
                    s.speedup,
                    fmt_us(s.compile_us),
                );
                report.scenarios.push(s);
            }
            Err(e) => die(&format!("bench scenario {} failed: {}", name, e)),
        }
    }
    match measure_continuous_decode(iters, &rec) {
        Ok(s) => {
            println!(
                "{:<32} interp p50 {:>10}  compiled p50 {:>10}  speedup {:>6.2}x  \
                 ({:.1} streams/s, disabled-tracing overhead {:.3}%)",
                s.name,
                fmt_us(s.interp_p50_us),
                fmt_us(s.compiled_p50_us),
                s.speedup,
                s.throughput_per_s,
                (s.trace_overhead - 1.0) * 100.0,
            );
            report.scenarios.push(s);
        }
        Err(e) => die(&format!("bench scenario continuous_decode failed: {}", e)),
    }
    println!(
        "geomean compiled-vs-interp speedup: {:.2}x over {} scenarios",
        report.geomean_speedup(),
        report.scenarios.len()
    );
    match report.save(&out_path) {
        Ok(()) => println!("wrote {}", out_path),
        Err(e) => die(&e),
    }
    obs_finish(&rec, &trace, &metrics);
}

/// The `tilelang bench-check` subcommand: relative-speedup regression
/// gate between two bench reports (see `util::bench::compare`).
fn run_bench_check(flags: &HashMap<String, String>) {
    let baseline_path = flags
        .get("baseline")
        .unwrap_or_else(|| die("bench-check needs --baseline BENCH_N.json"));
    let current_path = flags
        .get("current")
        .unwrap_or_else(|| die("bench-check needs --current BENCH_current.json"));
    let tol: f64 = flags
        .get("tol")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.20);
    let baseline = BenchReport::load(baseline_path).unwrap_or_else(|e| die(&e));
    let current = BenchReport::load(current_path).unwrap_or_else(|e| die(&e));
    let estimated = baseline.provenance_kind == "estimated";
    if estimated {
        eprintln!(
            "warning: baseline {} is estimated, not measured ({}); regressions \
             below are warnings, not failures — re-baseline with `tilelang bench` \
             on real hardware",
            baseline.label, baseline.provenance
        );
    }
    let failures = compare(&baseline, &current, tol);
    if failures.is_empty() {
        println!(
            "bench check passed: geomean {:.2}x vs baseline {:.2}x ({} scenarios, tol {:.0}%)",
            current.geomean_speedup(),
            baseline.geomean_speedup(),
            current.scenarios.len(),
            tol * 100.0
        );
    } else if estimated {
        for f in &failures {
            eprintln!("WARNING: {}", f);
        }
        println!(
            "bench check: {} regression(s) against the ESTIMATED baseline {} \
             reported as warnings",
            failures.len(),
            baseline.label
        );
    } else {
        for f in &failures {
            eprintln!("REGRESSION: {}", f);
        }
        die(&format!("bench check failed: {} regression(s)", failures.len()));
    }
}

/// One `tilelang profile` table row: a measurable unit (whole request,
/// graph node, shard compute, serve phase) with its measured span
/// aggregate and the cost model's prediction for the same unit.
struct ProfileRow {
    unit: String,
    /// Spans recorded for the unit (iters, or iters x shards).
    count: usize,
    /// Mean recorded span duration, µs.
    measured_us: f64,
    /// Modeled µs; `None` = the simulator cannot cost this unit.
    model_us: Option<f64>,
}

/// Pair every modeled unit with the mean of its recorded spans. Span
/// names were chosen so each modeled unit matches exactly the span its
/// execution emits (`runtime` = artifact name, `graph` = node name,
/// `shard` = `compute`).
fn profile_rows(rec: &Recorder, model: &[(String, Option<f64>)]) -> Vec<ProfileRow> {
    model
        .iter()
        .map(|(unit, model_us)| {
            let durs = rec.span_durations_us(unit);
            let mean = if durs.is_empty() {
                0.0
            } else {
                durs.iter().sum::<f64>() / durs.len() as f64
            };
            ProfileRow {
                unit: unit.clone(),
                count: durs.len(),
                measured_us: mean,
                model_us: *model_us,
            }
        })
        .collect()
}

/// The `tilelang profile` subcommand: run every artifact (plus a
/// sharded configuration and the continuous-serve engine) under an
/// enabled recorder and print measured span times next to
/// `sim`-model predictions.
///
/// Measured numbers are host-CPU interpreter/VM times while the model
/// predicts time on a modeled accelerator, so the absolute ratio is
/// expected to be large and roughly constant; the signal is a row
/// whose ratio deviates from the run-wide geomean calibration. Rows
/// off by more than 3x either way are flagged with `!`.
fn run_profile(flags: &HashMap<String, String>, dir: &str) {
    let iters = geti(flags, "iters", 5).max(1) as usize;
    let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
        .unwrap_or_else(|| die("unknown device"));
    let manifest = Path::new(dir).join("manifest.tsv");
    if !manifest.exists() {
        match artifacts::generate_default_set(dir) {
            Ok(names) => println!("generated {} artifacts in {}/", names.len(), dir),
            Err(e) => die(&format!("artifact generation failed: {}", e)),
        }
    }
    let shards = geti(flags, "shards", 1).max(1) as usize;
    // (artifact, shards) sections: --artifact restricts to one; the
    // default sweep covers every manifest artifact on the chosen
    // backend plus one sharded configuration, then the continuous-serve
    // engine below
    let sections: Vec<(String, usize)> = match flags.get("artifact") {
        Some(n) => vec![(n.clone(), shards)],
        None => {
            let rt = Runtime::with_backend(dir, ExecBackend::interp())
                .unwrap_or_else(|e| die(&format!("{}\n(run `tilelang artifacts` first)", e)));
            let mut v: Vec<(String, usize)> =
                rt.artifact_names().into_iter().map(|n| (n, 1)).collect();
            v.push(("linear_64x256x64".to_string(), 2.max(shards)));
            v
        }
    };
    println!(
        "profile: measured = host-CPU {} backend means over {} iters; model = \
         sim cost on {}",
        flags.get("backend").map(|s| s.as_str()).unwrap_or("compiled"),
        iters,
        dev.name
    );
    let mut tables: Vec<(String, Vec<ProfileRow>, Vec<(String, u64)>)> = Vec::new();
    for (name, shards) in &sections {
        let backend = backend_from_flags(flags, *shards);
        let mut rt =
            Runtime::with_backend(dir, backend).unwrap_or_else(|e| die(&e.to_string()));
        let rec = Recorder::enabled();
        rt.set_recorder(rec.clone());
        let inputs = rt
            .example_inputs(name)
            .unwrap_or_else(|e| die(&e.to_string()));
        let loaded = rt
            .load(name)
            .unwrap_or_else(|e| die(&format!("{}: {}", name, e)));
        for _ in 0..iters {
            loaded
                .execute_rec(&inputs, rt.recorder())
                .unwrap_or_else(|e| die(&format!("{}: {}", name, e)));
        }
        let label = if *shards >= 2 {
            format!("{} ({} shards)", name, shards)
        } else {
            name.clone()
        };
        tables.push((
            label,
            profile_rows(&rec, &loaded.modeled_node_us(&dev)),
            rec.counters(),
        ));
    }
    // continuous serve: the paged decode graph through the batching
    // engine (same mix as the bench's decode-throughput scenario). The
    // model column costs the largest padded KV length the run prepared;
    // the measured node spans mix every padded length reached.
    if flags.get("artifact").is_none() {
        let rec = Recorder::enabled();
        let mut eng = Engine::new(EngineConfig {
            page_rows: 8,
            pool_pages: 64,
            compiled: true,
            ..Default::default()
        })
        .unwrap_or_else(|e| die(&e.to_string()));
        eng.set_recorder(rec.clone());
        let specs = continuous_specs(8, 12, 3);
        eng.run(&specs).unwrap_or_else(|e| die(&e.to_string()));
        let mut rows = profile_rows(&rec, &eng.node_modeled_us());
        // engine phases have no per-kernel model — measured only
        let phases: Vec<(String, Option<f64>)> = ["admit", "prefill", "decode", "gather"]
            .iter()
            .map(|p| (p.to_string(), None))
            .collect();
        rows.extend(profile_rows(&rec, &phases));
        tables.push((
            "continuous serve (decode graph, 8 streams x 3 steps)".to_string(),
            rows,
            rec.counters(),
        ));
    }
    // run-wide calibration: geomean of measured/model over every costed
    // row, i.e. the host-CPU-vs-modeled-accelerator scale factor
    let ratios: Vec<f64> = tables
        .iter()
        .flat_map(|(_, rows, _)| rows.iter())
        .filter(|r| r.count > 0)
        .filter_map(|r| r.model_us.filter(|&m| m > 0.0).map(|m| r.measured_us / m))
        .filter(|x| x.is_finite() && *x > 0.0)
        .collect();
    let cal = if ratios.is_empty() {
        1.0
    } else {
        (ratios.iter().map(|x| x.ln()).sum::<f64>() / ratios.len() as f64).exp()
    };
    for (label, rows, counters) in &tables {
        println!("\n{}", label);
        println!(
            "  {:<28} {:>5} {:>12} {:>12} {:>10}",
            "unit", "spans", "measured", "model", "ratio"
        );
        for r in rows {
            let measured = if r.count > 0 {
                fmt_us(r.measured_us)
            } else {
                "-".to_string()
            };
            let (model, ratio) = match r.model_us {
                Some(m) if m > 0.0 => {
                    let ratio_txt = if r.count > 0 {
                        let ratio = r.measured_us / m;
                        let dev_ratio = ratio / cal;
                        let flag = if dev_ratio > 3.0 || dev_ratio < 1.0 / 3.0 {
                            " !"
                        } else {
                            ""
                        };
                        format!("{:>8.0}x{}", ratio, flag)
                    } else {
                        "-".to_string()
                    };
                    (fmt_us(m), ratio_txt)
                }
                _ => ("-".to_string(), "-".to_string()),
            };
            println!(
                "  {:<28} {:>5} {:>12} {:>12} {:>10}",
                r.unit, r.count, measured, model, ratio
            );
        }
        let vm: Vec<String> = counters
            .iter()
            .filter(|(k, _)| k.starts_with("vm."))
            .map(|(k, v)| format!("{}={}", &k[3..], v))
            .collect();
        if !vm.is_empty() {
            println!("  vm: {}", vm.join(" "));
        }
    }
    println!(
        "\ncalibration: measured/model geomean {:.0}x over {} costed rows \
         (host-CPU execution vs modeled {}); ! marks rows deviating >3x from it",
        cal,
        ratios.len(),
        dev.name
    );
}

/// One `tilelang roofline` table row: a unit's counted data movement
/// joined with its measured span times and modeled bytes.
struct RooflineRow {
    unit: String,
    count: usize,
    measured_us: f64,
    traffic: Option<Traffic>,
    /// DRAM bytes the `sim` cost model predicts for this unit (the
    /// calibration denominator); `None` = not costable.
    modeled_bytes: Option<f64>,
}

fn fmt_bytes(b: u64) -> String {
    let f = b as f64;
    if f >= 1e9 {
        format!("{:.2}GB", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2}MB", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.1}KB", f / 1e3)
    } else {
        format!("{}B", b)
    }
}

fn fmt_flops(n: u64) -> String {
    let f = n as f64;
    if f >= 1e12 {
        format!("{:.2}T", f / 1e12)
    } else if f >= 1e9 {
        format!("{:.2}G", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2}M", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.1}K", f / 1e3)
    } else {
        format!("{}", n)
    }
}

/// Join per-unit traffic with recorded spans and modeled bytes. Shard
/// lanes record their spans under the shared `compute` name, so a
/// `shardN` unit with no span of its own reports the compute-span mean.
fn roofline_rows(
    rec: &Recorder,
    traffic: &[(String, Option<Traffic>)],
    modeled: &[(String, Option<f64>)],
) -> Vec<RooflineRow> {
    traffic
        .iter()
        .map(|(unit, t)| {
            let mut durs = rec.span_durations_us(unit);
            if durs.is_empty() && unit.starts_with("shard") {
                durs = rec.span_durations_us("compute");
            }
            let mean = if durs.is_empty() {
                0.0
            } else {
                durs.iter().sum::<f64>() / durs.len() as f64
            };
            let mb = modeled.iter().find(|(n, _)| n == unit).and_then(|(_, v)| *v);
            RooflineRow {
                unit: unit.clone(),
                count: durs.len(),
                measured_us: mean,
                traffic: *t,
                modeled_bytes: mb,
            }
        })
        .collect()
}

fn print_roofline_table(label: &str, rows: &[RooflineRow], dev: &Device) {
    let ridge = dev.ridge_flops_per_byte();
    println!("\n{}", label);
    println!(
        "  {:<28} {:>5} {:>10} {:>9} {:>8} {:>8} {:>9} {:>9}  {}",
        "unit", "spans", "measured", "dram", "flops", "flop/B", "%peakBW", "%peakFL", "verdict"
    );
    for r in rows {
        let measured = if r.count > 0 {
            fmt_us(r.measured_us)
        } else {
            "-".to_string()
        };
        match &r.traffic {
            Some(t) if !t.is_zero() => {
                let ai = t.arith_intensity();
                let (pbw, pfl) = if r.count > 0 && r.measured_us > 0.0 {
                    (
                        format!(
                            "{:.4}%",
                            t.achieved_dram_gbps(r.measured_us) / dev.dram_gbps * 100.0
                        ),
                        format!(
                            "{:.4}%",
                            t.achieved_tflops(r.measured_us) / dev.peak_tensor_tflops().max(1e-9)
                                * 100.0
                        ),
                    )
                } else {
                    ("-".to_string(), "-".to_string())
                };
                let ai_txt = if ai.is_infinite() {
                    "inf".to_string()
                } else {
                    format!("{:.2}", ai)
                };
                println!(
                    "  {:<28} {:>5} {:>10} {:>9} {:>8} {:>8} {:>9} {:>9}  {}",
                    r.unit,
                    r.count,
                    measured,
                    fmt_bytes(t.dram_bytes()),
                    fmt_flops(t.flops),
                    ai_txt,
                    pbw,
                    pfl,
                    bound_label(ai, ridge)
                );
            }
            _ => println!(
                "  {:<28} {:>5} {:>10} {:>9} {:>8} {:>8} {:>9} {:>9}  -",
                r.unit, r.count, measured, "-", "-", "-", "-", "-"
            ),
        }
    }
}

/// The `tilelang roofline` subcommand: execute every artifact (plus a
/// sharded configuration and the continuous-serve engine) with the
/// traffic counters on, join each unit's counted bytes/FLOPs with its
/// measured span times and the chosen device's peaks, and print
/// arithmetic intensity, achieved-vs-peak rates and a memory-/compute-
/// bound verdict per unit — then the measured-vs-modeled calibration
/// table that [`TrafficCalibration`] consumes, flagging units whose
/// bytes deviate >2x from the `sim` model.
///
/// Span times are host-CPU interpreter/VM times while the peaks are the
/// modeled accelerator's, so the achieved-vs-peak percentages read as a
/// (tiny, roughly constant) calibration scale, not silicon utilization.
/// The intensities and verdicts are exact: they depend only on counted
/// traffic and the device's ridge point.
fn run_roofline(flags: &HashMap<String, String>, dir: &str) {
    let iters = geti(flags, "iters", 3).max(1) as usize;
    let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
        .unwrap_or_else(|| die("unknown device"));
    let manifest = Path::new(dir).join("manifest.tsv");
    if !manifest.exists() {
        match artifacts::generate_default_set(dir) {
            Ok(names) => println!("generated {} artifacts in {}/", names.len(), dir),
            Err(e) => die(&format!("artifact generation failed: {}", e)),
        }
    }
    let shards = geti(flags, "shards", 1).max(1) as usize;
    let sections: Vec<(String, usize)> = match flags.get("artifact") {
        Some(n) => vec![(n.clone(), shards)],
        None => {
            let rt = Runtime::with_backend(dir, ExecBackend::interp())
                .unwrap_or_else(|e| die(&format!("{}\n(run `tilelang artifacts` first)", e)));
            let mut v: Vec<(String, usize)> =
                rt.artifact_names().into_iter().map(|n| (n, 1)).collect();
            v.push(("linear_64x256x64".to_string(), 2.max(shards)));
            v
        }
    };
    println!(
        "roofline on {}: peak {:.0} GB/s DRAM, {:.0} TFLOPS fp16 tensor, ridge {:.0} flop/B",
        dev.name,
        dev.dram_gbps,
        dev.peak_tensor_tflops(),
        dev.ridge_flops_per_byte()
    );
    println!(
        "(measured spans are host-CPU {} backend means over {} iters, so %peak reads as a \
         calibration scale; intensities and verdicts are exact)",
        flags.get("backend").map(|s| s.as_str()).unwrap_or("compiled"),
        iters
    );
    let mut cal = TrafficCalibration::default();
    for (name, shards) in &sections {
        let backend = backend_from_flags(flags, *shards);
        let mut rt =
            Runtime::with_backend(dir, backend).unwrap_or_else(|e| die(&e.to_string()));
        let rec = Recorder::enabled();
        rt.set_recorder(rec.clone());
        let inputs = rt
            .example_inputs(name)
            .unwrap_or_else(|e| die(&e.to_string()));
        let loaded = rt
            .load(name)
            .unwrap_or_else(|e| die(&format!("{}: {}", name, e)));
        for _ in 0..iters {
            loaded
                .execute_rec(&inputs, rt.recorder())
                .unwrap_or_else(|e| die(&format!("{}: {}", name, e)));
        }
        let label = if *shards >= 2 {
            format!("{} ({} shards)", name, shards)
        } else {
            name.clone()
        };
        let rows = roofline_rows(&rec, &loaded.node_traffic(), &loaded.modeled_node_bytes(&dev));
        print_roofline_table(&label, &rows, &dev);
        for r in &rows {
            if let (Some(t), Some(mb)) = (&r.traffic, r.modeled_bytes) {
                let unit = if r.unit == *name {
                    r.unit.clone()
                } else {
                    format!("{}/{}", name, r.unit)
                };
                cal.push(&unit, t.dram_bytes() as f64, mb);
            }
        }
        // cross-check: the dynamic counters accumulated over `iters`
        // runs must equal the summed static shadows exactly
        if !rows.is_empty() && rows.iter().all(|r| r.traffic.is_some()) {
            let mut stat = Traffic::default();
            for r in &rows {
                stat.merge(r.traffic.as_ref().expect("all rows Some"));
            }
            let dynamic = Traffic::from_counters(&rec.counters());
            let drift = stat
                .items()
                .iter()
                .zip(dynamic.items().iter())
                .any(|((_, s), (_, d))| s * iters as u64 != *d);
            if drift {
                println!(
                    "  WARNING: static shadow x {} iters != dynamic counters \
                     ({:?} vs {:?})",
                    iters, stat, dynamic
                );
            }
        }
    }
    // continuous serve: one decode step is the whole multi-output paged
    // decode graph; prefill is the K/V rows admission writes to the pool
    if flags.get("artifact").is_none() {
        let rec = Recorder::enabled();
        let mut eng = Engine::new(EngineConfig {
            page_rows: 8,
            pool_pages: 64,
            compiled: true,
            ..Default::default()
        })
        .unwrap_or_else(|e| die(&e.to_string()));
        eng.set_recorder(rec.clone());
        let specs = continuous_specs(8, 12, 3);
        eng.run(&specs).unwrap_or_else(|e| die(&e.to_string()));
        let mut rows = roofline_rows(&rec, &eng.node_traffic(), &eng.node_modeled_bytes());
        for r in &rows {
            if let (Some(t), Some(mb)) = (&r.traffic, r.modeled_bytes) {
                cal.push(&format!("serve/{}", r.unit), t.dram_bytes() as f64, mb);
            }
        }
        let mut step = Traffic::default();
        let complete = !rows.is_empty() && rows.iter().all(|r| r.traffic.is_some());
        for r in &rows {
            if let Some(t) = &r.traffic {
                step.merge(t);
            }
        }
        let decode_durs = rec.span_durations_us("decode");
        rows.push(RooflineRow {
            unit: "decode (per step)".to_string(),
            count: decode_durs.len(),
            measured_us: if decode_durs.is_empty() {
                0.0
            } else {
                decode_durs.iter().sum::<f64>() / decode_durs.len() as f64
            },
            traffic: complete.then_some(step),
            modeled_bytes: None,
        });
        let hd = eng.config().head_dim as u64;
        let prefill_rows_total: u64 = specs.iter().map(|s| s.prefill_rows as u64).sum();
        let prefill_durs = rec.span_durations_us("prefill");
        rows.push(RooflineRow {
            unit: "prefill (total)".to_string(),
            count: prefill_durs.len(),
            measured_us: prefill_durs.iter().sum::<f64>(),
            traffic: Some(Traffic {
                dram_wr_bytes: prefill_rows_total * hd * 2 * 4,
                ..Traffic::default()
            }),
            modeled_bytes: None,
        });
        print_roofline_table(
            "continuous serve (decode graph, 8 streams x 3 steps)",
            &rows,
            &dev,
        );
    }
    println!("\ncalibration: measured ÷ sim-modeled DRAM bytes per unit (! = >2x deviation)");
    for r in &cal.rows {
        let (ratio, flag) = match r.ratio() {
            Some(q) => (
                format!("{:.2}x", q),
                if q > 2.0 || q < 0.5 { " !" } else { "" },
            ),
            None => ("-".to_string(), ""),
        };
        println!(
            "  {:<36} measured {:>10} modeled {:>10} ratio {:>8}{}",
            r.name,
            fmt_bytes(r.measured_bytes as u64),
            fmt_bytes(r.modeled_bytes as u64),
            ratio,
            flag
        );
    }
    match cal.scale() {
        Some(s) => println!(
            "calibration geomean {:.2}x over {} comparable unit(s); {} deviate >2x; \
             sim::model::TrafficCalibration::apply rescales memory-bound estimates by \
             this factor",
            s,
            cal.rows.iter().filter(|r| r.ratio().is_some()).count(),
            cal.deviations(2.0).len()
        ),
        None => println!("calibration: no comparable units (no modeled bytes available)"),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&argv[1.min(argv.len())..]);
    let dir = flags
        .get("dir")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());

    match cmd {
        "devices" => {
            for d in ["rtx4090", "a100", "h100", "mi300x", "rtx3090"] {
                let dev = Device::by_name(d).unwrap();
                println!(
                    "{:<10} arch={:?} sms={} bw={}GB/s tensor={}TFLOPS",
                    dev.name,
                    dev.arch,
                    dev.sms,
                    dev.dram_gbps,
                    dev.peak_tensor_tflops()
                );
            }
        }
        "artifacts" => {
            let manifest = Path::new(&dir).join("manifest.tsv");
            if flags.contains_key("force") || !manifest.exists() {
                match artifacts::generate_default_set(&dir) {
                    Ok(names) => println!("generated {} artifacts in {}/", names.len(), dir),
                    Err(e) => die(&format!("artifact generation failed: {}", e)),
                }
            }
            match Runtime::new(&dir) {
                Ok(rt) => {
                    println!("backend: {}", rt.backend_name());
                    let mut failed = 0usize;
                    for name in rt.artifact_names() {
                        let spec = rt.spec(&name).unwrap().clone();
                        let tol = tilelang::runtime::golden_tol(&spec);
                        match rt.golden_check(&name) {
                            Ok(err) if err < tol => println!(
                                "{:<32} out={:?} golden max_err={:.2e}",
                                name, spec.out_shape, err
                            ),
                            Ok(err) => {
                                println!(
                                    "{:<32} out={:?} FAILED: golden max_err={:.2e} (tol {})",
                                    name, spec.out_shape, err, tol
                                );
                                failed += 1;
                            }
                            Err(e) => {
                                println!("{:<32} ERROR: {}", name, e);
                                failed += 1;
                            }
                        }
                    }
                    if failed > 0 {
                        die(&format!("{} artifact(s) failed their golden check", failed));
                    }
                }
                Err(e) => die(&e.to_string()),
            }
        }
        "tune" => {
            let kernel = flags.get("kernel").map(|s| s.as_str()).unwrap_or("gemm");
            let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
                .unwrap_or_else(|| {
                    eprintln!("unknown device");
                    std::process::exit(2);
                });
            let mut cache = open_cache(&flags);
            // every workload prints its decision inside build_kernel;
            // spaces with no feasible candidate exit with an error
            let _ = build_kernel(kernel, &flags, &dev, true, &mut cache);
            if let Err(e) = cache.save() {
                eprintln!("warning: could not persist tuning cache: {}", e);
            } else if !flags.contains_key("no-cache") {
                println!("cache: {} entries", cache.len());
            }
        }
        "compile" | "simulate" => {
            let kernel = flags.get("kernel").map(|s| s.as_str()).unwrap_or("gemm");
            let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
                .unwrap_or_else(|| {
                    eprintln!("unknown device");
                    std::process::exit(2);
                });
            let tune = flags.contains_key("tune");
            let mut cache = open_cache(&flags);
            let prog = build_kernel(kernel, &flags, &dev, tune, &mut cache);
            // mla/chunk kernels always go through the tuner, so their
            // sweep results must persist even without --tune
            let tuner_ran = tune || matches!(kernel, "mla" | "chunk_scan" | "chunk_state");
            if tuner_ran {
                if let Err(e) = cache.save() {
                    eprintln!("warning: could not persist tuning cache: {}", e);
                }
            }
            let lowered = match compile(&prog, &dev, &CompileOptions::default()) {
                Ok(l) => l,
                Err(e) => die(&format!("compile error: {}", e)),
            };
            let c = lowered.stmt_counts();
            println!("kernel {} on {}:", prog.name, dev.name);
            println!(
                "  grid={:?} threads={} smem={}B regs/thread={}",
                lowered.static_grid(),
                lowered.threads,
                lowered.schedule.smem_bytes,
                lowered.schedule.regs_per_thread
            );
            println!(
                "  stmts: {} copies ({} async), {} gemms, {} barriers, {} commits, {} waits",
                c.copies, c.async_copies, c.gemms, c.barriers, c.commits, c.waits
            );
            println!(
                "  pipeline stages={:?} warp_specialized={}",
                lowered
                    .schedule
                    .pipelines
                    .iter()
                    .map(|p| p.num_stages)
                    .collect::<Vec<_>>(),
                lowered.schedule.warp_specialized
            );
            if cmd == "simulate" {
                for (label, pen) in [
                    ("tilelang", Penalties::none()),
                    ("triton-like", Penalties::triton_like()),
                    ("torch-like", Penalties::torch_like()),
                ] {
                    let r = estimate(&lowered, &dev, &pen);
                    println!(
                        "  {:<12} {:>10}  {:>7.1} TFLOPS  bound={:?}  occ={:.2}",
                        label,
                        fmt_us(r.time_us),
                        r.tflops,
                        r.bound,
                        r.occupancy
                    );
                }
            }
        }
        "schedule" => {
            let kernel = flags
                .get("kernel")
                .map(|s| s.as_str())
                .unwrap_or("flash_attention");
            let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
                .unwrap_or_else(|| {
                    eprintln!("unknown device");
                    std::process::exit(2);
                });
            let top = geti(&flags, "top", 8).max(1) as usize;
            let pen = Penalties::none();
            // (candidate label, specialize knob, report)
            let mut rows: Vec<(String, Option<bool>, tilelang::sim::model::SimReport)> =
                Vec::new();
            match kernel {
                "gemm" => {
                    let (m, n, k) = (
                        geti(&flags, "m", 4096),
                        geti(&flags, "n", 4096),
                        geti(&flags, "k", 4096),
                    );
                    let t = GemmTunable::new(m, n, k, DType::F16);
                    println!("schedule space: gemm {}x{}x{} on {}", m, n, k, dev.name);
                    for cfg in t.candidates() {
                        if let Ok(r) = simulate_kernel(&t.build(&cfg), &dev, &pen) {
                            let label = format!(
                                "bm{:<3} bn{:<3} bk{:<2} stages{} thr{}",
                                cfg.block_m, cfg.block_n, cfg.block_k, cfg.num_stages, cfg.threads
                            );
                            rows.push((label, cfg.specialize, r));
                        }
                    }
                }
                "flash_attention" => {
                    let (bh, s, d) = (
                        geti(&flags, "bh", 32),
                        geti(&flags, "seq", 1024),
                        geti(&flags, "d", 128),
                    );
                    let causal = flags.contains_key("causal");
                    let shape = AttnShape {
                        name: "cli",
                        batch: 1,
                        heads: bh,
                        seq_len: s,
                        head_dim: d,
                        causal,
                    };
                    let t = AttentionTunable { shape };
                    println!(
                        "schedule space: flash_attention bh={} seq={} d={} causal={} on {}",
                        bh, s, d, causal, dev.name
                    );
                    for cfg in t.candidates() {
                        if let Ok(r) = simulate_kernel(&t.build(&cfg), &dev, &pen) {
                            let label = format!(
                                "bm{:<3} bn{:<3} stages{} thr{}",
                                cfg.block_m, cfg.block_n, cfg.num_stages, cfg.threads
                            );
                            rows.push((label, cfg.specialize, r));
                        }
                    }
                }
                other => die(&format!(
                    "schedule supports --kernel gemm|flash_attention, got {}",
                    other
                )),
            }
            if rows.is_empty() {
                die("no feasible candidates");
            }
            rows.sort_by(|a, b| a.2.time_us.partial_cmp(&b.2.time_us).unwrap());
            println!(
                "  {:<32} {:>5} {:>10} | per-pipeline: stages spec {:>9} {:>9} {:>9} {:>9}",
                "candidate", "spec", "time", "copy", "compute", "fill", "steady"
            );
            for (label, sp, r) in rows.iter().take(top) {
                let spec = match sp {
                    None => "auto",
                    Some(true) => "on",
                    Some(false) => "off",
                };
                let mut line = format!("  {:<32} {:>5} {:>10} |", label, spec, fmt_us(r.time_us));
                for p in &r.pipelines {
                    line.push_str(&format!(
                        "        {} {:>4} {:>9} {:>9} {:>9} {:>9}",
                        p.stages,
                        if p.specialized { "yes" } else { "no" },
                        fmt_us(p.copy_us),
                        fmt_us(p.compute_us),
                        fmt_us(p.fill_us),
                        fmt_us(p.steady_us),
                    ));
                }
                println!("{}", line);
            }
            // head-to-head: best specialized vs best unspecialized
            let best_on = rows
                .iter()
                .filter(|(_, sp, _)| *sp == Some(true))
                .map(|(_, _, r)| r.time_us)
                .fold(f64::INFINITY, f64::min);
            let best_off = rows
                .iter()
                .filter(|(_, sp, _)| *sp == Some(false))
                .map(|(_, _, r)| r.time_us)
                .fold(f64::INFINITY, f64::min);
            if best_on.is_finite() && best_off.is_finite() {
                let verdict = if best_on < best_off {
                    "specialized wins"
                } else {
                    "unspecialized wins"
                };
                println!(
                    "specialization: on={} off={} ({})",
                    fmt_us(best_on),
                    fmt_us(best_off),
                    verdict
                );
            }
        }
        "run" => {
            let name = flags
                .get("artifact")
                .cloned()
                .unwrap_or_else(|| "matmul_64x64x64".to_string());
            let backend = backend_from_flags(&flags, geti(&flags, "shards", 1).max(1) as usize);
            let bname = backend.name();
            let (rec, trace, metrics) = obs_from_flags(&flags);
            let res = Runtime::with_backend(&dir, backend).and_then(|mut rt| {
                rt.set_recorder(rec.clone());
                let inputs = rt.example_inputs(&name)?;
                let t0 = std::time::Instant::now();
                let out = rt.execute(&name, &inputs)?;
                Ok((out, t0.elapsed()))
            });
            match res {
                Ok((out, dt)) => {
                    println!(
                        "{}: {} outputs in {:?} on {} (first: {:?})",
                        name,
                        out.len(),
                        dt,
                        bname,
                        &out[..4.min(out.len())]
                    );
                    obs_finish(&rec, &trace, &metrics);
                }
                Err(e) => die(&format!("run failed: {}", e)),
            }
        }
        "plan" => {
            let name = flags
                .get("artifact")
                .cloned()
                .unwrap_or_else(|| "matmul_64x64x64".to_string());
            let shards = geti(&flags, "shards", 2).max(1) as usize;
            let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
                .unwrap_or_else(|| {
                    eprintln!("unknown device");
                    std::process::exit(2);
                });
            let rt = Runtime::new(&dir)
                .unwrap_or_else(|e| die(&format!("{}\n(run `tilelang artifacts` first)", e)));
            let spec = rt.spec(&name).unwrap_or_else(|e| die(&e.to_string())).clone();
            if let Some(gfile) = &spec.graph {
                // graph artifacts plan at the block level: one partition
                // axis for the whole graph, fused per-shard cost
                let graph = KernelGraph::load(Path::new(&dir).join(gfile))
                    .unwrap_or_else(|e| die(&e.to_string()));
                // one partition axis exists today, so the winner is the
                // whole table (planning builds real per-shard programs —
                // don't run it twice)
                let chosen = graph_shard::plan_graph(&graph, shards, &dev)
                    .unwrap_or_else(|e| die(&e.to_string()));
                println!(
                    "graph sharding plans for {} across {} executors on {}:",
                    name, shards, dev.name
                );
                println!(
                    "  * {:<14} concat_dim={}  spans={:?}  kernel={:>9} comm={:>9} \
                     total={:>9}",
                    chosen.strategy.to_string(),
                    chosen.concat_dim,
                    chosen.spans,
                    fmt_us(chosen.kernel_us),
                    fmt_us(chosen.comm_us),
                    fmt_us(chosen.cost_us())
                );
                return;
            }
            let kind =
                shard_plan::resolve_kind(&spec).unwrap_or_else(|e| die(&e.to_string()));
            // the planner picks the winner (and reports *why* when no
            // strategy is feasible); enumerate only fills the table
            let chosen =
                shard_plan::plan(&kind, &spec.in_shapes, &spec.out_shape, shards, &dev)
                    .unwrap_or_else(|e| die(&e.to_string()));
            let plans =
                shard_plan::enumerate(&kind, &spec.in_shapes, &spec.out_shape, shards, &dev);
            println!(
                "sharding plans for {} ({}) across {} executors on {}:",
                name,
                kind.tag(),
                shards,
                dev.name
            );
            for p in &plans {
                println!(
                    "  {} {:<14} collective={:<11} kernel={:>9} comm={:>9} total={:>9}",
                    if p.strategy == chosen.strategy { "*" } else { " " },
                    p.strategy.to_string(),
                    p.collective.to_string(),
                    fmt_us(p.kernel_us),
                    fmt_us(p.comm_us),
                    fmt_us(p.cost_us())
                );
            }
        }
        "graph" => {
            let name = flags
                .get("artifact")
                .cloned()
                .unwrap_or_else(|| "mlp_block_64x64x128".to_string());
            let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
                .unwrap_or_else(|| {
                    eprintln!("unknown device");
                    std::process::exit(2);
                });
            let rt = Runtime::with_backend(&dir, ExecBackend::interp())
                .unwrap_or_else(|e| die(&format!("{}\n(run `tilelang artifacts` first)", e)));
            let spec = rt.spec(&name).unwrap_or_else(|e| die(&e.to_string())).clone();
            let Some(gfile) = &spec.graph else {
                die(&format!(
                    "{} is not a graph artifact (no graph= manifest tag); try mlp_block_64x64x128",
                    name
                ));
            };
            let graph = KernelGraph::load(Path::new(&dir).join(gfile))
                .unwrap_or_else(|e| die(&e.to_string()));
            println!("graph {} ({} inputs):", graph.name, graph.inputs.len());
            for node in &graph.nodes {
                println!("  {}", node.describe());
            }
            let planned = if flags.contains_key("no-fuse") {
                println!("fusion: disabled (--no-fuse)");
                graph.clone()
            } else {
                let fp = graph_fuse::plan(&graph, &dev)
                    .unwrap_or_else(|e| die(&format!("fusion planning failed: {}", e)));
                println!(
                    "fusion: {} fold(s), modeled {:.1} us fused vs {:.1} us unfused:",
                    fp.fused.len(),
                    fp.fused_cost_us,
                    fp.unfused_cost_us
                );
                for f in &fp.fused {
                    println!(
                        "  + {} <- {} ({}), saves {:.2} us",
                        f.producer,
                        f.folded,
                        f.op.describe(),
                        f.saved_us
                    );
                }
                for (node, why) in &fp.rejected {
                    println!("  - {} stays materialized: {}", node, why);
                }
                println!("fused graph:");
                for node in &fp.graph.nodes {
                    println!("  {}", node.describe());
                }
                fp.graph
            };
            let mp = graph_memplan::plan(&planned);
            println!("memory plan:");
            for line in mp.describe(&planned) {
                println!("{}", line);
            }
            // --shards N: the graph-level partition (plans on the
            // *stored* unfused graph — each shard re-fuses its sub-graph)
            let shards = geti(&flags, "shards", 1).max(1) as usize;
            if shards >= 2 {
                match graph_shard::plan_graph(&graph, shards, &dev) {
                    Ok(p) => {
                        println!("sharding: {}", p.describe());
                        for (part, &(start, len)) in p.parts.iter().zip(&p.spans) {
                            let sliced: Vec<String> = part
                                .inputs
                                .iter()
                                .enumerate()
                                .filter_map(|(i, sl)| {
                                    sl.dim.map(|d| {
                                        format!(
                                            "{}[dim {}: {}..{}]",
                                            graph.inputs[i].name,
                                            d,
                                            sl.start,
                                            sl.start + sl.len
                                        )
                                    })
                                })
                                .collect();
                            println!(
                                "  shard {}: batch rows {}..{}, scatters {}",
                                part.index,
                                start,
                                start + len,
                                if sliced.is_empty() {
                                    "nothing".to_string()
                                } else {
                                    sliced.join(", ")
                                }
                            );
                        }
                    }
                    Err(e) => println!("sharding: not applicable ({})", e),
                }
            }
        }
        "serve" if flags.contains_key("continuous") => {
            // continuous-batching decode: streams co-batch through the
            // shared paged KV-cache pool and the decode_block_paged
            // multi-output graph (no artifact directory needed — the
            // engine prepares its graphs directly)
            let streams = geti(&flags, "streams", 8).max(1) as usize;
            let decode_steps = geti(&flags, "steps", 4).max(1) as usize;
            let prefill_max = geti(&flags, "prefill", 16).max(1) as usize;
            let compiled = match flags.get("backend").map(|s| s.as_str()) {
                None | Some("compiled") => true,
                Some("interp") => false,
                Some(other) => die(&format!(
                    "unknown --backend {:?} (expected interp or compiled)",
                    other
                )),
            };
            let cfg = EngineConfig {
                slots: geti(&flags, "slots", 16),
                page_rows: geti(&flags, "page-rows", 8).max(1) as usize,
                pool_pages: geti(&flags, "pool-pages", 96).max(1) as usize,
                seed: geti(&flags, "seed", 0xC0FFEE) as u64,
                compiled,
                ..Default::default()
            };
            let specs = continuous_specs(streams, prefill_max, decode_steps);
            println!(
                "continuous serve: {} streams x {} decode steps (prompts up to {} rows), \
                 {} slots, pool {} pages x {} rows, backend {}",
                streams,
                decode_steps,
                prefill_max,
                cfg.slots,
                cfg.pool_pages,
                cfg.page_rows,
                if compiled { "compiled" } else { "interp" }
            );
            let mut eng = Engine::new(cfg).unwrap_or_else(|e| die(&e.to_string()));
            let (rec, trace, metrics) = obs_from_flags(&flags);
            eng.set_recorder(rec.clone());
            let report = eng.run(&specs).unwrap_or_else(|e| die(&e.to_string()));
            println!("{}", report.summary());
            if flags.contains_key("verify") {
                let oracle = eng
                    .serial_oracle(&specs)
                    .unwrap_or_else(|e| die(&e.to_string()));
                for sp in &specs {
                    let (b, s) = (&report.outputs[&sp.id], &oracle[&sp.id]);
                    let same = b.len() == s.len()
                        && b.iter().zip(s).all(|(x, y)| {
                            x.iter().zip(y).all(|(a, b)| a.to_bits() == b.to_bits())
                        });
                    if !same {
                        die(&format!(
                            "stream {}: batched decode diverged from the serial oracle",
                            sp.id
                        ));
                    }
                }
                println!(
                    "verified: all {} streams bit-identical to the serial decode oracle",
                    specs.len()
                );
            }
            // the oracle swaps the recorder out while it reruns, so the
            // trace holds exactly one batched engine run
            obs_finish(&rec, &trace, &metrics);
        }
        "serve" => {
            let name = flags
                .get("artifact")
                .cloned()
                .unwrap_or_else(|| "linear_64x256x64".to_string());
            let n_requests = geti(&flags, "requests", 64).max(1) as usize;
            let shards = geti(&flags, "shards", 1).max(1) as usize;
            // open the manifest once on the interp backend for the spec
            let meta = Runtime::with_backend(&dir, ExecBackend::interp())
                .unwrap_or_else(|e| die(&format!("{}\n(run `tilelang artifacts` first)", e)));
            let spec = meta.spec(&name).unwrap_or_else(|e| die(&e.to_string())).clone();
            let backend = backend_from_flags(&flags, shards);
            let (rec, trace, metrics) = obs_from_flags(&flags);
            // reuse the metadata runtime when it already runs the chosen
            // backend (the common interp case); rebuild otherwise
            let mut rt = if matches!(backend, ExecBackend::Interp(_)) {
                meta
            } else {
                Runtime::with_backend(&dir, backend.clone())
                    .unwrap_or_else(|e| die(&e.to_string()))
            };
            rt.set_recorder(rec.clone());
            let inputs = rt
                .example_inputs(&name)
                .unwrap_or_else(|e| die(&e.to_string()));
            // row serving assumes input 0 carries the batch dim and the
            // output keeps it (dim 0); transposed-output or chunked
            // artifacts (dequant, chunk_state) cannot be row-sliced
            if spec.in_shapes[0].len() < 2 || spec.out_shape[0] != spec.in_shapes[0][0] {
                die(&format!(
                    "{} is not row-batchable (input 0 {:?}, out {:?}); \
                     serve a batch-major artifact like linear_64x256x64",
                    name, spec.in_shapes[0], spec.out_shape
                ));
            }
            let batch = spec.in_shapes[0][0] as usize;
            let row_len: usize = spec.in_shapes[0][1..].iter().product::<i64>() as usize;
            let out_row_len = spec.out_len() / batch;
            let loaded = rt
                .load(&name)
                .unwrap_or_else(|e| die(&format!("load failed: {}", e)));
            if let Some(p) = loaded.shard_plan() {
                println!("sharding: {}", p.describe());
            }
            if let Some(sg) = loaded.sharded_graph() {
                println!("graph sharding: {}", sg.describe());
            }
            if let Some(g) = loaded.graph_kernel() {
                println!("graph: {}", g.describe());
            }
            // mirror the coordinator's refusal with a clearer exit
            if loaded.graph_row_batchable() == Some(false) {
                die(&format!(
                    "{} is not row-batchable (output rows depend on other batch \
                     rows, e.g. attention over the row dim); serve \
                     mlp_block_64x64x128 or use `tilelang run`",
                    name
                ));
            }
            let direct = rt
                .execute(&name, &inputs)
                .unwrap_or_else(|e| die(&e.to_string()));
            let coord = Coordinator::start_batched_with_backend_rec(
                &dir,
                backend,
                &name,
                BatchPolicy::default(),
                rec.clone(),
            )
            .unwrap_or_else(|e| die(&e.to_string()));
            println!(
                "serving {} row requests of {} (batch dim {}, backend {})",
                n_requests,
                name,
                batch,
                rt.backend_name()
            );
            let t0 = std::time::Instant::now();
            let mut rxs = Vec::with_capacity(n_requests);
            for i in 0..n_requests {
                let slot = i % batch;
                let row = inputs[0][slot * row_len..(slot + 1) * row_len].to_vec();
                rxs.push((
                    slot,
                    coord
                        .submit_row(&name, row)
                        .unwrap_or_else(|e| die(&e.to_string())),
                ));
            }
            let mut latencies = Vec::with_capacity(n_requests);
            let mut batch_sizes = Vec::with_capacity(n_requests);
            for (slot, rx) in rxs {
                let reply = rx.recv().unwrap_or_else(|_| die("worker dropped reply"));
                let out = reply
                    .output
                    .unwrap_or_else(|e| die(&format!("row failed: {}", e)));
                let want = &direct[slot * out_row_len..(slot + 1) * out_row_len];
                let err = out
                    .iter()
                    .zip(want)
                    .map(|(g, w)| (g - w).abs())
                    .fold(0f32, f32::max);
                if err > 1e-4 {
                    die(&format!("served row diverged from direct execution: {}", err));
                }
                latencies.push(reply.latency_us);
                batch_sizes.push(reply.batch_size);
            }
            let wall = t0.elapsed();
            latencies.sort_unstable();
            let mean_batch =
                batch_sizes.iter().sum::<usize>() as f64 / batch_sizes.len().max(1) as f64;
            println!(
                "throughput: {:.1} rows/s ({} requests in {:.2?}); all outputs match direct \
                 execution",
                n_requests as f64 / wall.as_secs_f64(),
                n_requests,
                wall
            );
            println!(
                "latency: p50 = {:.2} ms, p90 = {:.2} ms, p99 = {:.2} ms; mean batch = {:.2}",
                percentile(&latencies, 50.0) as f64 / 1e3,
                percentile(&latencies, 90.0) as f64 / 1e3,
                percentile(&latencies, 99.0) as f64 / 1e3,
                mean_batch
            );
            coord.shutdown();
            obs_finish(&rec, &trace, &metrics);
        }
        "bench" => run_bench(&flags, &dir),
        "bench-check" => run_bench_check(&flags),
        "profile" => run_profile(&flags, &dir),
        "roofline" => run_roofline(&flags, &dir),
        "check-trace" => {
            let path = flags
                .get("file")
                .cloned()
                .unwrap_or_else(|| die("check-trace needs --file trace.json"));
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| die(&format!("read {}: {}", path, e)));
            let events = match read_chrome_trace(&text) {
                Ok(events) if events.is_empty() => {
                    die(&format!("{}: valid trace document but zero spans", path))
                }
                Ok(events) => events,
                Err(e) => die(&format!("{}: invalid trace: {}", path, e)),
            };
            let mut violations: Vec<String> = Vec::new();
            // counter tracks: running totals are emitted ts-sorted, so a
            // total dropping below an earlier one means the exporter (or
            // a hand-edited file) broke monotonicity
            let counter_tracks = match read_chrome_counters(&text) {
                Ok(points) => {
                    let mut by_name: std::collections::BTreeMap<&str, Vec<(f64, f64)>> =
                        std::collections::BTreeMap::new();
                    for (name, ts, total) in &points {
                        by_name.entry(name.as_str()).or_default().push((*ts, *total));
                    }
                    for (name, pts) in &mut by_name {
                        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite ts"));
                        let mut prev: Option<(f64, f64)> = None;
                        for (ts, total) in pts.iter() {
                            if let Some((pts_us, ptotal)) = prev {
                                if *total < ptotal {
                                    violations.push(format!(
                                        "counter {}: total {} at {:.1}us drops below {} at \
                                         {:.1}us (counters are cumulative and must be \
                                         non-decreasing)",
                                        name, total, ts, ptotal, pts_us
                                    ));
                                }
                            }
                            prev = Some((*ts, *total));
                        }
                    }
                    by_name.len()
                }
                Err(e) => {
                    violations.push(format!("counter events unreadable: {}", e));
                    0
                }
            };
            // span nesting: within one lane, two spans either nest fully
            // or do not overlap at all — a partial overlap means the
            // span timestamps are corrupt. 0.01us slack absorbs the
            // exporter's decimal rounding.
            const SLACK_US: f64 = 0.01;
            let mut lanes: std::collections::BTreeMap<u64, Vec<&tilelang::obs::Event>> =
                std::collections::BTreeMap::new();
            for e in &events {
                lanes.entry(e.tid).or_default().push(e);
            }
            for (tid, mut evs) in lanes.clone() {
                evs.sort_by(|a, b| {
                    a.ts_us
                        .partial_cmp(&b.ts_us)
                        .expect("finite ts")
                        .then(b.dur_us.partial_cmp(&a.dur_us).expect("finite dur"))
                });
                let mut stack: Vec<&tilelang::obs::Event> = Vec::new();
                for e in evs {
                    while let Some(top) = stack.last() {
                        if e.ts_us >= top.ts_us + top.dur_us - SLACK_US {
                            stack.pop();
                        } else {
                            break;
                        }
                    }
                    if let Some(top) = stack.last() {
                        if e.ts_us + e.dur_us > top.ts_us + top.dur_us + SLACK_US {
                            violations.push(format!(
                                "lane {}: span {:?} [{:.2}..{:.2}us] overlaps enclosing \
                                 {:?} [{:.2}..{:.2}us] without nesting inside it",
                                tid,
                                e.name,
                                e.ts_us,
                                e.ts_us + e.dur_us,
                                top.name,
                                top.ts_us,
                                top.ts_us + top.dur_us
                            ));
                        }
                    }
                    stack.push(e);
                }
            }
            if !violations.is_empty() {
                for v in &violations {
                    eprintln!("VIOLATION: {}", v);
                }
                die(&format!(
                    "{}: {} violation(s) in {} spans / {} counter tracks",
                    path,
                    violations.len(),
                    events.len(),
                    counter_tracks
                ));
            }
            let cats: std::collections::BTreeSet<&str> =
                events.iter().map(|e| e.cat.as_str()).collect();
            println!(
                "{}: {} spans across {} lanes (cats: {}); {} counter tracks monotonic; \
                 spans nest cleanly",
                path,
                events.len(),
                lanes.len(),
                cats.into_iter().collect::<Vec<_>>().join(","),
                counter_tracks
            );
        }
        _ => {
            println!(
                "tilelang {} — composable tiled programming model (reproduction)\n\
                 usage: tilelang <devices|artifacts|compile|simulate|schedule|tune|run|serve|plan|graph|bench|bench-check|profile|roofline|check-trace> [--flags]\n\
                 examples:\n\
                 \u{20}  tilelang simulate --kernel gemm --device a100 --m 4096 --n 4096 --k 4096 --tune\n\
                 \u{20}  tilelang schedule --kernel flash_attention --device h100 --seq 1024 --top 8\n\
                 \u{20}  tilelang tune --kernel flash_attention --device h100 --seq 4096\n\
                 \u{20}  tilelang artifacts --dir artifacts\n\
                 \u{20}  tilelang run --artifact matmul_64x64x64 --backend compiled\n\
                 \u{20}  tilelang serve --artifact linear_64x256x64 --requests 64\n\
                 \u{20}  tilelang serve --artifact linear_64x256x64 --backend interp\n\
                 \u{20}  tilelang serve --artifact linear_64x256x64 --shards 2\n\
                 \u{20}  tilelang plan --artifact matmul_64x64x64 --shards 4\n\
                 \u{20}  tilelang graph --artifact mlp_block_64x64x128\n\
                 \u{20}  tilelang graph --artifact decode_block_64x256x64 --shards 2\n\
                 \u{20}  tilelang serve --artifact mlp_block_64x64x128 --requests 32\n\
                 \u{20}  tilelang serve --artifact decode_block_64x256x64 --shards 2\n\
                 \u{20}  tilelang serve --continuous --streams 8 --steps 4 --backend compiled\n\
                 \u{20}  tilelang serve --continuous --verify --backend compiled\n\
                 \u{20}  tilelang serve --continuous --trace trace.json --metrics metrics.txt\n\
                 \u{20}  tilelang check-trace --file trace.json\n\
                 \u{20}  tilelang profile --iters 5 --device h100\n\
                 \u{20}  tilelang profile --artifact mlp_block_64x64x128\n\
                 \u{20}  tilelang roofline --device h100\n\
                 \u{20}  tilelang roofline --artifact matmul_64x64x64 --iters 5\n\
                 \u{20}  tilelang bench --quick --out BENCH_current.json\n\
                 \u{20}  tilelang bench-check --baseline BENCH_10.json --current BENCH_current.json",
                tilelang::version()
            );
        }
    }
}
