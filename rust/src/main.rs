//! tilelang CLI — leader entrypoint.
//!
//! Subcommands:
//!   devices                         list modeled devices
//!   artifacts [--dir D]             list AOT artifacts + golden check
//!   compile --kernel K --device D   compile a workload, print report
//!   simulate --kernel K --device D  compile + simulate across baselines
//!   tune --kernel K --device D      autotune a workload (persistent cache)
//!   run --artifact NAME [--dir D]   execute an artifact via PJRT
//!
//! `compile`/`simulate` accept `--tune` to pick the tile configuration
//! via the autotuner (served from the tuning cache when warm) instead of
//! the static defaults. `--cache PATH` overrides the cache location,
//! `--no-cache` forces a fresh sweep.
//!
//! (Hand-rolled argument parsing: the offline environment has no clap.)

use std::collections::HashMap;

use tilelang::autotuner::{tune_cached, TuneResult, Tunable, TuningCache};
use tilelang::ir::dtype::DType;
use tilelang::passes::lower::{compile, CompileOptions};
use tilelang::report::fmt_us;
use tilelang::runtime::Runtime;
use tilelang::sim::device::Device;
use tilelang::sim::model::{estimate, Penalties};
use tilelang::workloads::attention::{
    flash_attention_program, AttentionTunable, AttnConfig, MlaTunable,
};
use tilelang::workloads::dequant::{dequant_matmul_program, DequantConfig, DequantTunable, WeightFormat};
use tilelang::workloads::linear_attention::{ChunkKind, LinearAttentionTunable};
use tilelang::workloads::matmul::{matmul_program, GemmTunable, TileConfig};
use tilelang::workloads::shapes::{AttnShape, LinAttnShape, MlaShape};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn geti(flags: &HashMap<String, String>, k: &str, d: i64) -> i64 {
    flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn open_cache(flags: &HashMap<String, String>) -> TuningCache {
    if flags.contains_key("no-cache") {
        TuningCache::in_memory()
    } else if let Some(path) = flags.get("cache") {
        TuningCache::open(path)
    } else {
        TuningCache::open_default()
    }
}

fn die(msg: &str) -> ! {
    eprintln!("{}", msg);
    std::process::exit(1)
}

/// Tune one workload through the generic driver + cache, printing the
/// decision, and return the program built from the chosen config.
fn tuned_program<T: Tunable>(
    t: &T,
    dev: &Device,
    cache: &mut TuningCache,
) -> tilelang::ir::program::TileProgram {
    match tune_cached(t, dev, &Penalties::none(), cache) {
        Ok(r) => {
            print_tune_result(t.workload(), &r);
            t.build(&r.config)
        }
        Err(e) => die(&format!("tuning failed: {}", e)),
    }
}

fn print_tune_result<C: std::fmt::Debug>(workload: &str, r: &TuneResult<C>) {
    println!(
        "tuned {}: {:?}  ({} in {}; {} candidates evaluated)",
        workload,
        r.config,
        fmt_us(r.report.time_us),
        if r.cache_hit { "cache hit" } else { "fresh sweep" },
        r.evaluated
    );
}

/// Build a workload program; `tune` selects the config via the cached
/// autotuner, otherwise the static defaults are used.
fn build_kernel(
    name: &str,
    flags: &HashMap<String, String>,
    dev: &Device,
    tune: bool,
    cache: &mut TuningCache,
) -> tilelang::ir::program::TileProgram {
    match name {
        "gemm" => {
            let (m, n, k) = (geti(flags, "m", 4096), geti(flags, "n", 4096), geti(flags, "k", 4096));
            if tune {
                tuned_program(&GemmTunable::new(m, n, k, DType::F16), dev, cache)
            } else {
                matmul_program(m, n, k, DType::F16, &TileConfig::default_for(m, n, k))
            }
        }
        "flash_attention" => {
            let (bh, s, d) = (geti(flags, "bh", 32), geti(flags, "seq", 1024), geti(flags, "d", 128));
            let causal = flags.contains_key("causal");
            if tune {
                let shape = AttnShape {
                    name: "cli",
                    batch: 1,
                    heads: bh,
                    seq_len: s,
                    head_dim: d,
                    causal,
                };
                tuned_program(&AttentionTunable { shape }, dev, cache)
            } else {
                flash_attention_program(bh, s, d, causal, &AttnConfig::default_for(s))
            }
        }
        "dequant" => {
            let (m, n, k) = (geti(flags, "m", 16), geti(flags, "n", 4096), geti(flags, "k", 4096));
            if tune {
                tuned_program(&DequantTunable::new(m, n, k, WeightFormat::Int4), dev, cache)
            } else {
                dequant_matmul_program(m.max(16), n, k, WeightFormat::Int4, &DequantConfig::default())
            }
        }
        "mla" => {
            let shape = MlaShape {
                batch: geti(flags, "batch", 64),
                heads: geti(flags, "heads", 128),
                seqlen_kv: geti(flags, "seq-kv", 8192),
                dim: geti(flags, "dim", 512),
                pe_dim: geti(flags, "pe", 64),
            };
            tuned_program(&MlaTunable { shape }, dev, cache)
        }
        "chunk_scan" | "chunk_state" => {
            let shape = LinAttnShape {
                name: "cli",
                batch: geti(flags, "batch", 1),
                nheads: geti(flags, "heads", 64),
                seq_len: geti(flags, "seq", 2048),
                head_dim: geti(flags, "d", 64),
                d_state: geti(flags, "dstate", 128),
            };
            let kind = if name == "chunk_state" {
                ChunkKind::State
            } else {
                ChunkKind::Scan
            };
            tuned_program(&LinearAttentionTunable { kind, shape }, dev, cache)
        }
        other => {
            eprintln!(
                "unknown kernel {} (gemm|flash_attention|dequant|mla|chunk_scan|chunk_state)",
                other
            );
            std::process::exit(2);
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&argv[1.min(argv.len())..]);
    let dir = flags
        .get("dir")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());

    match cmd {
        "devices" => {
            for d in ["rtx4090", "a100", "h100", "mi300x", "rtx3090"] {
                let dev = Device::by_name(d).unwrap();
                println!(
                    "{:<10} arch={:?} sms={} bw={}GB/s tensor={}TFLOPS",
                    dev.name,
                    dev.arch,
                    dev.sms,
                    dev.dram_gbps,
                    dev.peak_tensor_tflops()
                );
            }
        }
        "artifacts" => match Runtime::new(&dir) {
            Ok(rt) => {
                for name in rt.artifact_names() {
                    let spec = rt.spec(&name).unwrap().clone();
                    match rt.golden_check(&name) {
                        Ok(err) => println!(
                            "{:<28} out={:?} golden max_err={:.2e}",
                            name, spec.out_shape, err
                        ),
                        Err(e) => println!("{:<28} ERROR: {}", name, e),
                    }
                }
            }
            Err(e) => die(&e.to_string()),
        },
        "tune" => {
            let kernel = flags.get("kernel").map(|s| s.as_str()).unwrap_or("gemm");
            let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
                .unwrap_or_else(|| {
                    eprintln!("unknown device");
                    std::process::exit(2);
                });
            let mut cache = open_cache(&flags);
            // every workload prints its decision inside build_kernel;
            // spaces with no feasible candidate exit with an error
            let _ = build_kernel(kernel, &flags, &dev, true, &mut cache);
            if let Err(e) = cache.save() {
                eprintln!("warning: could not persist tuning cache: {}", e);
            } else if !flags.contains_key("no-cache") {
                println!("cache: {} entries", cache.len());
            }
        }
        "compile" | "simulate" => {
            let kernel = flags.get("kernel").map(|s| s.as_str()).unwrap_or("gemm");
            let dev = Device::by_name(flags.get("device").map(|s| s.as_str()).unwrap_or("h100"))
                .unwrap_or_else(|| {
                    eprintln!("unknown device");
                    std::process::exit(2);
                });
            let tune = flags.contains_key("tune");
            let mut cache = open_cache(&flags);
            let prog = build_kernel(kernel, &flags, &dev, tune, &mut cache);
            // mla/chunk kernels always go through the tuner, so their
            // sweep results must persist even without --tune
            let tuner_ran = tune || matches!(kernel, "mla" | "chunk_scan" | "chunk_state");
            if tuner_ran {
                if let Err(e) = cache.save() {
                    eprintln!("warning: could not persist tuning cache: {}", e);
                }
            }
            let lowered = match compile(&prog, &dev, &CompileOptions::default()) {
                Ok(l) => l,
                Err(e) => die(&format!("compile error: {}", e)),
            };
            let c = lowered.stmt_counts();
            println!("kernel {} on {}:", prog.name, dev.name);
            println!(
                "  grid={:?} threads={} smem={}B regs/thread={}",
                lowered.static_grid(),
                lowered.threads,
                lowered.schedule.smem_bytes,
                lowered.schedule.regs_per_thread
            );
            println!(
                "  stmts: {} copies ({} async), {} gemms, {} barriers, {} commits, {} waits",
                c.copies, c.async_copies, c.gemms, c.barriers, c.commits, c.waits
            );
            println!(
                "  pipeline stages={:?} warp_specialized={}",
                lowered
                    .schedule
                    .pipelines
                    .iter()
                    .map(|p| p.num_stages)
                    .collect::<Vec<_>>(),
                lowered.schedule.warp_specialized
            );
            if cmd == "simulate" {
                for (label, pen) in [
                    ("tilelang", Penalties::none()),
                    ("triton-like", Penalties::triton_like()),
                    ("torch-like", Penalties::torch_like()),
                ] {
                    let r = estimate(&lowered, &dev, &pen);
                    println!(
                        "  {:<12} {:>10}  {:>7.1} TFLOPS  bound={:?}  occ={:.2}",
                        label,
                        fmt_us(r.time_us),
                        r.tflops,
                        r.bound,
                        r.occupancy
                    );
                }
            }
        }
        "run" => {
            let name = flags
                .get("artifact")
                .cloned()
                .unwrap_or_else(|| "matmul_128".to_string());
            let res = Runtime::new(&dir).and_then(|rt| {
                let inputs = rt.example_inputs(&name)?;
                let t0 = std::time::Instant::now();
                let out = rt.execute(&name, &inputs)?;
                Ok((out, t0.elapsed()))
            });
            match res {
                Ok((out, dt)) => {
                    println!(
                        "{}: {} outputs in {:?} (first: {:?})",
                        name,
                        out.len(),
                        dt,
                        &out[..4.min(out.len())]
                    );
                }
                Err(e) => die(&format!("run failed: {}", e)),
            }
        }
        _ => {
            println!(
                "tilelang {} — composable tiled programming model (reproduction)\n\
                 usage: tilelang <devices|artifacts|compile|simulate|tune|run> [--flags]\n\
                 examples:\n\
                 \u{20}  tilelang simulate --kernel gemm --device a100 --m 4096 --n 4096 --k 4096 --tune\n\
                 \u{20}  tilelang tune --kernel flash_attention --device h100 --seq 4096\n\
                 \u{20}  tilelang artifacts --dir artifacts\n\
                 \u{20}  tilelang run --artifact transformer_block",
                tilelang::version()
            );
        }
    }
}
