//! Continuous-batching decode engine over the paged KV-cache pool.
//!
//! The engine runs `decode_block_paged` as one multi-output graph per
//! step: every live stream occupies a batch slot, streams at different
//! sequence lengths co-batch through the per-step paged gather (cache
//! rows are copied out of the pool into contiguous `[slots, padded_kv,
//! head_dim]` buffers, padded to the longest live stream rounded up to
//! 16), and the graph's extra outputs hand back each slot's new K/V row
//! so the cache update happens in-graph rather than as a host-side
//! re-projection. Between steps the only authoritative cache copy lives
//! in the shared [`KvPool`]; appends go in place and retirement recycles
//! pages through the free list.
//!
//! Scheduling is deliberately simple and deterministic: arrivals queue
//! FIFO, and a stream is admitted when a batch slot is free and the
//! pool can *reserve* enough pages for the stream's whole lifetime
//! (prefill + every decode step). Reservations, not the instantaneous
//! free list, back the guarantee: pages are allocated lazily as caches
//! grow, so live streams' unallocated future pages must not be promised
//! to newcomers — admission never strands a stream mid-decode on pool
//! exhaustion. Prefill (writing the prompt's K/V rows) is timed
//! separately from decode, and queue latency is measured from arrival
//! to the stream's first decode step.
//!
//! Bit-exactness contract (the soak test's oracle): a stream's emitted
//! outputs are byte-identical whether it runs alone or co-batched with
//! any other streams at any interleaving. This holds because the paged
//! kernel's length mask zeroes padded scores *exactly* (the masked
//! score rescale underflows to 0.0 for any finite row max), GEMM rows
//! are computed independently with a fixed k-ascending accumulation
//! order, and the engine pins every tile config: it prepares graphs
//! unfused with `tune: false`, so no fusion or tuning decision can vary
//! with batch composition or padding.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::bail;
use crate::error::Result;
use crate::graph::exec::GraphKernel;
use crate::graph::ir::decode_block_paged;
use crate::obs::{Recorder, Traffic};
use crate::runtime::InterpOptions;
use crate::serve::pool::KvPool;
use crate::util::stats::percentile;
use crate::workloads::matmul::test_data;

/// Engine shape and pool sizing. `slots` is the fixed batch dimension
/// of the decode graph (GEMM block_m needs it ≥ 16 and 16-aligned);
/// live streams map onto slots, dead slots ride along masked out.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub slots: i64,
    pub heads: i64,
    pub head_dim: i64,
    /// Cache rows per pool page.
    pub page_rows: usize,
    /// Total pages in the shared pool.
    pub pool_pages: usize,
    /// Run node kernels through the compiled bytecode VM.
    pub compiled: bool,
    /// Seed for weights, prompts and initial inputs.
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            slots: 16,
            heads: 16,
            head_dim: 16,
            page_rows: 16,
            pool_pages: 64,
            compiled: false,
            seed: 0xC0FFEE,
        }
    }
}

impl EngineConfig {
    pub fn d_model(&self) -> i64 {
        self.heads * self.head_dim
    }

    fn validate(&self) -> Result<()> {
        if self.slots < 16 || self.slots % 16 != 0 {
            bail!("engine slots must be >= 16 and 16-aligned, got {}", self.slots);
        }
        if self.heads < 16 || self.heads % 16 != 0 || self.head_dim < 16 || self.head_dim % 16 != 0
        {
            bail!(
                "engine heads/head_dim must be >= 16 and 16-aligned, got {}x{}",
                self.heads,
                self.head_dim
            );
        }
        if self.page_rows == 0 || self.pool_pages == 0 {
            bail!(
                "engine pool needs positive sizing ({} pages x {} rows)",
                self.pool_pages,
                self.page_rows
            );
        }
        Ok(())
    }
}

/// One request: arrive at `arrival_step`, prefill `prefill_rows` prompt
/// K/V rows, then emit `decode_steps` autoregressive outputs.
#[derive(Clone, Debug)]
pub struct StreamSpec {
    pub id: u64,
    pub arrival_step: usize,
    pub prefill_rows: usize,
    pub decode_steps: usize,
}

impl StreamSpec {
    fn total_rows(&self) -> usize {
        // every decode step appends one K/V row after executing
        self.prefill_rows + self.decode_steps
    }
}

/// p50/p99 over one phase's latency samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseStats {
    pub p50_us: u128,
    pub p99_us: u128,
    pub samples: usize,
}

impl PhaseStats {
    fn from_samples(mut us: Vec<u128>) -> PhaseStats {
        us.sort_unstable();
        PhaseStats {
            p50_us: percentile(&us, 50.0),
            p99_us: percentile(&us, 99.0),
            samples: us.len(),
        }
    }
}

/// What a continuous-batching run produced and how it behaved.
pub struct EngineReport {
    /// Per stream, the emitted decode outputs in order (`d_model` f32s
    /// each) — the soak test bit-compares these against the serial
    /// oracle.
    pub outputs: BTreeMap<u64, Vec<Vec<f32>>>,
    pub prefill: PhaseStats,
    pub decode: PhaseStats,
    pub queue: PhaseStats,
    /// Scheduler steps that executed at least one live stream.
    pub steps: usize,
    pub streams: usize,
    /// Most live streams ever co-batched in one step.
    pub peak_concurrency: usize,
    /// Peak pool pages in use / total pages.
    pub peak_pages: usize,
    pub pool_pages: usize,
    /// Completed streams per wall-clock second.
    pub streams_per_s: f64,
}

impl EngineReport {
    /// The `tilelang serve --continuous` summary line.
    pub fn summary(&self) -> String {
        format!(
            "continuous batching: {} streams over {} steps (peak {} co-batched), {:.1} \
             streams/s | prefill p50/p99 {}us/{}us | decode p50/p99 {}us/{}us | queue p50/p99 \
             {}us/{}us | pool peak {}/{} pages",
            self.streams,
            self.steps,
            self.peak_concurrency,
            self.streams_per_s,
            self.prefill.p50_us,
            self.prefill.p99_us,
            self.decode.p50_us,
            self.decode.p99_us,
            self.queue.p50_us,
            self.queue.p99_us,
            self.peak_pages,
            self.pool_pages
        )
    }
}

struct StreamState {
    spec_idx: usize,
    /// Next decode input — the previous step's output row.
    x: Vec<f32>,
    remaining: usize,
    arrived_at: Instant,
    first_decode_pending: bool,
}

/// The continuous-batching engine. Holds the model weights (seeded,
/// deterministic) and a kernel cache keyed by padded KV length, so the
/// serial oracle and the batched run share prepared graphs.
pub struct Engine {
    cfg: EngineConfig,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    bo: Vec<f32>,
    kernels: HashMap<i64, GraphKernel>,
    cache_dir: PathBuf,
    /// Observability sink. Disabled by default; `--trace`/`--metrics`
    /// attach an enabled recorder via [`Engine::set_recorder`]. The
    /// [`EngineReport`] phase latencies are measured by this recorder's
    /// spans whether or not it records, so enabling tracing cannot
    /// change what gets reported — or what gets decoded (the bit-
    /// exactness contract above is timing-independent).
    recorder: Recorder,
}

/// Weights live in [-0.03, 0.03]: with d_model-wide dot products the
/// y -> next-x feedback loop then contracts instead of blowing past
/// f16 range (kernels compute through f16 staging).
const WEIGHT_SCALE: f32 = 0.06;

fn scaled(n: i64, seed: u64) -> Vec<f32> {
    test_data(n, seed).into_iter().map(|x| x * WEIGHT_SCALE).collect()
}

/// Per-stream data seeds, independent of arrival order and batch
/// composition so the serial oracle regenerates identical prompts.
fn stream_seed(base: u64, id: u64, lane: u64, row: u64) -> u64 {
    base.wrapping_mul(0x9E3779B97F4A7C15)
        ^ id.wrapping_mul(0xBF58476D1CE4E5B9)
        ^ lane.wrapping_mul(0x94D049BB133111EB)
        ^ row.wrapping_add(0x2545F4914F6CDD1D)
}

fn round_up16(n: usize) -> i64 {
    (n.div_ceil(16) * 16).max(16) as i64
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Result<Engine> {
        cfg.validate()?;
        let dm = cfg.d_model();
        let hd = cfg.head_dim;
        let s = cfg.seed;
        let cache_dir =
            std::env::temp_dir().join(format!("tilelang-serve-{}", std::process::id()));
        std::fs::create_dir_all(&cache_dir)?;
        Ok(Engine {
            wq: scaled(dm * dm, stream_seed(s, 0, 10, 0)),
            wk: scaled(dm * hd, stream_seed(s, 0, 11, 0)),
            wv: scaled(dm * hd, stream_seed(s, 0, 12, 0)),
            wo: scaled(dm * dm, stream_seed(s, 0, 13, 0)),
            bo: scaled(dm, stream_seed(s, 0, 14, 0)),
            kernels: HashMap::new(),
            cache_dir,
            cfg,
            recorder: Recorder::disabled(),
        })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Attach an observability recorder: admit/prefill/decode/gather
    /// spans and pool-occupancy samples report through it.
    pub fn set_recorder(&mut self, rec: Recorder) {
        self.recorder = rec;
    }

    /// The recorder this engine reports through (disabled by default).
    pub fn recorder(&self) -> &Recorder {
        &self.recorder
    }

    /// Per-node cost-model predictions for the decode graph — the
    /// `model` column of `tilelang profile`'s continuous-serve section.
    /// The decode graph is re-prepared per padded KV length; this
    /// reports the largest one prepared so far (the worst-case step the
    /// run reached). Empty before any run.
    pub fn node_modeled_us(&self) -> Vec<(String, Option<f64>)> {
        self.kernels
            .iter()
            .max_by_key(|(padded, _)| **padded)
            .map(|(_, k)| k.node_modeled_us())
            .unwrap_or_default()
    }

    /// Per-node traffic of the decode graph — static shadows for
    /// compiled kernel nodes plus the fixed element-wise formula. Like
    /// [`Engine::node_modeled_us`], reports the largest padded KV
    /// length prepared so far. Empty before any run.
    pub fn node_traffic(&self) -> Vec<(String, Option<Traffic>)> {
        self.kernels
            .iter()
            .max_by_key(|(padded, _)| **padded)
            .map(|(_, k)| k.node_traffic())
            .unwrap_or_default()
    }

    /// Per-node DRAM bytes the analytical model predicts for one decode
    /// step of the largest prepared graph (calibration denominator).
    pub fn node_modeled_bytes(&self) -> Vec<(String, Option<f64>)> {
        self.kernels
            .iter()
            .max_by_key(|(padded, _)| **padded)
            .map(|(_, k)| k.node_modeled_bytes())
            .unwrap_or_default()
    }

    /// A stream's prompt K/V row (prefill) — seeded by stream id and
    /// row index only, so it is identical in any batch composition.
    fn prompt_row(&self, id: u64, row: usize) -> (Vec<f32>, Vec<f32>) {
        let hd = self.cfg.head_dim;
        let k = test_data(hd, stream_seed(self.cfg.seed, id, 0, row as u64));
        let v = test_data(hd, stream_seed(self.cfg.seed, id, 1, row as u64));
        (k, v)
    }

    /// A stream's first decode input.
    fn initial_x(&self, id: u64) -> Vec<f32> {
        test_data(self.cfg.d_model(), stream_seed(self.cfg.seed, id, 2, 0))
    }

    /// Prepared decode graph for one padded KV length. Always unfused
    /// and untuned: fusion and tuning choices may differ across padded
    /// lengths, which would break serial-vs-batched bit equality.
    fn kernel_for(
        kernels: &mut HashMap<i64, GraphKernel>,
        cfg: &EngineConfig,
        dir: &Path,
        padded_kv: i64,
    ) -> Result<&GraphKernel> {
        use std::collections::hash_map::Entry;
        match kernels.entry(padded_kv) {
            Entry::Occupied(e) => Ok(e.into_mut()),
            Entry::Vacant(e) => {
                let g = decode_block_paged(cfg.slots, cfg.heads, cfg.head_dim, padded_kv);
                let opts = InterpOptions {
                    tune: false,
                    compiled: cfg.compiled,
                    ..Default::default()
                };
                Ok(e.insert(GraphKernel::prepare_unfused(&g, &opts, dir)?))
            }
        }
    }

    /// Run the continuous-batching scheduler over `specs` to completion.
    pub fn run(&mut self, specs: &[StreamSpec]) -> Result<EngineReport> {
        let cfg = self.cfg.clone();
        let slots_n = cfg.slots as usize;
        let (dm, hd) = (cfg.d_model() as usize, cfg.head_dim as usize);
        let mut seen = std::collections::HashSet::new();
        for sp in specs {
            if !seen.insert(sp.id) {
                bail!("duplicate stream id {}", sp.id);
            }
            if sp.prefill_rows == 0 || sp.decode_steps == 0 {
                bail!(
                    "stream {}: prefill_rows and decode_steps must be >= 1 ({} / {})",
                    sp.id,
                    sp.prefill_rows,
                    sp.decode_steps
                );
            }
        }
        let mut pool = KvPool::new(cfg.pool_pages, cfg.page_rows, hd)?;
        for sp in specs {
            if pool.pages_for(sp.total_rows()) > cfg.pool_pages {
                bail!(
                    "stream {} needs {} pages over its lifetime but the pool has {}",
                    sp.id,
                    pool.pages_for(sp.total_rows()),
                    cfg.pool_pages
                );
            }
        }

        // arrival order: by step, ties in spec order
        let mut arrival_order: Vec<usize> = (0..specs.len()).collect();
        arrival_order.sort_by_key(|&i| specs[i].arrival_step);
        let mut next_arrival = 0usize;

        let mut slot_live: Vec<Option<StreamState>> = (0..slots_n).map(|_| None).collect();
        let mut pending: VecDeque<usize> = VecDeque::new(); // FIFO admission queue of spec indices
        let mut arrived_at: Vec<Option<Instant>> = vec![None; specs.len()];
        let mut outputs: BTreeMap<u64, Vec<Vec<f32>>> = BTreeMap::new();
        let (mut prefill_us, mut decode_us, mut queue_us) =
            (Vec::new(), Vec::new(), Vec::new());
        let (mut peak_pages, mut peak_concurrency, mut exec_steps, mut finished) = (0, 0, 0, 0);

        // runaway guard: each spec needs at most decode_steps executing
        // steps once admitted, plus its arrival delay and queueing slack
        let max_arrival = specs.iter().map(|s| s.arrival_step).max().unwrap_or(0);
        let step_cap =
            max_arrival + specs.iter().map(|s| s.decode_steps).sum::<usize>() + specs.len() + 16;

        let t0 = Instant::now();
        let mut step = 0usize;
        while finished < specs.len() {
            if step > step_cap {
                bail!(
                    "scheduler stalled: {} of {} streams finished after {} steps",
                    finished,
                    specs.len(),
                    step
                );
            }
            // arrivals at this step join the FIFO queue
            while next_arrival < arrival_order.len()
                && specs[arrival_order[next_arrival]].arrival_step <= step
            {
                let i = arrival_order[next_arrival];
                arrived_at[i] = Some(Instant::now());
                pending.push_back(i);
                next_arrival += 1;
            }
            // admit from the queue head while a slot is free and the
            // pool can reserve the stream's whole lifetime; head-of-line
            // blocking keeps admission deterministic
            while let Some(&i) = pending.front() {
                let sp = &specs[i];
                let live = slot_live.iter().filter(|s| s.is_some()).count();
                if live >= slots_n || !pool.can_admit(sp.total_rows()) {
                    break;
                }
                pending.pop_front();
                let admit_sp = self.recorder.span_with("serve", "admit", || {
                    vec![
                        ("stream".to_string(), sp.id.to_string()),
                        ("rows".to_string(), sp.total_rows().to_string()),
                    ]
                });
                pool.admit(sp.id, sp.total_rows())?;
                let prefill_sp = self.recorder.span_with("serve", "prefill", || {
                    vec![
                        ("stream".to_string(), sp.id.to_string()),
                        ("rows".to_string(), sp.prefill_rows.to_string()),
                    ]
                });
                for r in 0..sp.prefill_rows {
                    let (k, v) = self.prompt_row(sp.id, r);
                    pool.append_row(sp.id, &k, &v)?;
                }
                // prefill movement: one K row + one V row per prompt row
                // lands in the pool's backing store
                self.recorder
                    .add("traffic.dram_wr_bytes", (sp.prefill_rows * hd * 2 * 4) as u64);
                prefill_us.push(prefill_sp.finish_us());
                admit_sp.finish_us();
                let slot = slot_live
                    .iter()
                    .position(|s| s.is_none())
                    .expect("live < slots implies a free slot");
                slot_live[slot] = Some(StreamState {
                    spec_idx: i,
                    x: self.initial_x(sp.id),
                    remaining: sp.decode_steps,
                    arrived_at: arrived_at[i].expect("arrived before admission"),
                    first_decode_pending: true,
                });
                outputs.insert(sp.id, Vec::new());
            }
            peak_pages = peak_pages.max(pool.used_pages());
            self.recorder.sample("serve.pool_pages", pool.used_pages() as f64);

            let live: Vec<usize> =
                (0..slots_n).filter(|&s| slot_live[s].is_some()).collect();
            if live.is_empty() {
                // idle tick waiting on future arrivals
                step += 1;
                continue;
            }
            peak_concurrency = peak_concurrency.max(live.len());
            self.recorder.sample("serve.batch_size", live.len() as f64);

            // gather: pad to the longest live cache, 16-aligned
            let gather_sp = self.recorder.span_with("serve", "gather", || {
                vec![
                    ("step".to_string(), step.to_string()),
                    ("live".to_string(), live.len().to_string()),
                ]
            });
            let max_len = live
                .iter()
                .map(|&s| {
                    let st = slot_live[s].as_ref().expect("live slot");
                    pool.rows_of(specs[st.spec_idx].id)
                })
                .collect::<Result<Vec<_>>>()?
                .into_iter()
                .max()
                .expect("non-empty live set");
            let padded = round_up16(max_len);
            let pd = padded as usize;
            let mut x_buf = vec![0.0f32; slots_n * dm];
            let mut k_buf = vec![0.0f32; slots_n * pd * hd];
            let mut v_buf = vec![0.0f32; slots_n * pd * hd];
            let mut lens = vec![0.0f32; slots_n];
            for &s in &live {
                let st = slot_live[s].as_mut().expect("live slot");
                let id = specs[st.spec_idx].id;
                let rows = pool.gather_into(
                    id,
                    &mut k_buf[s * pd * hd..(s + 1) * pd * hd],
                    &mut v_buf[s * pd * hd..(s + 1) * pd * hd],
                )?;
                lens[s] = rows as f32;
                x_buf[s * dm..(s + 1) * dm].copy_from_slice(&st.x);
                if st.first_decode_pending {
                    st.first_decode_pending = false;
                    let waited = st.arrived_at.elapsed().as_micros();
                    queue_us.push(waited);
                    self.recorder.sample("serve.queue_us", waited as f64);
                }
            }
            gather_sp.finish_us();

            // execute the multi-output decode graph: [Y, K_new, V_new]
            let kern = Engine::kernel_for(&mut self.kernels, &cfg, &self.cache_dir, padded)?;
            let decode_sp = self.recorder.span_with("serve", "decode", || {
                vec![
                    ("step".to_string(), step.to_string()),
                    ("live".to_string(), live.len().to_string()),
                    ("padded_kv".to_string(), padded.to_string()),
                ]
            });
            let mut outs = kern.execute_all_refs_rec(
                &[
                    x_buf.as_slice(),
                    self.wq.as_slice(),
                    k_buf.as_slice(),
                    v_buf.as_slice(),
                    lens.as_slice(),
                    self.wk.as_slice(),
                    self.wv.as_slice(),
                    self.wo.as_slice(),
                    self.bo.as_slice(),
                ],
                &self.recorder,
            )?;
            decode_us.push(decode_sp.finish_us());
            exec_steps += 1;
            let v_new = outs.pop().expect("decode graph emits V_new");
            let k_new = outs.pop().expect("decode graph emits K_new");
            let y = outs.pop().expect("decode graph emits Y");

            // commit: emit the output row, append the new K/V row in
            // place, feed y back as the next input, retire finished
            for &s in &live {
                let st = slot_live[s].as_mut().expect("live slot");
                let id = specs[st.spec_idx].id;
                let y_row = &y[s * dm..(s + 1) * dm];
                outputs.get_mut(&id).expect("admitted").push(y_row.to_vec());
                pool.append_row(id, &k_new[s * hd..(s + 1) * hd], &v_new[s * hd..(s + 1) * hd])?;
                st.x = y_row.to_vec();
                st.remaining -= 1;
                if st.remaining == 0 {
                    pool.retire(id)?;
                    slot_live[s] = None;
                    finished += 1;
                }
            }
            peak_pages = peak_pages.max(pool.used_pages());
            self.recorder.sample("serve.pool_pages", pool.used_pages() as f64);
            pool.validate()?;
            step += 1;
        }
        if pool.live_count() != 0 || pool.used_pages() != 0 {
            bail!("engine finished with {} streams still in the pool", pool.live_count());
        }

        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        Ok(EngineReport {
            outputs,
            prefill: PhaseStats::from_samples(prefill_us),
            decode: PhaseStats::from_samples(decode_us),
            queue: PhaseStats::from_samples(queue_us),
            steps: exec_steps,
            streams: specs.len(),
            peak_concurrency,
            peak_pages,
            pool_pages: cfg.pool_pages,
            streams_per_s: specs.len() as f64 / wall_s,
        })
    }

    /// The serial-decode oracle: run every stream alone (arrival 0, one
    /// live stream, its own padding) through the same engine machinery.
    /// Continuous batching must reproduce these outputs bit for bit.
    pub fn serial_oracle(
        &mut self,
        specs: &[StreamSpec],
    ) -> Result<BTreeMap<u64, Vec<Vec<f32>>>> {
        // oracle reruns must not pollute the attached trace: swap in a
        // disabled recorder for the duration (timing is observability-
        // only, so this cannot change the decoded bits)
        let saved = std::mem::take(&mut self.recorder);
        let mut run_all = || -> Result<BTreeMap<u64, Vec<Vec<f32>>>> {
            let mut all = BTreeMap::new();
            for sp in specs {
                let solo = StreamSpec { arrival_step: 0, ..sp.clone() };
                let report = self.run(&[solo])?;
                let (id, outs) = report.outputs.into_iter().next().expect("one stream");
                all.insert(id, outs);
            }
            Ok(all)
        };
        let result = run_all();
        self.recorder = saved;
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_rejects_unaligned_shapes() {
        let bad = EngineConfig { slots: 8, ..Default::default() };
        assert!(Engine::new(bad).is_err());
        let bad = EngineConfig { head_dim: 24, ..Default::default() };
        assert!(Engine::new(bad).is_err());
        let bad = EngineConfig { pool_pages: 0, ..Default::default() };
        assert!(Engine::new(bad).is_err());
    }

    #[test]
    fn single_stream_decodes_and_recycles_the_pool() {
        let mut eng = Engine::new(EngineConfig::default()).unwrap();
        let specs = [StreamSpec { id: 3, arrival_step: 0, prefill_rows: 5, decode_steps: 4 }];
        let report = eng.run(&specs).unwrap();
        assert_eq!(report.outputs[&3].len(), 4);
        assert_eq!(report.steps, 4);
        assert_eq!(report.peak_concurrency, 1);
        assert!(report.peak_pages >= 1 && report.peak_pages <= report.pool_pages);
        assert_eq!(report.decode.samples, 4);
        for y in &report.outputs[&3] {
            assert_eq!(y.len(), 256);
            assert!(y.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn staggered_streams_match_the_serial_oracle_bit_for_bit() {
        let mut eng = Engine::new(EngineConfig::default()).unwrap();
        let specs = [
            StreamSpec { id: 1, arrival_step: 0, prefill_rows: 7, decode_steps: 5 },
            StreamSpec { id: 2, arrival_step: 1, prefill_rows: 3, decode_steps: 6 },
            StreamSpec { id: 3, arrival_step: 2, prefill_rows: 19, decode_steps: 3 },
        ];
        let batched = eng.run(&specs).unwrap();
        assert!(batched.peak_concurrency >= 2, "streams must actually co-batch");
        let serial = eng.serial_oracle(&specs).unwrap();
        for sp in &specs {
            let (b, s) = (&batched.outputs[&sp.id], &serial[&sp.id]);
            assert_eq!(b.len(), s.len());
            for (step, (br, sr)) in b.iter().zip(s).enumerate() {
                for (i, (x, y)) in br.iter().zip(sr).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "stream {} step {} idx {}: batched {} vs serial {}",
                        sp.id,
                        step,
                        i,
                        x,
                        y
                    );
                }
            }
        }
    }

    #[test]
    fn pool_pressure_defers_admission_instead_of_failing() {
        // pool holds 6 pages of 4 rows; each stream needs 3 pages, so
        // only two fit at once and the third must wait its turn
        let cfg = EngineConfig { pool_pages: 6, page_rows: 4, ..Default::default() };
        let mut eng = Engine::new(cfg).unwrap();
        let specs = [
            StreamSpec { id: 1, arrival_step: 0, prefill_rows: 6, decode_steps: 5 },
            StreamSpec { id: 2, arrival_step: 0, prefill_rows: 6, decode_steps: 5 },
            StreamSpec { id: 3, arrival_step: 0, prefill_rows: 6, decode_steps: 5 },
        ];
        let report = eng.run(&specs).unwrap();
        assert_eq!(report.outputs.len(), 3);
        assert!(report.peak_pages <= 6);
        assert_eq!(report.queue.samples, 3);
        // and the deferred stream still matches its solo run
        let serial = eng.serial_oracle(&specs[2..]).unwrap();
        assert_eq!(
            report.outputs[&3]
                .iter()
                .flatten()
                .map(|v| v.to_bits())
                .collect::<Vec<_>>(),
            serial[&3].iter().flatten().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn admission_reserves_lifetime_pages_not_just_free_ones() {
        // two streams each need 3 pages over their lifetime (1 prefill
        // row + 11 decode rows, 4 rows/page) but touch only 1 page at
        // admit time; a free-list-only gate would admit both into a
        // 4-page pool and strand one mid-decode once lazy growth
        // collides (3 + 3 pages > 4)
        let cfg = EngineConfig { pool_pages: 4, page_rows: 4, ..Default::default() };
        let mut eng = Engine::new(cfg).unwrap();
        let specs = [
            StreamSpec { id: 1, arrival_step: 0, prefill_rows: 1, decode_steps: 11 },
            StreamSpec { id: 2, arrival_step: 0, prefill_rows: 1, decode_steps: 11 },
        ];
        let report = eng.run(&specs).expect("must defer, not exhaust mid-decode");
        assert_eq!(report.peak_concurrency, 1, "second stream must wait for the first");
        assert!(report.peak_pages <= 4);
        assert_eq!(report.outputs[&1].len(), 11);
        assert_eq!(report.outputs[&2].len(), 11);
        // and the deferred stream is still bit-identical to its solo run
        let serial = eng.serial_oracle(&specs).unwrap();
        for id in [1u64, 2] {
            assert_eq!(
                report.outputs[&id]
                    .iter()
                    .flatten()
                    .map(|v| v.to_bits())
                    .collect::<Vec<_>>(),
                serial[&id].iter().flatten().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "stream {id}"
            );
        }
    }

    #[test]
    fn oversized_stream_is_rejected_up_front() {
        let cfg = EngineConfig { pool_pages: 2, page_rows: 4, ..Default::default() };
        let mut eng = Engine::new(cfg).unwrap();
        let specs = [StreamSpec { id: 1, arrival_step: 0, prefill_rows: 20, decode_steps: 4 }];
        let err = eng.run(&specs).unwrap_err().to_string();
        assert!(err.contains("over its lifetime"), "got: {}", err);
    }
}
