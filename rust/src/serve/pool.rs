//! Slab/paged KV-cache allocator for the continuous-batching engine.
//!
//! One shared pool holds every live stream's cache: two f32 slabs (K
//! and V) cut into fixed-size pages of `page_rows` cache rows each.
//! Per-stream [`PageTable`]s map a stream's logical row sequence onto
//! pool pages; appends write in place into the stream's last page (rows
//! are never moved once committed), and retiring a stream returns its
//! pages to the free list for recycling — vLLM-style paged attention,
//! scaled to the interp runtime.
//!
//! Pages are allocated lazily (a stream takes a fresh page only when an
//! append crosses a page boundary), so the instantaneous free list
//! over-states what is really available: live streams' unallocated
//! future pages still sit on it. Admission therefore works on
//! *reservations* — [`KvPool::admit`] sets aside capacity for the
//! stream's whole lifetime up front, and [`KvPool::can_admit`] compares
//! against reserved (not free) pages — so an admitted stream can never
//! strand mid-decode on pool exhaustion.
//!
//! The allocator is exactly the kind of code that is subtly wrong under
//! rare interleavings, so [`KvPool::validate`] checks the full
//! invariant set (no page aliased by two live streams, free + live ==
//! pool, page counts match committed rows) and the fuzz suite in
//! `rust/tests/kv_pool.rs` runs it after every randomized operation.

use std::collections::BTreeMap;

use crate::error::Result;
use crate::{anyhow, bail};

/// A stream's mapping from logical cache rows to pool pages. Row `r`
/// lives in `pages[r / page_rows]` at page-local row `r % page_rows`.
#[derive(Clone, Debug)]
pub struct PageTable {
    pages: Vec<usize>,
    rows: usize,
    /// Lifetime row budget fixed at admission; appends past it fail.
    reserved_rows: usize,
}

impl PageTable {
    /// Committed cache rows (the stream's current KV length).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Pool page indices backing this stream, logical order.
    pub fn pages(&self) -> &[usize] {
        &self.pages
    }

    /// The lifetime row budget this stream reserved at admission.
    pub fn reserved_rows(&self) -> usize {
        self.reserved_rows
    }
}

/// What a pool page belongs to while [`KvPool::validate`] sweeps the
/// ownership table — a dedicated enum rather than a sentinel stream id,
/// so a real stream can use any `u64` id without confusing diagnostics.
#[derive(Clone, Copy, Debug)]
enum PageOwner {
    Live(u64),
    Free,
}

/// The shared paged KV-cache pool.
pub struct KvPool {
    page_rows: usize,
    head_dim: usize,
    total_pages: usize,
    /// K slab: page `p` occupies `p * page_rows * head_dim ..`.
    k: Vec<f32>,
    v: Vec<f32>,
    /// Free page indices. Allocation pops from the back, retirement
    /// pushes to the back — LIFO recycling keeps the working set hot.
    free: Vec<usize>,
    /// Pages promised to live streams' lifetimes (sum over streams of
    /// `pages_for(reserved_rows)`), whether or not allocated yet.
    reserved_pages: usize,
    /// Live streams by id (BTreeMap: deterministic iteration).
    streams: BTreeMap<u64, PageTable>,
}

impl KvPool {
    pub fn new(total_pages: usize, page_rows: usize, head_dim: usize) -> Result<KvPool> {
        if total_pages == 0 || page_rows == 0 || head_dim == 0 {
            bail!(
                "kv pool needs positive dimensions (pages {}, rows/page {}, head_dim {})",
                total_pages,
                page_rows,
                head_dim
            );
        }
        let elems = total_pages * page_rows * head_dim;
        Ok(KvPool {
            page_rows,
            head_dim,
            total_pages,
            k: vec![0.0; elems],
            v: vec![0.0; elems],
            free: (0..total_pages).rev().collect(),
            reserved_pages: 0,
            streams: BTreeMap::new(),
        })
    }

    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages promised to live streams' lifetime reservations (allocated
    /// or not yet).
    pub fn reserved_pages(&self) -> usize {
        self.reserved_pages
    }

    pub fn used_pages(&self) -> usize {
        self.streams.values().map(|t| t.pages.len()).sum()
    }

    /// Pages needed to hold `rows` cache rows.
    pub fn pages_for(&self, rows: usize) -> usize {
        rows.div_ceil(self.page_rows)
    }

    /// Can a stream that will eventually commit `rows` rows be admitted
    /// right now without ever hitting pool exhaustion? The engine's
    /// admission policy: hold arrivals in the queue until this is true.
    ///
    /// Compares against *reserved* pages, not the free list: pages are
    /// allocated lazily on append, so live streams' unallocated future
    /// pages still sit on the free list — counting them as available
    /// would double-promise capacity and strand someone mid-decode.
    pub fn can_admit(&self, rows: usize) -> bool {
        self.reserved_pages + self.pages_for(rows) <= self.total_pages
    }

    pub fn is_live(&self, id: u64) -> bool {
        self.streams.contains_key(&id)
    }

    pub fn live_count(&self) -> usize {
        self.streams.len()
    }

    /// Committed rows of a live stream.
    pub fn rows_of(&self, id: u64) -> Result<usize> {
        Ok(self.table(id)?.rows)
    }

    pub fn table(&self, id: u64) -> Result<&PageTable> {
        self.streams
            .get(&id)
            .ok_or_else(|| anyhow!("stream {} is not live in the kv pool", id))
    }

    /// Register a new stream with an empty cache, reserving pool
    /// capacity for its whole lifetime of `reserved_rows` committed
    /// rows. The reservation is what makes [`KvPool::can_admit`] a real
    /// guarantee: pages are still allocated lazily on append, but every
    /// live stream's future growth is set aside up front, so appends
    /// within the reservation can never hit pool exhaustion.
    pub fn admit(&mut self, id: u64, reserved_rows: usize) -> Result<()> {
        if reserved_rows == 0 {
            bail!("stream {}: reservation must cover at least one row", id);
        }
        if self.streams.contains_key(&id) {
            bail!("stream {} is already live", id);
        }
        if !self.can_admit(reserved_rows) {
            bail!(
                "cannot admit stream {}: its lifetime needs {} pages but only {} of {} are \
                 unreserved",
                id,
                self.pages_for(reserved_rows),
                self.total_pages - self.reserved_pages,
                self.total_pages
            );
        }
        self.reserved_pages += self.pages_for(reserved_rows);
        self.streams.insert(id, PageTable { pages: Vec::new(), rows: 0, reserved_rows });
        Ok(())
    }

    /// Append one K/V cache row for `id`, in place: a fresh page is
    /// taken from the free list only on a page boundary, and committed
    /// rows are never moved or copied.
    pub fn append_row(&mut self, id: u64, k_row: &[f32], v_row: &[f32]) -> Result<()> {
        if k_row.len() != self.head_dim || v_row.len() != self.head_dim {
            bail!(
                "stream {}: appended row has {}/{} values, head_dim is {}",
                id,
                k_row.len(),
                v_row.len(),
                self.head_dim
            );
        }
        let (page_rows, head_dim) = (self.page_rows, self.head_dim);
        let needs_page = {
            let t = self.table(id)?;
            if t.rows == t.reserved_rows {
                bail!(
                    "stream {}: append would exceed its lifetime reservation of {} rows",
                    id,
                    t.reserved_rows
                );
            }
            t.rows == t.pages.len() * page_rows
        };
        if needs_page {
            // within the reservation this cannot fail: reserved_pages
            // <= total_pages and every stream's allocation stays under
            // its own reservation, so a free page always exists
            let page = self.free.pop().ok_or_else(|| {
                anyhow!("kv pool exhausted appending to stream {} (reservation accounting broken)", id)
            })?;
            self.streams.get_mut(&id).expect("checked live").pages.push(page);
        }
        let t = self.streams.get_mut(&id).expect("checked live");
        let page = t.pages[t.rows / page_rows];
        let off = (page * page_rows + t.rows % page_rows) * head_dim;
        self.k[off..off + head_dim].copy_from_slice(k_row);
        self.v[off..off + head_dim].copy_from_slice(v_row);
        t.rows += 1;
        Ok(())
    }

    /// Retire a stream: its pages go back to the free list and its
    /// lifetime reservation is released.
    pub fn retire(&mut self, id: u64) -> Result<()> {
        let t = self
            .streams
            .remove(&id)
            .ok_or_else(|| anyhow!("cannot retire stream {}: not live", id))?;
        self.reserved_pages -= self.pages_for(t.reserved_rows);
        self.free.extend(t.pages);
        Ok(())
    }

    /// Copy a stream's committed rows, page by page, into the head of
    /// contiguous K/V buffers (the per-step gather that lets streams at
    /// different lengths co-batch). The tail beyond `rows * head_dim`
    /// is zero-filled; returns the committed row count.
    pub fn gather_into(&self, id: u64, k_out: &mut [f32], v_out: &mut [f32]) -> Result<usize> {
        let t = self.table(id)?;
        let need = t.rows * self.head_dim;
        if k_out.len() < need || v_out.len() < need || k_out.len() != v_out.len() {
            bail!(
                "stream {}: gather buffers hold {}/{} values, cache needs {}",
                id,
                k_out.len(),
                v_out.len(),
                need
            );
        }
        let mut written = 0usize;
        for (pi, &page) in t.pages.iter().enumerate() {
            let rows_here = (t.rows - pi * self.page_rows).min(self.page_rows);
            let src = page * self.page_rows * self.head_dim;
            let n = rows_here * self.head_dim;
            k_out[written..written + n].copy_from_slice(&self.k[src..src + n]);
            v_out[written..written + n].copy_from_slice(&self.v[src..src + n]);
            written += n;
        }
        k_out[written..].fill(0.0);
        v_out[written..].fill(0.0);
        Ok(t.rows)
    }

    /// Allocating gather padded to `padded_rows` (test convenience).
    pub fn gather(&self, id: u64, padded_rows: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut k = vec![0.0; padded_rows * self.head_dim];
        let mut v = vec![0.0; padded_rows * self.head_dim];
        self.gather_into(id, &mut k, &mut v)?;
        Ok((k, v))
    }

    /// Check every pool invariant; the fuzz suite calls this after each
    /// randomized operation and the engine after each decode step.
    ///
    /// 1. every page index (live or free) is in range;
    /// 2. no page is owned by two live streams, both owned and free, or
    ///    listed free twice;
    /// 3. free + live accounts for exactly the whole pool;
    /// 4. each stream holds exactly `ceil(rows / page_rows)` pages and
    ///    stays within its lifetime reservation;
    /// 5. the reserved-page tally matches the live streams' lifetime
    ///    reservations and fits the pool.
    pub fn validate(&self) -> Result<()> {
        let mut owner: Vec<Option<PageOwner>> = vec![None; self.total_pages];
        for (&id, t) in &self.streams {
            if t.pages.len() != self.pages_for(t.rows) {
                bail!(
                    "stream {}: {} pages for {} rows ({} rows/page)",
                    id,
                    t.pages.len(),
                    t.rows,
                    self.page_rows
                );
            }
            if t.rows > t.reserved_rows {
                bail!(
                    "stream {}: {} committed rows exceed its reservation of {}",
                    id,
                    t.rows,
                    t.reserved_rows
                );
            }
            for &p in &t.pages {
                if p >= self.total_pages {
                    bail!("stream {}: page {} out of range ({})", id, p, self.total_pages);
                }
                match owner[p] {
                    Some(PageOwner::Live(other)) => {
                        bail!("page {} aliased by live streams {} and {}", p, other, id)
                    }
                    Some(PageOwner::Free) => {
                        unreachable!("free list is swept after live streams")
                    }
                    None => owner[p] = Some(PageOwner::Live(id)),
                }
            }
        }
        for &p in &self.free {
            if p >= self.total_pages {
                bail!("free list holds out-of-range page {}", p);
            }
            match owner[p] {
                Some(PageOwner::Live(id)) => {
                    bail!("page {} is both free and owned by stream {}", p, id)
                }
                Some(PageOwner::Free) => bail!("page {} listed twice in the free list", p),
                None => owner[p] = Some(PageOwner::Free),
            }
        }
        let accounted = owner.iter().filter(|o| o.is_some()).count();
        if accounted != self.total_pages {
            bail!(
                "page conservation violated: {} of {} pages accounted for (free {} + live {})",
                accounted,
                self.total_pages,
                self.free.len(),
                self.used_pages()
            );
        }
        let promised: usize =
            self.streams.values().map(|t| self.pages_for(t.reserved_rows)).sum();
        if promised != self.reserved_pages {
            bail!(
                "reservation accounting drifted: tracked {} pages, live streams reserve {}",
                self.reserved_pages,
                promised
            );
        }
        if self.reserved_pages > self.total_pages {
            bail!(
                "over-reserved: {} pages promised but the pool has {}",
                self.reserved_pages,
                self.total_pages
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_append_gather_round_trip() {
        let mut pool = KvPool::new(4, 2, 4).unwrap();
        pool.admit(7, 4).unwrap();
        let row = |x: f32| vec![x; 4];
        for i in 0..3 {
            pool.append_row(7, &row(i as f32 + 1.0), &row(-(i as f32) - 1.0)).unwrap();
            pool.validate().unwrap();
        }
        assert_eq!(pool.rows_of(7).unwrap(), 3);
        assert_eq!(pool.used_pages(), 2);
        let (k, v) = pool.gather(7, 4).unwrap();
        assert_eq!(&k[..4], &[1.0; 4]);
        assert_eq!(&k[8..12], &[3.0; 4]);
        assert_eq!(&k[12..], &[0.0; 4][..]); // zero tail padding
        assert_eq!(&v[..4], &[-1.0; 4]);
        pool.retire(7).unwrap();
        pool.validate().unwrap();
        assert_eq!(pool.free_pages(), 4);
    }

    #[test]
    fn reservation_and_admission_guards() {
        let mut pool = KvPool::new(2, 2, 4).unwrap();
        pool.admit(1, 4).unwrap();
        assert!(pool.admit(1, 1).is_err(), "double admit");
        assert!(pool.admit(2, 0).is_err(), "empty reservation");
        assert!(!pool.can_admit(1), "whole pool reserved before any page is allocated");
        assert!(pool.admit(2, 1).is_err(), "no unreserved capacity");
        for _ in 0..4 {
            pool.append_row(1, &[0.0; 4], &[0.0; 4]).unwrap();
        }
        assert!(
            pool.append_row(1, &[0.0; 4], &[0.0; 4])
                .unwrap_err()
                .to_string()
                .contains("reservation"),
            "append past the lifetime budget"
        );
        pool.validate().unwrap();
        assert!(pool.retire(2).is_err(), "retire unknown stream");
        pool.retire(1).unwrap();
        assert_eq!(pool.reserved_pages(), 0, "retire releases the reservation");
        assert!(pool.can_admit(4));
        assert!(!pool.can_admit(5));
    }

    #[test]
    fn reservations_cover_lazy_growth_not_just_allocated_pages() {
        // the mid-decode-exhaustion scenario a free-list-only gate gets
        // wrong: 4 pages of 4 rows, two streams each needing 12 rows
        // (3 pages) over their lifetime but holding only 1 page early
        let mut pool = KvPool::new(4, 4, 4).unwrap();
        pool.admit(1, 12).unwrap();
        pool.append_row(1, &[0.0; 4], &[0.0; 4]).unwrap();
        assert_eq!(pool.free_pages(), 3, "free list alone would still admit the second");
        assert!(!pool.can_admit(12), "reservation gate must refuse it");
        assert!(pool.admit(2, 12).unwrap_err().to_string().contains("unreserved"));
        // the admitted stream grows to its full lifetime without ever
        // hitting exhaustion
        for _ in 1..12 {
            pool.append_row(1, &[0.0; 4], &[0.0; 4]).unwrap();
        }
        pool.validate().unwrap();
        pool.retire(1).unwrap();
        assert!(pool.can_admit(12), "retirement frees the reservation");
    }

    #[test]
    fn validate_catches_aliasing_and_leaks() {
        let mut pool = KvPool::new(4, 2, 4).unwrap();
        pool.admit(1, 4).unwrap();
        pool.admit(2, 2).unwrap();
        pool.append_row(1, &[0.0; 4], &[0.0; 4]).unwrap();
        pool.append_row(2, &[0.0; 4], &[0.0; 4]).unwrap();
        pool.validate().unwrap();
        // alias stream 2's page into stream 1's table
        let stolen = pool.streams[&2].pages[0];
        pool.streams.get_mut(&1).unwrap().pages.push(stolen);
        pool.streams.get_mut(&1).unwrap().rows += 2;
        assert!(pool.validate().unwrap_err().to_string().contains("aliased"));
        // leak a page: drop it from the free list
        let mut pool = KvPool::new(4, 2, 4).unwrap();
        pool.free.pop();
        assert!(pool.validate().unwrap_err().to_string().contains("conservation"));
    }
}
