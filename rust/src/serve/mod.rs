//! Continuous-batching serving: a shared paged KV-cache pool
//! ([`pool::KvPool`]) plus a decode engine ([`engine::Engine`]) that
//! co-batches streams at different sequence lengths through the
//! multi-output `decode_block_paged` graph. The engine's outputs are
//! bit-identical to running each stream alone (the engine's
//! `serial_oracle`) — the property the `serve_soak` integration test
//! enforces on both the interp and compiled backends.

pub mod engine;
pub mod pool;

pub use engine::{Engine, EngineConfig, EngineReport, PhaseStats, StreamSpec};
pub use pool::KvPool;
