//! Dequantize-GEMM tile programs (Fig. 17 / Fig. 15): weight-only
//! quantized matmul `Ct[N,M] = dequant(B)[N,K] @ A[M,K]^T` with packed
//! sub-byte weights (INT4 / INT2 / NF4 / FP4-E2M1) and per-group scales.
//!
//! The packed weight tensor stores *bytes*: `B[N, K/elems_per_byte]`
//! (`storage_dtype = uint8`, exactly the paper's Fig. 17 convention);
//! codes travel global -> shared -> registers and are decoded in
//! registers right before the tensor-core GEMM — the pattern Triton
//! cannot express efficiently (§5.2).

use crate::autotuner::{Tunable, TunableConfig};
use crate::ir::builder::KernelBuilder;
use crate::ir::dtype::{fp4_e2m1_decode, fp4_e2m1_encode, nf4_encode, DType, NF4_TABLE};
use crate::ir::expr::Expr;
use crate::ir::program::{DequantScheme, GemmWarpPolicy, TileProgram};
use crate::util::json::Json;

/// Weight format of the dequant GEMM family (Fig. 15's x-axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WeightFormat {
    /// `W_INT4 A_FP16` (Marlin's format).
    Int4,
    /// `W_INT2 A_INT8` (the BitBLAS headline config).
    Int2,
    /// `W_NF4 A_FP16` (BitsandBytes).
    Nf4,
    /// `W_FP4_E2M1 A_FP16` (Fig. 17).
    Fp4,
}

impl WeightFormat {
    pub fn bits(self) -> u32 {
        match self {
            WeightFormat::Int4 | WeightFormat::Nf4 | WeightFormat::Fp4 => 4,
            WeightFormat::Int2 => 2,
        }
    }
    pub fn elems_per_byte(self) -> i64 {
        (8 / self.bits()) as i64
    }
    pub fn scheme(self) -> DequantScheme {
        match self {
            WeightFormat::Int4 => DequantScheme::UintAffine { zero: 8 },
            WeightFormat::Int2 => DequantScheme::UintAffine { zero: 2 },
            WeightFormat::Nf4 => DequantScheme::Nf4Lut,
            WeightFormat::Fp4 => DequantScheme::Fp4E2m1,
        }
    }
    /// Activation dtype (paper: fp16 except the W2A8 config).
    pub fn act_dtype(self) -> DType {
        match self {
            WeightFormat::Int2 => DType::I8,
            _ => DType::F16,
        }
    }
}

/// Tile configuration for dequant GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DequantConfig {
    pub block_m: i64,
    pub block_n: i64,
    pub block_k: i64,
    pub num_stages: usize,
    pub threads: i64,
    pub group_size: i64,
}

impl Default for DequantConfig {
    fn default() -> Self {
        DequantConfig {
            block_m: 16,
            block_n: 64,
            block_k: 64,
            num_stages: 2,
            threads: 128,
            group_size: 32,
        }
    }
}

/// Build the Fig. 17 kernel: `Ct[N, M] = dequant(B) @ A^T`.
pub fn dequant_matmul_program(
    m: i64,
    n: i64,
    k: i64,
    fmt: WeightFormat,
    cfg: &DequantConfig,
) -> TileProgram {
    dequant_matmul_program_ep(m, n, k, fmt, cfg, &[])
}

/// [`dequant_matmul_program`] with a fused epilogue on the transposed
/// `Ct[n, m]` output: bias-add broadcasts along output dim 0 (the weight
/// rows / output features), residual-add takes a full `[n, m]` operand.
/// Epilogue params follow `Scales` and precede `Ct`.
pub fn dequant_matmul_program_ep(
    m: i64,
    n: i64,
    k: i64,
    fmt: WeightFormat,
    cfg: &DequantConfig,
    eps: &[crate::workloads::epilogue::EpilogueOp],
) -> TileProgram {
    let (bm, bn, bk) = (cfg.block_m, cfg.block_n, cfg.block_k);
    assert!(m % bm == 0 && n % bn == 0 && k % bk == 0);
    let epb = fmt.elems_per_byte();
    let group = cfg.group_size;
    assert!(bk % epb == 0 && bk % group == 0);
    let act = fmt.act_dtype();

    let name = if eps.is_empty() {
        "dequant_matmul"
    } else {
        "dequant_matmul_ep"
    };
    let mut t = KernelBuilder::new(name, cfg.threads);
    let a = t.param("A", &[m, k], act);
    let b = t.param("B", &[n, k / epb], DType::U8);
    let scales = t.param("Scales", &[n, k / group], DType::F16);
    let ep_params =
        crate::workloads::epilogue::declare_epilogue_params(&mut t, eps, [n, m]);
    let ct = t.param("Ct", &[n, m], DType::F32);
    let (bx, by) = t.kernel2(n / bn, m / bm);

    // weights + scales are repacked tile-major offline (Ladder), so
    // tile reads stream at full bandwidth — the optimization Triton
    // cannot express (§5.2)
    t.annotate_layout(b, crate::layout::Layout::row_major(&[n, k / epb]));
    t.annotate_layout(scales, crate::layout::Layout::row_major(&[n, k / group]));

    let a_s = t.alloc_shared("A_shared", &[bm, bk], act);
    let b_s = t.alloc_shared("B_shared", &[bn, bk / epb], DType::U8);
    let b_local = t.alloc_fragment("B_local", &[bn, bk / epb], DType::U8);
    let b_dq = t.alloc_fragment("B_dequantize_local", &[bn, bk], act);
    let s_local = t.alloc_fragment("Scale_local", &[bn, bk / group], DType::F16);
    let ct_l = t.alloc_fragment("Ct_local", &[bn, bm], DType::F32);

    if act.is_float() {
        // fp16 activations: decode+scale in registers, single accumulator
        t.clear(ct_l);
        t.pipelined(k / bk, cfg.num_stages, |t, ko| {
            t.copy_in(a, vec![by.expr() * bm, ko.expr() * bk], a_s);
            t.copy_in(b, vec![bx.expr() * bn, ko.expr() * (bk / epb)], b_s);
            t.copy(b_s, b_local);
            t.copy_in(
                scales,
                vec![bx.expr() * bn, ko.expr() * (bk / group)],
                s_local,
            );
            t.dequant(b_local, b_dq, fmt.scheme(), Some(s_local), group);
            t.gemm_opts(b_dq, a_s, ct_l, false, true, GemmWarpPolicy::FullCol);
        });
    } else {
        // integer activations (W2A8): weights must STAY integer codes
        // through the IMMA gemm; the per-group scale is applied on the
        // int32 partial accumulator (requires group == block_k so one
        // scale covers each k-slice)
        assert_eq!(group, bk, "W-int/A-int path needs group_size == block_k");
        let partial = t.alloc_fragment("Partial", &[bn, bm], DType::F32);
        t.clear(ct_l);
        t.pipelined(k / bk, cfg.num_stages, |t, ko| {
            t.copy_in(a, vec![by.expr() * bm, ko.expr() * bk], a_s);
            t.copy_in(b, vec![bx.expr() * bn, ko.expr() * (bk / epb)], b_s);
            t.copy(b_s, b_local);
            t.copy_in(
                scales,
                vec![bx.expr() * bn, ko.expr() * (bk / group)],
                s_local,
            );
            t.dequant(b_local, b_dq, fmt.scheme(), None, group);
            t.clear(partial);
            t.gemm_opts(b_dq, a_s, partial, false, true, GemmWarpPolicy::FullCol);
            t.parallel(&[bn, bm], |v| {
                let (i, j) = (&v[0], &v[1]);
                vec![crate::ir::builder::store(
                    ct_l,
                    vec![i.expr(), j.expr()],
                    Expr::load(ct_l, vec![i.expr(), j.expr()])
                        + Expr::load(partial, vec![i.expr(), j.expr()])
                            * Expr::load(s_local, vec![i.expr(), Expr::int(0)]),
                )]
            });
        });
    }
    crate::workloads::epilogue::emit_epilogues(
        &mut t,
        eps,
        &ep_params,
        ct_l,
        [bn, bm],
        &[bx.expr() * bn, by.expr() * bm],
    );
    t.copy_out(ct_l, ct, vec![bx.expr() * bn, by.expr() * bm]);
    t.finish()
}

impl TunableConfig for DequantConfig {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("block_m".into(), Json::Num(self.block_m as f64)),
            ("block_n".into(), Json::Num(self.block_n as f64)),
            ("block_k".into(), Json::Num(self.block_k as f64)),
            ("num_stages".into(), Json::Num(self.num_stages as f64)),
            ("threads".into(), Json::Num(self.threads as f64)),
            ("group_size".into(), Json::Num(self.group_size as f64)),
        ])
    }

    fn from_json(v: &Json) -> Option<DequantConfig> {
        Some(DequantConfig {
            block_m: v.get("block_m")?.as_i64()?,
            block_n: v.get("block_n")?.as_i64()?,
            block_k: v.get("block_k")?.as_i64()?,
            num_stages: v.get("num_stages")?.as_i64()?.max(1) as usize,
            threads: v.get("threads")?.as_i64()?,
            group_size: v.get("group_size")?.as_i64()?,
        })
    }
}

/// Dequant-GEMM tuning problem: `Ct[n,m] = dequant(B)[n,k] @ A[m,k]^T`.
/// Decode shapes (m = 1) are padded to the 16-row instruction tile.
#[derive(Clone, Copy, Debug)]
pub struct DequantTunable {
    pub m: i64,
    pub n: i64,
    pub k: i64,
    pub fmt: WeightFormat,
    padded_m: i64,
}

impl DequantTunable {
    pub fn new(m: i64, n: i64, k: i64, fmt: WeightFormat) -> DequantTunable {
        DequantTunable {
            m,
            n,
            k,
            fmt,
            padded_m: m.max(16),
        }
    }
}

impl Tunable for DequantTunable {
    type Config = DequantConfig;

    fn workload(&self) -> &'static str {
        "dequant_gemm"
    }

    fn shape_key(&self) -> Vec<i64> {
        vec![self.m, self.n, self.k]
    }

    fn dtype_key(&self) -> String {
        match self.fmt {
            WeightFormat::Int4 => "w4a16",
            WeightFormat::Int2 => "w2a8",
            WeightFormat::Nf4 => "nf4a16",
            WeightFormat::Fp4 => "fp4a16",
        }
        .to_string()
    }

    fn accepts(&self, cfg: &DequantConfig) -> bool {
        let epb = self.fmt.elems_per_byte();
        cfg.block_m > 0
            && cfg.block_n > 0
            && cfg.block_k > 0
            && cfg.group_size > 0
            && cfg.threads > 0
            && cfg.threads % 32 == 0
            && self.padded_m % cfg.block_m == 0
            && self.n % cfg.block_n == 0
            && self.k % cfg.block_k == 0
            && cfg.block_k % epb == 0
            && cfg.block_k % cfg.group_size == 0
            // the W-int/A-int path applies one scale per k-slice, which
            // requires group_size == block_k (see dequant_matmul_program)
            && (self.fmt.act_dtype().is_float() || cfg.group_size == cfg.block_k)
    }

    fn candidates(&self) -> Vec<DequantConfig> {
        let mut out = Vec::new();
        for bm in [16i64, 32, 64] {
            for bn in [32i64, 64, 128] {
                for bk in [32i64, 64, 128] {
                    for stages in [2usize, 3] {
                        // fp16 activations: fixed fine-grained groups;
                        // int8 activations: group must span block_k
                        let group = if self.fmt.act_dtype().is_float() {
                            32
                        } else {
                            bk
                        };
                        let cfg = DequantConfig {
                            block_m: bm,
                            block_n: bn,
                            block_k: bk,
                            num_stages: stages,
                            threads: 128,
                            group_size: group,
                        };
                        if self.accepts(&cfg) {
                            out.push(cfg);
                        }
                    }
                }
            }
        }
        out
    }

    fn build(&self, cfg: &DequantConfig) -> TileProgram {
        dequant_matmul_program(self.padded_m, self.n, self.k, self.fmt, cfg)
    }
}

// ---- host-side quantization helpers (shared with tests/benches) ------

/// Quantize a row-major f32 weight matrix `[n, k]` into packed bytes +
/// per-group scales for `fmt`. Returns (packed[n, k/epb] as byte values,
/// scales[n, k/groups]).
pub fn quantize_weights(
    w: &[f32],
    n: i64,
    k: i64,
    fmt: WeightFormat,
    group: i64,
) -> (Vec<f32>, Vec<f32>) {
    let epb = fmt.elems_per_byte();
    let bits = fmt.bits();
    let groups = k / group;
    let mut packed = vec![0f32; (n * k / epb) as usize];
    let mut scales = vec![0f32; (n * groups) as usize];
    for i in 0..n {
        for g in 0..groups {
            // per-group absmax scaling
            let mut mx = 1e-8f32;
            for t in 0..group {
                mx = mx.max(w[(i * k + g * group + t) as usize].abs());
            }
            let scale = match fmt {
                WeightFormat::Int4 => mx / 7.0,
                WeightFormat::Int2 => mx / 1.0,
                WeightFormat::Nf4 => mx,
                WeightFormat::Fp4 => mx / 6.0,
            };
            scales[(i * groups + g) as usize] = scale;
            for t in 0..group {
                let j = g * group + t;
                let x = w[(i * k + j) as usize] / scale;
                let code: u8 = match fmt {
                    WeightFormat::Int4 => (x.round().clamp(-7.0, 7.0) + 8.0) as u8,
                    WeightFormat::Int2 => (x.round().clamp(-1.0, 1.0) + 2.0) as u8,
                    WeightFormat::Nf4 => nf4_encode(x.clamp(-1.0, 1.0)),
                    WeightFormat::Fp4 => fp4_e2m1_encode(x.clamp(-6.0, 6.0)),
                };
                let byte_idx = (i * k / epb + j / epb) as usize;
                let shift = ((j % epb) as u32) * bits;
                let cur = packed[byte_idx] as u32;
                packed[byte_idx] = (cur | ((code as u32) << shift)) as f32;
            }
        }
    }
    (packed, scales)
}

/// Decode packed weights back to f32 (reference for the Dequant op).
pub fn dequantize_weights(
    packed: &[f32],
    scales: &[f32],
    n: i64,
    k: i64,
    fmt: WeightFormat,
    group: i64,
) -> Vec<f32> {
    let epb = fmt.elems_per_byte();
    let bits = fmt.bits();
    let mask = (1u32 << bits) - 1;
    let groups = k / group;
    let mut out = vec![0f32; (n * k) as usize];
    for i in 0..n {
        for j in 0..k {
            let byte = packed[(i * k / epb + j / epb) as usize] as u32;
            let code = (byte >> (((j % epb) as u32) * bits)) & mask;
            let base = match fmt {
                WeightFormat::Int4 => code as f32 - 8.0,
                WeightFormat::Int2 => code as f32 - 2.0,
                WeightFormat::Nf4 => NF4_TABLE[code as usize],
                WeightFormat::Fp4 => fp4_e2m1_decode(code as u8),
            };
            out[(i * k + j) as usize] = base * scales[(i * groups + j / group) as usize];
        }
    }
    out
}

/// Reference dequant-GEMM in f32: `Ct[n, m] = dequant(packed) @ A^T`.
/// The oracle for artifact goldens and graph differential tests.
#[allow(clippy::too_many_arguments)]
pub fn reference_dequant_matmul(
    a: &[f32],
    packed: &[f32],
    scales: &[f32],
    m: i64,
    n: i64,
    k: i64,
    fmt: WeightFormat,
    group: i64,
) -> Vec<f32> {
    let wdq = dequantize_weights(packed, scales, n, k, fmt, group);
    let (mu, nu, ku) = (m as usize, n as usize, k as usize);
    let mut out = vec![0f32; nu * mu];
    for i in 0..nu {
        for j in 0..mu {
            let mut acc = 0f32;
            for kk in 0..ku {
                acc += wdq[i * ku + kk] * a[j * ku + kk];
            }
            out[i * mu + j] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::lower::{compile, CompileOptions};
    use crate::sim::device::Device;
    use crate::tir::interp::{Interp, Tensors};
    use crate::workloads::matmul::test_data;

    fn run_fmt(fmt: WeightFormat, tol: f32) {
        let (m, n, k) = (32i64, 64i64, 64i64);
        let cfg = DequantConfig {
            block_m: 32,
            block_n: 32,
            block_k: 32,
            num_stages: 2,
            threads: 128,
            group_size: 32,
        };
        let p = dequant_matmul_program(m, n, k, fmt, &cfg);
        let l = compile(&p, &Device::a100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();

        let mut aval = test_data(m * k, 31);
        if fmt == WeightFormat::Int2 {
            // int8 activations: integer values in [-4, 4)
            for x in aval.iter_mut() {
                *x = (*x * 8.0).round().clamp(-4.0, 3.0);
            }
        }
        let w = test_data(n * k, 32);
        let (packed, scales) = quantize_weights(&w, n, k, fmt, cfg.group_size);

        let mut t = Tensors::new();
        t.insert(p.params[0].id, aval.clone());
        t.insert(p.params[1].id, packed.clone());
        t.insert(p.params[2].id, scales.clone());
        interp.run(&mut t).unwrap();

        // reference: dequantize then GEMM against A^T
        let wdq = dequantize_weights(&packed, &scales, n, k, fmt, cfg.group_size);
        let got = &t[&p.params[3].id];
        let mut max_err = 0f32;
        for i in 0..n as usize {
            for j in 0..m as usize {
                let mut acc = 0f32;
                for kk in 0..k as usize {
                    acc += wdq[i * k as usize + kk] * aval[j * k as usize + kk];
                }
                let g = got[i * m as usize + j];
                max_err = max_err.max((g - acc).abs());
            }
        }
        assert!(max_err < tol, "{:?}: max err {}", fmt, max_err);
    }

    #[test]
    fn int4_dequant_gemm_matches_reference() {
        run_fmt(WeightFormat::Int4, 0.05);
    }

    #[test]
    fn int2_w2a8_matches_reference() {
        run_fmt(WeightFormat::Int2, 0.5);
    }

    #[test]
    fn nf4_dequant_gemm_matches_reference() {
        run_fmt(WeightFormat::Nf4, 0.05);
    }

    #[test]
    fn fp4_dequant_gemm_matches_reference() {
        run_fmt(WeightFormat::Fp4, 0.05);
    }

    #[test]
    fn dequant_epilogues_match_reference() {
        use crate::workloads::epilogue::{reference_apply, Activation, EpilogueOp};
        let (m, n, k) = (32i64, 64i64, 64i64);
        let cfg = DequantConfig {
            block_m: 32,
            block_n: 32,
            block_k: 32,
            num_stages: 2,
            threads: 128,
            group_size: 32,
        };
        // bias broadcasts along the transposed output's dim 0 (features)
        let eps = [
            EpilogueOp::BiasAdd { dim: 0 },
            EpilogueOp::Activation(Activation::Relu),
        ];
        let p = dequant_matmul_program_ep(m, n, k, WeightFormat::Int4, &cfg, &eps);
        // A, B, Scales, bias, Ct
        assert_eq!(p.params.len(), 5);
        let l = compile(&p, &Device::a100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let aval = test_data(m * k, 41);
        let w = test_data(n * k, 42);
        let bias = test_data(n, 43);
        let (packed, scales) = quantize_weights(&w, n, k, WeightFormat::Int4, 32);
        let mut t = Tensors::new();
        t.insert(p.params[0].id, aval.clone());
        t.insert(p.params[1].id, packed.clone());
        t.insert(p.params[2].id, scales.clone());
        t.insert(p.params[3].id, bias.clone());
        interp.run(&mut t).unwrap();
        let mut want =
            reference_dequant_matmul(&aval, &packed, &scales, m, n, k, WeightFormat::Int4, 32);
        reference_apply(&eps[0], &mut want, Some(&bias), &[n, m]).unwrap();
        reference_apply(&eps[1], &mut want, None, &[n, m]).unwrap();
        let got = &t[&p.params[4].id];
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 0.05, "{} vs {}", g, w);
        }
    }

    #[test]
    fn quantize_roundtrip_error_is_bounded() {
        let w = test_data(64 * 128, 5);
        for fmt in [WeightFormat::Int4, WeightFormat::Nf4, WeightFormat::Fp4] {
            let (p, s) = quantize_weights(&w, 64, 128, fmt, 32);
            let dq = dequantize_weights(&p, &s, 64, 128, fmt, 32);
            let mse: f32 = w
                .iter()
                .zip(&dq)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                / w.len() as f32;
            assert!(mse < 0.002, "{:?} mse {}", fmt, mse);
        }
    }
}
