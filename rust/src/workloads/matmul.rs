//! GEMM tile programs (paper Fig. 16 / appendix B.1) parameterized by a
//! tile configuration — the search space the autotuner explores and the
//! baselines restrict.

use crate::autotuner::{Tunable, TunableConfig};
use crate::ir::builder::KernelBuilder;
use crate::ir::dtype::DType;
use crate::ir::program::{GemmWarpPolicy, TileProgram};
use crate::util::json::Json;

/// A GEMM tile configuration (the scheduling decision vector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileConfig {
    pub block_m: i64,
    pub block_n: i64,
    pub block_k: i64,
    pub num_stages: usize,
    pub threads: i64,
    pub policy: GemmWarpPolicy,
    /// L2 rasterization swizzle (T.use_swizzle).
    pub rasterize: bool,
    /// Producer/consumer warp specialization: `Some(on)` pins the
    /// decision (a searchable schedule knob); `None` leaves it to the
    /// per-architecture default (Hopper on, others off).
    pub specialize: Option<bool>,
}

impl TileConfig {
    pub fn default_for(m: i64, n: i64, k: i64) -> TileConfig {
        let pow2 = |v: i64| (v as u64).next_power_of_two() as i64;
        let block_m = if m >= 128 { 128 } else { pow2(m.max(16)).min(64) };
        let block_n = if n >= 128 { 128 } else { pow2(n.max(16)).min(64) };
        // shallow reductions (split-K shards, K < 32) get a K tile that
        // still divides them instead of an infeasible fixed 32
        let block_k = if k >= 32 { 32 } else { pow2(k.max(16)).min(32) };
        TileConfig {
            block_m,
            block_n,
            block_k,
            num_stages: 3,
            threads: 128,
            policy: GemmWarpPolicy::Square,
            rasterize: true,
            specialize: None,
        }
    }

    /// The candidate set the autotuner sweeps (a superset of Triton's
    /// usual autotune space; the paper's advantage on odd shapes comes
    /// from also varying warp policy and stages freely).
    pub fn search_space(m: i64, n: i64, k: i64) -> Vec<TileConfig> {
        let mut out = Vec::new();
        for &bm in &[32i64, 64, 128, 256] {
            for &bn in &[32i64, 64, 128, 256] {
                for &bk in &[32i64, 64] {
                    // stage 1 = unpipelined serial loop (the degenerate
                    // baseline); 2..4 = multi-buffered async pipelines
                    for &stages in &[1usize, 2, 3, 4] {
                        if bm > m.max(16) * 2 || bn > n.max(16) * 2 || bk > k {
                            continue;
                        }
                        if bm * bk + bn * bk > 64 * 1024 {
                            continue;
                        }
                        // both specialization settings are candidates
                        // (unspecialized first, so ties break to it);
                        // 1-stage loops have no pipeline to specialize
                        for &sp in &[Some(false), Some(true)] {
                            if stages < 2 && sp == Some(true) {
                                continue;
                            }
                            out.push(TileConfig {
                                block_m: bm.min(m.max(16)),
                                block_n: bn.min(n.max(16)),
                                block_k: bk,
                                num_stages: stages,
                                threads: 128,
                                policy: GemmWarpPolicy::Square,
                                rasterize: true,
                                specialize: sp,
                            });
                        }
                    }
                }
            }
        }
        out
    }
}

/// Build the Fig. 16 GEMM: `C[m,n] = A[m,k] @ B[k,n]` in fp16 with fp32
/// accumulation. Shapes must be multiples of the block tile (the bench
/// pads; the dynamic-shape path handles tails via predication).
pub fn matmul_program(
    m: i64,
    n: i64,
    k: i64,
    dtype: DType,
    cfg: &TileConfig,
) -> TileProgram {
    matmul_program_ep(m, n, k, dtype, cfg, &[])
}

/// [`matmul_program`] with a fused epilogue: after the K loop the
/// accumulator tile takes the epilogue ops (bias-add over the feature
/// dim `n`, activation, residual-add, scale) in registers before the
/// single copy-out — the `graph::fuse` target that removes a DRAM round
/// trip per folded element-wise node. Epilogue operand params follow the
/// GEMM operands and precede `C` (the runtime's `inputs..., output`
/// contract).
pub fn matmul_program_ep(
    m: i64,
    n: i64,
    k: i64,
    dtype: DType,
    cfg: &TileConfig,
    eps: &[crate::workloads::epilogue::EpilogueOp],
) -> TileProgram {
    assert!(m % cfg.block_m == 0 && n % cfg.block_n == 0 && k % cfg.block_k == 0,
        "shape {}x{}x{} not divisible by tile {}x{}x{}", m, n, k, cfg.block_m, cfg.block_n, cfg.block_k);
    let name = if eps.is_empty() { "matmul" } else { "matmul_ep" };
    let mut t = KernelBuilder::new(name, cfg.threads);
    let a = t.param("A", &[m, k], dtype);
    let b = t.param("B", &[k, n], dtype);
    let ep_params =
        crate::workloads::epilogue::declare_epilogue_params(&mut t, eps, [m, n]);
    let c = t.param("C", &[m, n], DType::F32);
    let (bx, by) = t.kernel2(n / cfg.block_n, m / cfg.block_m);
    if cfg.rasterize {
        t.use_swizzle(3);
    }
    if let Some(on) = cfg.specialize {
        t.warp_specialize(on);
    }
    let a_s = t.alloc_shared("A_shared", &[cfg.block_m, cfg.block_k], dtype);
    let b_s = t.alloc_shared("B_shared", &[cfg.block_k, cfg.block_n], dtype);
    let c_l = t.alloc_fragment("C_local", &[cfg.block_m, cfg.block_n], DType::F32);
    t.clear(c_l);
    let (bm, bn, bk) = (cfg.block_m, cfg.block_n, cfg.block_k);
    t.pipelined(k / bk, cfg.num_stages, |t, ko| {
        t.copy_in(a, vec![by.expr() * bm, ko.expr() * bk], a_s);
        t.copy_in(b, vec![ko.expr() * bk, bx.expr() * bn], b_s);
        t.gemm_opts(a_s, b_s, c_l, false, false, cfg.policy);
    });
    crate::workloads::epilogue::emit_epilogues(
        &mut t,
        eps,
        &ep_params,
        c_l,
        [bm, bn],
        &[by.expr() * bm, bx.expr() * bn],
    );
    t.copy_out(c_l, c, vec![by.expr() * bm, bx.expr() * bn]);
    t.finish()
}

/// Build a GEMM with a *dynamic* M dimension (the serving-side shape):
/// `C[M,n] = A[M,k] @ B[k,n]` where `M` is a runtime scalar parameter
/// and the row grid is `ceil(M / block_m)`. Specializing `M` to a
/// concrete value (`ir::program::specialize`) folds the grid to a
/// constant; when `M` is not a multiple of the row tile, the last block
/// runs as a predicated tail — out-of-bounds rows read as zero and
/// their stores are dropped, so the first `M` output rows are exact.
/// Returns the program and the `M` variable for binding.
pub fn matmul_program_dyn(
    n: i64,
    k: i64,
    dtype: DType,
    cfg: &TileConfig,
) -> (TileProgram, crate::ir::expr::Var) {
    assert!(
        n % cfg.block_n == 0 && k % cfg.block_k == 0,
        "static dims {}x{} not divisible by tile {}x{}",
        n,
        k,
        cfg.block_n,
        cfg.block_k
    );
    let mut t = KernelBuilder::new("matmul_dyn_m", cfg.threads);
    let m = t.dyn_var("M");
    let a = t.param_dyn(
        "A",
        vec![m.expr(), crate::ir::expr::Expr::int(k)],
        dtype,
    );
    let b = t.param("B", &[k, n], dtype);
    let c = t.param_dyn(
        "C",
        vec![m.expr(), crate::ir::expr::Expr::int(n)],
        DType::F32,
    );
    let (bm, bn, bk) = (cfg.block_m, cfg.block_n, cfg.block_k);
    let (bx, by) = t.kernel2(n / bn, (m.expr() + (bm - 1)).floordiv(bm));
    if cfg.rasterize {
        t.use_swizzle(3);
    }
    if let Some(on) = cfg.specialize {
        t.warp_specialize(on);
    }
    let a_s = t.alloc_shared("A_shared", &[bm, bk], dtype);
    let b_s = t.alloc_shared("B_shared", &[bk, bn], dtype);
    let c_l = t.alloc_fragment("C_local", &[bm, bn], DType::F32);
    t.clear(c_l);
    t.pipelined(k / bk, cfg.num_stages, |t, ko| {
        t.copy_in(a, vec![by.expr() * bm, ko.expr() * bk], a_s);
        t.copy_in(b, vec![ko.expr() * bk, bx.expr() * bn], b_s);
        t.gemm_opts(a_s, b_s, c_l, false, false, cfg.policy);
    });
    t.copy_out(c_l, c, vec![by.expr() * bm, bx.expr() * bn]);
    (t.finish(), m)
}

/// Reference GEMM in f32 (row-major).
pub fn reference_matmul(a: &[f32], b: &[f32], m: i64, n: i64, k: i64) -> Vec<f32> {
    let mut c = vec![0f32; (m * n) as usize];
    for i in 0..m as usize {
        for kk in 0..k as usize {
            let av = a[i * k as usize + kk];
            if av == 0.0 {
                continue;
            }
            for j in 0..n as usize {
                c[i * n as usize + j] += av * b[kk * n as usize + j];
            }
        }
    }
    c
}

impl TunableConfig for TileConfig {
    fn to_json(&self) -> Json {
        let policy = match self.policy {
            GemmWarpPolicy::Square => "square",
            GemmWarpPolicy::FullRow => "full_row",
            GemmWarpPolicy::FullCol => "full_col",
        };
        let specialize = match self.specialize {
            None => "auto",
            Some(true) => "on",
            Some(false) => "off",
        };
        Json::Obj(vec![
            ("block_m".into(), Json::Num(self.block_m as f64)),
            ("block_n".into(), Json::Num(self.block_n as f64)),
            ("block_k".into(), Json::Num(self.block_k as f64)),
            ("num_stages".into(), Json::Num(self.num_stages as f64)),
            ("threads".into(), Json::Num(self.threads as f64)),
            ("policy".into(), Json::Str(policy.into())),
            ("rasterize".into(), Json::Bool(self.rasterize)),
            ("specialize".into(), Json::Str(specialize.into())),
        ])
    }

    fn from_json(v: &Json) -> Option<TileConfig> {
        let policy = match v.get("policy")?.as_str()? {
            "square" => GemmWarpPolicy::Square,
            "full_row" => GemmWarpPolicy::FullRow,
            "full_col" => GemmWarpPolicy::FullCol,
            _ => return None,
        };
        // pre-specialization cache entries have no "specialize" key:
        // decode as `None` (the architecture default) so old tune_cache
        // files keep hitting
        let specialize = match v.get("specialize").and_then(|s| s.as_str()) {
            Some("on") => Some(true),
            Some("off") => Some(false),
            _ => None,
        };
        Some(TileConfig {
            block_m: v.get("block_m")?.as_i64()?,
            block_n: v.get("block_n")?.as_i64()?,
            block_k: v.get("block_k")?.as_i64()?,
            num_stages: v.get("num_stages")?.as_i64()?.max(1) as usize,
            threads: v.get("threads")?.as_i64()?,
            policy,
            rasterize: v.get("rasterize")?.as_bool()?,
            specialize,
        })
    }
}

/// GEMM tuning problem: `C[m,n] = A[m,k] @ B[k,n]`. Degenerate dims are
/// padded to the 16-wide minimum hardware tile (decode GEMV shapes).
#[derive(Clone, Copy, Debug)]
pub struct GemmTunable {
    pub m: i64,
    pub n: i64,
    pub k: i64,
    pub dtype: DType,
    padded: (i64, i64, i64),
}

impl GemmTunable {
    pub fn new(m: i64, n: i64, k: i64, dtype: DType) -> GemmTunable {
        GemmTunable {
            m,
            n,
            k,
            dtype,
            padded: (m.max(16), n.max(16), k.max(16)),
        }
    }
}

impl Tunable for GemmTunable {
    type Config = TileConfig;

    fn workload(&self) -> &'static str {
        "gemm"
    }

    fn shape_key(&self) -> Vec<i64> {
        vec![self.m, self.n, self.k]
    }

    fn dtype_key(&self) -> String {
        self.dtype.to_string()
    }

    fn accepts(&self, cfg: &TileConfig) -> bool {
        let (pm, pn, pk) = self.padded;
        cfg.block_m > 0
            && cfg.block_n > 0
            && cfg.block_k > 0
            && cfg.threads > 0
            && cfg.threads % 32 == 0
            && pm % cfg.block_m == 0
            && pn % cfg.block_n == 0
            && pk % cfg.block_k == 0
            // register pressure: the fp32 accumulator tile alone must
            // fit the per-thread register file, or the candidate spills
            // and the model would mis-rank it (see
            // sim::model::MAX_REGS_PER_THREAD)
            && cfg.block_m * cfg.block_n / cfg.threads
                <= crate::sim::model::MAX_REGS_PER_THREAD
    }

    fn candidates(&self) -> Vec<TileConfig> {
        let (pm, pn, pk) = self.padded;
        TileConfig::search_space(pm, pn, pk)
            .into_iter()
            .filter(|cfg| self.accepts(cfg))
            .collect()
    }

    fn build(&self, cfg: &TileConfig) -> TileProgram {
        let (pm, pn, pk) = self.padded;
        matmul_program(pm, pn, pk, self.dtype, cfg)
    }
}

/// Deterministic pseudo-random test data in [-0.5, 0.5].
pub fn test_data(n: i64, seed: u64) -> Vec<f32> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::lower::{compile, CompileOptions};
    use crate::sim::device::Device;
    use crate::tir::interp::{Interp, Tensors};

    fn check(m: i64, n: i64, k: i64, cfg: &TileConfig) {
        let p = matmul_program(m, n, k, DType::F16, cfg);
        let l = compile(&p, &Device::a100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let a = test_data(m * k, 1);
        let b = test_data(k * n, 2);
        let mut t = Tensors::new();
        t.insert(p.params[0].id, a.clone());
        t.insert(p.params[1].id, b.clone());
        interp.run(&mut t).unwrap();
        // inputs round to fp16 on the shared-memory store; compare with
        // a tolerance that covers it
        let want = reference_matmul(&a, &b, m, n, k);
        let got = &t[&p.params[2].id];
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 0.05 + 0.02 * w.abs(), "{} vs {}", g, w);
        }
    }

    #[test]
    fn fig16_matmul_various_configs() {
        check(
            64,
            64,
            64,
            &TileConfig {
                block_m: 32,
                block_n: 32,
                block_k: 32,
                num_stages: 2,
                threads: 64,
                policy: GemmWarpPolicy::Square,
                rasterize: false,
                specialize: None,
            },
        );
        check(
            128,
            64,
            32,
            &TileConfig {
                block_m: 64,
                block_n: 32,
                block_k: 16,
                num_stages: 3,
                threads: 64,
                policy: GemmWarpPolicy::FullRow,
                rasterize: true,
                specialize: None,
            },
        );
    }

    #[test]
    fn matmul_epilogues_match_reference() {
        use crate::workloads::epilogue::{reference_apply, Activation, EpilogueOp};
        let (m, n, k) = (64i64, 64, 64);
        let cfg = TileConfig {
            block_m: 32,
            block_n: 32,
            block_k: 32,
            num_stages: 2,
            threads: 64,
            policy: GemmWarpPolicy::Square,
            rasterize: false,
            specialize: None,
        };
        let eps = [
            EpilogueOp::BiasAdd { dim: 1 },
            EpilogueOp::Activation(Activation::Gelu),
            EpilogueOp::ResidualAdd,
            EpilogueOp::Scale(0.5),
        ];
        let p = matmul_program_ep(m, n, k, DType::F16, &cfg, &eps);
        // A, B, bias, residual, C — epilogue operands precede the output
        assert_eq!(p.params.len(), 5);
        let l = compile(&p, &Device::h100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let a = test_data(m * k, 1);
        let b = test_data(k * n, 2);
        let bias = test_data(n, 3);
        let res = test_data(m * n, 4);
        let mut t = Tensors::new();
        t.insert(p.params[0].id, a.clone());
        t.insert(p.params[1].id, b.clone());
        t.insert(p.params[2].id, bias.clone());
        t.insert(p.params[3].id, res.clone());
        interp.run(&mut t).unwrap();
        let mut want = reference_matmul(&a, &b, m, n, k);
        reference_apply(&eps[0], &mut want, Some(&bias), &[m, n]).unwrap();
        reference_apply(&eps[1], &mut want, None, &[m, n]).unwrap();
        reference_apply(&eps[2], &mut want, Some(&res), &[m, n]).unwrap();
        reference_apply(&eps[3], &mut want, None, &[m, n]).unwrap();
        let got = &t[&p.params[4].id];
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 0.05 + 0.02 * w.abs(), "{} vs {}", g, w);
        }
    }

    #[test]
    fn search_space_is_nonempty_and_bounded() {
        let space = TileConfig::search_space(4096, 8192, 8192);
        assert!(space.len() >= 20 && space.len() <= 400);
        for c in &space {
            assert!(c.block_m * c.block_k + c.block_n * c.block_k <= 64 * 1024);
        }
        // the specialization knob is actually searched
        assert!(space.iter().any(|c| c.specialize == Some(true)));
        assert!(space.iter().any(|c| c.specialize == Some(false)));
        // ...but never on a 1-stage loop (nothing to specialize)
        assert!(space
            .iter()
            .all(|c| c.num_stages >= 2 || c.specialize != Some(true)));
        // skinny decode shapes still get candidates
        let skinny = TileConfig::search_space(1, 16384, 16384);
        assert!(!skinny.is_empty());
    }
}
