//! The paper's benchmark shape tables (Appendix A).

/// GEMM / dequant-GEMM shapes (Table 2). `V*` are the skinny m=1
/// dequantize shapes, `M*` the square-ish training shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    pub name: &'static str,
    pub m: i64,
    pub n: i64,
    pub k: i64,
}

/// Table 2, top: V0..V7 (m = 1 decode GEMV shapes).
pub const V_SHAPES: [GemmShape; 8] = [
    GemmShape { name: "V0", m: 1, n: 16384, k: 16384 },
    GemmShape { name: "V1", m: 1, n: 43008, k: 14336 },
    GemmShape { name: "V2", m: 1, n: 14336, k: 14336 },
    GemmShape { name: "V3", m: 1, n: 57344, k: 14336 },
    GemmShape { name: "V4", m: 1, n: 14336, k: 57344 },
    GemmShape { name: "V5", m: 1, n: 9216, k: 9216 },
    GemmShape { name: "V6", m: 1, n: 36864, k: 9216 },
    GemmShape { name: "V7", m: 1, n: 9216, k: 36864 },
];

/// Table 2, bottom: M0..M7.
pub const M_SHAPES: [GemmShape; 8] = [
    GemmShape { name: "M0", m: 4096, n: 1024, k: 8192 },
    GemmShape { name: "M1", m: 4096, n: 8192, k: 8192 },
    GemmShape { name: "M2", m: 4096, n: 28672, k: 8192 },
    GemmShape { name: "M3", m: 4096, n: 8192, k: 28672 },
    GemmShape { name: "M4", m: 8192, n: 1024, k: 8192 },
    GemmShape { name: "M5", m: 8192, n: 8192, k: 8192 },
    GemmShape { name: "M6", m: 8192, n: 28672, k: 8192 },
    GemmShape { name: "M7", m: 8192, n: 8192, k: 28672 },
];

/// FlashAttention shapes (Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnShape {
    pub name: &'static str,
    pub batch: i64,
    pub heads: i64,
    pub seq_len: i64,
    pub head_dim: i64,
    pub causal: bool,
}

pub const FA_SHAPES: [AttnShape; 5] = [
    AttnShape { name: "FA0", batch: 1, heads: 32, seq_len: 512, head_dim: 128, causal: true },
    AttnShape { name: "FA1", batch: 1, heads: 32, seq_len: 512, head_dim: 128, causal: false },
    AttnShape { name: "FA2", batch: 1, heads: 32, seq_len: 1024, head_dim: 128, causal: true },
    AttnShape { name: "FA3", batch: 1, heads: 32, seq_len: 1024, head_dim: 128, causal: false },
    AttnShape { name: "FA4", batch: 1, heads: 32, seq_len: 4096, head_dim: 128, causal: true },
];

/// Linear-attention (Mamba-2 chunk) shapes (Table 4). `CC*` = chunk_scan,
/// `CT*` = chunk_state; the table uses the same grid for both.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinAttnShape {
    pub name: &'static str,
    pub batch: i64,
    pub nheads: i64,
    pub seq_len: i64,
    pub head_dim: i64,
    pub d_state: i64,
}

pub const CC_SHAPES: [LinAttnShape; 6] = [
    LinAttnShape { name: "CC0", batch: 1, nheads: 64, seq_len: 1024, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CC1", batch: 1, nheads: 64, seq_len: 2048, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CC2", batch: 1, nheads: 64, seq_len: 8192, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CC3", batch: 64, nheads: 64, seq_len: 1024, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CC4", batch: 64, nheads: 64, seq_len: 2048, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CC5", batch: 64, nheads: 64, seq_len: 8192, head_dim: 64, d_state: 128 },
];

pub const CT_SHAPES: [LinAttnShape; 6] = [
    LinAttnShape { name: "CT0", batch: 1, nheads: 64, seq_len: 1024, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CT1", batch: 1, nheads: 64, seq_len: 2048, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CT2", batch: 1, nheads: 64, seq_len: 8192, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CT3", batch: 64, nheads: 64, seq_len: 1024, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CT4", batch: 64, nheads: 64, seq_len: 2048, head_dim: 64, d_state: 128 },
    LinAttnShape { name: "CT5", batch: 64, nheads: 64, seq_len: 8192, head_dim: 64, d_state: 128 },
];

/// The MLA decode configuration of Fig. 14 (DeepSeek-V2 geometry, as in
/// the paper's FlashMLA comparison).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MlaShape {
    pub batch: i64,
    pub heads: i64,
    pub seqlen_kv: i64,
    pub dim: i64,
    pub pe_dim: i64,
}

pub const MLA_DECODE: MlaShape = MlaShape {
    batch: 64,
    heads: 128,
    seqlen_kv: 8192,
    dim: 512,
    pe_dim: 64,
};

/// FLOP count helpers used by every bench.
impl GemmShape {
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }
}

impl AttnShape {
    /// FLOPs of (masked) attention: QK^T + PV, both 2*s*s*d per head.
    pub fn flops(&self) -> f64 {
        let full = 4.0
            * self.batch as f64
            * self.heads as f64
            * self.seq_len as f64
            * self.seq_len as f64
            * self.head_dim as f64;
        if self.causal {
            full / 2.0
        } else {
            full
        }
    }
}

impl LinAttnShape {
    /// FLOPs of one chunked pass (chunk length 256, as in Mamba-2).
    pub fn flops(&self, chunk: i64) -> f64 {
        let chunks = (self.seq_len / chunk) as f64;
        let b = self.batch as f64 * self.nheads as f64;
        // state update: chunk x d_state x head_dim per chunk
        b * chunks * 2.0 * chunk as f64 * self.d_state as f64 * self.head_dim as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_match_paper() {
        assert_eq!(V_SHAPES.len(), 8);
        assert_eq!(M_SHAPES.len(), 8);
        assert!(V_SHAPES.iter().all(|s| s.m == 1));
        assert_eq!(M_SHAPES[2].n, 28672);
        assert_eq!(M_SHAPES[7], GemmShape { name: "M7", m: 8192, n: 8192, k: 28672 });
        assert_eq!(FA_SHAPES[4].seq_len, 4096);
        assert!(FA_SHAPES[1].causal == false && FA_SHAPES[0].causal);
        assert!(CC_SHAPES.iter().all(|s| s.d_state == 128 && s.head_dim == 64));
        assert_eq!(MLA_DECODE.dim, 512);
    }

    #[test]
    fn flop_counts() {
        let g = GemmShape { name: "t", m: 2, n: 3, k: 4 };
        assert_eq!(g.flops(), 48.0);
        let causal = AttnShape { name: "t", batch: 1, heads: 1, seq_len: 8, head_dim: 2, causal: true };
        let full = AttnShape { causal: false, ..causal };
        assert_eq!(full.flops(), 2.0 * causal.flops());
    }
}
