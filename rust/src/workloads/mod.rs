//! Paper workloads as tile programs: GEMM (Fig. 16), FlashAttention and
//! FlashMLA (Fig. 18), Mamba-2 linear-attention chunk kernels, and the
//! dequantize-GEMM family (Fig. 17), plus the Appendix A shape tables
//! and CPU reference implementations.
//!
//! These families are also the execution vocabulary of the serving
//! layer: the runtime's interp backend resolves a manifest artifact's
//! `workload=` tag to one of these program builders, and the CPU
//! references are the ground truth for artifact goldens
//! (`runtime::artifacts`) and the differential tests.
//!
//! The [`epilogue`] module adds the fused epilogue vocabulary
//! (bias-add, activation, residual-add, scale) that the GEMM-family
//! builders accept and the graph layer's fusion planner folds producer
//! consumers into (`graph::fuse`).

pub mod attention;
pub mod dequant;
pub mod epilogue;
pub mod linear_attention;
pub mod matmul;
pub mod shapes;
