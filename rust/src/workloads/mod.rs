//! Paper workloads as tile programs: GEMM (Fig. 16), FlashAttention and
//! FlashMLA (Fig. 18), Mamba-2 linear-attention chunk kernels, and the
//! dequantize-GEMM family (Fig. 17), plus the Appendix A shape tables
//! and CPU reference implementations.

pub mod attention;
pub mod dequant;
pub mod linear_attention;
pub mod matmul;
pub mod shapes;
