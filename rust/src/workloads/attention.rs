//! Attention tile programs: FlashAttention-style MHA (Table 3 / Fig. 12),
//! the FlashMLA decode kernel (Fig. 18 / Fig. 14), and the serving-side
//! flash-decode kernel ([`flash_decode_program`]: one query per stream
//! against a KV cache, MQA-style shared cache per stream).
//!
//! All follow the paper's appendix kernels: online-softmax over a
//! pipelined KV loop, with `T.reduce_max/sum`, exp2 rescaling in
//! `T.Parallel` bodies, and the S-tile staged through shared memory
//! between the two GEMMs. The flash and decode kernels also accept a
//! fused epilogue list applied to the O accumulator before the copy-out
//! (the graph layer's attention-family epilogues — e.g. a residual
//! folded into the O tile).

use crate::autotuner::{Tunable, TunableConfig};
use crate::ir::builder::{store, KernelBuilder};
use crate::ir::dtype::DType;
use crate::ir::expr::{Expr, UnOp};
use crate::ir::program::{GemmWarpPolicy, ReduceKind, TileProgram};
use crate::util::json::Json;
use crate::workloads::epilogue::{declare_epilogue_params_rank3, emit_epilogues_rank3, EpilogueOp};
use crate::workloads::shapes::{AttnShape, MlaShape};

/// Attention tile configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnConfig {
    pub block_m: i64,
    pub block_n: i64,
    pub num_stages: usize,
    pub threads: i64,
    /// Producer/consumer warp specialization: `Some(on)` pins the
    /// decision (a searchable schedule knob); `None` leaves it to the
    /// per-architecture default (Hopper on, others off).
    pub specialize: Option<bool>,
}

impl AttnConfig {
    pub fn default_for(seq_len: i64) -> AttnConfig {
        // adaptive tiles: short sequences get smaller blocks (the
        // advantage Fig. 12 attributes to TileLang over FA3's fixed 128)
        let block_m = if seq_len >= 2048 { 128 } else { 64 };
        let block_n = if seq_len >= 2048 { 128 } else { 64 };
        AttnConfig {
            block_m,
            block_n,
            num_stages: 2,
            threads: 128,
            specialize: None,
        }
    }
}

/// Build a FlashAttention forward kernel over flattened (batch*heads)
/// tensors: `Q,K,V: [bh, seq, d]`, `O: [bh, seq, d]`.
/// Grid = (seq/block_m, bh); the KV loop is pipelined.
pub fn flash_attention_program(
    bh: i64,
    seq_len: i64,
    head_dim: i64,
    causal: bool,
    cfg: &AttnConfig,
) -> TileProgram {
    flash_attention_program_ep(bh, seq_len, head_dim, causal, cfg, &[])
}

/// [`flash_attention_program`] with a fused epilogue: after the final
/// softmax normalization the O accumulator tile takes the epilogue ops
/// (activation, scale, residual-add against a full `[bh, seq, d]`
/// operand) in registers before the single copy-out — the
/// `graph::fuse` target for attention-family folds. Epilogue operand
/// params follow Q/K/V and precede `O` (the runtime's
/// `inputs..., output` contract). `BiasAdd` is not accepted: rank-3
/// attention outputs have no rank-2 feature dim to broadcast along.
pub fn flash_attention_program_ep(
    bh: i64,
    seq_len: i64,
    head_dim: i64,
    causal: bool,
    cfg: &AttnConfig,
    eps: &[EpilogueOp],
) -> TileProgram {
    let (bm, bn, d) = (cfg.block_m, cfg.block_n, head_dim);
    assert!(seq_len % bm == 0 && seq_len % bn == 0);
    let scale = 1.0f64 / (head_dim as f64).sqrt() * std::f64::consts::LOG2_E;

    let name = if eps.is_empty() {
        "flash_attention"
    } else {
        "flash_attention_ep"
    };
    let mut t = KernelBuilder::new(name, cfg.threads);
    let q = t.param("Q", &[bh, seq_len, d], DType::F16);
    let k = t.param("K", &[bh, seq_len, d], DType::F16);
    let v = t.param("V", &[bh, seq_len, d], DType::F16);
    let ep_params = declare_epilogue_params_rank3(&mut t, eps, [bh, seq_len, d]);
    let o = t.param("O", &[bh, seq_len, d], DType::F16);
    let (bx, bz) = t.kernel2(seq_len / bm, bh);
    t.use_swizzle(8);
    if let Some(on) = cfg.specialize {
        t.warp_specialize(on);
    }

    let q_s = t.alloc_shared("Q_shared", &[bm, d], DType::F16);
    let k_s = t.alloc_shared("K_shared", &[bn, d], DType::F16);
    let v_s = t.alloc_shared("V_shared", &[bn, d], DType::F16);
    let s_s = t.alloc_shared("S_shared", &[bm, bn], DType::F16);
    let acc_s = t.alloc_fragment("acc_s", &[bm, bn], DType::F32);
    let acc_o = t.alloc_fragment("acc_o", &[bm, d], DType::F32);
    let m_prev = t.alloc_fragment("scores_max_prev", &[bm], DType::F32);
    let m_cur = t.alloc_fragment("scores_max", &[bm], DType::F32);
    let r_scale = t.alloc_fragment("scores_scale", &[bm], DType::F32);
    let r_sum = t.alloc_fragment("scores_sum", &[bm], DType::F32);
    let logsum = t.alloc_fragment("logsum", &[bm], DType::F32);

    t.copy_in(q, vec![bz.expr(), bx.expr() * bm, Expr::int(0)], q_s);
    t.fill(acc_o, 0.0);
    t.fill(logsum, 0.0);
    t.fill(m_cur, f64::NEG_INFINITY);

    // causal: KV blocks strictly past the diagonal contribute nothing;
    // bound the loop by the query block (what FA kernels do)
    let loop_range: Expr = if causal {
        ((bx.expr() + 1) * bm + (bn - 1)).floordiv(bn)
    } else {
        Expr::int(seq_len / bn)
    };
    t.pipelined(loop_range, cfg.num_stages, |t, ko| {
        t.copy_in(k, vec![bz.expr(), ko.expr() * bn, Expr::int(0)], k_s);
        t.copy_in(v, vec![bz.expr(), ko.expr() * bn, Expr::int(0)], v_s);
        t.clear(acc_s);
        // acc_s = Q @ K^T
        t.gemm_opts(q_s, k_s, acc_s, false, true, GemmWarpPolicy::FullRow);
        if causal {
            // mask out j > i (global indices)
            let ko_e = ko.expr();
            t.parallel(&[bm, bn], |vrs| {
                let (i, j) = (&vrs[0], &vrs[1]);
                let gi = bx.expr() * bm + i.expr();
                let gj = ko_e.clone() * bn + j.expr();
                vec![store(
                    acc_s,
                    vec![i.expr(), j.expr()],
                    Expr::select(
                        gj.le(gi),
                        Expr::load(acc_s, vec![i.expr(), j.expr()]),
                        Expr::float(-1e30),
                    ),
                )]
            });
        }
        t.copy(m_cur, m_prev);
        t.reduce(acc_s, m_cur, 1, ReduceKind::Max, false);
        // rescale: exp2-based online softmax (Fig. 18 lines 49-58)
        t.parallel(&[bm], |vrs| {
            let i = &vrs[0];
            vec![store(
                r_scale,
                vec![i.expr()],
                Expr::un(
                    UnOp::Exp2,
                    Expr::load(m_prev, vec![i.expr()]) * scale
                        - Expr::load(m_cur, vec![i.expr()]) * scale,
                ),
            )]
        });
        t.parallel(&[bm, bn], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                acc_s,
                vec![i.expr(), j.expr()],
                Expr::un(
                    UnOp::Exp2,
                    Expr::load(acc_s, vec![i.expr(), j.expr()]) * scale
                        - Expr::load(m_cur, vec![i.expr()]) * scale,
                ),
            )]
        });
        t.reduce(acc_s, r_sum, 1, ReduceKind::Sum, true);
        t.parallel(&[bm], |vrs| {
            let i = &vrs[0];
            vec![store(
                logsum,
                vec![i.expr()],
                Expr::load(logsum, vec![i.expr()]) * Expr::load(r_scale, vec![i.expr()])
                    + Expr::load(r_sum, vec![i.expr()]),
            )]
        });
        t.parallel(&[bm, d], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                acc_o,
                vec![i.expr(), j.expr()],
                Expr::load(acc_o, vec![i.expr(), j.expr()])
                    * Expr::load(r_scale, vec![i.expr()]),
            )]
        });
        // stage S through shared memory for the PV gemm (paper line 54)
        t.copy(acc_s, s_s);
        t.gemm_opts(s_s, v_s, acc_o, false, false, GemmWarpPolicy::FullRow);
    });
    t.parallel(&[bm, d], |vrs| {
        let (i, j) = (&vrs[0], &vrs[1]);
        vec![store(
            acc_o,
            vec![i.expr(), j.expr()],
            Expr::load(acc_o, vec![i.expr(), j.expr()])
                * Expr::float(1.0).floordiv_f(Expr::load(logsum, vec![i.expr()])),
        )]
    });
    emit_epilogues_rank3(
        &mut t,
        eps,
        &ep_params,
        acc_o,
        [bm, d],
        &[bz.expr(), bx.expr() * bm, Expr::int(0)],
    );
    t.copy_out(acc_o, o, vec![bz.expr(), bx.expr() * bm, Expr::int(0)]);
    t.finish()
}

/// Flash-decode tile configuration (the serving decode kernel's knobs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeConfig {
    /// Heads processed per block. Warp tiles hold whole 16x8 MMA tiles
    /// along M, so `block_h` is a multiple of 16 and the kernel needs at
    /// least 16 heads — the planner-side feasibility audit for
    /// head-parallel shards lives in `runtime`'s `decode_config`.
    pub block_h: i64,
    /// KV-cache positions per pipelined loop step.
    pub block_n: i64,
    pub num_stages: usize,
    pub threads: i64,
}

impl DecodeConfig {
    /// Static default, narrowed to the shape: the widest feasible head
    /// tile (16 or 32) and a KV tile that divides the cache length.
    pub fn default_for(heads: i64, seqlen_kv: i64) -> DecodeConfig {
        let block_h = if heads >= 32 && heads % 32 == 0 { 32 } else { 16 };
        let block_n = if seqlen_kv % 32 == 0 { 32 } else { 16 };
        DecodeConfig {
            block_h,
            block_n,
            num_stages: 2,
            // decode tiles are narrow ([block_h, d] accumulators); 2
            // warps keep every warp split a whole-MMA-tile partition
            threads: 64,
        }
    }
}

/// Build the serving flash-decode kernel: one query position per
/// (stream, head) against a per-stream KV cache shared by all heads
/// (MQA-style) — `Q: [batch, heads, d]`, `K,V: [batch, seqlen_kv, d]`,
/// `O: [batch, heads, d]`. This is the m=1 decode analogue of
/// [`flash_attention_program`], structured like the MLA kernel: one
/// block handles `block_h` heads of one stream, so the score tile stays
/// a full `[block_h, block_n]` MMA problem even though each head reads a
/// single query row. The KV loop runs the same exp2 online softmax and
/// is pipelined `num_stages` deep; the cache is attended in full (a
/// decode step sees every cached position — causality is enforced by
/// what the serving layer has appended, not by a mask).
///
/// `eps` fuses an epilogue list into the O accumulator before the
/// copy-out (activation, scale, residual against a `[batch, heads, d]`
/// operand) — the graph layer folds e.g. a block residual here instead
/// of materializing the attention output.
pub fn flash_decode_program(
    batch: i64,
    heads: i64,
    seqlen_kv: i64,
    head_dim: i64,
    cfg: &DecodeConfig,
    eps: &[EpilogueOp],
) -> TileProgram {
    let (bh, bn, d) = (cfg.block_h, cfg.block_n, head_dim);
    assert!(
        heads % bh == 0 && seqlen_kv % bn == 0,
        "decode shape (heads {}, kv {}) not tileable by {}x{}",
        heads,
        seqlen_kv,
        bh,
        bn
    );
    let scale = 1.0f64 / (head_dim as f64).sqrt() * std::f64::consts::LOG2_E;

    let name = if eps.is_empty() {
        "flash_decode"
    } else {
        "flash_decode_ep"
    };
    let mut t = KernelBuilder::new(name, cfg.threads);
    let q = t.param("Q", &[batch, heads, d], DType::F16);
    let k = t.param("K", &[batch, seqlen_kv, d], DType::F16);
    let v = t.param("V", &[batch, seqlen_kv, d], DType::F16);
    let ep_params = declare_epilogue_params_rank3(&mut t, eps, [batch, heads, d]);
    let o = t.param("O", &[batch, heads, d], DType::F16);
    let (bx, by) = t.kernel2(batch, heads / bh);
    t.use_swizzle(8);

    let q_s = t.alloc_shared("Q_shared", &[bh, d], DType::F16);
    let k_s = t.alloc_shared("K_shared", &[bn, d], DType::F16);
    let v_s = t.alloc_shared("V_shared", &[bn, d], DType::F16);
    let s_s = t.alloc_shared("S_shared", &[bh, bn], DType::F16);
    let acc_s = t.alloc_fragment("acc_s", &[bh, bn], DType::F32);
    let acc_o = t.alloc_fragment("acc_o", &[bh, d], DType::F32);
    let m_prev = t.alloc_fragment("scores_max_prev", &[bh], DType::F32);
    let m_cur = t.alloc_fragment("scores_max", &[bh], DType::F32);
    let r_scale = t.alloc_fragment("scores_scale", &[bh], DType::F32);
    let r_sum = t.alloc_fragment("scores_sum", &[bh], DType::F32);
    let logsum = t.alloc_fragment("logsum", &[bh], DType::F32);

    t.copy_in(q, vec![bx.expr(), by.expr() * bh, Expr::int(0)], q_s);
    t.fill(acc_o, 0.0);
    t.fill(logsum, 0.0);
    t.fill(m_cur, f64::NEG_INFINITY);

    t.pipelined(Expr::int(seqlen_kv / bn), cfg.num_stages, |t, ko| {
        t.copy_in(k, vec![bx.expr(), ko.expr() * bn, Expr::int(0)], k_s);
        t.copy_in(v, vec![bx.expr(), ko.expr() * bn, Expr::int(0)], v_s);
        t.clear(acc_s);
        // acc_s = Q @ K_cache^T: every head row scores the shared cache
        t.gemm_opts(q_s, k_s, acc_s, false, true, GemmWarpPolicy::FullCol);
        t.copy(m_cur, m_prev);
        t.reduce(acc_s, m_cur, 1, ReduceKind::Max, false);
        t.parallel(&[bh], |vrs| {
            let i = &vrs[0];
            vec![store(
                r_scale,
                vec![i.expr()],
                Expr::un(
                    UnOp::Exp2,
                    Expr::load(m_prev, vec![i.expr()]) * scale
                        - Expr::load(m_cur, vec![i.expr()]) * scale,
                ),
            )]
        });
        t.parallel(&[bh, bn], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                acc_s,
                vec![i.expr(), j.expr()],
                Expr::un(
                    UnOp::Exp2,
                    Expr::load(acc_s, vec![i.expr(), j.expr()]) * scale
                        - Expr::load(m_cur, vec![i.expr()]) * scale,
                ),
            )]
        });
        t.reduce(acc_s, r_sum, 1, ReduceKind::Sum, true);
        t.parallel(&[bh], |vrs| {
            let i = &vrs[0];
            vec![store(
                logsum,
                vec![i.expr()],
                Expr::load(logsum, vec![i.expr()]) * Expr::load(r_scale, vec![i.expr()])
                    + Expr::load(r_sum, vec![i.expr()]),
            )]
        });
        t.parallel(&[bh, d], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                acc_o,
                vec![i.expr(), j.expr()],
                Expr::load(acc_o, vec![i.expr(), j.expr()])
                    * Expr::load(r_scale, vec![i.expr()]),
            )]
        });
        t.copy(acc_s, s_s);
        t.gemm_opts(s_s, v_s, acc_o, false, false, GemmWarpPolicy::FullCol);
    });
    t.parallel(&[bh, d], |vrs| {
        let (i, j) = (&vrs[0], &vrs[1]);
        vec![store(
            acc_o,
            vec![i.expr(), j.expr()],
            Expr::load(acc_o, vec![i.expr(), j.expr()])
                * Expr::float(1.0).floordiv_f(Expr::load(logsum, vec![i.expr()])),
        )]
    });
    emit_epilogues_rank3(
        &mut t,
        eps,
        &ep_params,
        acc_o,
        [bh, d],
        &[bx.expr(), by.expr() * bh, Expr::int(0)],
    );
    t.copy_out(acc_o, o, vec![bx.expr(), by.expr() * bh, Expr::int(0)]);
    t.finish()
}

/// The serving engine's paged-gather decode kernel: [`flash_decode_program`]
/// plus a per-stream valid-length mask, so streams at *different* sequence
/// lengths co-batch against one `[batch, max_kv, d]` gather of their paged
/// caches. A fourth input `Lens: [batch]` carries each stream's committed
/// row count; cache positions `j >= Lens[bx]` are masked to `-1e30` before
/// the online-softmax max, which makes them exact no-ops on the running
/// `(m, logsum, acc_o)` state:
///
/// * a masked score rescales to `exp2(-1e30*scale - m*scale)`, which
///   underflows to exactly `0.0` in f32 whenever any valid row has been
///   seen (`m` finite), so `r_sum` and the `S@V` GEMM contribute nothing;
/// * a *fully* masked trailing block leaves `m` unchanged (`max(m, -1e30)
///   = m`), so `r_scale = exp2(0) = 1` and the state passes through
///   bit-for-bit.
///
/// That no-op property is what the continuous-batching oracle tests rely
/// on: padding a stream's cache view out to the co-batch's `max_kv` (or
/// any longer 16-aligned length) cannot change its output, so a batched
/// step equals the one-stream-at-a-time serial decode exactly — provided
/// the tile config is pinned across lengths (see the runtime's
/// `paged_decode_config`, which never varies `block_n` with `max_kv`).
///
/// A dead co-batch slot (`Lens[bx] = 0`, zeroed Q/K/V rows) degenerates to
/// `exp2(0)` scores over zero V rows: output exactly `0.0`, never NaN.
pub fn flash_decode_paged_program(
    batch: i64,
    heads: i64,
    max_kv: i64,
    head_dim: i64,
    cfg: &DecodeConfig,
    eps: &[EpilogueOp],
) -> TileProgram {
    let (bh, bn, d) = (cfg.block_h, cfg.block_n, head_dim);
    assert!(
        heads % bh == 0 && max_kv % bn == 0,
        "paged decode shape (heads {}, max_kv {}) not tileable by {}x{}",
        heads,
        max_kv,
        bh,
        bn
    );
    let scale = 1.0f64 / (head_dim as f64).sqrt() * std::f64::consts::LOG2_E;

    let name = if eps.is_empty() {
        "flash_decode_paged"
    } else {
        "flash_decode_paged_ep"
    };
    let mut t = KernelBuilder::new(name, cfg.threads);
    let q = t.param("Q", &[batch, heads, d], DType::F16);
    let k = t.param("K", &[batch, max_kv, d], DType::F16);
    let v = t.param("V", &[batch, max_kv, d], DType::F16);
    // per-stream committed cache rows; f32 holds lengths < 2^24 exactly
    let lens = t.param("Lens", &[batch], DType::F32);
    let ep_params = declare_epilogue_params_rank3(&mut t, eps, [batch, heads, d]);
    let o = t.param("O", &[batch, heads, d], DType::F16);
    let (bx, by) = t.kernel2(batch, heads / bh);
    t.use_swizzle(8);

    let q_s = t.alloc_shared("Q_shared", &[bh, d], DType::F16);
    let k_s = t.alloc_shared("K_shared", &[bn, d], DType::F16);
    let v_s = t.alloc_shared("V_shared", &[bn, d], DType::F16);
    let s_s = t.alloc_shared("S_shared", &[bh, bn], DType::F16);
    let acc_s = t.alloc_fragment("acc_s", &[bh, bn], DType::F32);
    let acc_o = t.alloc_fragment("acc_o", &[bh, d], DType::F32);
    let m_prev = t.alloc_fragment("scores_max_prev", &[bh], DType::F32);
    let m_cur = t.alloc_fragment("scores_max", &[bh], DType::F32);
    let r_scale = t.alloc_fragment("scores_scale", &[bh], DType::F32);
    let r_sum = t.alloc_fragment("scores_sum", &[bh], DType::F32);
    let logsum = t.alloc_fragment("logsum", &[bh], DType::F32);

    t.copy_in(q, vec![bx.expr(), by.expr() * bh, Expr::int(0)], q_s);
    t.fill(acc_o, 0.0);
    t.fill(logsum, 0.0);
    t.fill(m_cur, f64::NEG_INFINITY);

    t.pipelined(Expr::int(max_kv / bn), cfg.num_stages, |t, ko| {
        t.copy_in(k, vec![bx.expr(), ko.expr() * bn, Expr::int(0)], k_s);
        t.copy_in(v, vec![bx.expr(), ko.expr() * bn, Expr::int(0)], v_s);
        t.clear(acc_s);
        t.gemm_opts(q_s, k_s, acc_s, false, true, GemmWarpPolicy::FullCol);
        // the paged-gather mask: global cache position ko*bn + j is a
        // real committed row only below this stream's length
        let (ko_e, bx_e) = (ko.expr(), bx.expr());
        t.parallel(&[bh, bn], move |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                acc_s,
                vec![i.expr(), j.expr()],
                Expr::select(
                    (ko_e * bn + j.expr()).lt(Expr::load(lens, vec![bx_e])),
                    Expr::load(acc_s, vec![i.expr(), j.expr()]),
                    Expr::float(-1e30),
                ),
            )]
        });
        t.copy(m_cur, m_prev);
        t.reduce(acc_s, m_cur, 1, ReduceKind::Max, false);
        t.parallel(&[bh], |vrs| {
            let i = &vrs[0];
            vec![store(
                r_scale,
                vec![i.expr()],
                Expr::un(
                    UnOp::Exp2,
                    Expr::load(m_prev, vec![i.expr()]) * scale
                        - Expr::load(m_cur, vec![i.expr()]) * scale,
                ),
            )]
        });
        t.parallel(&[bh, bn], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                acc_s,
                vec![i.expr(), j.expr()],
                Expr::un(
                    UnOp::Exp2,
                    Expr::load(acc_s, vec![i.expr(), j.expr()]) * scale
                        - Expr::load(m_cur, vec![i.expr()]) * scale,
                ),
            )]
        });
        t.reduce(acc_s, r_sum, 1, ReduceKind::Sum, true);
        t.parallel(&[bh], |vrs| {
            let i = &vrs[0];
            vec![store(
                logsum,
                vec![i.expr()],
                Expr::load(logsum, vec![i.expr()]) * Expr::load(r_scale, vec![i.expr()])
                    + Expr::load(r_sum, vec![i.expr()]),
            )]
        });
        t.parallel(&[bh, d], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                acc_o,
                vec![i.expr(), j.expr()],
                Expr::load(acc_o, vec![i.expr(), j.expr()])
                    * Expr::load(r_scale, vec![i.expr()]),
            )]
        });
        t.copy(acc_s, s_s);
        t.gemm_opts(s_s, v_s, acc_o, false, false, GemmWarpPolicy::FullCol);
    });
    t.parallel(&[bh, d], |vrs| {
        let (i, j) = (&vrs[0], &vrs[1]);
        vec![store(
            acc_o,
            vec![i.expr(), j.expr()],
            Expr::load(acc_o, vec![i.expr(), j.expr()])
                * Expr::float(1.0).floordiv_f(Expr::load(logsum, vec![i.expr()])),
        )]
    });
    emit_epilogues_rank3(
        &mut t,
        eps,
        &ep_params,
        acc_o,
        [bh, d],
        &[bx.expr(), by.expr() * bh, Expr::int(0)],
    );
    t.copy_out(acc_o, o, vec![bx.expr(), by.expr() * bh, Expr::int(0)]);
    t.finish()
}

/// MLA decode kernel (Fig. 18): queries `[b, h, dim]` + rope part
/// `[b, h, pe]`, compressed KV `[b, s_kv, dim]` + `K_pe [b, s_kv, pe]`,
/// output `[b, h, dim]`. One block handles `block_h` heads of one batch
/// element. `kv_head_num = 1` (MQA-style shared KV), as in the paper.
#[allow(clippy::too_many_arguments)]
pub fn mla_program(
    batch: i64,
    heads: i64,
    seqlen_kv: i64,
    dim: i64,
    pe_dim: i64,
    block_h: i64,
    block_n: i64,
    num_stages: usize,
) -> TileProgram {
    mla_program_opts(batch, heads, seqlen_kv, dim, pe_dim, block_h, block_n, num_stages, true)
}

/// `mla_program` with the O-staging knob: `stage_output = false` writes
/// the accumulator straight to global, saving `block_h * dim` shared
/// bytes (needed to fit MI300X's 64KB LDS with a pipelined KV loop).
#[allow(clippy::too_many_arguments)]
pub fn mla_program_opts(
    batch: i64,
    heads: i64,
    seqlen_kv: i64,
    dim: i64,
    pe_dim: i64,
    block_h: i64,
    block_n: i64,
    num_stages: usize,
    stage_output: bool,
) -> TileProgram {
    let scale = 1.0f64 / ((dim + pe_dim) as f64).sqrt() * std::f64::consts::LOG2_E;
    let threads = 128;
    let mut t = KernelBuilder::new("flash_mla", threads);
    let q = t.param("Q", &[batch, heads, dim], DType::F16);
    let q_pe = t.param("Q_pe", &[batch, heads, pe_dim], DType::F16);
    let kv = t.param("KV", &[batch, seqlen_kv, dim], DType::F16);
    let k_pe = t.param("K_pe", &[batch, seqlen_kv, pe_dim], DType::F16);
    let out = t.param("Output", &[batch, heads, dim], DType::F16);
    let (bx, by) = t.kernel2(batch, heads / block_h);
    t.use_swizzle(10);

    let q_s = t.alloc_shared("Q_shared", &[block_h, dim], DType::F16);
    let qpe_s = t.alloc_shared("Q_pe_shared", &[block_h, pe_dim], DType::F16);
    let kv_s = t.alloc_shared("KV_shared", &[block_n, dim], DType::F16);
    let kpe_s = t.alloc_shared("K_pe_shared", &[block_n, pe_dim], DType::F16);
    let s_s = t.alloc_shared("S_shared", &[block_h, block_n], DType::F16);
    let o_s = if stage_output {
        Some(t.alloc_shared("O_shared", &[block_h, dim], DType::F16))
    } else {
        None
    };
    let acc_s = t.alloc_fragment("acc_s", &[block_h, block_n], DType::F32);
    let acc_o = t.alloc_fragment("acc_o", &[block_h, dim], DType::F32);
    let m_prev = t.alloc_fragment("scores_max_prev", &[block_h], DType::F32);
    let m_cur = t.alloc_fragment("scores_max", &[block_h], DType::F32);
    let r_scale = t.alloc_fragment("scores_scale", &[block_h], DType::F32);
    let r_sum = t.alloc_fragment("scores_sum", &[block_h], DType::F32);
    let logsum = t.alloc_fragment("logsum", &[block_h], DType::F32);

    t.copy_in(q, vec![bx.expr(), by.expr() * block_h, Expr::int(0)], q_s);
    t.copy_in(q_pe, vec![bx.expr(), by.expr() * block_h, Expr::int(0)], qpe_s);
    t.fill(acc_o, 0.0);
    t.fill(logsum, 0.0);
    t.fill(m_cur, f64::NEG_INFINITY);

    let loop_range = seqlen_kv / block_n;
    t.pipelined(loop_range, num_stages, |t, ko| {
        t.copy_in(kv, vec![bx.expr(), ko.expr() * block_n, Expr::int(0)], kv_s);
        t.copy_in(k_pe, vec![bx.expr(), ko.expr() * block_n, Expr::int(0)], kpe_s);
        t.clear(acc_s);
        t.gemm_opts(q_s, kv_s, acc_s, false, true, GemmWarpPolicy::FullCol);
        t.gemm_opts(qpe_s, kpe_s, acc_s, false, true, GemmWarpPolicy::FullCol);
        t.copy(m_cur, m_prev);
        t.reduce(acc_s, m_cur, 1, ReduceKind::Max, false);
        t.parallel(&[block_h], |vrs| {
            let i = &vrs[0];
            vec![store(
                r_scale,
                vec![i.expr()],
                Expr::un(
                    UnOp::Exp2,
                    Expr::load(m_prev, vec![i.expr()]) * scale
                        - Expr::load(m_cur, vec![i.expr()]) * scale,
                ),
            )]
        });
        t.parallel(&[block_h, block_n], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                acc_s,
                vec![i.expr(), j.expr()],
                Expr::un(
                    UnOp::Exp2,
                    Expr::load(acc_s, vec![i.expr(), j.expr()]) * scale
                        - Expr::load(m_cur, vec![i.expr()]) * scale,
                ),
            )]
        });
        t.reduce(acc_s, r_sum, 1, ReduceKind::Sum, true);
        t.copy(acc_s, s_s);
        t.parallel(&[block_h], |vrs| {
            let i = &vrs[0];
            vec![store(
                logsum,
                vec![i.expr()],
                Expr::load(logsum, vec![i.expr()]) * Expr::load(r_scale, vec![i.expr()])
                    + Expr::load(r_sum, vec![i.expr()]),
            )]
        });
        t.parallel(&[block_h, dim], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                acc_o,
                vec![i.expr(), j.expr()],
                Expr::load(acc_o, vec![i.expr(), j.expr()])
                    * Expr::load(r_scale, vec![i.expr()]),
            )]
        });
        t.gemm_opts(s_s, kv_s, acc_o, false, false, GemmWarpPolicy::FullCol);
    });
    t.parallel(&[block_h, dim], |vrs| {
        let (i, j) = (&vrs[0], &vrs[1]);
        vec![store(
            acc_o,
            vec![i.expr(), j.expr()],
            Expr::load(acc_o, vec![i.expr(), j.expr()])
                * Expr::float(1.0).floordiv_f(Expr::load(logsum, vec![i.expr()])),
        )]
    });
    if let Some(o_s) = o_s {
        t.copy(acc_o, o_s);
        t.copy_out(o_s, out, vec![bx.expr(), by.expr() * block_h, Expr::int(0)]);
    } else {
        t.copy_out(acc_o, out, vec![bx.expr(), by.expr() * block_h, Expr::int(0)]);
    }
    t.finish()
}

impl TunableConfig for AttnConfig {
    fn to_json(&self) -> Json {
        let specialize = match self.specialize {
            None => "auto",
            Some(true) => "on",
            Some(false) => "off",
        };
        Json::Obj(vec![
            ("block_m".into(), Json::Num(self.block_m as f64)),
            ("block_n".into(), Json::Num(self.block_n as f64)),
            ("num_stages".into(), Json::Num(self.num_stages as f64)),
            ("threads".into(), Json::Num(self.threads as f64)),
            ("specialize".into(), Json::Str(specialize.into())),
        ])
    }

    fn from_json(v: &Json) -> Option<AttnConfig> {
        // pre-specialization cache entries have no "specialize" key:
        // decode as `None` (the architecture default) so old tune_cache
        // files keep hitting
        let specialize = match v.get("specialize").and_then(|s| s.as_str()) {
            Some("on") => Some(true),
            Some("off") => Some(false),
            _ => None,
        };
        Some(AttnConfig {
            block_m: v.get("block_m")?.as_i64()?,
            block_n: v.get("block_n")?.as_i64()?,
            num_stages: v.get("num_stages")?.as_i64()?.max(1) as usize,
            threads: v.get("threads")?.as_i64()?,
            specialize,
        })
    }
}

/// FlashAttention tuning problem over one Table 3 shape.
#[derive(Clone, Copy, Debug)]
pub struct AttentionTunable {
    pub shape: AttnShape,
}

impl Tunable for AttentionTunable {
    type Config = AttnConfig;

    fn workload(&self) -> &'static str {
        "flash_attention"
    }

    fn shape_key(&self) -> Vec<i64> {
        let s = &self.shape;
        vec![s.batch, s.heads, s.seq_len, s.head_dim, s.causal as i64]
    }

    fn dtype_key(&self) -> String {
        DType::F16.to_string()
    }

    fn accepts(&self, cfg: &AttnConfig) -> bool {
        cfg.block_m > 0
            && cfg.block_n > 0
            && cfg.threads % 32 == 0
            && cfg.threads > 0
            && self.shape.seq_len % cfg.block_m == 0
            && self.shape.seq_len % cfg.block_n == 0
            // register pressure: the score + output accumulator tiles
            // must fit the per-thread register file, or the candidate
            // spills and the model would mis-rank it (see
            // sim::model::MAX_REGS_PER_THREAD)
            && cfg.block_m * (cfg.block_n + self.shape.head_dim) / cfg.threads
                <= crate::sim::model::MAX_REGS_PER_THREAD
    }

    fn candidates(&self) -> Vec<AttnConfig> {
        let mut out = Vec::new();
        for bm in [32i64, 64, 128] {
            for bn in [32i64, 64, 128] {
                for stages in [2usize, 3] {
                    // thread count is part of the space: short sequences
                    // on small blocks keep 128, saturated shapes can use
                    // a second warp-group (the IR supports any multiple
                    // of the warp size)
                    for threads in [128i64, 256] {
                        // both specialization settings are candidates
                        // (unspecialized first, so ties break to it)
                        for sp in [Some(false), Some(true)] {
                            let cfg = AttnConfig {
                                block_m: bm,
                                block_n: bn,
                                num_stages: stages,
                                threads,
                                specialize: sp,
                            };
                            if self.accepts(&cfg) {
                                out.push(cfg);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    fn build(&self, cfg: &AttnConfig) -> TileProgram {
        let s = &self.shape;
        flash_attention_program(s.batch * s.heads, s.seq_len, s.head_dim, s.causal, cfg)
    }
}

impl TunableConfig for DecodeConfig {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("block_h".into(), Json::Num(self.block_h as f64)),
            ("block_n".into(), Json::Num(self.block_n as f64)),
            ("num_stages".into(), Json::Num(self.num_stages as f64)),
            ("threads".into(), Json::Num(self.threads as f64)),
        ])
    }

    fn from_json(v: &Json) -> Option<DecodeConfig> {
        Some(DecodeConfig {
            block_h: v.get("block_h")?.as_i64()?,
            block_n: v.get("block_n")?.as_i64()?,
            num_stages: v.get("num_stages")?.as_i64()?.max(1) as usize,
            threads: v.get("threads")?.as_i64()?,
        })
    }
}

/// Flash-decode tuning problem: one query per (stream, head) against a
/// per-stream KV cache.
#[derive(Clone, Copy, Debug)]
pub struct DecodeTunable {
    pub batch: i64,
    pub heads: i64,
    pub seqlen_kv: i64,
    pub head_dim: i64,
}

impl Tunable for DecodeTunable {
    type Config = DecodeConfig;

    fn workload(&self) -> &'static str {
        "flash_decode"
    }

    fn shape_key(&self) -> Vec<i64> {
        vec![self.batch, self.heads, self.seqlen_kv, self.head_dim]
    }

    fn dtype_key(&self) -> String {
        DType::F16.to_string()
    }

    /// The feasibility contract the sharding planners rely on: head
    /// tiles are whole 16-row MMA warp tiles, so fewer than 16 heads
    /// (e.g. an over-split head-parallel shard) is rejected here rather
    /// than producing an infeasible program downstream.
    fn accepts(&self, cfg: &DecodeConfig) -> bool {
        cfg.block_h >= 16
            && cfg.block_h % 16 == 0
            && cfg.block_n >= 16
            && cfg.block_n % 16 == 0
            && cfg.threads > 0
            && cfg.threads % 32 == 0
            && self.heads % cfg.block_h == 0
            && self.seqlen_kv % cfg.block_n == 0
            && self.head_dim % 16 == 0
    }

    fn candidates(&self) -> Vec<DecodeConfig> {
        let mut out = Vec::new();
        for bh in [16i64, 32, 64] {
            for bn in [16i64, 32, 64] {
                for stages in [1usize, 2] {
                    for threads in [32i64, 64] {
                        let cfg = DecodeConfig {
                            block_h: bh,
                            block_n: bn,
                            num_stages: stages,
                            threads,
                        };
                        if self.accepts(&cfg) {
                            out.push(cfg);
                        }
                    }
                }
            }
        }
        out
    }

    fn build(&self, cfg: &DecodeConfig) -> TileProgram {
        flash_decode_program(self.batch, self.heads, self.seqlen_kv, self.head_dim, cfg, &[])
    }
}

/// MLA decode tile configuration (Fig. 14 knobs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MlaConfig {
    pub block_h: i64,
    pub block_n: i64,
    pub num_stages: usize,
    /// Stage the output tile through shared memory before the final
    /// copy-out (saves global traffic; costs `block_h * dim` smem bytes).
    pub stage_output: bool,
}

impl TunableConfig for MlaConfig {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("block_h".into(), Json::Num(self.block_h as f64)),
            ("block_n".into(), Json::Num(self.block_n as f64)),
            ("num_stages".into(), Json::Num(self.num_stages as f64)),
            ("stage_output".into(), Json::Bool(self.stage_output)),
        ])
    }

    fn from_json(v: &Json) -> Option<MlaConfig> {
        Some(MlaConfig {
            block_h: v.get("block_h")?.as_i64()?,
            block_n: v.get("block_n")?.as_i64()?,
            num_stages: v.get("num_stages")?.as_i64()?.max(1) as usize,
            stage_output: v.get("stage_output")?.as_bool()?,
        })
    }
}

/// MLA decode tuning problem (Fig. 14 geometry). Device feasibility
/// (e.g. MI300X's 64KB LDS rejecting wide double-buffered tiles) is
/// discovered by compilation — infeasible candidates are skipped, so
/// the same space adapts per device, which is exactly the paper's
/// H100-vs-MI300X configuration split.
#[derive(Clone, Copy, Debug)]
pub struct MlaTunable {
    pub shape: MlaShape,
}

impl Tunable for MlaTunable {
    type Config = MlaConfig;

    fn workload(&self) -> &'static str {
        "mla_decode"
    }

    fn shape_key(&self) -> Vec<i64> {
        let s = &self.shape;
        vec![s.batch, s.heads, s.seqlen_kv, s.dim, s.pe_dim]
    }

    fn dtype_key(&self) -> String {
        DType::F16.to_string()
    }

    fn accepts(&self, cfg: &MlaConfig) -> bool {
        cfg.block_h > 0
            && cfg.block_n > 0
            && self.shape.heads % cfg.block_h == 0
            && self.shape.seqlen_kv % cfg.block_n == 0
    }

    fn candidates(&self) -> Vec<MlaConfig> {
        let mut out = Vec::new();
        for block_h in [16i64, 32, 64] {
            for block_n in [16i64, 32, 64] {
                for stages in [1usize, 2] {
                    for stage_output in [true, false] {
                        let cfg = MlaConfig {
                            block_h,
                            block_n,
                            num_stages: stages,
                            stage_output,
                        };
                        if self.accepts(&cfg) {
                            out.push(cfg);
                        }
                    }
                }
            }
        }
        out
    }

    fn build(&self, cfg: &MlaConfig) -> TileProgram {
        let s = &self.shape;
        mla_program_opts(
            s.batch,
            s.heads,
            s.seqlen_kv,
            s.dim,
            s.pe_dim,
            cfg.block_h,
            cfg.block_n,
            cfg.num_stages,
            cfg.stage_output,
        )
    }
}

/// Reference attention in f32 (supports causal masking).
pub fn reference_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bh: i64,
    seq: i64,
    d: i64,
    causal: bool,
) -> Vec<f32> {
    let (s, du) = (seq as usize, d as usize);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0f32; (bh * seq * d) as usize];
    for b in 0..bh as usize {
        let qb = &q[b * s * du..(b + 1) * s * du];
        let kb = &k[b * s * du..(b + 1) * s * du];
        let vb = &v[b * s * du..(b + 1) * s * du];
        for i in 0..s {
            let jmax = if causal { i + 1 } else { s };
            let mut scores = vec![0f32; jmax];
            let mut mx = f32::NEG_INFINITY;
            for (j, sc) in scores.iter_mut().enumerate() {
                let mut acc = 0f32;
                for x in 0..du {
                    acc += qb[i * du + x] * kb[j * du + x];
                }
                *sc = acc * scale;
                mx = mx.max(*sc);
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            for x in 0..du {
                let mut acc = 0f32;
                for (j, sc) in scores.iter().enumerate() {
                    acc += sc * vb[j * du + x];
                }
                out[b * s * du + i * du + x] = acc / denom;
            }
        }
    }
    out
}

/// Reference flash decode in f32: softmax over the full cache per
/// (stream, head); every head of a stream shares that stream's cache.
#[allow(clippy::too_many_arguments)]
pub fn reference_flash_decode(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    batch: i64,
    heads: i64,
    s_kv: i64,
    d: i64,
) -> Vec<f32> {
    let (b_, h_, s_, d_) = (batch as usize, heads as usize, s_kv as usize, d as usize);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0f32; b_ * h_ * d_];
    for b in 0..b_ {
        let kb = &k[b * s_ * d_..(b + 1) * s_ * d_];
        let vb = &v[b * s_ * d_..(b + 1) * s_ * d_];
        for h in 0..h_ {
            let qo = (b * h_ + h) * d_;
            let mut scores = vec![0f32; s_];
            let mut mx = f32::NEG_INFINITY;
            for (j, sc) in scores.iter_mut().enumerate() {
                let mut acc = 0f32;
                for x in 0..d_ {
                    acc += q[qo + x] * kb[j * d_ + x];
                }
                *sc = acc * scale;
                mx = mx.max(*sc);
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            for x in 0..d_ {
                let mut acc = 0f32;
                for (j, sc) in scores.iter().enumerate() {
                    acc += sc * vb[j * d_ + x];
                }
                out[qo + x] = acc / denom;
            }
        }
    }
    out
}

/// Reference for the paged decode kernel: per-stream softmax over the
/// first `lens[b]` cache positions only (positions beyond a stream's
/// committed length do not exist, whatever `max_kv` the co-batch padded
/// to). A zero-length stream (dead co-batch slot) outputs zeros.
#[allow(clippy::too_many_arguments)]
pub fn reference_flash_decode_paged(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    lens: &[f32],
    batch: i64,
    heads: i64,
    max_kv: i64,
    d: i64,
) -> Vec<f32> {
    let (b_, h_, s_, d_) = (batch as usize, heads as usize, max_kv as usize, d as usize);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0f32; b_ * h_ * d_];
    for b in 0..b_ {
        let len = (lens[b].max(0.0) as usize).min(s_);
        if len == 0 {
            continue;
        }
        let kb = &k[b * s_ * d_..(b + 1) * s_ * d_];
        let vb = &v[b * s_ * d_..(b + 1) * s_ * d_];
        for h in 0..h_ {
            let qo = (b * h_ + h) * d_;
            let mut scores = vec![0f32; len];
            let mut mx = f32::NEG_INFINITY;
            for (j, sc) in scores.iter_mut().enumerate() {
                let mut acc = 0f32;
                for x in 0..d_ {
                    acc += q[qo + x] * kb[j * d_ + x];
                }
                *sc = acc * scale;
                mx = mx.max(*sc);
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            for x in 0..d_ {
                let mut acc = 0f32;
                for (j, sc) in scores.iter().enumerate() {
                    acc += sc * vb[j * d_ + x];
                }
                out[qo + x] = acc / denom;
            }
        }
    }
    out
}

/// Reference MLA decode in f32.
#[allow(clippy::too_many_arguments)]
pub fn reference_mla(
    q: &[f32],
    q_pe: &[f32],
    kv: &[f32],
    k_pe: &[f32],
    batch: i64,
    heads: i64,
    s_kv: i64,
    dim: i64,
    pe: i64,
) -> Vec<f32> {
    let (b_, h_, s_, d_, p_) = (
        batch as usize,
        heads as usize,
        s_kv as usize,
        dim as usize,
        pe as usize,
    );
    let scale = 1.0 / ((dim + pe) as f32).sqrt();
    let mut out = vec![0f32; b_ * h_ * d_];
    for b in 0..b_ {
        for h in 0..h_ {
            let qo = (b * h_ + h) * d_;
            let qpo = (b * h_ + h) * p_;
            let mut scores = vec![0f32; s_];
            let mut mx = f32::NEG_INFINITY;
            for (j, sc) in scores.iter_mut().enumerate() {
                let mut acc = 0f32;
                for x in 0..d_ {
                    acc += q[qo + x] * kv[(b * s_ + j) * d_ + x];
                }
                for x in 0..p_ {
                    acc += q_pe[qpo + x] * k_pe[(b * s_ + j) * p_ + x];
                }
                *sc = acc * scale;
                mx = mx.max(*sc);
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            for x in 0..d_ {
                let mut acc = 0f32;
                for (j, sc) in scores.iter().enumerate() {
                    acc += sc * kv[(b * s_ + j) * d_ + x];
                }
                out[qo + x] = acc / denom;
            }
        }
    }
    out
}

pub trait ExprDivExt {
    fn floordiv_f(self, rhs: Expr) -> Expr;
}
impl ExprDivExt for Expr {
    /// Float division in value expressions (FloorDiv evaluates as x/y
    /// floored in int context; in the f32 evaluator we want true division
    /// — use mul by reciprocal via Select-free path).
    fn floordiv_f(self, rhs: Expr) -> Expr {
        // value evaluator maps FloorDiv to (x/y).floor(); for softmax
        // normalization we need true division: x * y^-1 via exp/log is
        // overkill — add a dedicated path: x / y == x * exp(-ln(y)) only
        // for y > 0. logsum > 0 always holds post-softmax.
        self * Expr::un(UnOp::Exp, Expr::un(UnOp::Neg, Expr::un(UnOp::Log, rhs)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::lower::{compile, CompileOptions};
    use crate::sim::device::Device;
    use crate::tir::interp::{Interp, Tensors};
    use crate::workloads::matmul::test_data;

    #[test]
    fn flash_attention_matches_reference() {
        let (bh, s, d) = (2i64, 128i64, 64i64);
        for causal in [false, true] {
            let cfg = AttnConfig {
                block_m: 32,
                block_n: 32,
                num_stages: 2,
                threads: 128,
                specialize: None,
            };
            let p = flash_attention_program(bh, s, d, causal, &cfg);
            let l = compile(&p, &Device::h100(), &CompileOptions::default()).unwrap();
            let interp = Interp::new(&l).unwrap();
            let q = test_data(bh * s * d, 11);
            let k = test_data(bh * s * d, 12);
            let v = test_data(bh * s * d, 13);
            let mut t = Tensors::new();
            t.insert(p.params[0].id, q.clone());
            t.insert(p.params[1].id, k.clone());
            t.insert(p.params[2].id, v.clone());
            interp.run(&mut t).unwrap();
            let want = reference_attention(&q, &k, &v, bh, s, d, causal);
            let got = &t[&p.params[3].id];
            let mut max_err = 0f32;
            for (g, w) in got.iter().zip(&want) {
                max_err = max_err.max((g - w).abs());
            }
            assert!(
                max_err < 0.02,
                "causal={} max attention error {}",
                causal,
                max_err
            );
        }
    }

    #[test]
    fn flash_attention_o_epilogue_matches_reference() {
        use crate::workloads::epilogue::{reference_apply, EpilogueOp};
        let (bh, s, d) = (2i64, 128i64, 64i64);
        let cfg = AttnConfig {
            block_m: 32,
            block_n: 32,
            num_stages: 2,
            threads: 128,
            specialize: None,
        };
        let eps = [EpilogueOp::ResidualAdd];
        let p = flash_attention_program_ep(bh, s, d, false, &cfg, &eps);
        assert_eq!(p.params.len(), 5); // Q, K, V, residual, O
        let l = compile(&p, &Device::h100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let q = test_data(bh * s * d, 51);
        let k = test_data(bh * s * d, 52);
        let v = test_data(bh * s * d, 53);
        let res = test_data(bh * s * d, 54);
        let mut t = Tensors::new();
        t.insert(p.params[0].id, q.clone());
        t.insert(p.params[1].id, k.clone());
        t.insert(p.params[2].id, v.clone());
        t.insert(p.params[3].id, res.clone());
        interp.run(&mut t).unwrap();
        let mut want = reference_attention(&q, &k, &v, bh, s, d, false);
        reference_apply(&eps[0], &mut want, Some(&res), &[bh, s, d]).unwrap();
        let got = &t[&p.params[4].id];
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 0.02 + 0.02 * w.abs(), "{} vs {}", g, w);
        }
    }

    #[test]
    fn flash_decode_matches_reference() {
        let (b, h, skv, d) = (2i64, 16i64, 64i64, 16i64);
        let cfg = DecodeConfig {
            block_h: 16,
            block_n: 32,
            num_stages: 2,
            threads: 64,
        };
        let p = flash_decode_program(b, h, skv, d, &cfg, &[]);
        let l = compile(&p, &Device::h100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let q = test_data(b * h * d, 31);
        let k = test_data(b * skv * d, 32);
        let v = test_data(b * skv * d, 33);
        let mut t = Tensors::new();
        t.insert(p.params[0].id, q.clone());
        t.insert(p.params[1].id, k.clone());
        t.insert(p.params[2].id, v.clone());
        interp.run(&mut t).unwrap();
        let want = reference_flash_decode(&q, &k, &v, b, h, skv, d);
        let got = &t[&p.params[3].id];
        let mut max_err = 0f32;
        for (g, w) in got.iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(max_err < 0.02, "flash decode max error {}", max_err);
    }

    #[test]
    fn flash_decode_o_epilogues_match_reference() {
        use crate::workloads::epilogue::{reference_apply, EpilogueOp};
        let (b, h, skv, d) = (2i64, 16i64, 64i64, 16i64);
        let cfg = DecodeConfig {
            block_h: 16,
            block_n: 32,
            num_stages: 2,
            threads: 64,
        };
        // residual into the O epilogue + a scale behind it
        let eps = [EpilogueOp::ResidualAdd, EpilogueOp::Scale(0.5)];
        let p = flash_decode_program(b, h, skv, d, &cfg, &eps);
        // Q, K, V, residual, O — epilogue operands precede the output
        assert_eq!(p.params.len(), 5);
        let l = compile(&p, &Device::h100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let q = test_data(b * h * d, 41);
        let k = test_data(b * skv * d, 42);
        let v = test_data(b * skv * d, 43);
        let res = test_data(b * h * d, 44);
        let mut t = Tensors::new();
        t.insert(p.params[0].id, q.clone());
        t.insert(p.params[1].id, k.clone());
        t.insert(p.params[2].id, v.clone());
        t.insert(p.params[3].id, res.clone());
        interp.run(&mut t).unwrap();
        let mut want = reference_flash_decode(&q, &k, &v, b, h, skv, d);
        reference_apply(&eps[0], &mut want, Some(&res), &[b, h, d]).unwrap();
        reference_apply(&eps[1], &mut want, None, &[b, h, d]).unwrap();
        let got = &t[&p.params[4].id];
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 0.02 + 0.02 * w.abs(), "{} vs {}", g, w);
        }
    }

    #[test]
    fn decode_tunable_rejects_sub_tile_heads() {
        // the head-parallel infeasibility audit: fewer than 16 heads can
        // never hold a 16-row MMA warp tile, so no candidate exists and
        // the static default is rejected by accepts()
        let t = DecodeTunable {
            batch: 4,
            heads: 8,
            seqlen_kv: 64,
            head_dim: 16,
        };
        assert!(t.candidates().is_empty());
        assert!(!t.accepts(&DecodeConfig::default_for(8, 64)));
        // 16 heads is the floor and works
        let t = DecodeTunable {
            batch: 4,
            heads: 16,
            seqlen_kv: 64,
            head_dim: 16,
        };
        assert!(!t.candidates().is_empty());
        assert!(t.accepts(&DecodeConfig::default_for(16, 64)));
    }

    #[test]
    fn mla_matches_reference() {
        let (b, h, skv, dim, pe) = (1i64, 16i64, 128i64, 64i64, 32i64);
        let p = mla_program(b, h, skv, dim, pe, 16, 32, 2);
        let l = compile(&p, &Device::h100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let q = test_data(b * h * dim, 21);
        let qpe = test_data(b * h * pe, 22);
        let kv = test_data(b * skv * dim, 23);
        let kpe = test_data(b * skv * pe, 24);
        let mut t = Tensors::new();
        t.insert(p.params[0].id, q.clone());
        t.insert(p.params[1].id, qpe.clone());
        t.insert(p.params[2].id, kv.clone());
        t.insert(p.params[3].id, kpe.clone());
        interp.run(&mut t).unwrap();
        let want = reference_mla(&q, &qpe, &kv, &kpe, b, h, skv, dim, pe);
        let got = &t[&p.params[4].id];
        let mut max_err = 0f32;
        for (g, w) in got.iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(max_err < 0.02, "MLA max error {}", max_err);
    }

    #[test]
    fn frontend_loc_is_about_70_lines() {
        // Fig. 14: "Tilelang requires only around 70 lines of Python"
        let p = mla_program(64, 128, 512, 512, 64, 64, 64, 2);
        let loc = p.frontend_loc();
        assert!(
            (30..120).contains(&loc),
            "MLA frontend LOC should be paper-scale, got {}",
            loc
        );
    }

    /// Run the paged decode kernel on the interpreter.
    fn run_paged(
        b: i64,
        h: i64,
        max_kv: i64,
        d: i64,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        lens: &[f32],
    ) -> Vec<f32> {
        let cfg = DecodeConfig {
            block_h: 16,
            block_n: 16,
            num_stages: 2,
            threads: 64,
        };
        let p = flash_decode_paged_program(b, h, max_kv, d, &cfg, &[]);
        let l = compile(&p, &Device::h100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let mut t = Tensors::new();
        t.insert(p.params[0].id, q.to_vec());
        t.insert(p.params[1].id, k.to_vec());
        t.insert(p.params[2].id, v.to_vec());
        t.insert(p.params[3].id, lens.to_vec());
        interp.run(&mut t).unwrap();
        t[&p.params[4].id].clone()
    }

    #[test]
    fn flash_decode_paged_matches_masked_reference() {
        let (b, h, max_kv, d) = (2i64, 16i64, 64i64, 16i64);
        let q = test_data(b * h * d, 71);
        let k = test_data(b * max_kv * d, 72);
        let v = test_data(b * max_kv * d, 73);
        // stream 0 at a partial, unaligned length; stream 1 at full length
        let lens = vec![37.0f32, 64.0];
        let got = run_paged(b, h, max_kv, d, &q, &k, &v, &lens);
        let want = reference_flash_decode_paged(&q, &k, &v, &lens, b, h, max_kv, d);
        let mut max_err = 0f32;
        for (g, w) in got.iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(max_err < 0.02, "paged decode max error {}", max_err);
    }

    #[test]
    fn flash_decode_paged_at_full_length_equals_unmasked_kernel() {
        // lens == max_kv: the mask never fires, so the paged kernel must be
        // bit-identical to flash_decode on the same inputs and tile config
        let (b, h, max_kv, d) = (2i64, 16i64, 64i64, 16i64);
        let cfg = DecodeConfig {
            block_h: 16,
            block_n: 16,
            num_stages: 2,
            threads: 64,
        };
        let q = test_data(b * h * d, 81);
        let k = test_data(b * max_kv * d, 82);
        let v = test_data(b * max_kv * d, 83);
        let lens = vec![max_kv as f32; b as usize];
        let got = run_paged(b, h, max_kv, d, &q, &k, &v, &lens);

        let p = flash_decode_program(b, h, max_kv, d, &cfg, &[]);
        let l = compile(&p, &Device::h100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let mut t = Tensors::new();
        t.insert(p.params[0].id, q.clone());
        t.insert(p.params[1].id, k.clone());
        t.insert(p.params[2].id, v.clone());
        interp.run(&mut t).unwrap();
        assert_eq!(got, t[&p.params[3].id], "mask at full length must be a no-op");
    }

    #[test]
    fn flash_decode_paged_tail_padding_is_bit_exact() {
        // the serial-oracle property: padding a stream's cache view past its
        // committed length (fully masked trailing blocks) must not change
        // its output at all — same tile config, longer max_kv, same bits
        let (b, h, d) = (1i64, 16i64, 16i64);
        let len = 37usize;
        let q = test_data(b * h * d, 91);
        let rows_k = test_data(128 * d, 92);
        let rows_v = test_data(128 * d, 93);
        let build = |max_kv: usize| -> (Vec<f32>, Vec<f32>) {
            let mut k = vec![0f32; max_kv * d as usize];
            let mut v = vec![0f32; max_kv * d as usize];
            let n = d as usize * len;
            k[..n].copy_from_slice(&rows_k[..n]);
            v[..n].copy_from_slice(&rows_v[..n]);
            (k, v)
        };
        let (k48, v48) = build(48);
        let (k96, v96) = build(96);
        let lens = vec![len as f32];
        let short = run_paged(b, h, 48, d, &q, &k48, &v48, &lens);
        let long = run_paged(b, h, 96, d, &q, &k96, &v96, &lens);
        assert_eq!(short, long, "masked tail blocks must be exact no-ops");
    }

    #[test]
    fn flash_decode_paged_dead_slot_outputs_zero() {
        let (b, h, max_kv, d) = (2i64, 16i64, 32i64, 16i64);
        let q = test_data(b * h * d, 95);
        let k = test_data(b * max_kv * d, 96);
        let v = test_data(b * max_kv * d, 97);
        // stream 1 is a dead co-batch slot: no committed rows
        let lens = vec![32.0f32, 0.0];
        let got = run_paged(b, h, max_kv, d, &q, &k, &v, &lens);
        let per_stream = (h * d) as usize;
        assert!(
            got[per_stream..].iter().all(|&x| x == 0.0),
            "dead slot must decode to exact zeros, never NaN"
        );
        assert!(got[..per_stream].iter().any(|&x| x != 0.0));
    }
}
