//! Epilogue vocabulary for the tile-program builders: bias-add,
//! activation, residual-add and scale operators that fuse into a
//! kernel's accumulator tile before the final copy-out.
//!
//! The same enum describes (a) standalone element-wise nodes in a
//! `graph::ir::KernelGraph` and (b) the fused epilogue list a kernel
//! node carries after `graph::fuse` folds its consumers in. The
//! builder-side helpers stage epilogue operands global -> shared ->
//! fragment (the dequant idiom) and apply them in `T.Parallel` bodies on
//! the accumulator, so layout inference replicates operands across the
//! owning threads exactly as in the Fig. 7 bias example.
//!
//! [`reference_apply`] is the f32 CPU semantics used by goldens, the
//! differential tests and the unfused graph executor; the activation
//! expressions are built so the interpreter computes bit-identical math
//! (GELU uses the tanh approximation on both sides).

use crate::ir::builder::{store, KernelBuilder};
use crate::ir::buffer::BufferId;
use crate::ir::dtype::DType;
use crate::ir::expr::{Expr, UnOp};
use crate::util::json::Json;

/// GELU tanh-approximation constants (sqrt(2/pi) and the cubic term).
const GELU_C0: f64 = 0.797_884_560_802_865_4;
const GELU_C1: f64 = 0.044_715;

/// Element-wise nonlinearity applied to an accumulator tile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    /// tanh-approximated GELU: `0.5 x (1 + tanh(c0 (x + c1 x^3)))`.
    Gelu,
    /// SiLU via the exact tanh identity: `x * 0.5 * (1 + tanh(x/2))`.
    Silu,
}

impl Activation {
    /// Stable serialization tag.
    pub fn tag(self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Gelu => "gelu",
            Activation::Silu => "silu",
        }
    }

    /// Inverse of [`Activation::tag`].
    pub fn parse(tag: &str) -> Option<Activation> {
        match tag {
            "relu" => Some(Activation::Relu),
            "gelu" => Some(Activation::Gelu),
            "silu" => Some(Activation::Silu),
            _ => None,
        }
    }

    /// The on-chip element-wise expression (interpreter semantics).
    pub fn expr(self, x: Expr) -> Expr {
        match self {
            Activation::Relu => x.emax(Expr::float(0.0)),
            Activation::Gelu => {
                let cubic = x.clone() * x.clone() * x.clone() * Expr::float(GELU_C1);
                let inner = (x.clone() + cubic) * Expr::float(GELU_C0);
                Expr::float(0.5) * x * (Expr::float(1.0) + Expr::un(UnOp::Tanh, inner))
            }
            Activation::Silu => {
                Expr::float(0.5)
                    * x.clone()
                    * (Expr::float(1.0) + Expr::un(UnOp::Tanh, x * Expr::float(0.5)))
            }
        }
    }

    /// Scalar CPU reference. Must mirror [`Activation::expr`] exactly
    /// (same approximation, f32 arithmetic) so fused and reference
    /// executions agree to rounding, not to model error.
    pub fn reference(self, x: f32) -> f32 {
        match self {
            Activation::Relu => x.max(0.0),
            Activation::Gelu => {
                let cubic = x * x * x * GELU_C1 as f32;
                let inner = (x + cubic) * GELU_C0 as f32;
                0.5 * x * (1.0 + inner.tanh())
            }
            Activation::Silu => 0.5 * x * (1.0 + (x * 0.5).tanh()),
        }
    }
}

/// One epilogue operator. As a standalone graph node it transforms its
/// primary input; fused, it transforms a kernel's accumulator tile
/// in registers before the copy-out.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EpilogueOp {
    /// `out[i0, i1] += bias[i_dim]` — a rank-1 bias broadcast along the
    /// other output dimension. `dim` is the *output* dimension the bias
    /// indexes (1 for row-major GEMM features, 0 for the transposed
    /// dequant-GEMM output).
    BiasAdd { dim: usize },
    /// `out = act(out)`.
    Activation(Activation),
    /// `out += residual` (same shape as the output).
    ResidualAdd,
    /// `out *= factor` (compile-time constant; no operand tensor).
    Scale(f64),
}

impl EpilogueOp {
    /// Whether this op consumes an extra operand tensor.
    pub fn takes_operand(&self) -> bool {
        matches!(self, EpilogueOp::BiasAdd { .. } | EpilogueOp::ResidualAdd)
    }

    /// The operand tensor shape for a given output shape (`None` for
    /// operand-free ops).
    pub fn operand_shape(&self, out_shape: &[i64]) -> Option<Vec<i64>> {
        match self {
            EpilogueOp::BiasAdd { dim } => Some(vec![*out_shape.get(*dim)?]),
            EpilogueOp::ResidualAdd => Some(out_shape.to_vec()),
            _ => None,
        }
    }

    /// Short human tag for plans and logs.
    pub fn describe(&self) -> String {
        match self {
            EpilogueOp::BiasAdd { dim } => format!("bias_add[dim={}]", dim),
            EpilogueOp::Activation(a) => a.tag().to_string(),
            EpilogueOp::ResidualAdd => "residual_add".to_string(),
            EpilogueOp::Scale(f) => format!("scale({})", f),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            EpilogueOp::BiasAdd { dim } => Json::Obj(vec![
                ("op".into(), Json::Str("bias_add".into())),
                ("dim".into(), Json::Num(*dim as f64)),
            ]),
            EpilogueOp::Activation(a) => Json::Obj(vec![
                ("op".into(), Json::Str("activation".into())),
                ("act".into(), Json::Str(a.tag().into())),
            ]),
            EpilogueOp::ResidualAdd => {
                Json::Obj(vec![("op".into(), Json::Str("residual_add".into()))])
            }
            EpilogueOp::Scale(f) => Json::Obj(vec![
                ("op".into(), Json::Str("scale".into())),
                ("factor".into(), Json::Num(*f)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Option<EpilogueOp> {
        match v.get("op")?.as_str()? {
            "bias_add" => Some(EpilogueOp::BiasAdd {
                dim: v.get("dim")?.as_i64()? as usize,
            }),
            "activation" => Some(EpilogueOp::Activation(Activation::parse(
                v.get("act")?.as_str()?,
            )?)),
            "residual_add" => Some(EpilogueOp::ResidualAdd),
            "scale" => Some(EpilogueOp::Scale(v.get("factor")?.as_f64()?)),
            _ => None,
        }
    }
}

/// Declare the global parameters an epilogue list consumes, in epilogue
/// order. Call *after* the kernel's main operand params and *before* its
/// output param, so the program parameter list keeps the runtime
/// contract `inputs..., epilogue inputs..., output`. Returns one entry
/// per epilogue (`None` for operand-free ops).
pub fn declare_epilogue_params(
    t: &mut KernelBuilder,
    eps: &[EpilogueOp],
    out_shape: [i64; 2],
) -> Vec<Option<BufferId>> {
    eps.iter()
        .enumerate()
        .map(|(i, ep)| match ep {
            EpilogueOp::BiasAdd { dim } => {
                assert!(*dim < 2, "bias dim {} out of rank 2", dim);
                Some(t.param(&format!("Bias{}", i), &[out_shape[*dim]], DType::F32))
            }
            EpilogueOp::ResidualAdd => Some(t.param(
                &format!("Residual{}", i),
                &[out_shape[0], out_shape[1]],
                DType::F32,
            )),
            _ => None,
        })
        .collect()
}

/// Emit the epilogue ops on the accumulator fragment `acc`, which holds
/// the `[tile[0], tile[1]]` output tile at global offsets `off` (both in
/// *output* coordinates — for the transposed dequant output the tile is
/// `[block_n, block_m]` and `dim = 0` indexes its first axis). Operand
/// tiles stage global -> shared -> fragment, so layout inference
/// replicates them across the accumulator's owning threads.
pub fn emit_epilogues(
    t: &mut KernelBuilder,
    eps: &[EpilogueOp],
    params: &[Option<BufferId>],
    acc: BufferId,
    tile: [i64; 2],
    off: &[Expr; 2],
) {
    emit_epilogue_ops(t, eps, params, acc, tile, off)
}

/// The shared emitter behind [`emit_epilogues`] (rank-2 GEMM outputs)
/// and [`emit_epilogues_rank3`] (attention O tiles): the accumulator is
/// always a rank-2 `[tile[0], tile[1]]` fragment; `off` carries as many
/// global coordinates as the output tensor has dims (the trailing two
/// locate the tile). `BiasAdd` is rank-2-only — rank-3 callers are
/// filtered by `graph::fuse::admits` before any builder runs.
fn emit_epilogue_ops(
    t: &mut KernelBuilder,
    eps: &[EpilogueOp],
    params: &[Option<BufferId>],
    acc: BufferId,
    tile: [i64; 2],
    off: &[Expr],
) {
    for (i, ep) in eps.iter().enumerate() {
        match ep {
            EpilogueOp::BiasAdd { dim } => {
                assert!(
                    off.len() == 2 && *dim < 2,
                    "bias epilogues need a rank-2 output (admits() rejects rank-3 folds)"
                );
                let d = *dim;
                let bias = params[i].expect("bias param declared");
                let b_s =
                    t.alloc_shared(&format!("Bias{}_shared", i), &[tile[d]], DType::F32);
                let b_l =
                    t.alloc_fragment(&format!("Bias{}_local", i), &[tile[d]], DType::F32);
                t.copy_in(bias, vec![off[d].clone()], b_s);
                t.copy(b_s, b_l);
                t.parallel(&[tile[0], tile[1]], |v| {
                    let (pi, pj) = (&v[0], &v[1]);
                    let bidx = if d == 0 { pi.expr() } else { pj.expr() };
                    vec![store(
                        acc,
                        vec![pi.expr(), pj.expr()],
                        Expr::load(acc, vec![pi.expr(), pj.expr()])
                            + Expr::load(b_l, vec![bidx]),
                    )]
                });
            }
            EpilogueOp::ResidualAdd => {
                let res = params[i].expect("residual param declared");
                let r_s = t.alloc_shared(
                    &format!("Residual{}_shared", i),
                    &[tile[0], tile[1]],
                    DType::F32,
                );
                let r_l = t.alloc_fragment(
                    &format!("Residual{}_local", i),
                    &[tile[0], tile[1]],
                    DType::F32,
                );
                t.copy_in(res, off.to_vec(), r_s);
                t.copy(r_s, r_l);
                t.parallel(&[tile[0], tile[1]], |v| {
                    let (pi, pj) = (&v[0], &v[1]);
                    vec![store(
                        acc,
                        vec![pi.expr(), pj.expr()],
                        Expr::load(acc, vec![pi.expr(), pj.expr()])
                            + Expr::load(r_l, vec![pi.expr(), pj.expr()]),
                    )]
                });
            }
            EpilogueOp::Activation(a) => {
                let a = *a;
                t.parallel(&[tile[0], tile[1]], |v| {
                    let (pi, pj) = (&v[0], &v[1]);
                    vec![store(
                        acc,
                        vec![pi.expr(), pj.expr()],
                        a.expr(Expr::load(acc, vec![pi.expr(), pj.expr()])),
                    )]
                });
            }
            EpilogueOp::Scale(f) => {
                let f = *f;
                t.parallel(&[tile[0], tile[1]], |v| {
                    let (pi, pj) = (&v[0], &v[1]);
                    vec![store(
                        acc,
                        vec![pi.expr(), pj.expr()],
                        Expr::load(acc, vec![pi.expr(), pj.expr()]) * Expr::float(f),
                    )]
                });
            }
        }
    }
}

/// Declare the global parameters an epilogue list consumes for a
/// *rank-3* attention-family output `[bh, rows, d]` (flash attention
/// `[bh, seq, d]`, flash decode `[batch, heads, d]`). Only the
/// element-wise subset applies on rank-3 outputs: `ResidualAdd` takes a
/// full-shape operand, `Activation`/`Scale` take none, and `BiasAdd` is
/// structurally excluded (`graph::fuse::admits` rejects it before any
/// builder runs — there is no rank-2 feature dimension to broadcast
/// along). Same parameter-ordering contract as
/// [`declare_epilogue_params`]: call after the kernel operands, before
/// the output.
pub fn declare_epilogue_params_rank3(
    t: &mut KernelBuilder,
    eps: &[EpilogueOp],
    out_shape: [i64; 3],
) -> Vec<Option<BufferId>> {
    eps.iter()
        .enumerate()
        .map(|(i, ep)| match ep {
            EpilogueOp::ResidualAdd => Some(t.param(
                &format!("Residual{}", i),
                &[out_shape[0], out_shape[1], out_shape[2]],
                DType::F32,
            )),
            EpilogueOp::BiasAdd { .. } => {
                unreachable!("bias epilogues need a rank-2 output; admits() rejects this fold")
            }
            _ => None,
        })
        .collect()
}

/// Emit the epilogue ops on a rank-3 kernel's output accumulator `acc`
/// (`[tile[0], tile[1]]` — the attention O tile `[block_rows, d]`),
/// whose global position is `off` (three output-space coordinates, e.g.
/// `[bz, bx * block_m, 0]`). Residual operand tiles stage
/// global -> shared -> fragment exactly like the rank-2 path, so layout
/// inference replicates them across the accumulator's owning threads.
pub fn emit_epilogues_rank3(
    t: &mut KernelBuilder,
    eps: &[EpilogueOp],
    params: &[Option<BufferId>],
    acc: BufferId,
    tile: [i64; 2],
    off: &[Expr; 3],
) {
    emit_epilogue_ops(t, eps, params, acc, tile, off)
}

/// Apply one epilogue op to a row-major f32 tensor in place — the CPU
/// reference semantics (goldens, differential oracles) and the executor
/// of *unfused* element-wise graph nodes. `BiasAdd` requires rank 2.
pub fn reference_apply(
    op: &EpilogueOp,
    data: &mut [f32],
    operand: Option<&[f32]>,
    shape: &[i64],
) -> Result<(), String> {
    match op {
        EpilogueOp::BiasAdd { dim } => {
            if shape.len() != 2 {
                return Err(format!("bias_add needs a rank-2 tensor, got {:?}", shape));
            }
            if *dim >= 2 {
                return Err(format!("bias_add dim {} out of rank 2", dim));
            }
            let bias = operand.ok_or("bias_add needs an operand")?;
            let (r, c) = (shape[0] as usize, shape[1] as usize);
            if bias.len() != shape[*dim] as usize {
                return Err(format!(
                    "bias length {} != output dim {} ({})",
                    bias.len(),
                    dim,
                    shape[*dim]
                ));
            }
            for i in 0..r {
                for j in 0..c {
                    data[i * c + j] += bias[if *dim == 0 { i } else { j }];
                }
            }
        }
        EpilogueOp::Activation(a) => {
            for x in data.iter_mut() {
                *x = a.reference(*x);
            }
        }
        EpilogueOp::ResidualAdd => {
            let res = operand.ok_or("residual_add needs an operand")?;
            if res.len() != data.len() {
                return Err(format!(
                    "residual length {} != output length {}",
                    res.len(),
                    data.len()
                ));
            }
            for (x, r) in data.iter_mut().zip(res) {
                *x += r;
            }
        }
        EpilogueOp::Scale(f) => {
            let f = *f as f32;
            for x in data.iter_mut() {
                *x *= f;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activations_are_sane() {
        for a in [Activation::Relu, Activation::Gelu, Activation::Silu] {
            assert!(a.reference(0.0).abs() < 1e-6, "{:?}(0) != 0", a);
            // monotone-ish on the positive side, near-identity for large x
            assert!(a.reference(3.0) > 2.5, "{:?}(3) too small", a);
            assert_eq!(Activation::parse(a.tag()), Some(a));
        }
        assert_eq!(Activation::Relu.reference(-1.0), 0.0);
        assert!(Activation::Gelu.reference(-0.5) < 0.0);
        assert!(Activation::parse("wat").is_none());
    }

    #[test]
    fn epilogue_json_round_trips() {
        let ops = [
            EpilogueOp::BiasAdd { dim: 1 },
            EpilogueOp::BiasAdd { dim: 0 },
            EpilogueOp::Activation(Activation::Gelu),
            EpilogueOp::ResidualAdd,
            EpilogueOp::Scale(0.125),
        ];
        for op in ops {
            let j = op.to_json();
            let back = EpilogueOp::from_json(&j).expect("parse back");
            assert_eq!(back, op, "{}", j.dump());
        }
        assert!(EpilogueOp::from_json(&Json::parse("{\"op\":\"nope\"}").unwrap()).is_none());
    }

    #[test]
    fn reference_apply_bias_and_residual() {
        // [2, 3] tensor
        let mut d = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        reference_apply(
            &EpilogueOp::BiasAdd { dim: 1 },
            &mut d,
            Some(&[10.0, 20.0, 30.0]),
            &[2, 3],
        )
        .unwrap();
        assert_eq!(d, vec![11.0, 22.0, 33.0, 14.0, 25.0, 36.0]);
        reference_apply(
            &EpilogueOp::BiasAdd { dim: 0 },
            &mut d,
            Some(&[100.0, 200.0]),
            &[2, 3],
        )
        .unwrap();
        assert_eq!(d, vec![111.0, 122.0, 133.0, 214.0, 225.0, 236.0]);
        let res = vec![1.0f32; 6];
        reference_apply(&EpilogueOp::ResidualAdd, &mut d, Some(&res), &[2, 3]).unwrap();
        assert_eq!(d[0], 112.0);
        reference_apply(&EpilogueOp::Scale(2.0), &mut d, None, &[2, 3]).unwrap();
        assert_eq!(d[0], 224.0);
        // errors, not panics, on malformed operands
        assert!(reference_apply(
            &EpilogueOp::BiasAdd { dim: 1 },
            &mut d,
            Some(&[1.0]),
            &[2, 3]
        )
        .is_err());
        assert!(reference_apply(&EpilogueOp::ResidualAdd, &mut d, None, &[2, 3]).is_err());
    }

    #[test]
    fn operand_shapes() {
        assert_eq!(
            EpilogueOp::BiasAdd { dim: 1 }.operand_shape(&[64, 128]),
            Some(vec![128])
        );
        assert_eq!(
            EpilogueOp::BiasAdd { dim: 0 }.operand_shape(&[64, 128]),
            Some(vec![64])
        );
        assert_eq!(
            EpilogueOp::ResidualAdd.operand_shape(&[64, 128]),
            Some(vec![64, 128])
        );
        assert_eq!(EpilogueOp::Scale(2.0).operand_shape(&[64, 128]), None);
        assert!(!EpilogueOp::Scale(2.0).takes_operand());
        assert!(EpilogueOp::ResidualAdd.takes_operand());
    }
}
