//! Linear-attention (Mamba-2 SSD) chunk kernels: `chunk_state` and
//! `chunk_scan` (§5.2 "we use the chunk-scan and chunk-state functions
//! from Mamba-2"), Table 4 shapes.
//!
//! Semantics (per batch*head, chunk length `L`, state size `N`, head dim
//! `P`, with per-step decay weights `w`):
//!   chunk_state:  S[n, p]   = sum_t  B[t, n] * w_st[t] * X[t, p]
//!   chunk_scan:   Y[t, p]   = w_sc[t] * sum_n C[t, n] * S[n, p]
//! (the intra-chunk causal correction term of full SSD is carried by the
//! same GEMM machinery and omitted here; the benchmark's arithmetic
//! profile — two chunked GEMM families — is preserved).

use crate::autotuner::{Tunable, TunableConfig};
use crate::ir::builder::{store, KernelBuilder};
use crate::ir::dtype::DType;
use crate::ir::expr::Expr;
use crate::ir::program::{GemmWarpPolicy, TileProgram};
use crate::util::json::Json;
use crate::workloads::shapes::LinAttnShape;

/// chunk_state: grid (nchunks, bh); inputs flattened per chunk:
/// `B: [bh, seq, N]`, `X: [bh, seq, P]`, `W: [bh, seq]`,
/// output `S: [bh, nchunks, N, P]` stored as `[bh * nchunks, N, P]`.
pub fn chunk_state_program(
    bh: i64,
    seq: i64,
    d_state: i64,
    head_dim: i64,
    chunk: i64,
    num_stages: usize,
) -> TileProgram {
    assert!(seq % chunk == 0);
    let nchunks = seq / chunk;
    let threads = 128;
    let mut t = KernelBuilder::new("chunk_state", threads);
    let b_in = t.param("B", &[bh, seq, d_state], DType::F16);
    let x_in = t.param("X", &[bh, seq, head_dim], DType::F16);
    let w_in = t.param("W", &[bh, seq], DType::F32);
    let s_out = t.param("S", &[bh * nchunks, d_state, head_dim], DType::F32);
    let (bc, bz) = t.kernel2(nchunks, bh);

    let b_s = t.alloc_shared("B_shared", &[chunk, d_state], DType::F16);
    let x_s = t.alloc_shared("X_shared", &[chunk, head_dim], DType::F16);
    let xw = t.alloc_fragment("Xw", &[chunk, head_dim], DType::F16);
    let w_l = t.alloc_fragment("W_local", &[chunk], DType::F32);
    let s_l = t.alloc_fragment("S_local", &[d_state, head_dim], DType::F32);

    t.clear(s_l);
    // one chunk per block: a single pipelined iteration keeps the
    // dataflow identical to the multi-chunk variant
    t.pipelined(1, num_stages, |t, _ko| {
        t.copy_in(b_in, vec![bz.expr(), bc.expr() * chunk, Expr::int(0)], b_s);
        t.copy_in(x_in, vec![bz.expr(), bc.expr() * chunk, Expr::int(0)], x_s);
        t.copy_in(w_in, vec![bz.expr(), bc.expr() * chunk], w_l);
        // Xw[t, p] = w[t] * X[t, p]
        t.parallel(&[chunk, head_dim], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                xw,
                vec![i.expr(), j.expr()],
                Expr::load(x_s, vec![i.expr(), j.expr()]) * Expr::load(w_l, vec![i.expr()]),
            )]
        });
        // S += B^T @ Xw  (shared x register GEMM: the "sr" case)
        t.gemm_opts(b_s, xw, s_l, true, false, GemmWarpPolicy::Square);
    });
    t.copy_out(
        s_l,
        s_out,
        vec![bz.expr() * nchunks + bc.expr(), Expr::int(0), Expr::int(0)],
    );
    t.finish()
}

/// chunk_scan: grid (nchunks, bh); `C: [bh, seq, N]`,
/// `S: [bh * nchunks, N, P]`, `W2: [bh, seq]`, output `Y: [bh, seq, P]`.
pub fn chunk_scan_program(
    bh: i64,
    seq: i64,
    d_state: i64,
    head_dim: i64,
    chunk: i64,
    num_stages: usize,
) -> TileProgram {
    assert!(seq % chunk == 0);
    let nchunks = seq / chunk;
    let threads = 128;
    let mut t = KernelBuilder::new("chunk_scan", threads);
    let c_in = t.param("C", &[bh, seq, d_state], DType::F16);
    let s_in = t.param("S", &[bh * nchunks, d_state, head_dim], DType::F16);
    let w_in = t.param("W2", &[bh, seq], DType::F32);
    let y_out = t.param("Y", &[bh, seq, head_dim], DType::F32);
    let (bc, bz) = t.kernel2(nchunks, bh);

    let c_s = t.alloc_shared("C_shared", &[chunk, d_state], DType::F16);
    let s_s = t.alloc_shared("S_shared", &[d_state, head_dim], DType::F16);
    let w_l = t.alloc_fragment("W2_local", &[chunk], DType::F32);
    let y_l = t.alloc_fragment("Y_local", &[chunk, head_dim], DType::F32);

    t.clear(y_l);
    t.pipelined(1, num_stages, |t, _ko| {
        t.copy_in(c_in, vec![bz.expr(), bc.expr() * chunk, Expr::int(0)], c_s);
        t.copy_in(
            s_in,
            vec![bz.expr() * nchunks + bc.expr(), Expr::int(0), Expr::int(0)],
            s_s,
        );
        t.copy_in(w_in, vec![bz.expr(), bc.expr() * chunk], w_l);
        t.gemm_opts(c_s, s_s, y_l, false, false, GemmWarpPolicy::Square);
        t.parallel(&[chunk, head_dim], |vrs| {
            let (i, j) = (&vrs[0], &vrs[1]);
            vec![store(
                y_l,
                vec![i.expr(), j.expr()],
                Expr::load(y_l, vec![i.expr(), j.expr()]) * Expr::load(w_l, vec![i.expr()]),
            )]
        });
    });
    t.copy_out(y_l, y_out, vec![bz.expr(), bc.expr() * chunk, Expr::int(0)]);
    t.finish()
}

/// Which of the two Mamba-2 chunk kernels is being tuned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkKind {
    State,
    Scan,
}

/// Linear-attention chunk-kernel configuration: chunk length + pipeline
/// depth (the scheduling knobs both kernels expose).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinAttnConfig {
    pub chunk: i64,
    pub num_stages: usize,
}

impl TunableConfig for LinAttnConfig {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("chunk".into(), Json::Num(self.chunk as f64)),
            ("num_stages".into(), Json::Num(self.num_stages as f64)),
        ])
    }

    fn from_json(v: &Json) -> Option<LinAttnConfig> {
        Some(LinAttnConfig {
            chunk: v.get("chunk")?.as_i64()?,
            num_stages: v.get("num_stages")?.as_i64()?.max(1) as usize,
        })
    }
}

/// Tuning problem for one Table 4 shape of `chunk_state` / `chunk_scan`.
#[derive(Clone, Copy, Debug)]
pub struct LinearAttentionTunable {
    pub kind: ChunkKind,
    pub shape: LinAttnShape,
}

impl Tunable for LinearAttentionTunable {
    type Config = LinAttnConfig;

    fn workload(&self) -> &'static str {
        match self.kind {
            ChunkKind::State => "chunk_state",
            ChunkKind::Scan => "chunk_scan",
        }
    }

    fn shape_key(&self) -> Vec<i64> {
        let s = &self.shape;
        vec![s.batch, s.nheads, s.seq_len, s.head_dim, s.d_state]
    }

    fn dtype_key(&self) -> String {
        DType::F16.to_string()
    }

    fn accepts(&self, cfg: &LinAttnConfig) -> bool {
        cfg.chunk > 0 && self.shape.seq_len % cfg.chunk == 0
    }

    fn candidates(&self) -> Vec<LinAttnConfig> {
        let mut out = Vec::new();
        for chunk in [32i64, 64, 128, 256] {
            for stages in [1usize, 2, 3] {
                let cfg = LinAttnConfig {
                    chunk,
                    num_stages: stages,
                };
                if self.accepts(&cfg) {
                    out.push(cfg);
                }
            }
        }
        out
    }

    fn build(&self, cfg: &LinAttnConfig) -> TileProgram {
        let s = &self.shape;
        let bh = s.batch * s.nheads;
        match self.kind {
            ChunkKind::State => chunk_state_program(
                bh,
                s.seq_len,
                s.d_state,
                s.head_dim,
                cfg.chunk,
                cfg.num_stages,
            ),
            ChunkKind::Scan => chunk_scan_program(
                bh,
                s.seq_len,
                s.d_state,
                s.head_dim,
                cfg.chunk,
                cfg.num_stages,
            ),
        }
    }
}

/// Reference chunk_state.
pub fn reference_chunk_state(
    b: &[f32],
    x: &[f32],
    w: &[f32],
    bh: i64,
    seq: i64,
    n: i64,
    p: i64,
    chunk: i64,
) -> Vec<f32> {
    let nchunks = seq / chunk;
    let mut out = vec![0f32; (bh * nchunks * n * p) as usize];
    for z in 0..bh {
        for c in 0..nchunks {
            for t in 0..chunk {
                let tt = c * chunk + t;
                let wv = w[(z * seq + tt) as usize];
                for ni in 0..n {
                    let bv = b[((z * seq + tt) * n + ni) as usize] * wv;
                    for pi in 0..p {
                        out[(((z * nchunks + c) * n + ni) * p + pi) as usize] +=
                            bv * x[((z * seq + tt) * p + pi) as usize];
                    }
                }
            }
        }
    }
    out
}

/// Reference chunk_scan.
#[allow(clippy::too_many_arguments)]
pub fn reference_chunk_scan(
    c: &[f32],
    s: &[f32],
    w2: &[f32],
    bh: i64,
    seq: i64,
    n: i64,
    p: i64,
    chunk: i64,
) -> Vec<f32> {
    let nchunks = seq / chunk;
    let mut out = vec![0f32; (bh * seq * p) as usize];
    for z in 0..bh {
        for ch in 0..nchunks {
            for t in 0..chunk {
                let tt = ch * chunk + t;
                for pi in 0..p {
                    let mut acc = 0f32;
                    for ni in 0..n {
                        acc += c[((z * seq + tt) * n + ni) as usize]
                            * s[(((z * nchunks + ch) * n + ni) * p + pi) as usize];
                    }
                    out[((z * seq + tt) * p + pi) as usize] =
                        acc * w2[(z * seq + tt) as usize];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::lower::{compile, CompileOptions};
    use crate::sim::device::Device;
    use crate::tir::interp::{Interp, Tensors};
    use crate::workloads::matmul::test_data;

    #[test]
    fn chunk_state_matches_reference() {
        let (bh, seq, n, p, chunk) = (2i64, 128i64, 32i64, 32i64, 64i64);
        let prog = chunk_state_program(bh, seq, n, p, chunk, 2);
        let l = compile(&prog, &Device::h100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let b = test_data(bh * seq * n, 41);
        let x = test_data(bh * seq * p, 42);
        let w: Vec<f32> = test_data(bh * seq, 43).iter().map(|v| v + 0.75).collect();
        let mut t = Tensors::new();
        t.insert(prog.params[0].id, b.clone());
        t.insert(prog.params[1].id, x.clone());
        t.insert(prog.params[2].id, w.clone());
        interp.run(&mut t).unwrap();
        let want = reference_chunk_state(&b, &x, &w, bh, seq, n, p, chunk);
        let got = &t[&prog.params[3].id];
        for (g, wv) in got.iter().zip(&want) {
            assert!((g - wv).abs() < 0.05 + 0.02 * wv.abs(), "{} vs {}", g, wv);
        }
    }

    #[test]
    fn chunk_scan_matches_reference() {
        let (bh, seq, n, p, chunk) = (2i64, 128i64, 32i64, 32i64, 64i64);
        let prog = chunk_scan_program(bh, seq, n, p, chunk, 2);
        let l = compile(&prog, &Device::h100(), &CompileOptions::default()).unwrap();
        let interp = Interp::new(&l).unwrap();
        let nchunks = seq / chunk;
        let c = test_data(bh * seq * n, 51);
        let s = test_data(bh * nchunks * n * p, 52);
        let w2: Vec<f32> = test_data(bh * seq, 53).iter().map(|v| v + 0.75).collect();
        let mut t = Tensors::new();
        t.insert(prog.params[0].id, c.clone());
        t.insert(prog.params[1].id, s.clone());
        t.insert(prog.params[2].id, w2.clone());
        interp.run(&mut t).unwrap();
        let want = reference_chunk_scan(&c, &s, &w2, bh, seq, n, p, chunk);
        let got = &t[&prog.params[3].id];
        for (g, wv) in got.iter().zip(&want) {
            assert!((g - wv).abs() < 0.05 + 0.02 * wv.abs(), "{} vs {}", g, wv);
        }
    }
}
