//! Data-movement accounting: per-tier byte and FLOP counters.
//!
//! The paper's framing is that AI kernels are dataflow over tiles moving
//! between DRAM, shared memory and register fragments — this module is
//! the common vocabulary both execution backends use to *count* that
//! movement. [`Traffic`] holds read/write bytes per tier plus FLOPs; the
//! compiled VM produces one from a static shadow pass over its bytecode
//! (`CompiledProgram::traffic`), the tree-walking interpreter counts the
//! identical quantities dynamically as it executes
//! (`Interp::run_traffic`), and the two must agree bit-exactly — the
//! accounting is defined on *logical* per-instruction extents (guards
//! and replication ignored), which both backends share by construction.
//!
//! Counting conventions (one entry per executed instruction):
//!
//! * `Copy` — src-tier read + dst-tier write of `4 * count` bytes,
//!   `count` the product of the destination region's extents.
//! * `Gemm` m×n×k — A-tier read `4mk`, B-tier read `4nk`, fragment
//!   read+write `4mn` each (the accumulator is read-modify-write),
//!   `2mnk` FLOPs.
//! * `Reduce` — fragment read `4·out·red` (+`4·out` when accumulating
//!   into live values), fragment write `4·out`, `out·red` FLOPs.
//! * `Dequant` — packed-tier read `4·rows·ceil(cols/epb)`, scale-tier
//!   read `4·rows·ceil(cols/group)` when scaled, fragment write
//!   `4·rows·cols`, `rows·cols` FLOPs.
//! * `Atomic` — src-tier read, dst-tier read *and* write (read-modify-
//!   write) of `4 * count` bytes each, `count` FLOPs.
//! * `Elems` — per statement: each surviving load reads `4·total`
//!   bytes from its tier, the destination is written `4·total` bytes,
//!   and FLOPs are `total ×` the statement's arithmetic tape ops
//!   (constant-folded subtrees cost nothing, a select with a static
//!   condition keeps only the taken branch — exactly the compiled
//!   tape's folding rules).
//! * `Fill` — a write of the buffer's whole storage (`4·cells·slots`).
//!   Block-start arena zeroing is *not* counted: it is allocation, not
//!   data movement.
//!
//! The roofline helpers at the bottom turn a [`Traffic`] plus a
//! measured span time and a `sim::device` peak pair into arithmetic
//! intensity, achieved-vs-peak rates, and a memory-/compute-bound
//! verdict — the math behind `tilelang roofline`.

/// A memory tier, as both backends classify buffer storage: global
/// params live in DRAM, on-chip buffers are shared memory or register
/// fragments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Dram,
    Shared,
    Fragment,
}

/// Byte/FLOP totals per tier. All counts follow the logical-extent
/// conventions in the module doc, so the compiled static shadow and the
/// interpreter's dynamic count are equal by construction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Traffic {
    pub dram_rd_bytes: u64,
    pub dram_wr_bytes: u64,
    pub shared_rd_bytes: u64,
    pub shared_wr_bytes: u64,
    pub frag_rd_bytes: u64,
    pub frag_wr_bytes: u64,
    pub flops: u64,
}

impl Traffic {
    /// The recorder counter names, in `items()` order.
    pub const COUNTER_NAMES: [&'static str; 7] = [
        "traffic.dram_rd_bytes",
        "traffic.dram_wr_bytes",
        "traffic.shared_rd_bytes",
        "traffic.shared_wr_bytes",
        "traffic.frag_rd_bytes",
        "traffic.frag_wr_bytes",
        "traffic.flops",
    ];

    /// `(counter name, value)` pairs for the recorder.
    pub fn items(&self) -> [(&'static str, u64); 7] {
        [
            (Self::COUNTER_NAMES[0], self.dram_rd_bytes),
            (Self::COUNTER_NAMES[1], self.dram_wr_bytes),
            (Self::COUNTER_NAMES[2], self.shared_rd_bytes),
            (Self::COUNTER_NAMES[3], self.shared_wr_bytes),
            (Self::COUNTER_NAMES[4], self.frag_rd_bytes),
            (Self::COUNTER_NAMES[5], self.frag_wr_bytes),
            (Self::COUNTER_NAMES[6], self.flops),
        ]
    }

    /// Rebuild a `Traffic` from recorder counter totals (ignores
    /// non-`traffic.*` names).
    pub fn from_counters(counters: &[(String, u64)]) -> Traffic {
        let mut t = Traffic::default();
        for (name, v) in counters {
            match name.as_str() {
                "traffic.dram_rd_bytes" => t.dram_rd_bytes = *v,
                "traffic.dram_wr_bytes" => t.dram_wr_bytes = *v,
                "traffic.shared_rd_bytes" => t.shared_rd_bytes = *v,
                "traffic.shared_wr_bytes" => t.shared_wr_bytes = *v,
                "traffic.frag_rd_bytes" => t.frag_rd_bytes = *v,
                "traffic.frag_wr_bytes" => t.frag_wr_bytes = *v,
                "traffic.flops" => t.flops = *v,
                _ => {}
            }
        }
        t
    }

    pub fn merge(&mut self, o: &Traffic) {
        self.dram_rd_bytes += o.dram_rd_bytes;
        self.dram_wr_bytes += o.dram_wr_bytes;
        self.shared_rd_bytes += o.shared_rd_bytes;
        self.shared_wr_bytes += o.shared_wr_bytes;
        self.frag_rd_bytes += o.frag_rd_bytes;
        self.frag_wr_bytes += o.frag_wr_bytes;
        self.flops += o.flops;
    }

    pub fn add_rd(&mut self, tier: Tier, bytes: u64) {
        match tier {
            Tier::Dram => self.dram_rd_bytes += bytes,
            Tier::Shared => self.shared_rd_bytes += bytes,
            Tier::Fragment => self.frag_rd_bytes += bytes,
        }
    }

    pub fn add_wr(&mut self, tier: Tier, bytes: u64) {
        match tier {
            Tier::Dram => self.dram_wr_bytes += bytes,
            Tier::Shared => self.shared_wr_bytes += bytes,
            Tier::Fragment => self.frag_wr_bytes += bytes,
        }
    }

    /// Total DRAM bytes (read + write) — the roofline denominator.
    pub fn dram_bytes(&self) -> u64 {
        self.dram_rd_bytes + self.dram_wr_bytes
    }

    /// Bytes across every tier, reads and writes.
    pub fn total_bytes(&self) -> u64 {
        self.dram_bytes()
            + self.shared_rd_bytes
            + self.shared_wr_bytes
            + self.frag_rd_bytes
            + self.frag_wr_bytes
    }

    pub fn is_zero(&self) -> bool {
        *self == Traffic::default()
    }

    /// Arithmetic intensity: FLOPs per DRAM byte. Zero DRAM traffic
    /// with nonzero FLOPs is `inf` (fully resident — never
    /// memory-bound); zero FLOPs is 0.
    pub fn arith_intensity(&self) -> f64 {
        let b = self.dram_bytes();
        if b == 0 {
            if self.flops > 0 {
                f64::INFINITY
            } else {
                0.0
            }
        } else {
            self.flops as f64 / b as f64
        }
    }

    /// Achieved DRAM bandwidth in GB/s over a measured span time.
    pub fn achieved_dram_gbps(&self, time_us: f64) -> f64 {
        if time_us <= 0.0 {
            return 0.0;
        }
        self.dram_bytes() as f64 / 1e9 / (time_us / 1e6)
    }

    /// Achieved compute rate in TFLOP/s over a measured span time.
    pub fn achieved_tflops(&self, time_us: f64) -> f64 {
        if time_us <= 0.0 {
            return 0.0;
        }
        self.flops as f64 / 1e12 / (time_us / 1e6)
    }
}

/// The roofline verdict: a unit whose arithmetic intensity sits below
/// the device ridge point (`peak FLOP/s ÷ peak DRAM B/s`) is limited by
/// memory bandwidth, above it by compute throughput.
pub fn bound_label(arith_intensity: f64, ridge_flops_per_byte: f64) -> &'static str {
    if arith_intensity < ridge_flops_per_byte {
        "memory-bound"
    } else {
        "compute-bound"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Traffic {
        Traffic {
            dram_rd_bytes: 100,
            dram_wr_bytes: 28,
            shared_rd_bytes: 7,
            shared_wr_bytes: 5,
            frag_rd_bytes: 3,
            frag_wr_bytes: 2,
            flops: 640,
        }
    }

    #[test]
    fn items_round_trip_through_counters() {
        let t = sample();
        let counters: Vec<(String, u64)> = t
            .items()
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
        assert_eq!(Traffic::from_counters(&counters), t);
        // foreign counters are ignored
        let mut with_noise = counters.clone();
        with_noise.push(("vm.gemm_tiles".into(), 9));
        assert_eq!(Traffic::from_counters(&with_noise), t);
    }

    #[test]
    fn merge_and_tier_adds_accumulate() {
        let mut t = sample();
        t.merge(&sample());
        assert_eq!(t.dram_bytes(), 2 * 128);
        assert_eq!(t.flops, 1280);
        let mut u = Traffic::default();
        u.add_rd(Tier::Dram, 8);
        u.add_wr(Tier::Shared, 4);
        u.add_rd(Tier::Fragment, 2);
        assert_eq!(u.dram_rd_bytes, 8);
        assert_eq!(u.shared_wr_bytes, 4);
        assert_eq!(u.frag_rd_bytes, 2);
        assert!(!u.is_zero());
        assert!(Traffic::default().is_zero());
    }

    #[test]
    fn arith_intensity_handles_empty_denominators() {
        assert_eq!(sample().arith_intensity(), 640.0 / 128.0);
        let resident = Traffic {
            flops: 10,
            ..Traffic::default()
        };
        assert!(resident.arith_intensity().is_infinite());
        assert_eq!(Traffic::default().arith_intensity(), 0.0);
    }

    #[test]
    fn roofline_rates_and_verdict() {
        let t = sample(); // 128 DRAM bytes, 640 flops
        // 128 bytes over 1 µs = 0.128 GB/s
        assert!((t.achieved_dram_gbps(1.0) - 0.128).abs() < 1e-12);
        assert!((t.achieved_tflops(1.0) - 640e-6).abs() < 1e-12);
        assert_eq!(t.achieved_dram_gbps(0.0), 0.0);
        assert_eq!(bound_label(1.0, 295.0), "memory-bound");
        assert_eq!(bound_label(400.0, 295.0), "compute-bound");
    }
}
