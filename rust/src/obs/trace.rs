//! The span recorder: lightweight `Instant`-based spans, counters and
//! sample series behind one cloneable handle.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonic per-thread ids for trace lanes. Global (not per recorder):
/// a thread keeps one lane across every recorder it touches, which is
/// what a trace viewer expects.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn current_tid() -> u64 {
    TID.with(|t| *t)
}

/// One completed span, in microseconds relative to the recorder epoch.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub name: String,
    /// Span family: `runtime`, `graph`, `shard`, `serve`, `coord`,
    /// `profile`, ...
    pub cat: String,
    /// Start offset from the recorder's creation, µs.
    pub ts_us: f64,
    pub dur_us: f64,
    /// Trace lane (stable per OS thread).
    pub tid: u64,
    /// Free-form annotations (epilogues, buffer ids, shard index, ...).
    pub args: Vec<(String, String)>,
}

/// One counter increment: which counter, when (µs from the recorder
/// epoch), and by how much. The Chrome exporter turns the per-name
/// point sequence into a counter *track* (running totals over time), so
/// `tilelang check-trace` can validate monotonicity.
#[derive(Clone, Debug, PartialEq)]
pub struct CounterPoint {
    pub name: String,
    pub ts_us: f64,
    pub delta: u64,
}

struct Inner {
    epoch: Instant,
    events: Mutex<Vec<Event>>,
    counters: Mutex<BTreeMap<String, u64>>,
    counter_points: Mutex<Vec<CounterPoint>>,
    samples: Mutex<BTreeMap<String, Vec<f64>>>,
}

/// Handle to a trace/metrics sink. `Recorder::disabled()` (the
/// `Default`) is a cheap no-op: spans still return elapsed time, but
/// nothing is allocated or stored. Clones share the same sink; the
/// handle is `Send + Sync` so one recorder spans worker threads.
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Option<Arc<Inner>>,
}

impl Recorder {
    /// A recorder that stores spans, counters and samples.
    pub fn enabled() -> Recorder {
        Recorder {
            inner: Some(Arc::new(Inner {
                epoch: Instant::now(),
                events: Mutex::new(Vec::new()),
                counters: Mutex::new(BTreeMap::new()),
                counter_points: Mutex::new(Vec::new()),
                samples: Mutex::new(BTreeMap::new()),
            })),
        }
    }

    /// A recorder that drops everything (the default in every layer).
    pub fn disabled() -> Recorder {
        Recorder { inner: None }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Open a span. The returned guard records on [`Span::finish_us`]
    /// (or drop) and always reports its elapsed microseconds — serving
    /// reports read their latencies from this return value, so tracing
    /// on/off cannot change what gets measured.
    pub fn span(&self, cat: &'static str, name: &str) -> Span {
        self.span_with(cat, name, Vec::new)
    }

    /// [`Recorder::span`] with annotations. `args` is a closure so the
    /// disabled path never formats or allocates them.
    pub fn span_with(
        &self,
        cat: &'static str,
        name: &str,
        args: impl FnOnce() -> Vec<(String, String)>,
    ) -> Span {
        let recorded = self.inner.as_ref().map(|inner| RecordedSpan {
            inner: Arc::clone(inner),
            name: name.to_string(),
            args: args(),
        });
        Span {
            recorded,
            cat,
            start: Instant::now(),
            done: false,
        }
    }

    /// Add to a named monotonic counter. Each nonzero add also records
    /// a timestamped [`CounterPoint`], so exported counter tracks show
    /// *when* the counting happened, not just the final total.
    pub fn add(&self, name: &str, delta: u64) {
        if let Some(inner) = &self.inner {
            if delta > 0 {
                let ts_us = Instant::now().duration_since(inner.epoch).as_secs_f64() * 1e6;
                let mut c = inner.counters.lock().expect("obs counters lock");
                *c.entry(name.to_string()).or_insert(0) += delta;
                drop(c);
                inner
                    .counter_points
                    .lock()
                    .expect("obs counter points lock")
                    .push(CounterPoint {
                        name: name.to_string(),
                        ts_us,
                        delta,
                    });
            }
        }
    }

    /// Record one observation of a sample series (pool occupancy, batch
    /// size, queue latency, ...). Series become histogram buckets and
    /// p50/p99 gauges in the metrics dump.
    pub fn sample(&self, name: &str, value: f64) {
        if let Some(inner) = &self.inner {
            let mut s = inner.samples.lock().expect("obs samples lock");
            s.entry(name.to_string()).or_default().push(value);
        }
    }

    /// Fork a per-thread buffer: spans and counters accumulate locally
    /// and merge into the recorder in one step when the buffer drops —
    /// the contention-free way for `std::thread::scope` shard workers
    /// to record.
    pub fn fork(&self) -> ThreadBuf {
        ThreadBuf {
            inner: self.inner.clone(),
            events: Vec::new(),
            counters: Vec::new(),
        }
    }

    /// Every recorded span, sorted by start time.
    pub fn events(&self) -> Vec<Event> {
        match &self.inner {
            None => Vec::new(),
            Some(inner) => {
                let mut ev = inner.events.lock().expect("obs events lock").clone();
                ev.sort_by(|a, b| a.ts_us.partial_cmp(&b.ts_us).expect("finite ts"));
                ev
            }
        }
    }

    /// Every counter increment in timestamp order (name ties keep
    /// record order). Running per-name totals over this sequence are
    /// non-decreasing by construction (deltas are unsigned).
    pub fn counter_points(&self) -> Vec<CounterPoint> {
        match &self.inner {
            None => Vec::new(),
            Some(inner) => {
                let mut pts = inner
                    .counter_points
                    .lock()
                    .expect("obs counter points lock")
                    .clone();
                pts.sort_by(|a, b| a.ts_us.partial_cmp(&b.ts_us).expect("finite ts"));
                pts
            }
        }
    }

    /// Counter totals, name-sorted.
    pub fn counters(&self) -> Vec<(String, u64)> {
        match &self.inner {
            None => Vec::new(),
            Some(inner) => inner
                .counters
                .lock()
                .expect("obs counters lock")
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
        }
    }

    /// Sample series, name-sorted, observations in record order.
    pub fn samples(&self) -> Vec<(String, Vec<f64>)> {
        match &self.inner {
            None => Vec::new(),
            Some(inner) => inner
                .samples
                .lock()
                .expect("obs samples lock")
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
        }
    }

    /// Microseconds since the recorder was created (0 when disabled).
    fn epoch_us(&self, at: Instant) -> f64 {
        match &self.inner {
            None => 0.0,
            Some(inner) => at.duration_since(inner.epoch).as_secs_f64() * 1e6,
        }
    }

    fn push(&self, ev: Event) {
        if let Some(inner) = &self.inner {
            inner.events.lock().expect("obs events lock").push(ev);
        }
    }

    /// Durations (µs) of every recorded span named `name`, start order.
    pub fn span_durations_us(&self, name: &str) -> Vec<f64> {
        self.events()
            .into_iter()
            .filter(|e| e.name == name)
            .map(|e| e.dur_us)
            .collect()
    }
}

/// The enabled half of a [`Span`]: where the event goes and what it is
/// called. Absent entirely on a disabled recorder.
struct RecordedSpan {
    inner: Arc<Inner>,
    name: String,
    args: Vec<(String, String)>,
}

/// An open span guard. Call [`Span::finish_us`] to close it and read
/// the elapsed microseconds; dropping it unfinished records the span
/// too (guard style).
pub struct Span {
    recorded: Option<RecordedSpan>,
    cat: &'static str,
    start: Instant,
    done: bool,
}

impl Span {
    /// Close the span; returns elapsed µs whether or not recording.
    pub fn finish_us(mut self) -> u128 {
        let elapsed = self.start.elapsed();
        self.record(elapsed.as_secs_f64() * 1e6);
        self.done = true;
        elapsed.as_micros()
    }

    fn record(&mut self, dur_us: f64) {
        if let Some(rec) = self.recorded.take() {
            let ts_us = self.start.duration_since(rec.inner.epoch).as_secs_f64() * 1e6;
            rec.inner.events.lock().expect("obs events lock").push(Event {
                name: rec.name,
                cat: self.cat.to_string(),
                ts_us,
                dur_us,
                tid: current_tid(),
                args: rec.args,
            });
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.done {
            let dur = self.start.elapsed().as_secs_f64() * 1e6;
            self.record(dur);
        }
    }
}

/// A per-thread event buffer forked from a [`Recorder`]: spans and
/// counter increments land in thread-local `Vec`s with no locking, and
/// merge into the shared recorder in one step when the buffer drops at
/// the end of the thread's work.
pub struct ThreadBuf {
    inner: Option<Arc<Inner>>,
    events: Vec<Event>,
    counters: Vec<CounterPoint>,
}

impl ThreadBuf {
    /// Record a completed span that began at `start`; returns elapsed
    /// µs (measured whether or not recording, like [`Span::finish_us`]).
    pub fn span(&mut self, cat: &'static str, name: &str, start: Instant) -> u128 {
        self.span_with(cat, name, start, Vec::new)
    }

    /// [`ThreadBuf::span`] with lazily-built annotations.
    pub fn span_with(
        &mut self,
        cat: &'static str,
        name: &str,
        start: Instant,
        args: impl FnOnce() -> Vec<(String, String)>,
    ) -> u128 {
        let elapsed = start.elapsed();
        if let Some(inner) = &self.inner {
            let ts_us = start.duration_since(inner.epoch).as_secs_f64() * 1e6;
            self.events.push(Event {
                name: name.to_string(),
                cat: cat.to_string(),
                ts_us,
                dur_us: elapsed.as_secs_f64() * 1e6,
                tid: current_tid(),
                args: args(),
            });
        }
        elapsed.as_micros()
    }

    /// Add to a named counter (merged with the recorder's at finish).
    /// The increment is timestamped now, so the exported counter track
    /// reflects when the work happened, not when the buffer merged.
    pub fn add(&mut self, name: &str, delta: u64) {
        if let Some(inner) = &self.inner {
            if delta > 0 {
                let ts_us = Instant::now().duration_since(inner.epoch).as_secs_f64() * 1e6;
                self.counters.push(CounterPoint {
                    name: name.to_string(),
                    ts_us,
                    delta,
                });
            }
        }
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        if let Some(inner) = &self.inner {
            if !self.events.is_empty() {
                inner
                    .events
                    .lock()
                    .expect("obs events lock")
                    .append(&mut self.events);
            }
            if !self.counters.is_empty() {
                let mut c = inner.counters.lock().expect("obs counters lock");
                for pt in &self.counters {
                    *c.entry(pt.name.clone()).or_insert(0) += pt.delta;
                }
                drop(c);
                inner
                    .counter_points
                    .lock()
                    .expect("obs counter points lock")
                    .append(&mut self.counters);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing_but_still_times() {
        let rec = Recorder::disabled();
        assert!(!rec.is_enabled());
        let sp = rec.span("test", "noop");
        std::thread::sleep(std::time::Duration::from_millis(2));
        let us = sp.finish_us();
        assert!(us >= 2_000, "span must still measure elapsed time, got {}us", us);
        rec.add("c", 5);
        rec.sample("s", 1.0);
        let mut tb = rec.fork();
        tb.add("c", 5);
        tb.span("test", "forked", Instant::now());
        drop(tb);
        assert!(rec.events().is_empty());
        assert!(rec.counters().is_empty());
        assert!(rec.counter_points().is_empty());
        assert!(rec.samples().is_empty());
    }

    #[test]
    fn spans_counters_and_samples_round_through_the_recorder() {
        let rec = Recorder::enabled();
        {
            let _outer = rec.span_with("test", "outer", || {
                vec![("k".to_string(), "v".to_string())]
            });
            let inner = rec.span("test", "inner");
            inner.finish_us();
        } // outer records on drop
        rec.add("hits", 2);
        rec.add("hits", 3);
        rec.add("zero", 0); // no-op: zero deltas are not materialized
        rec.sample("occupancy", 4.0);
        rec.sample("occupancy", 6.0);

        let ev = rec.events();
        assert_eq!(ev.len(), 2);
        // sorted by start: outer opened first
        assert_eq!(ev[0].name, "outer");
        assert_eq!(ev[0].args, vec![("k".to_string(), "v".to_string())]);
        assert_eq!(ev[1].name, "inner");
        // inner nests within outer on the same thread
        assert_eq!(ev[0].tid, ev[1].tid);
        assert!(ev[1].ts_us >= ev[0].ts_us);
        assert!(ev[1].ts_us + ev[1].dur_us <= ev[0].ts_us + ev[0].dur_us + 1.0);

        assert_eq!(rec.counters(), vec![("hits".to_string(), 5)]);
        // every nonzero add leaves a timestamped point, in ts order
        let pts = rec.counter_points();
        assert_eq!(pts.len(), 2);
        assert_eq!((pts[0].name.as_str(), pts[0].delta), ("hits", 2));
        assert_eq!((pts[1].name.as_str(), pts[1].delta), ("hits", 3));
        assert!(pts[0].ts_us <= pts[1].ts_us);
        assert_eq!(rec.samples(), vec![("occupancy".to_string(), vec![4.0, 6.0])]);
        assert_eq!(rec.span_durations_us("inner").len(), 1);
    }

    #[test]
    fn thread_buffers_merge_at_finish() {
        let rec = Recorder::enabled();
        std::thread::scope(|scope| {
            for i in 0..4 {
                let rec = &rec;
                scope.spawn(move || {
                    let mut tb = rec.fork();
                    let t0 = Instant::now();
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    tb.span_with("shard", &format!("worker{}", i), t0, || {
                        vec![("shard".to_string(), i.to_string())]
                    });
                    tb.add("tiles", 10);
                });
            }
        });
        let ev = rec.events();
        assert_eq!(ev.len(), 4);
        let tids: std::collections::HashSet<u64> = ev.iter().map(|e| e.tid).collect();
        assert_eq!(tids.len(), 4, "each scoped thread gets its own lane");
        assert_eq!(rec.counters(), vec![("tiles".to_string(), 40)]);
        assert_eq!(rec.counter_points().len(), 4, "one point per thread add");
    }
}
