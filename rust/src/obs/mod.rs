//! Unified tracing + metrics: the observability substrate every
//! execution layer reports through.
//!
//! The paper's claim is that schedules can be chosen *transparently*;
//! this module is how the repo checks what the stack actually did. A
//! [`Recorder`] is threaded through the layers — `Runtime::load`
//! (artifact load + tuning), [`crate::graph::exec::GraphKernel`] (one
//! span per node, annotated with fused epilogues and memplan buffer
//! ids), the sharded executors (scatter / per-shard compute / gather,
//! so shard imbalance is visible), the compiled VM (static
//! per-instruction-class counters: tiles, f32 ops, bytes moved), the
//! coordinator workers (queue/exec split per reply) and the
//! continuous-batching engine (admit/prefill/decode/gather spans plus
//! pool-occupancy samples).
//!
//! Design rules:
//!
//! * **Disabled is (almost) free.** A disabled recorder is a `None`;
//!   spans still measure elapsed time (two `Instant` reads — the serve
//!   reports need the numbers either way) but allocate nothing and
//!   touch no locks. The bench gate asserts the end-to-end overhead of
//!   the disabled path stays under 2% on `continuous_decode_8streams`.
//! * **Numbers come from the recorder.** `EngineReport`, `KernelReply`
//!   and `RowReply` latencies are the *same* measurements the trace
//!   file shows — no parallel bespoke timers that can drift from the
//!   exported spans.
//! * **Thread safety by per-thread buffers.** Shard threads record
//!   into a [`ThreadBuf`] forked from the recorder and merge once at
//!   finish (one lock per thread, not per span).
//!
//! Exporters: Chrome trace-event JSON (`chrome://tracing` /
//! `ui.perfetto.dev`-loadable, written with [`crate::util::json`]) and
//! a Prometheus-style text metrics dump (counters + decade histogram
//! buckets per span family and sample series). `tilelang profile`
//! joins the measured spans against `sim::simulate_kernel` predictions
//! into the model-vs-measured table; see `docs/OBSERVABILITY.md`.
//!
//! [`traffic`] is the data-movement half: per-tier byte/FLOP counters
//! ([`Traffic`]) that the compiled VM derives statically and the
//! interpreter counts dynamically — bit-identical by construction —
//! surfaced as `traffic.*` recorder counters and joined with measured
//! span times and `sim::device` peaks by `tilelang roofline`.

mod export;
mod trace;
pub mod traffic;

pub use export::{
    chrome_trace, metrics_text, read_chrome_counters, read_chrome_trace, write_chrome_trace,
    write_metrics,
};
pub use trace::{CounterPoint, Event, Recorder, Span, ThreadBuf};
pub use traffic::{bound_label, Tier, Traffic};
