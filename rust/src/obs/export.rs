//! Trace and metrics exporters.
//!
//! * [`chrome_trace`] — the Chrome trace-event JSON object array format
//!   (`{"traceEvents": [{"ph": "X", ...}]}`), loadable in
//!   `chrome://tracing` and `ui.perfetto.dev`. [`read_chrome_trace`]
//!   parses it back (the round-trip tests and `scripts/check_trace` use
//!   the same reader).
//! * [`metrics_text`] — a Prometheus-style text dump: one `counter`
//!   family per recorder counter, and per span family / sample series a
//!   decade-bucket `histogram` plus `p50`/`p99` gauges.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use crate::obs::trace::{Event, Recorder};
use crate::util::json::Json;
use crate::util::stats::{summarize, Histogram};

/// The Chrome trace-event document for everything the recorder holds.
/// Spans become `ph: "X"` (complete) events; every counter increment
/// becomes a `ph: "C"` event carrying the running total at that
/// moment, so counters render as real (monotonic) tracks over time.
pub fn chrome_trace(rec: &Recorder) -> Json {
    let mut events: Vec<Json> = vec![Json::Obj(vec![
        ("name".into(), Json::Str("process_name".into())),
        ("ph".into(), Json::Str("M".into())),
        ("pid".into(), Json::Num(1.0)),
        (
            "args".into(),
            Json::Obj(vec![("name".into(), Json::Str("tilelang".into()))]),
        ),
    ])];
    for ev in rec.events() {
        events.push(Json::Obj(vec![
            ("name".into(), Json::Str(ev.name.clone())),
            ("cat".into(), Json::Str(ev.cat.clone())),
            ("ph".into(), Json::Str("X".into())),
            ("pid".into(), Json::Num(1.0)),
            ("tid".into(), Json::Num(ev.tid as f64)),
            ("ts".into(), Json::Num(ev.ts_us)),
            ("dur".into(), Json::Num(ev.dur_us)),
            (
                "args".into(),
                Json::Obj(
                    ev.args
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
        ]));
    }
    let mut totals: BTreeMap<String, u64> = BTreeMap::new();
    for pt in rec.counter_points() {
        let total = totals.entry(pt.name.clone()).or_insert(0);
        *total += pt.delta;
        events.push(Json::Obj(vec![
            ("name".into(), Json::Str(pt.name.clone())),
            ("ph".into(), Json::Str("C".into())),
            ("pid".into(), Json::Num(1.0)),
            ("ts".into(), Json::Num(pt.ts_us)),
            (
                "args".into(),
                Json::Obj(vec![("value".into(), Json::Num(*total as f64))]),
            ),
        ]));
    }
    Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(events)),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
    ])
}

/// Write the Chrome trace to `path`.
pub fn write_chrome_trace(rec: &Recorder, path: impl AsRef<Path>) -> Result<(), String> {
    std::fs::write(path.as_ref(), chrome_trace(rec).dump())
        .map_err(|e| format!("write trace {:?}: {}", path.as_ref(), e))
}

/// Parse a Chrome trace-event document back into span [`Event`]s.
/// Non-span phases (`M` metadata, `C` counters) are skipped; a document
/// without a `traceEvents` array, or a span event missing a required
/// field, is an error — this is the validator behind
/// `scripts/check_trace`.
pub fn read_chrome_trace(text: &str) -> Result<Vec<Event>, String> {
    let doc = Json::parse(text)?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or("trace: missing traceEvents array")?;
    let mut out = Vec::new();
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("trace event {}: missing ph", i))?;
        if ph != "X" {
            continue;
        }
        let field = |k: &str| -> Result<&Json, String> {
            ev.get(k).ok_or_else(|| format!("trace event {}: missing {}", i, k))
        };
        let num = |k: &str| -> Result<f64, String> {
            field(k)?
                .as_f64()
                .ok_or_else(|| format!("trace event {}: {} is not a number", i, k))
        };
        let args = match ev.get("args") {
            Some(Json::Obj(fields)) => fields
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect(),
            _ => Vec::new(),
        };
        out.push(Event {
            name: field("name")?
                .as_str()
                .ok_or_else(|| format!("trace event {}: name is not a string", i))?
                .to_string(),
            cat: ev
                .get("cat")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            ts_us: num("ts")?,
            dur_us: num("dur")?,
            tid: num("tid")? as u64,
            args,
        });
    }
    Ok(out)
}

/// Parse the `ph: "C"` counter events out of a Chrome trace document:
/// `(counter name, ts µs, running total)` in document order. Used by
/// `tilelang check-trace` to validate that every counter track is
/// monotonically non-decreasing.
pub fn read_chrome_counters(text: &str) -> Result<Vec<(String, f64, f64)>, String> {
    let doc = Json::parse(text)?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or("trace: missing traceEvents array")?;
    let mut out = Vec::new();
    for (i, ev) in events.iter().enumerate() {
        if ev.get("ph").and_then(|v| v.as_str()) != Some("C") {
            continue;
        }
        let name = ev
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("trace counter event {}: missing name", i))?
            .to_string();
        let ts = ev
            .get("ts")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("trace counter event {}: missing ts", i))?;
        let value = ev
            .get("args")
            .and_then(|a| a.get("value"))
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("trace counter event {}: missing args.value", i))?;
        out.push((name, ts, value));
    }
    Ok(out)
}

/// A metric-safe name: `serve.decode` -> `tilelang_serve_decode`.
/// Every character outside `[a-zA-Z0-9_]` (dots, dashes, spaces,
/// unicode) is replaced with `_` so the result is always a valid
/// Prometheus metric name.
fn metric_name(raw: &str) -> String {
    let mut out = String::from("tilelang_");
    for c in raw.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

/// Escape a label value per the Prometheus exposition format:
/// backslash, double-quote and newline must be escaped inside the
/// quoted label string.
fn escape_label_value(raw: &str) -> String {
    let mut out = String::with_capacity(raw.len());
    for c in raw.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{:.3}", v)
    }
}

fn write_series(out: &mut String, name: &str, values: &[f64]) {
    let mut h = Histogram::decades(1.0, 1e7);
    for &v in values {
        h.observe(v);
    }
    let s = summarize(values);
    let _ = writeln!(out, "# TYPE {} histogram", name);
    for (bound, count) in h.cumulative() {
        let le = if bound.is_infinite() {
            "+Inf".to_string()
        } else {
            fmt_f64(bound)
        };
        let _ = writeln!(
            out,
            "{}_bucket{{le=\"{}\"}} {}",
            name,
            escape_label_value(&le),
            count
        );
    }
    let _ = writeln!(out, "{}_sum {}", name, fmt_f64(s.sum));
    let _ = writeln!(out, "{}_count {}", name, s.count);
    let _ = writeln!(out, "# TYPE {}_p50 gauge", name);
    let _ = writeln!(out, "{}_p50 {}", name, fmt_f64(s.p50));
    let _ = writeln!(out, "# TYPE {}_p99 gauge", name);
    let _ = writeln!(out, "{}_p99 {}", name, fmt_f64(s.p99));
}

/// The Prometheus-style text dump: counters, then one histogram +
/// p50/p99 pair per span family (span durations, µs, keyed
/// `<cat>.<name>`) and per sample series.
pub fn metrics_text(rec: &Recorder) -> String {
    let mut out = String::new();
    for (name, value) in rec.counters() {
        let n = format!("{}_total", metric_name(&name));
        let _ = writeln!(out, "# TYPE {} counter", n);
        let _ = writeln!(out, "{} {}", n, value);
    }
    let mut span_us: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for ev in rec.events() {
        span_us
            .entry(format!("{}.{}", ev.cat, ev.name))
            .or_default()
            .push(ev.dur_us);
    }
    for (key, durs) in &span_us {
        write_series(&mut out, &format!("{}_us", metric_name(key)), durs);
    }
    for (name, values) in rec.samples() {
        write_series(&mut out, &metric_name(&name), &values);
    }
    out
}

/// Write the metrics dump to `path`.
pub fn write_metrics(rec: &Recorder, path: impl AsRef<Path>) -> Result<(), String> {
    std::fs::write(path.as_ref(), metrics_text(rec))
        .map_err(|e| format!("write metrics {:?}: {}", path.as_ref(), e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chrome_trace_round_trips_through_the_reader() {
        let rec = Recorder::enabled();
        rec.span_with("graph", "q_proj", || {
            vec![
                ("epilogues".to_string(), "bias,relu".to_string()),
                ("buffer".to_string(), "2".to_string()),
            ]
        })
        .finish_us();
        rec.span("serve", "decode").finish_us();
        rec.add("vm.gemm_tiles", 7);

        let text = chrome_trace(&rec).dump();
        let back = read_chrome_trace(&text).expect("parse trace");
        let orig = rec.events();
        assert_eq!(back.len(), orig.len());
        for (b, o) in back.iter().zip(&orig) {
            assert_eq!(b.name, o.name);
            assert_eq!(b.cat, o.cat);
            assert_eq!(b.tid, o.tid);
            assert_eq!(b.args, o.args);
            assert!((b.ts_us - o.ts_us).abs() < 1e-6);
            assert!((b.dur_us - o.dur_us).abs() < 1e-6);
        }
    }

    #[test]
    fn reader_rejects_malformed_documents() {
        assert!(read_chrome_trace("{}").is_err());
        assert!(read_chrome_trace("not json").is_err());
        // an X event without a ts is an error, metadata is skipped
        let bad = r#"{"traceEvents":[{"name":"x","ph":"X","dur":1,"tid":1}]}"#;
        assert!(read_chrome_trace(bad).is_err());
        let ok = r#"{"traceEvents":[{"name":"m","ph":"M","args":{}}]}"#;
        assert_eq!(read_chrome_trace(ok).unwrap().len(), 0);
    }

    #[test]
    fn metrics_text_has_counters_and_histograms() {
        let rec = Recorder::enabled();
        rec.add("runtime.cache_hit", 3);
        rec.span("serve", "decode").finish_us();
        rec.sample("serve.pool_pages", 12.0);
        rec.sample("serve.pool_pages", 20.0);
        let text = metrics_text(&rec);
        assert!(text.contains("tilelang_runtime_cache_hit_total 3"), "{}", text);
        assert!(text.contains("# TYPE tilelang_serve_decode_us histogram"), "{}", text);
        assert!(text.contains("tilelang_serve_decode_us_bucket{le=\"+Inf\"} 1"), "{}", text);
        assert!(text.contains("tilelang_serve_pool_pages_count 2"), "{}", text);
        assert!(text.contains("tilelang_serve_pool_pages_p99 20"), "{}", text);
    }

    #[test]
    fn counter_tracks_carry_running_totals_per_add() {
        let rec = Recorder::enabled();
        rec.add("traffic.flops", 10);
        rec.add("traffic.flops", 5);
        rec.add("vm.gemm_tiles", 2);
        let text = chrome_trace(&rec).dump();
        let pts = read_chrome_counters(&text).expect("parse counters");
        let flops: Vec<&(String, f64, f64)> =
            pts.iter().filter(|(n, _, _)| n == "traffic.flops").collect();
        assert_eq!(flops.len(), 2, "one C event per add");
        assert_eq!(flops[0].2, 10.0);
        assert_eq!(flops[1].2, 15.0, "C events carry the running total");
        assert!(flops[0].1 <= flops[1].1, "points in timestamp order");
        assert_eq!(
            pts.iter().filter(|(n, _, _)| n == "vm.gemm_tiles").count(),
            1
        );
        // the span reader still skips C events
        assert!(read_chrome_trace(&text).expect("parse spans").is_empty());
    }

    #[test]
    fn exposition_format_is_pinned_for_hostile_names_and_labels() {
        // metric names: every invalid char ('.', '-', space, unicode)
        // sanitizes to '_'
        let rec = Recorder::enabled();
        rec.add("traffic.dram_rd_bytes", 7);
        rec.add("weird-name with µchars", 1);
        let text = metrics_text(&rec);
        assert!(
            text.contains("# TYPE tilelang_traffic_dram_rd_bytes_total counter\ntilelang_traffic_dram_rd_bytes_total 7"),
            "{}",
            text
        );
        assert!(
            text.contains("tilelang_weird_name_with__chars_total 1"),
            "{}",
            text
        );
        // only [a-zA-Z0-9_] survives in metric names
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let name = line.split([' ', '{']).next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                "unsanitized metric name {:?}",
                name
            );
        }
        // label values: exposition-format escapes
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\"b"), "a\\\"b");
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("a\nb"), "a\\nb");
    }

    #[test]
    fn disabled_recorder_exports_empty_documents() {
        let rec = Recorder::disabled();
        let doc = chrome_trace(&rec);
        let back = read_chrome_trace(&doc.dump()).unwrap();
        assert!(back.is_empty());
        assert_eq!(metrics_text(&rec), "");
    }
}
