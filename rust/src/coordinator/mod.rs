//! L3 kernel-library coordinator: the serving layer that owns the event
//! loop, worker threads and dynamic batching over the artifact runtime.
//!
//! For a kernel-compiler paper the coordinator is deliberately thin
//! (DESIGN.md: "if the paper's contribution lives entirely at L2/L1, L3
//! is a thin driver") — but it is a real one: per-kernel worker threads
//! each own a loaded executable, requests flow through mpsc queues, and
//! model workers micro-batch row requests up to the artifact's batch
//! dimension with a flush deadline (the vLLM-router pattern scaled to
//! this repo).
//!
//! Workers execute through the runtime's [`ExecBackend`]: the interp
//! backend by default (offline builds serve real requests through the
//! TIR interpreter), PJRT when the `pjrt` feature supplies it. Loading
//! an artifact on the interp backend selects its tile configuration
//! through the persistent tuning cache, so serving starts pre-compile
//! tuned configs for their artifact shapes. Graph artifacts (manifest
//! `graph=` tag) serve through the same workers: the runtime loads them
//! as fused, buffer-planned `graph::GraphKernel`s, so a batched model
//! worker can serve a whole transformer block per request batch — and on
//! the sharded backend (`start_sharded`) the block itself is partitioned,
//! so every executed micro-batch scatters across the graph shard plan's
//! executors and gathers back before rows are replied.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::error::Result;
use crate::obs::Recorder;
use crate::runtime::{ExecBackend, Runtime, WorkloadKind};

/// A raw kernel invocation result.
pub struct KernelReply {
    /// Full output tensor, or a stringified worker-side error.
    pub output: Result<Vec<f32>, String>,
    /// Time the job waited in the worker queue.
    pub queue_us: u128,
    /// Backend execution time.
    pub exec_us: u128,
}

/// A batched-row invocation result (one row of the model batch).
///
/// Like [`KernelReply`], the latency splits into a queue and an exec
/// component: `queue_us` is submit-to-execute-start (including the
/// micro-batch flush wait), `exec_us` is the *shared* backend execution
/// time of the batch this row rode in (every co-batched row reports the
/// same `exec_us` — measured once, by the worker's recorder span).
pub struct RowReply {
    /// This row's output slice, or a stringified worker-side error.
    pub output: Result<Vec<f32>, String>,
    /// Submit-to-reply latency (includes micro-batch wait).
    pub latency_us: u128,
    /// Submit-to-execute-start wait (micro-batch assembly included).
    pub queue_us: u128,
    /// Backend execution time of the shared batch.
    pub exec_us: u128,
    /// Rows that shared the executed batch.
    pub batch_size: usize,
}

enum Job {
    Raw {
        inputs: Vec<Vec<f32>>,
        reply: Sender<KernelReply>,
        enqueued: Instant,
    },
    Row {
        row: Vec<f32>,
        reply: Sender<RowReply>,
        enqueued: Instant,
    },
    Shutdown,
}

struct Worker {
    tx: Sender<Job>,
    handle: JoinHandle<()>,
}

/// The coordinator: routes requests to per-kernel workers.
pub struct Coordinator {
    workers: HashMap<String, Worker>,
}

/// Configuration for a batched model worker.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Max rows per executed batch. `None` uses the artifact's batch
    /// dimension; an explicit cap is clamped to that dimension.
    pub max_batch: Option<usize>,
    /// Flush waiting rows after this long even if the batch is not full.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: None, // artifact batch dim
            max_wait: Duration::from_millis(2),
        }
    }
}

impl Coordinator {
    /// Start raw workers for `kernels` from the artifacts in `dir`, on
    /// the build's default execution backend. Each worker owns its own
    /// runtime + loaded executable (the handles are not required to be
    /// Send, so threads build their own).
    pub fn start(dir: impl Into<PathBuf>, kernels: &[&str]) -> Result<Coordinator> {
        Coordinator::start_with_backend(dir, ExecBackend::default_backend(), kernels)
    }

    /// [`Coordinator::start`] with an explicit execution backend.
    pub fn start_with_backend(
        dir: impl Into<PathBuf>,
        backend: ExecBackend,
        kernels: &[&str],
    ) -> Result<Coordinator> {
        Coordinator::start_with_backend_rec(dir, backend, kernels, Recorder::disabled())
    }

    /// [`Coordinator::start_with_backend`] reporting through `rec`:
    /// worker runtimes attach the recorder and every reply's queue/exec
    /// split comes from its spans.
    pub fn start_with_backend_rec(
        dir: impl Into<PathBuf>,
        backend: ExecBackend,
        kernels: &[&str],
        rec: Recorder,
    ) -> Result<Coordinator> {
        let dir = dir.into();
        let mut workers = HashMap::new();
        for &k in kernels {
            let (tx, rx) = mpsc::channel::<Job>();
            let name = k.to_string();
            let d = dir.clone();
            let be = backend.clone();
            let r = rec.clone();
            let handle = std::thread::Builder::new()
                .name(format!("kernel-{}", k))
                .spawn(move || raw_worker(d, be, name, rx, r))
                .map_err(|e| anyhow!("spawn: {}", e))?;
            workers.insert(k.to_string(), Worker { tx, handle });
        }
        Ok(Coordinator { workers })
    }

    /// Start a batched model worker for `kernel` (input 0 is the batch
    /// tensor; remaining inputs are weights loaded from the recorded
    /// example bins), on the build's default execution backend.
    pub fn start_batched(
        dir: impl Into<PathBuf>,
        kernel: &str,
        policy: BatchPolicy,
    ) -> Result<Coordinator> {
        Coordinator::start_batched_with_backend(
            dir,
            ExecBackend::default_backend(),
            kernel,
            policy,
        )
    }

    /// Start a batched model worker whose artifact is partitioned across
    /// `shards` parallel executors ([`ExecBackend::Sharded`]): the worker
    /// assembles micro-batches exactly as [`Coordinator::start_batched`]
    /// does, and every executed batch is scattered across the shard
    /// plan's executors and gathered back before rows are replied.
    pub fn start_sharded(
        dir: impl Into<PathBuf>,
        kernel: &str,
        policy: BatchPolicy,
        shards: usize,
    ) -> Result<Coordinator> {
        Coordinator::start_batched_with_backend(dir, ExecBackend::sharded(shards), kernel, policy)
    }

    /// [`Coordinator::start_batched`] with an explicit execution backend.
    pub fn start_batched_with_backend(
        dir: impl Into<PathBuf>,
        backend: ExecBackend,
        kernel: &str,
        policy: BatchPolicy,
    ) -> Result<Coordinator> {
        Coordinator::start_batched_with_backend_rec(
            dir,
            backend,
            kernel,
            policy,
            Recorder::disabled(),
        )
    }

    /// [`Coordinator::start_batched_with_backend`] reporting through
    /// `rec`: the worker runtime attaches the recorder, each executed
    /// micro-batch is a `coord` span, and [`RowReply::exec_us`] is that
    /// span's measured duration.
    pub fn start_batched_with_backend_rec(
        dir: impl Into<PathBuf>,
        backend: ExecBackend,
        kernel: &str,
        policy: BatchPolicy,
        rec: Recorder,
    ) -> Result<Coordinator> {
        let dir = dir.into();
        let (tx, rx) = mpsc::channel::<Job>();
        let name = kernel.to_string();
        let handle = std::thread::Builder::new()
            .name(format!("model-{}", kernel))
            .spawn(move || batched_worker(dir, backend, name, policy, rx, rec))
            .map_err(|e| anyhow!("spawn: {}", e))?;
        let mut workers = HashMap::new();
        workers.insert(kernel.to_string(), Worker { tx, handle });
        Ok(Coordinator { workers })
    }

    /// Submit a raw kernel invocation.
    pub fn submit(&self, kernel: &str, inputs: Vec<Vec<f32>>) -> Result<Receiver<KernelReply>> {
        let w = self
            .workers
            .get(kernel)
            .ok_or_else(|| anyhow!("no worker for {}", kernel))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        w.tx.send(Job::Raw {
            inputs,
            reply: reply_tx,
            enqueued: Instant::now(),
        })
        .map_err(|_| anyhow!("worker for {} is gone", kernel))?;
        Ok(reply_rx)
    }

    /// Submit one row to a batched model worker.
    pub fn submit_row(&self, kernel: &str, row: Vec<f32>) -> Result<Receiver<RowReply>> {
        let w = self
            .workers
            .get(kernel)
            .ok_or_else(|| anyhow!("no worker for {}", kernel))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        w.tx.send(Job::Row {
            row,
            reply: reply_tx,
            enqueued: Instant::now(),
        })
        .map_err(|_| anyhow!("worker for {} is gone", kernel))?;
        Ok(reply_rx)
    }

    /// Graceful shutdown: drains queues, joins workers.
    pub fn shutdown(self) {
        for (_, w) in self.workers.iter() {
            let _ = w.tx.send(Job::Shutdown);
        }
        for (_, w) in self.workers.into_iter() {
            let _ = w.handle.join();
        }
    }
}

fn raw_worker(
    dir: PathBuf,
    backend: ExecBackend,
    kernel: String,
    rx: Receiver<Job>,
    rec: Recorder,
) {
    let runtime = match Runtime::with_backend(&dir, backend) {
        Ok(mut r) => {
            r.set_recorder(rec.clone());
            r
        }
        Err(e) => {
            drain_with_error(&rx, &format!("runtime init failed: {}", e));
            return;
        }
    };
    let loaded = match runtime.load(&kernel) {
        Ok(k) => k,
        Err(e) => {
            drain_with_error(&rx, &format!("compile failed: {}", e));
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        match job {
            Job::Raw {
                inputs,
                reply,
                enqueued,
            } => {
                let queue_us = enqueued.elapsed().as_micros();
                rec.sample("coord.queue_us", queue_us as f64);
                let sp = rec.span_with("coord", "exec", || {
                    vec![("kernel".to_string(), kernel.clone())]
                });
                let output = loaded.execute_rec(&inputs, &rec).map_err(|e| e.to_string());
                let _ = reply.send(KernelReply {
                    output,
                    queue_us,
                    exec_us: sp.finish_us(),
                });
            }
            Job::Row { reply, enqueued, .. } => {
                let _ = reply.send(error_row_reply("raw worker cannot batch rows", enqueued));
            }
            Job::Shutdown => break,
        }
    }
}

fn batched_worker(
    dir: PathBuf,
    backend: ExecBackend,
    kernel: String,
    policy: BatchPolicy,
    rx: Receiver<Job>,
    rec: Recorder,
) {
    let runtime = match Runtime::with_backend(&dir, backend) {
        Ok(mut r) => {
            r.set_recorder(rec.clone());
            r
        }
        Err(e) => {
            drain_with_error(&rx, &format!("runtime init failed: {}", e));
            return;
        }
    };
    let loaded = match runtime.load(&kernel) {
        Ok(k) => k,
        Err(e) => {
            drain_with_error(&rx, &format!("compile failed: {}", e));
            return;
        }
    };
    let weights = match runtime.example_inputs(&kernel) {
        Ok(mut ins) => {
            if ins.is_empty() {
                // a malformed artifact must fail requests, not panic the
                // worker thread (satellite: no unwrap on serving paths)
                drain_with_error(&rx, "artifact has no inputs; cannot serve rows");
                return;
            }
            ins.remove(0);
            ins
        }
        Err(e) => {
            drain_with_error(&rx, &format!("weights missing: {}", e));
            return;
        }
    };
    // row serving needs the output to keep input 0's batch dim as its
    // own dim 0 — transposed (dequant) or re-chunked (chunk_state)
    // outputs would interleave co-batched requests' data into every
    // reply. This also guarantees out_len divides by the batch dim.
    let batch_shape = loaded.spec.in_shapes[0].clone();
    if batch_shape.len() < 2 || loaded.spec.out_shape.first() != batch_shape.first() {
        drain_with_error(
            &rx,
            &format!(
                "artifact {} is not row-batchable (input 0 {:?}, output {:?} does \
                 not keep the batch dim); use raw submit instead",
                kernel, batch_shape, loaded.spec.out_shape
            ),
        );
        return;
    }
    // the dequant family always writes a transposed output and the
    // chunk kernels re-chunk theirs: even a shape coincidence (square
    // dequant, m == n) must not row-serve. Graph artifacts skip this —
    // they get the dedicated `row_batchable` dataflow analysis below,
    // and `for_spec`'s name-prefix fallback would misread their names.
    // Unclassifiable legacy manifests keep the shape guard alone.
    let kind_blocks_rows = loaded.spec.graph.is_none()
        && WorkloadKind::for_spec(&loaded.spec)
            .map(|k| {
                matches!(
                    k,
                    WorkloadKind::Dequant { .. }
                        | WorkloadKind::ChunkState
                        | WorkloadKind::ChunkScan
                )
            })
            .unwrap_or(false);
    if kind_blocks_rows {
        drain_with_error(
            &rx,
            &format!(
                "artifact {} is not row-batchable (its workload family transposes or \
                 re-chunks the output); use raw submit instead",
                kernel
            ),
        );
        return;
    }
    // graph artifacts (single-executor or sharded) must additionally be
    // provably row-independent: an attention block keeps the batch dim
    // structurally but mixes across it, which would serve silently wrong
    // numbers
    if loaded.graph_row_batchable() == Some(false) {
        drain_with_error(
            &rx,
            &format!(
                "graph artifact {} is not row-batchable (output rows depend on \
                 other batch rows); serve it through raw submit instead",
                kernel
            ),
        );
        return;
    }
    let batch_cap = batch_shape[0] as usize;
    let max_batch = match policy.max_batch {
        None => batch_cap,
        Some(m) => m.clamp(1, batch_cap),
    };
    let row_len: usize = batch_shape[1..].iter().product::<i64>() as usize;
    let out_row_len = loaded.spec.out_len() / batch_cap;

    let mut pending: Vec<(Vec<f32>, Sender<RowReply>, Instant)> = Vec::new();
    let mut shutdown = false;
    while !shutdown {
        // wait for the first row, then micro-batch up to the deadline
        let deadline = if pending.is_empty() {
            match rx.recv() {
                Ok(Job::Row { row, reply, enqueued }) => {
                    pending.push((row, reply, enqueued));
                    Instant::now() + policy.max_wait
                }
                Ok(Job::Shutdown) | Err(_) => break,
                Ok(Job::Raw { reply, enqueued, .. }) => {
                    let _ = reply
                        .send(error_kernel_reply("batched worker only accepts rows", enqueued));
                    continue;
                }
            }
        } else {
            Instant::now() + policy.max_wait
        };
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Job::Row { row, reply, enqueued }) => {
                    pending.push((row, reply, enqueued))
                }
                Ok(Job::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Ok(Job::Raw { reply, enqueued, .. }) => {
                    let _ = reply
                        .send(error_kernel_reply("batched worker only accepts rows", enqueued));
                }
            }
        }
        if pending.is_empty() {
            continue;
        }
        // assemble the batch (zero-pad unused slots)
        let rows = std::mem::take(&mut pending);
        let n = rows.len();
        let row_refs: Vec<&[f32]> = rows.iter().map(|(r, _, _)| r.as_slice()).collect();
        let (batch, bad) = assemble_batch(&row_refs, row_len, batch_shape[0] as usize);
        let mut inputs = vec![batch];
        inputs.extend(weights.iter().cloned());
        // snapshot each row's queue wait at execute start: the reply's
        // queue/exec split is queue_us (submit -> batch start, flush
        // wait included) + exec_us (the shared batch span below)
        let queue_marks: Vec<u128> =
            rows.iter().map(|(_, _, enq)| enq.elapsed().as_micros()).collect();
        rec.sample("coord.batch_size", n as f64);
        let sp = rec.span_with("coord", "batch_exec", || {
            vec![
                ("kernel".to_string(), kernel.clone()),
                ("batch_size".to_string(), n.to_string()),
            ]
        });
        let result = loaded.execute_rec(&inputs, &rec).map_err(|e| e.to_string());
        let exec_us = sp.finish_us();
        for (i, (_, reply, enq)) in rows.into_iter().enumerate() {
            let output = if bad.contains(&i) {
                Err(format!("row length != {}", row_len))
            } else {
                // row slices go through `get`: a backend returning a
                // short output yields per-row errors, never a panicking
                // worker
                match &result {
                    Ok(out) => out
                        .get(i * out_row_len..(i + 1) * out_row_len)
                        .map(|s| s.to_vec())
                        .ok_or_else(|| {
                            format!("backend output too short for batch row {}", i)
                        }),
                    Err(e) => Err(e.clone()),
                }
            };
            let _ = reply.send(RowReply {
                output,
                latency_us: enq.elapsed().as_micros(),
                queue_us: queue_marks[i],
                exec_us,
                batch_size: n,
            });
        }
    }
}

fn drain_with_error(rx: &Receiver<Job>, msg: &str) {
    while let Ok(job) = rx.recv() {
        match job {
            Job::Raw { reply, enqueued, .. } => {
                let _ = reply.send(error_kernel_reply(msg, enqueued));
            }
            Job::Row { reply, enqueued, .. } => {
                let _ = reply.send(error_row_reply(msg, enqueued));
            }
            Job::Shutdown => break,
        }
    }
}

/// Error replies must carry the *real* elapsed time since submit, not
/// zero: a failure path that reports `latency_us: 0` drags the latency
/// percentiles down exactly when the service is misbehaving, flattering
/// p99 in the serve summary.
fn error_kernel_reply(msg: &str, enqueued: Instant) -> KernelReply {
    KernelReply {
        output: Err(msg.to_string()),
        queue_us: enqueued.elapsed().as_micros(),
        exec_us: 0,
    }
}

fn error_row_reply(msg: &str, enqueued: Instant) -> RowReply {
    // the full wait counts as queue time: the row never reached a batch
    let waited = enqueued.elapsed().as_micros();
    RowReply {
        output: Err(msg.to_string()),
        latency_us: waited,
        queue_us: waited,
        exec_us: 0,
        batch_size: 0,
    }
}

/// Assemble pending rows into one zero-padded batch tensor of
/// `capacity * row_len` values. Rows beyond `capacity` are ignored (the
/// worker never collects more than `max_batch`); rows whose length does
/// not match `row_len` are skipped and reported in the second return
/// value so the worker can reply with a per-row error instead of
/// corrupting the batch.
pub fn assemble_batch(
    rows: &[&[f32]],
    row_len: usize,
    capacity: usize,
) -> (Vec<f32>, Vec<usize>) {
    let mut batch = vec![0f32; capacity * row_len];
    let mut bad = Vec::new();
    for (i, row) in rows.iter().enumerate().take(capacity) {
        if row.len() != row_len {
            bad.push(i);
            continue;
        }
        batch[i * row_len..(i + 1) * row_len].copy_from_slice(row);
    }
    (batch, bad)
}

/// Latency percentile helper for serving reports. Re-exported from
/// [`crate::util::stats`], where the serve engine, benches and the
/// metrics exporter share the same nearest-rank definition.
pub fn percentile(sorted_us: &[u128], p: f64) -> u128 {
    crate::util::stats::percentile(sorted_us, p)
}

#[cfg(test)]
mod tests {
    use super::{assemble_batch, error_kernel_reply, error_row_reply, percentile};

    #[test]
    fn error_replies_report_real_elapsed_time() {
        // the zero-latency bug: error paths used to send latency_us: 0,
        // which dragged p99 *down* when the service failed
        let t0 = std::time::Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let row = error_row_reply("boom", t0);
        assert!(row.output.is_err());
        assert!(
            row.latency_us >= 5_000,
            "error row reply claims {}us after a 5ms wait",
            row.latency_us
        );
        // the queue/exec split must not hide the wait either: a row that
        // never executed spent its whole latency queued
        assert_eq!(row.queue_us, row.latency_us);
        assert_eq!(row.exec_us, 0);
        let kr = error_kernel_reply("boom", t0);
        assert!(kr.output.is_err());
        assert!(
            kr.queue_us >= 5_000,
            "error kernel reply claims {}us queue after a 5ms wait",
            kr.queue_us
        );
    }

    #[test]
    fn percentile_basics() {
        let v = vec![1u128, 2, 3, 4, 100];
        assert_eq!(percentile(&v, 50.0), 3);
        assert_eq!(percentile(&v, 99.0), 100);
        assert_eq!(percentile(&v, 0.0), 1);
        assert_eq!(percentile(&[], 50.0), 0);
    }

    #[test]
    fn percentile_boundary_cases() {
        // single element: every percentile is that element
        let one = [7u128];
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&one, p), 7);
        }
        // two elements: the midpoint rounds to the upper rank
        let two = [10u128, 20];
        assert_eq!(percentile(&two, 0.0), 10);
        assert_eq!(percentile(&two, 49.0), 10);
        assert_eq!(percentile(&two, 50.0), 20);
        assert_eq!(percentile(&two, 100.0), 20);
        // p beyond 100 clamps to the max instead of panicking
        assert_eq!(percentile(&two, 250.0), 20);
        // p100 is exactly the max, never out of bounds
        let v = [1u128, 2, 3, 4, 5, 6, 7, 8, 9];
        assert_eq!(percentile(&v, 100.0), 9);
        assert_eq!(percentile(&v, 25.0), 3);
    }

    #[test]
    fn assemble_batch_zero_pads_unused_slots() {
        let r0 = [1.0f32, 2.0];
        let r1 = [3.0f32, 4.0];
        let rows: Vec<&[f32]> = vec![&r0, &r1];
        let (batch, bad) = assemble_batch(&rows, 2, 4);
        assert!(bad.is_empty());
        assert_eq!(batch, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn assemble_batch_rejects_wrong_row_lengths() {
        let ok = [1.0f32, 2.0, 3.0];
        let short = [9.0f32];
        let long = [9.0f32, 9.0, 9.0, 9.0];
        let ok2 = [4.0f32, 5.0, 6.0];
        let rows: Vec<&[f32]> = vec![&ok, &short, &long, &ok2];
        let (batch, bad) = assemble_batch(&rows, 3, 4);
        assert_eq!(bad, vec![1, 2]);
        // good rows land in their slots; bad slots stay zeroed
        assert_eq!(&batch[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&batch[3..6], &[0.0, 0.0, 0.0]);
        assert_eq!(&batch[6..9], &[0.0, 0.0, 0.0]);
        assert_eq!(&batch[9..12], &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn assemble_batch_empty_and_overflow() {
        let (batch, bad) = assemble_batch(&[], 3, 2);
        assert_eq!(batch, vec![0.0; 6]);
        assert!(bad.is_empty());
        // rows beyond capacity are ignored, not panicked on
        let r = [1.0f32];
        let rows: Vec<&[f32]> = vec![&r, &r, &r];
        let (batch, bad) = assemble_batch(&rows, 1, 2);
        assert_eq!(batch, vec![1.0, 1.0]);
        assert!(bad.is_empty());
    }
}
