//! Dependency-free utility modules (the offline vendor set has no
//! serde/anyhow-class crates; see DESIGN.md dependency note).

pub mod bench;
pub mod json;
pub mod stats;
