//! A small JSON value type with a writer and a recursive-descent parser.
//!
//! The offline vendor set has no `serde`; the persistent tuning cache
//! (autotuner/cache.rs) needs a stable on-disk format, so we carry a
//! ~200-line self-contained implementation. Objects preserve insertion
//! order (they are association lists, not maps), which keeps cache files
//! diff-friendly.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9.0e15 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: an array of integers.
    pub fn as_i64_arr(&self) -> Option<Vec<i64>> {
        self.as_arr()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Serialize to a compact JSON string.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    let _ = write!(out, "{}", x);
                } else {
                    // JSON has no inf/nan; degrade to null
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns the value and rejects trailing junk.
    pub fn parse(text: &str) -> Result<Json, String> {
        let chars: Vec<char> = text.chars().collect();
        let mut p = Parser { chars, pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            return Err(format!("trailing characters at offset {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser {
    chars: Vec<char>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: char) -> Result<(), String> {
        self.skip_ws();
        match self.bump() {
            Some(x) if x == c => Ok(()),
            other => Err(format!("expected '{}', found {:?} at {}", c, other, self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        for c in word.chars() {
            if self.bump() != Some(c) {
                return Err(format!("bad literal near offset {}", self.pos));
            }
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some('n') => self.literal("null", Json::Null),
            Some('t') => self.literal("true", Json::Bool(true)),
            Some('f') => self.literal("false", Json::Bool(false)),
            Some('"') => Ok(Json::Str(self.string()?)),
            Some('[') => self.array(),
            Some('{') => self.object(),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at offset {}", other, self.pos)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some('-' | '+' | '.' | 'e' | 'E' | '0'..='9')
        ) {
            self.pos += 1;
        }
        let s: String = self.chars[start..self.pos].iter().collect();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {:?}: {}", s, e))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some('"') => return Ok(out),
                Some('\\') => match self.bump() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('/') => out.push('/'),
                    Some('n') => out.push('\n'),
                    Some('r') => out.push('\r'),
                    Some('t') => out.push('\t'),
                    Some('b') => out.push('\u{0008}'),
                    Some('f') => out.push('\u{000C}'),
                    Some('u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| c.to_digit(16))
                                .ok_or("bad \\u escape")?;
                            code = code * 16 + d;
                        }
                        // surrogate pairs are not needed for cache content;
                        // map unpaired surrogates to the replacement char
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {:?}", other)),
                },
                Some(c) => out.push(c),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect('[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some(']') => return Ok(Json::Arr(items)),
                other => return Err(format!("expected ',' or ']', found {:?}", other)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect('{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some('}') => return Ok(Json::Obj(fields)),
                other => return Err(format!("expected ',' or '}}', found {:?}", other)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::Obj(vec![
            ("name".into(), Json::Str("gemm".into())),
            ("shape".into(), Json::Arr(vec![Json::Num(4096.0), Json::Num(1024.0)])),
            ("rasterize".into(), Json::Bool(true)),
            ("time_us".into(), Json::Num(12.625)),
            ("none".into(), Json::Null),
        ]);
        let text = v.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.get("name").unwrap().as_str(), Some("gemm"));
        assert_eq!(back.get("shape").unwrap().as_i64_arr(), Some(vec![4096, 1024]));
        assert_eq!(back.get("time_us").unwrap().as_f64(), Some(12.625));
    }

    #[test]
    fn integers_serialize_without_decimal_point() {
        assert_eq!(Json::Num(128.0).dump(), "128");
        assert_eq!(Json::Num(-3.0).dump(), "-3");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\te".into());
        let text = v.dump();
        assert_eq!(Json::parse(&text).unwrap(), v);
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn whitespace_and_errors() {
        let v = Json::parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_i64_arr(), Some(vec![1, 2]));
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn lookup_misses() {
        let v = Json::parse("{\"a\":1}").unwrap();
        assert!(v.get("b").is_none());
        assert!(v.get("a").unwrap().as_str().is_none());
        assert_eq!(Json::Num(1.5).as_i64(), None);
    }
}
