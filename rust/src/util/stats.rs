//! Shared latency statistics: percentiles, summaries and histogram
//! buckets.
//!
//! One implementation for every consumer of timing samples — the
//! coordinator's serve summary, `serve::engine`'s per-phase p50/p99,
//! `tilelang bench`, and the [`crate::obs`] metrics exporter — so the
//! edge cases (empty slice, single sample, p0/p100, p > 100) are handled
//! once and identically everywhere.

/// Nearest-rank percentile over a **sorted** slice of microsecond
/// samples. `p` is in percent; out-of-range values clamp (p <= 0 is the
/// minimum, p >= 100 the maximum). An empty slice yields 0.
pub fn percentile(sorted_us: &[u128], p: f64) -> u128 {
    if sorted_us.is_empty() {
        return 0;
    }
    let idx = ((sorted_us.len() as f64 - 1.0) * p.max(0.0) / 100.0).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

/// [`percentile`] over f64 samples (bench numbers, metrics samples).
pub fn percentile_f64(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p.max(0.0) / 100.0).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Five-number-ish summary of a sample set.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p99: f64,
}

/// Summarize unsorted samples (sorts a copy; non-finite values are
/// dropped so one NaN cannot poison a whole metrics dump).
pub fn summarize(values: &[f64]) -> Summary {
    let mut v: Vec<f64> = values.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return Summary::default();
    }
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite values"));
    Summary {
        count: v.len(),
        sum: v.iter().sum(),
        min: v[0],
        max: v[v.len() - 1],
        p50: percentile_f64(&v, 50.0),
        p99: percentile_f64(&v, 99.0),
    }
}

/// A fixed-bound histogram in the Prometheus style: `bounds` are the
/// inclusive upper edges of the finite buckets; everything above the
/// last bound lands in the implicit `+Inf` bucket.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// One count per finite bound, plus the trailing `+Inf` bucket.
    counts: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl Histogram {
    /// A histogram over the given (ascending) finite bucket bounds.
    pub fn new(bounds: Vec<f64>) -> Histogram {
        let n = bounds.len();
        Histogram {
            bounds,
            counts: vec![0; n + 1],
            count: 0,
            sum: 0.0,
        }
    }

    /// Decade buckets from `lo` (>= 1) through `hi`: 10, 100, 1000, ...
    /// — the right shape for microsecond latencies spanning orders of
    /// magnitude.
    pub fn decades(lo: f64, hi: f64) -> Histogram {
        let mut bounds = Vec::new();
        let mut b = lo.max(1.0);
        while b <= hi {
            bounds.push(b);
            b *= 10.0;
        }
        Histogram::new(bounds)
    }

    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Cumulative `(upper_bound, count <= bound)` pairs, ending with the
    /// `(+Inf, total)` bucket — exactly what a Prometheus text
    /// `_bucket{le="..."}` series wants.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.counts.len());
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            let bound = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((bound, acc));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_edge_cases() {
        assert_eq!(percentile(&[], 50.0), 0);
        let one = [7u128];
        for p in [-5.0, 0.0, 50.0, 99.0, 100.0, 250.0] {
            assert_eq!(percentile(&one, p), 7);
        }
        let v = [1u128, 2, 3, 4, 100];
        assert_eq!(percentile(&v, 0.0), 1);
        assert_eq!(percentile(&v, 50.0), 3);
        assert_eq!(percentile(&v, 99.0), 100);
        assert_eq!(percentile(&v, 100.0), 100);
        // two elements: midpoint rounds to the upper rank
        let two = [10u128, 20];
        assert_eq!(percentile(&two, 49.0), 10);
        assert_eq!(percentile(&two, 50.0), 20);
    }

    #[test]
    fn summarize_handles_empty_singleton_and_nan() {
        assert_eq!(summarize(&[]), Summary::default());
        let s = summarize(&[42.0]);
        assert_eq!((s.count, s.min, s.max, s.p50, s.p99), (1, 42.0, 42.0, 42.0, 42.0));
        let s = summarize(&[1.0, f64::NAN, 3.0, f64::INFINITY]);
        assert_eq!(s.count, 2);
        assert_eq!(s.sum, 4.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let mut h = Histogram::decades(10.0, 10_000.0);
        for v in [5.0, 15.0, 150.0, 1_500.0, 150_000.0] {
            h.observe(v);
        }
        h.observe(f64::NAN); // dropped
        assert_eq!(h.count, 5);
        let cum = h.cumulative();
        assert_eq!(cum.len(), 5); // 10, 100, 1k, 10k, +Inf
        assert_eq!(cum[0], (10.0, 1));
        assert_eq!(cum[1], (100.0, 2));
        assert_eq!(cum[2], (1_000.0, 3));
        assert_eq!(cum[3], (10_000.0, 4));
        assert!(cum[4].0.is_infinite());
        assert_eq!(cum[4].1, 5);
    }
}
