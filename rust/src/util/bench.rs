//! Bench record format (`BENCH_*.json`) + regression comparison.
//!
//! `tilelang bench` measures each scenario on both execution backends
//! (interp oracle, compiled bytecode VM) and writes a [`BenchReport`].
//! One report is committed per PR (`BENCH_<n>.json` at the repo root),
//! so the perf trajectory accrues alongside the code. CI re-runs the
//! bench and gates with [`compare`]: a regression check on *relative*
//! speedups — machine-independent, unlike absolute wall times — failing
//! when the compiled-vs-interp speedup of any shared scenario (or the
//! geomean) drops more than the tolerance below the committed baseline.

use std::fs;
use std::path::Path;

use crate::util::json::Json;

/// One measured scenario: a kernel, serve loop or graph block, timed on
/// both backends. Times are microseconds per execution.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchScenario {
    pub name: String,
    /// `kernel`, `serve`, `graph` or `sharded` — display grouping only.
    pub kind: String,
    pub interp_p50_us: f64,
    pub interp_p99_us: f64,
    pub compiled_p50_us: f64,
    pub compiled_p99_us: f64,
    /// One-time bytecode compile cost (lowered program -> instruction
    /// stream), amortized over every subsequent request.
    pub compile_us: f64,
    /// Executions per second on the compiled backend (p50-based).
    pub throughput_per_s: f64,
    /// `interp_p50_us / compiled_p50_us`.
    pub speedup: f64,
    /// Disabled-tracing overhead ratio (`>= 1.0`): the cost the
    /// observability span sites add to one execution when no recorder is
    /// attached, relative to the execution's p50. `0.0` = not measured
    /// for this scenario (the field is omitted from the JSON). Gated at
    /// [`TRACE_OVERHEAD_CEILING`] by [`compare`].
    pub trace_overhead: f64,
    /// DRAM bytes one execution moves, from the `traffic.*` counters.
    /// Deterministic (counted on logical extents, identical on both
    /// backends by construction), so [`compare`] gates it with *exact*
    /// equality against the baseline — any drift means the accounting
    /// or the kernels changed, not the machine. `0` = not measured
    /// (field omitted from the JSON).
    pub dram_bytes: u64,
    /// FLOPs per DRAM byte for one execution (`traffic.flops /
    /// dram_bytes`) — the roofline x-coordinate. `0.0` = not measured
    /// (field omitted from the JSON).
    pub arith_intensity: f64,
}

/// Disabled tracing must cost less than 2% of the traced scenario:
/// `compare` fails any measured `trace_overhead` above this ratio.
pub const TRACE_OVERHEAD_CEILING: f64 = 1.02;

/// A full bench run: the committed perf record for one PR.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    /// Report label, e.g. `BENCH_6`.
    pub label: String,
    /// `full` or `quick` (same scenario set, fewer iterations).
    pub mode: String,
    /// Where the numbers came from (host class, measured vs estimated).
    pub provenance: String,
    /// `"measured"` (real bench run) or `"estimated"` (hand-authored
    /// numbers, e.g. when the authoring environment has no toolchain).
    /// `bench-check` downgrades regressions against an estimated
    /// baseline to warnings. Older records without the field sniff it
    /// from the `provenance` prefix at load time.
    pub provenance_kind: String,
    pub scenarios: Vec<BenchScenario>,
}

impl BenchReport {
    /// Geometric mean of the per-scenario compiled-vs-interp speedups.
    pub fn geomean_speedup(&self) -> f64 {
        let positive: Vec<f64> = self
            .scenarios
            .iter()
            .map(|s| s.speedup)
            .filter(|&s| s > 0.0)
            .collect();
        if positive.is_empty() {
            return 0.0;
        }
        let log_sum: f64 = positive.iter().map(|s| s.ln()).sum();
        (log_sum / positive.len() as f64).exp()
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("schema".into(), Json::Str("tilelang-bench-v1".into())),
            ("label".into(), Json::Str(self.label.clone())),
            ("mode".into(), Json::Str(self.mode.clone())),
            ("provenance".into(), Json::Str(self.provenance.clone())),
            (
                "provenance_kind".into(),
                Json::Str(self.provenance_kind.clone()),
            ),
            (
                "geomean_speedup".into(),
                Json::Num(round3(self.geomean_speedup())),
            ),
            (
                "scenarios".into(),
                Json::Arr(
                    self.scenarios
                        .iter()
                        .map(|s| {
                            Json::Obj(vec![
                                ("name".into(), Json::Str(s.name.clone())),
                                ("kind".into(), Json::Str(s.kind.clone())),
                                ("interp_p50_us".into(), Json::Num(round3(s.interp_p50_us))),
                                ("interp_p99_us".into(), Json::Num(round3(s.interp_p99_us))),
                                (
                                    "compiled_p50_us".into(),
                                    Json::Num(round3(s.compiled_p50_us)),
                                ),
                                (
                                    "compiled_p99_us".into(),
                                    Json::Num(round3(s.compiled_p99_us)),
                                ),
                                ("compile_us".into(), Json::Num(round3(s.compile_us))),
                                (
                                    "throughput_per_s".into(),
                                    Json::Num(round3(s.throughput_per_s)),
                                ),
                                ("speedup".into(), Json::Num(round3(s.speedup))),
                            ]
                            .into_iter()
                            .chain((s.trace_overhead > 0.0).then(|| {
                                (
                                    "trace_overhead".to_string(),
                                    Json::Num(round5(s.trace_overhead)),
                                )
                            }))
                            .chain((s.dram_bytes > 0).then(|| {
                                (
                                    "dram_bytes".to_string(),
                                    Json::Num(s.dram_bytes as f64),
                                )
                            }))
                            .chain((s.arith_intensity > 0.0).then(|| {
                                (
                                    "arith_intensity".to_string(),
                                    Json::Num(round5(s.arith_intensity)),
                                )
                            }))
                            .collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<BenchReport, String> {
        let schema = v
            .get("schema")
            .and_then(|s| s.as_str())
            .ok_or("bench report: missing schema")?;
        if schema != "tilelang-bench-v1" {
            return Err(format!("bench report: unknown schema {:?}", schema));
        }
        let sstr = |o: &Json, k: &str| -> Result<String, String> {
            Ok(o.get(k)
                .and_then(|x| x.as_str())
                .ok_or_else(|| format!("bench report: missing string field {:?}", k))?
                .to_string())
        };
        let snum = |o: &Json, k: &str| -> Result<f64, String> {
            o.get(k)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| format!("bench report: missing numeric field {:?}", k))
        };
        let mut scenarios = Vec::new();
        for s in v
            .get("scenarios")
            .and_then(|a| a.as_arr())
            .ok_or("bench report: missing scenarios array")?
        {
            scenarios.push(BenchScenario {
                name: sstr(s, "name")?,
                kind: sstr(s, "kind")?,
                interp_p50_us: snum(s, "interp_p50_us")?,
                interp_p99_us: snum(s, "interp_p99_us")?,
                compiled_p50_us: snum(s, "compiled_p50_us")?,
                compiled_p99_us: snum(s, "compiled_p99_us")?,
                compile_us: snum(s, "compile_us")?,
                throughput_per_s: snum(s, "throughput_per_s")?,
                speedup: snum(s, "speedup")?,
                trace_overhead: snum(s, "trace_overhead").unwrap_or(0.0),
                dram_bytes: snum(s, "dram_bytes").unwrap_or(0.0) as u64,
                arith_intensity: snum(s, "arith_intensity").unwrap_or(0.0),
            });
        }
        let provenance = sstr(v, "provenance")?;
        // records predating the field sniff the kind from the free-form
        // provenance string (BENCH_7 and older start with "estimated:")
        let provenance_kind = sstr(v, "provenance_kind").unwrap_or_else(|_| {
            if provenance.starts_with("estimated") {
                "estimated".to_string()
            } else {
                "measured".to_string()
            }
        });
        Ok(BenchReport {
            label: sstr(v, "label")?,
            mode: sstr(v, "mode")?,
            provenance,
            provenance_kind,
            scenarios,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        fs::write(path.as_ref(), pretty(&self.to_json()) + "\n")
            .map_err(|e| format!("write {:?}: {}", path.as_ref(), e))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<BenchReport, String> {
        let text = fs::read_to_string(path.as_ref())
            .map_err(|e| format!("read {:?}: {}", path.as_ref(), e))?;
        BenchReport::from_json(&Json::parse(&text)?)
    }
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

/// Five decimals for ratios near 1.0 (`trace_overhead`), where round3
/// would erase the measurement entirely.
fn round5(x: f64) -> f64 {
    (x * 100_000.0).round() / 100_000.0
}

/// Indent a compact JSON dump for a diff-friendly committed file:
/// objects-in-arrays each get their own line. Good enough for the bench
/// schema (no nested arrays-of-arrays).
fn pretty(v: &Json) -> String {
    let mut out = String::new();
    write_pretty(v, 0, &mut out);
    out
}

fn write_pretty(v: &Json, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    let pad_in = "  ".repeat(indent + 1);
    match v {
        Json::Arr(items) if !items.is_empty() => {
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                out.push_str(&pad_in);
                write_pretty(item, indent + 1, out);
                if i + 1 < items.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push(']');
        }
        Json::Obj(fields) if !fields.is_empty() => {
            out.push_str("{\n");
            for (i, (k, val)) in fields.iter().enumerate() {
                out.push_str(&pad_in);
                out.push_str(&Json::Str(k.clone()).dump());
                out.push_str(": ");
                write_pretty(val, indent + 1, out);
                if i + 1 < fields.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push('}');
        }
        other => out.push_str(&other.dump()),
    }
}

/// Compare a current bench run against a committed baseline. Returns the
/// list of regression messages (empty = pass).
///
/// The gate is on *relative* speedups: absolute microseconds differ per
/// machine, but compiled-vs-interp ratios on the same host are stable.
/// A scenario regresses when its speedup drops more than `tol`
/// (fractional, e.g. `0.20`) below the baseline's; scenarios present in
/// only one report are reported as informational mismatches but do not
/// fail unless they vanished from the current run.
pub fn compare(baseline: &BenchReport, current: &BenchReport, tol: f64) -> Vec<String> {
    let mut failures = Vec::new();
    for b in &baseline.scenarios {
        match current.scenarios.iter().find(|c| c.name == b.name) {
            None => failures.push(format!(
                "scenario {} present in baseline but missing from current run",
                b.name
            )),
            Some(c) => {
                let floor = b.speedup * (1.0 - tol);
                if c.speedup < floor {
                    failures.push(format!(
                        "scenario {}: speedup {:.2}x < {:.2}x (baseline {:.2}x - {:.0}% tol)",
                        b.name,
                        c.speedup,
                        floor,
                        b.speedup,
                        tol * 100.0
                    ));
                }
                // DRAM traffic is counted, not timed: when both records
                // carry it, the bytes must match exactly — drift means
                // the kernels or the accounting changed
                if b.dram_bytes > 0 && c.dram_bytes > 0 && b.dram_bytes != c.dram_bytes {
                    failures.push(format!(
                        "scenario {}: DRAM bytes {} != baseline {} (traffic counters \
                         are deterministic; this is a semantic change, not noise)",
                        b.name, c.dram_bytes, b.dram_bytes
                    ));
                }
            }
        }
    }
    // absolute gate, independent of the baseline: instrumentation with
    // the recorder off must stay in the noise (< 2% of the scenario)
    for c in &current.scenarios {
        if c.trace_overhead > TRACE_OVERHEAD_CEILING {
            failures.push(format!(
                "scenario {}: disabled-tracing overhead {:.2}% exceeds {:.0}%",
                c.name,
                (c.trace_overhead - 1.0) * 100.0,
                (TRACE_OVERHEAD_CEILING - 1.0) * 100.0
            ));
        }
    }
    let (bg, cg) = (baseline.geomean_speedup(), current.geomean_speedup());
    if cg < bg * (1.0 - tol) {
        failures.push(format!(
            "geomean speedup {:.2}x < {:.2}x (baseline {:.2}x - {:.0}% tol)",
            cg,
            bg * (1.0 - tol),
            bg,
            tol * 100.0
        ));
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scenario(name: &str, speedup: f64) -> BenchScenario {
        BenchScenario {
            name: name.into(),
            kind: "kernel".into(),
            interp_p50_us: 1000.0 * speedup,
            interp_p99_us: 1100.0 * speedup,
            compiled_p50_us: 1000.0,
            compiled_p99_us: 1100.0,
            compile_us: 50.0,
            throughput_per_s: 1000.0,
            speedup,
            trace_overhead: 0.0,
            dram_bytes: 0,
            arith_intensity: 0.0,
        }
    }

    fn report(speedups: &[(&str, f64)]) -> BenchReport {
        BenchReport {
            label: "BENCH_TEST".into(),
            mode: "quick".into(),
            provenance: "unit test".into(),
            provenance_kind: "measured".into(),
            scenarios: speedups.iter().map(|(n, s)| scenario(n, *s)).collect(),
        }
    }

    #[test]
    fn json_roundtrip_preserves_report() {
        let mut r = report(&[("gemm", 4.0), ("attn", 6.5)]);
        r.scenarios[0].trace_overhead = 1.00341;
        r.scenarios[0].dram_bytes = 98304;
        r.scenarios[0].arith_intensity = 5.33333;
        let back = BenchReport::from_json(&Json::parse(&pretty(&r.to_json())).unwrap()).unwrap();
        assert_eq!(back, r);
        // zero-valued traffic fields stay out of the serialized record
        let dump = pretty(&r.to_json());
        assert!(dump.contains("\"dram_bytes\""));
        let plain = pretty(&report(&[("gemm", 4.0)]).to_json());
        assert!(!plain.contains("dram_bytes") && !plain.contains("arith_intensity"));
    }

    #[test]
    fn dram_bytes_gate_is_exact_and_skips_unmeasured_records() {
        let mut base = report(&[("gemm", 4.0)]);
        let mut cur = report(&[("gemm", 4.0)]);
        base.scenarios[0].dram_bytes = 98304;
        // current run without traffic fields (old binary): no gate
        assert!(compare(&base, &cur, 0.20).is_empty());
        cur.scenarios[0].dram_bytes = 98304;
        assert!(compare(&base, &cur, 0.20).is_empty());
        cur.scenarios[0].dram_bytes = 98308;
        let fails = compare(&base, &cur, 0.20);
        assert_eq!(fails.len(), 1, "{:?}", fails);
        assert!(fails[0].contains("DRAM bytes"), "{}", fails[0]);
    }

    #[test]
    fn provenance_kind_is_sniffed_from_legacy_records() {
        // a pre-provenance_kind record: the field is absent from the JSON
        let mut r = report(&[("gemm", 4.0)]);
        r.provenance = "estimated: no toolchain on the authoring host".into();
        let mut doc = r.to_json();
        if let Json::Obj(fields) = &mut doc {
            fields.retain(|(k, _)| k != "provenance_kind");
        }
        let back = BenchReport::from_json(&doc).unwrap();
        assert_eq!(back.provenance_kind, "estimated");

        r.provenance = "measured: tilelang bench on x86_64-linux".into();
        let mut doc = r.to_json();
        if let Json::Obj(fields) = &mut doc {
            fields.retain(|(k, _)| k != "provenance_kind");
        }
        assert_eq!(BenchReport::from_json(&doc).unwrap().provenance_kind, "measured");
    }

    #[test]
    fn trace_overhead_above_ceiling_is_a_regression() {
        let base = report(&[("gemm", 4.0)]);
        let mut cur = report(&[("gemm", 4.0)]);
        cur.scenarios[0].trace_overhead = 1.01; // within the 2% ceiling
        assert!(compare(&base, &cur, 0.20).is_empty());
        cur.scenarios[0].trace_overhead = 1.05;
        let fails = compare(&base, &cur, 0.20);
        assert_eq!(fails.len(), 1, "{:?}", fails);
        assert!(fails[0].contains("tracing overhead"), "{}", fails[0]);
    }

    #[test]
    fn geomean_is_geometric() {
        let r = report(&[("a", 2.0), ("b", 8.0)]);
        assert!((r.geomean_speedup() - 4.0).abs() < 1e-9);
        assert_eq!(report(&[]).geomean_speedup(), 0.0);
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let base = report(&[("gemm", 4.0), ("attn", 6.0)]);
        let cur = report(&[("gemm", 3.5), ("attn", 5.2)]);
        assert!(compare(&base, &cur, 0.20).is_empty());
    }

    #[test]
    fn compare_fails_on_regression_and_missing_scenarios() {
        let base = report(&[("gemm", 4.0), ("attn", 6.0)]);
        let cur = report(&[("gemm", 2.0), ("attn", 6.0)]);
        let fails = compare(&base, &cur, 0.20);
        // the gemm scenario and the geomean both drop past 20%
        assert_eq!(fails.len(), 2, "{:?}", fails);
        assert!(fails[0].contains("gemm"));

        let missing = report(&[("attn", 6.0)]);
        let fails = compare(&base, &missing, 0.20);
        assert!(fails.iter().any(|f| f.contains("missing")), "{:?}", fails);
        // new scenarios in the current run are fine
        let extra = report(&[("gemm", 4.0), ("attn", 6.0), ("new", 1.0)]);
        assert!(compare(&base, &extra, 0.20).is_empty());
    }

    #[test]
    fn save_and_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("tilelang-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_T.json");
        let r = report(&[("gemm", 4.0)]);
        r.save(&path).unwrap();
        assert_eq!(BenchReport::load(&path).unwrap(), r);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
