//! Minimal error plumbing for the runtime/coordinator layers.
//!
//! The offline vendor set has no `anyhow`; this module provides the small
//! subset the crate uses: a string-backed `Error`, a `Result` alias, the
//! `anyhow!` / `bail!` macros and a `Context` extension trait.

use std::fmt;

/// A boxed, message-carrying error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

/// Crate-wide result alias. The error type defaults to [`Error`] but can
/// be overridden (`Result<T, String>`), mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style helpers for any displayable error type.
pub trait Context<T> {
    fn context(self, msg: &str) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {}", msg, e)))
    }
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {}", f(), e)))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: &str) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_context() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let c = r.context("opening file");
        assert!(c.unwrap_err().to_string().starts_with("opening file: "));
        let n: Option<i32> = None;
        assert_eq!(n.context("empty").unwrap_err().to_string(), "empty");
    }

    #[test]
    fn macros() {
        fn f(fail: bool) -> Result<i32> {
            if fail {
                bail!("code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "code 7");
        let e = anyhow!("x={}", 3);
        assert_eq!(e.to_string(), "x=3");
    }
}
