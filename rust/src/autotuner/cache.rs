//! Persistent on-disk tuning cache.
//!
//! JSON file keyed by (workload, shape, dtype, device, variant); see
//! `rust/src/autotuner/README.md` for the format. Benches, the CLI and
//! the coordinator share one cache so a shape is swept once per device
//! and every later run reuses the stored config (`evaluated == 0`).

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Identity of one tuning entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheKey {
    /// Workload family (`"gemm"`, `"flash_attention"`, ...).
    pub workload: String,
    /// Logical shape signature (problem dims, not tile dims).
    pub shape: Vec<i64>,
    /// Input dtype signature (`"float16"`, `"w4a16"`, ...).
    pub dtype: String,
    /// Device name (`Device::name`).
    pub device: String,
    /// Cost-model variant (penalty fingerprint); `"default"` for
    /// `Penalties::none()`. Keeps baseline sweeps from colliding with
    /// the tilelang entries under the same workload/shape key.
    pub variant: String,
    /// Shard count the kernel is tuned under (`1` = unsharded). Sharded
    /// serving tunes per-shard sub-shapes whose optima need not match a
    /// same-shape single-device kernel, so the count is part of the
    /// identity. Entries written before this field existed decode as 1.
    pub shards: i64,
}

impl CacheKey {
    fn to_json_fields(&self) -> Vec<(String, Json)> {
        vec![
            ("workload".into(), Json::Str(self.workload.clone())),
            (
                "shape".into(),
                Json::Arr(self.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
            ),
            ("dtype".into(), Json::Str(self.dtype.clone())),
            ("device".into(), Json::Str(self.device.clone())),
            ("variant".into(), Json::Str(self.variant.clone())),
            ("shards".into(), Json::Num(self.shards as f64)),
        ]
    }

    fn from_json(v: &Json) -> Option<CacheKey> {
        Some(CacheKey {
            workload: v.get("workload")?.as_str()?.to_string(),
            shape: v.get("shape")?.as_i64_arr()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
            device: v.get("device")?.as_str()?.to_string(),
            variant: v.get("variant")?.as_str()?.to_string(),
            shards: v.get("shards").and_then(|s| s.as_i64()).unwrap_or(1),
        })
    }
}

/// One cached tuning decision.
#[derive(Clone, Debug)]
struct Entry {
    key: CacheKey,
    config: Json,
    time_us: f64,
}

/// The persistent tuning cache.
///
/// Load errors are non-fatal: a missing, unreadable or corrupt file
/// yields an empty cache (tuning falls back to a fresh sweep), so a bad
/// cache can never break a bench or serving start.
pub struct TuningCache {
    path: Option<PathBuf>,
    entries: Vec<Entry>,
}

pub const CACHE_FORMAT_VERSION: i64 = 1;

impl TuningCache {
    /// A cache that never touches disk (tests, one-shot runs).
    pub fn in_memory() -> TuningCache {
        TuningCache {
            path: None,
            entries: Vec::new(),
        }
    }

    /// Open (or initialize) a cache file.
    pub fn open(path: impl Into<PathBuf>) -> TuningCache {
        let path = path.into();
        let mut cache = TuningCache {
            path: Some(path.clone()),
            entries: Vec::new(),
        };
        let Ok(text) = std::fs::read_to_string(&path) else {
            return cache;
        };
        match Json::parse(&text) {
            Ok(doc) => {
                if doc.get("version").and_then(|v| v.as_i64()) != Some(CACHE_FORMAT_VERSION) {
                    eprintln!(
                        "tuning cache {:?}: unknown version, starting fresh",
                        path
                    );
                    return cache;
                }
                for e in doc.get("entries").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                    let (Some(key), Some(config)) = (CacheKey::from_json(e), e.get("config"))
                    else {
                        continue;
                    };
                    let time_us = e.get("time_us").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    cache.entries.push(Entry {
                        key,
                        config: config.clone(),
                        time_us,
                    });
                }
            }
            Err(err) => {
                eprintln!("tuning cache {:?}: parse error ({}), starting fresh", path, err);
            }
        }
        cache
    }

    /// Default cache location: `$TILELANG_TUNE_CACHE` or
    /// `.tilelang/tune_cache.json` under the working directory.
    pub fn default_path() -> PathBuf {
        std::env::var_os("TILELANG_TUNE_CACHE")
            .map(PathBuf::from)
            .unwrap_or_else(|| Path::new(".tilelang").join("tune_cache.json"))
    }

    /// Open the default cache.
    pub fn open_default() -> TuningCache {
        TuningCache::open(TuningCache::default_path())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up the stored config for a key.
    pub fn get(&self, key: &CacheKey) -> Option<&Json> {
        self.entries
            .iter()
            .find(|e| &e.key == key)
            .map(|e| &e.config)
    }

    /// The stored model time for a key, if any.
    pub fn time_us(&self, key: &CacheKey) -> Option<f64> {
        self.entries.iter().find(|e| &e.key == key).map(|e| e.time_us)
    }

    /// Insert or replace an entry.
    pub fn put(&mut self, key: CacheKey, config: Json, time_us: f64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.key == key) {
            e.config = config;
            e.time_us = time_us;
        } else {
            self.entries.push(Entry {
                key,
                config,
                time_us,
            });
        }
    }

    /// Serialize the whole cache document.
    pub fn to_json(&self) -> Json {
        let entries = self
            .entries
            .iter()
            .map(|e| {
                let mut fields = e.key.to_json_fields();
                fields.push(("time_us".into(), Json::Num(e.time_us)));
                fields.push(("config".into(), e.config.clone()));
                Json::Obj(fields)
            })
            .collect();
        Json::Obj(vec![
            ("version".into(), Json::Num(CACHE_FORMAT_VERSION as f64)),
            ("entries".into(), Json::Arr(entries)),
        ])
    }

    /// Write the cache back to its file (no-op for in-memory caches).
    /// The write is atomic (temp file + rename) so concurrent savers —
    /// e.g. coordinator workers tuning different artifacts — can never
    /// leave a torn, malformed cache behind; the worst outcome of a
    /// race is last-writer-wins on the entry set.
    pub fn save(&self) -> Result<(), String> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("creating {:?}: {}", parent, e))?;
            }
        }
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SAVE_SEQ: AtomicUsize = AtomicUsize::new(0);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            SAVE_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, self.to_json().dump())
            .map_err(|e| format!("writing {:?}: {}", tmp, e))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| format!("renaming {:?} -> {:?}: {}", tmp, path, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(workload: &str) -> CacheKey {
        CacheKey {
            workload: workload.into(),
            shape: vec![128, 256, 64],
            dtype: "float16".into(),
            device: "A100-80G".into(),
            variant: "default".into(),
            shards: 1,
        }
    }

    #[test]
    fn put_get_replace() {
        let mut c = TuningCache::in_memory();
        assert!(c.is_empty());
        assert!(c.get(&key("gemm")).is_none());
        c.put(key("gemm"), Json::Num(1.0), 10.0);
        c.put(key("attn"), Json::Num(2.0), 20.0);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&key("gemm")), Some(&Json::Num(1.0)));
        assert_eq!(c.time_us(&key("attn")), Some(20.0));
        // replace keeps one entry per key
        c.put(key("gemm"), Json::Num(3.0), 30.0);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&key("gemm")), Some(&Json::Num(3.0)));
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let mut c = TuningCache::in_memory();
        c.put(key("gemm"), Json::Num(1.0), 1.0);
        let mut other_dev = key("gemm");
        other_dev.device = "H100-SXM".into();
        let mut other_variant = key("gemm");
        other_variant.variant = "triton".into();
        let mut other_shards = key("gemm");
        other_shards.shards = 2;
        assert!(c.get(&other_dev).is_none());
        assert!(c.get(&other_variant).is_none());
        assert!(c.get(&other_shards).is_none());
    }

    #[test]
    fn disk_roundtrip_and_corrupt_files() {
        let dir = std::env::temp_dir().join(format!("tilelang-cache-test-{}", std::process::id()));
        let path = dir.join("tune_cache.json");
        let _ = std::fs::remove_file(&path);

        let mut c = TuningCache::open(&path);
        assert!(c.is_empty());
        c.put(
            key("gemm"),
            Json::Obj(vec![("block_m".into(), Json::Num(128.0))]),
            42.5,
        );
        c.save().expect("save");

        let c2 = TuningCache::open(&path);
        assert_eq!(c2.len(), 1);
        let cfg = c2.get(&key("gemm")).expect("hit");
        assert_eq!(cfg.get("block_m").and_then(|v| v.as_i64()), Some(128));
        assert_eq!(c2.time_us(&key("gemm")), Some(42.5));

        // corrupt file degrades to an empty cache, not a panic
        std::fs::write(&path, "{not json").unwrap();
        let c3 = TuningCache::open(&path);
        assert!(c3.is_empty());

        let _ = std::fs::remove_dir_all(&dir);
    }
}
