//! Tile-configuration autotuner.
//!
//! Sweeps the `TileConfig` search space, scoring each candidate with the
//! analytical model — the mechanism behind the paper's adaptive-tile
//! advantage over fixed-configuration libraries (§5.2: FlashAttention-3
//! "cannot efficiently adapt to varying workload sizes").

use crate::ir::dtype::DType;
use crate::sim::device::Device;
use crate::sim::model::{simulate_kernel, Penalties, SimReport};
use crate::workloads::attention::{flash_attention_program, AttnConfig};
use crate::workloads::matmul::{matmul_program, TileConfig};
use crate::workloads::shapes::AttnShape;

/// Result of an autotuning sweep.
#[derive(Clone, Debug)]
pub struct TuneResult<C> {
    pub config: C,
    pub report: SimReport,
    pub evaluated: usize,
}

/// Autotune a GEMM. Candidates that fail to compile (e.g. shared-memory
/// budget) are skipped, mirroring `tilelang.autotune` behaviour.
pub fn tune_gemm(
    m: i64,
    n: i64,
    k: i64,
    dtype: DType,
    dev: &Device,
    pen: &Penalties,
) -> TuneResult<TileConfig> {
    // pad degenerate dims to the minimum tile the hardware supports
    let (pm, pn, pk) = (m.max(16), n.max(16), k.max(16));
    let mut best: Option<(TileConfig, SimReport)> = None;
    let mut evaluated = 0;
    for cfg in TileConfig::search_space(pm, pn, pk) {
        if pm % cfg.block_m != 0 || pn % cfg.block_n != 0 || pk % cfg.block_k != 0 {
            continue;
        }
        let prog = matmul_program(pm, pn, pk, dtype, &cfg);
        match simulate_kernel(&prog, dev, pen) {
            Ok(r) => {
                evaluated += 1;
                if best.as_ref().map(|(_, b)| r.time_us < b.time_us).unwrap_or(true) {
                    best = Some((cfg, r));
                }
            }
            Err(_) => continue,
        }
    }
    let (config, report) = best.expect("no feasible GEMM configuration");
    TuneResult {
        config,
        report,
        evaluated,
    }
}

/// Autotune FlashAttention block sizes.
pub fn tune_attention(
    s: &AttnShape,
    dev: &Device,
    pen: &Penalties,
) -> TuneResult<AttnConfig> {
    let mut best: Option<(AttnConfig, SimReport)> = None;
    let mut evaluated = 0;
    for bm in [32i64, 64, 128] {
        for bn in [32i64, 64, 128] {
            for stages in [2usize, 3] {
                if s.seq_len % bm != 0 || s.seq_len % bn != 0 {
                    continue;
                }
                let cfg = AttnConfig {
                    block_m: bm,
                    block_n: bn,
                    num_stages: stages,
                    threads: 128,
                };
                let prog = flash_attention_program(
                    s.batch * s.heads,
                    s.seq_len,
                    s.head_dim,
                    s.causal,
                    &cfg,
                );
                match simulate_kernel(&prog, dev, pen) {
                    Ok(r) => {
                        evaluated += 1;
                        if best
                            .as_ref()
                            .map(|(_, b)| r.time_us < b.time_us)
                            .unwrap_or(true)
                        {
                            best = Some((cfg, r));
                        }
                    }
                    Err(_) => continue,
                }
            }
        }
    }
    let (config, report) = best.expect("no feasible attention configuration");
    TuneResult {
        config,
        report,
        evaluated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::shapes::FA_SHAPES;

    #[test]
    fn gemm_tuner_finds_feasible_configs() {
        let dev = Device::a100();
        let r = tune_gemm(4096, 1024, 8192, DType::F16, &dev, &Penalties::none());
        assert!(r.evaluated > 5);
        assert!(r.report.time_us > 0.0);
        assert!(r.config.block_m >= 32);
    }

    #[test]
    fn tuner_adapts_tiles_to_sequence_length() {
        let dev = Device::h100();
        // tiny workload: 8 heads x seq 256 -> 128-wide tiles leave most
        // SMs idle; the tuner must pick small blocks (the adaptive-tile
        // advantage over FA3's fixed 128 of §5.2)
        let tiny = AttnShape {
            name: "tiny",
            batch: 1,
            heads: 8,
            seq_len: 256,
            head_dim: 128,
            causal: false,
        };
        let tuned = tune_attention(&tiny, &dev, &Penalties::none());
        assert!(
            tuned.config.block_m <= 64,
            "tiny workloads should pick small tiles, got {}",
            tuned.config.block_m
        );
        // and the tuned config never loses to the fixed-128 config
        let fixed = AttnConfig { block_m: 128, block_n: 128, num_stages: 2, threads: 128 };
        let prog = flash_attention_program(8, 256, 128, false, &fixed);
        let fixed_r = simulate_kernel(&prog, &dev, &Penalties::none()).unwrap();
        assert!(tuned.report.time_us <= fixed_r.time_us * 1.001);
        // long sequences still reach good efficiency
        let long = tune_attention(&FA_SHAPES[4], &dev, &Penalties::none());
        assert!(long.report.tflops > tuned.report.tflops);
    }
}
