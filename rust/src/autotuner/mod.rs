//! Tile-configuration autotuner.
//!
//! The mechanism behind the paper's adaptive-tile advantage over
//! fixed-configuration libraries (§5.2: FlashAttention-3 "cannot
//! efficiently adapt to varying workload sizes"), grown into a unified
//! subsystem:
//!
//! * [`Tunable`] — implemented by every workload family (GEMM, flash
//!   attention, MLA decode, linear attention, dequant-GEMM): enumerates
//!   candidate configs and builds the `TileProgram` for each;
//! * [`search::tune`] — one generic, parallel, deterministic search
//!   driver scoring candidates with `sim::simulate_kernel` (no
//!   per-workload argmin loops);
//! * [`cache::TuningCache`] — a persistent JSON cache keyed by
//!   (workload, shape, dtype, device, variant) so benches, the CLI and
//!   serving starts reuse tuned configs instead of re-sweeping;
//! * `Result`-based error handling throughout: infeasible spaces return
//!   [`TuneError`], never panic.
//!
//! See `rust/src/autotuner/README.md` for the API walkthrough and the
//! cache file format.

pub mod cache;
pub mod search;

pub use cache::{CacheKey, TuningCache};
pub use search::tune;

use std::fmt;

use crate::ir::dtype::DType;
use crate::ir::program::TileProgram;
use crate::sim::device::Device;
use crate::sim::model::{simulate_kernel, Penalties, SimReport};
use crate::util::json::Json;
use crate::workloads::attention::{AttentionTunable, AttnConfig, MlaConfig, MlaTunable};
use crate::workloads::dequant::{DequantConfig, DequantTunable, WeightFormat};
use crate::workloads::linear_attention::{ChunkKind, LinAttnConfig, LinearAttentionTunable};
use crate::workloads::matmul::{GemmTunable, TileConfig};
use crate::workloads::shapes::{AttnShape, LinAttnShape, MlaShape};

/// Result of an autotuning sweep.
#[derive(Clone, Debug)]
pub struct TuneResult<C> {
    pub config: C,
    pub report: SimReport,
    /// Candidates that compiled and were scored during this call.
    /// `0` when the config came from the cache (no sweep happened).
    pub evaluated: usize,
    /// True when the config was served from the tuning cache.
    pub cache_hit: bool,
}

/// Tuning failure: every infeasible search space is an error, not a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TuneError {
    /// The workload produced no candidates (e.g. no tile divides the shape).
    EmptySpace { workload: String },
    /// Candidates existed but none compiled on this device.
    NoFeasibleConfig { workload: String, candidates: usize },
}

impl fmt::Display for TuneError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TuneError::EmptySpace { workload } => {
                write!(f, "{}: empty tuning space for this shape", workload)
            }
            TuneError::NoFeasibleConfig {
                workload,
                candidates,
            } => write!(
                f,
                "{}: none of {} candidate configs compiled on this device",
                workload, candidates
            ),
        }
    }
}

impl std::error::Error for TuneError {}

/// A tile configuration that can be persisted in the tuning cache.
pub trait TunableConfig: Clone + PartialEq + fmt::Debug + Send + Sync + 'static {
    fn to_json(&self) -> Json;
    fn from_json(v: &Json) -> Option<Self>;
}

/// A workload the generic driver can tune.
///
/// Contract: every config returned by [`candidates`](Tunable::candidates)
/// must satisfy [`accepts`](Tunable::accepts), and `build` must not panic
/// on accepted configs — device-level feasibility (shared-memory budget,
/// layout constraints) is checked by compilation inside the driver and
/// failing candidates are skipped.
pub trait Tunable: Sync {
    type Config: TunableConfig;

    /// Stable workload name (cache key component).
    fn workload(&self) -> &'static str;
    /// Logical problem-shape signature (cache key component).
    fn shape_key(&self) -> Vec<i64>;
    /// Dtype signature (cache key component).
    fn dtype_key(&self) -> String;
    /// Structural feasibility of a config for this problem (divisibility,
    /// packing). Used both to filter the candidate space and to reject
    /// stale cache entries without panicking.
    fn accepts(&self, cfg: &Self::Config) -> bool;
    /// Enumerate the candidate configs (all satisfying `accepts`).
    fn candidates(&self) -> Vec<Self::Config>;
    /// Build the tile program for an accepted candidate.
    fn build(&self, cfg: &Self::Config) -> TileProgram;
}

/// Stable fingerprint of a penalty model for the cache `variant` key:
/// baseline sweeps (triton-like, torch-like) must not collide with the
/// unpenalized tilelang entries.
pub fn penalties_variant(pen: &Penalties) -> String {
    let is_default = !pen.scalar_dequant
        && !pen.no_warp_specialization
        && pen.forced_bank_conflict <= 1
        && (pen.overlap_cap - 1.0).abs() < 1e-12;
    if is_default {
        "default".to_string()
    } else {
        format!(
            "sd{}-ws{}-bc{}-oc{}",
            pen.scalar_dequant as u8,
            pen.no_warp_specialization as u8,
            pen.forced_bank_conflict,
            pen.overlap_cap
        )
    }
}

/// Tune with a persistent cache: a hit decodes the stored config and
/// re-scores only that config (`evaluated == 0`); a miss runs the full
/// parallel sweep and stores the winner.
pub fn tune_cached<T: Tunable>(
    t: &T,
    dev: &Device,
    pen: &Penalties,
    cache: &mut TuningCache,
) -> Result<TuneResult<T::Config>, TuneError> {
    tune_cached_sharded(t, dev, pen, cache, 1)
}

/// [`tune_cached`] under a shard count: per-shard sub-shape configs are
/// cached independently of same-shape single-device entries (the shard
/// count is a [`CacheKey`] component).
pub fn tune_cached_sharded<T: Tunable>(
    t: &T,
    dev: &Device,
    pen: &Penalties,
    cache: &mut TuningCache,
    shards: usize,
) -> Result<TuneResult<T::Config>, TuneError> {
    let key = CacheKey {
        workload: t.workload().to_string(),
        shape: t.shape_key(),
        dtype: t.dtype_key(),
        device: dev.name.to_string(),
        variant: penalties_variant(pen),
        shards: shards.max(1) as i64,
    };
    if let Some(cfg_json) = cache.get(&key) {
        if let Some(config) = T::Config::from_json(cfg_json) {
            if t.accepts(&config) {
                let prog = t.build(&config);
                if let Ok(report) = simulate_kernel(&prog, dev, pen) {
                    return Ok(TuneResult {
                        config,
                        report,
                        evaluated: 0,
                        cache_hit: true,
                    });
                }
            }
        }
        // stale or undecodable entry: fall through to a fresh sweep
    }
    let result = search::tune(t, dev, pen)?;
    cache.put(key, result.config.to_json(), result.report.time_us);
    Ok(result)
}

// ---- per-workload convenience wrappers --------------------------------

/// Autotune a GEMM (degenerate dims padded to the 16-wide minimum tile).
pub fn tune_gemm(
    m: i64,
    n: i64,
    k: i64,
    dtype: DType,
    dev: &Device,
    pen: &Penalties,
) -> Result<TuneResult<TileConfig>, TuneError> {
    search::tune(&GemmTunable::new(m, n, k, dtype), dev, pen)
}

/// Cached [`tune_gemm`].
pub fn tune_gemm_cached(
    m: i64,
    n: i64,
    k: i64,
    dtype: DType,
    dev: &Device,
    pen: &Penalties,
    cache: &mut TuningCache,
) -> Result<TuneResult<TileConfig>, TuneError> {
    tune_cached(&GemmTunable::new(m, n, k, dtype), dev, pen, cache)
}

/// Autotune FlashAttention block sizes / stages / thread counts.
pub fn tune_attention(
    s: &AttnShape,
    dev: &Device,
    pen: &Penalties,
) -> Result<TuneResult<AttnConfig>, TuneError> {
    search::tune(&AttentionTunable { shape: *s }, dev, pen)
}

/// Cached [`tune_attention`].
pub fn tune_attention_cached(
    s: &AttnShape,
    dev: &Device,
    pen: &Penalties,
    cache: &mut TuningCache,
) -> Result<TuneResult<AttnConfig>, TuneError> {
    tune_cached(&AttentionTunable { shape: *s }, dev, pen, cache)
}

/// Autotune the MLA decode kernel (block_h x block_n x stages x staging).
pub fn tune_mla(
    s: &MlaShape,
    dev: &Device,
    pen: &Penalties,
) -> Result<TuneResult<MlaConfig>, TuneError> {
    search::tune(&MlaTunable { shape: *s }, dev, pen)
}

/// Cached [`tune_mla`].
pub fn tune_mla_cached(
    s: &MlaShape,
    dev: &Device,
    pen: &Penalties,
    cache: &mut TuningCache,
) -> Result<TuneResult<MlaConfig>, TuneError> {
    tune_cached(&MlaTunable { shape: *s }, dev, pen, cache)
}

/// Autotune a Mamba-2 chunk kernel (chunk length x stages).
pub fn tune_linear_attention(
    kind: ChunkKind,
    s: &LinAttnShape,
    dev: &Device,
    pen: &Penalties,
) -> Result<TuneResult<LinAttnConfig>, TuneError> {
    search::tune(&LinearAttentionTunable { kind, shape: *s }, dev, pen)
}

/// Cached [`tune_linear_attention`].
pub fn tune_linear_attention_cached(
    kind: ChunkKind,
    s: &LinAttnShape,
    dev: &Device,
    pen: &Penalties,
    cache: &mut TuningCache,
) -> Result<TuneResult<LinAttnConfig>, TuneError> {
    tune_cached(&LinearAttentionTunable { kind, shape: *s }, dev, pen, cache)
}

/// Autotune a dequantize-GEMM (decode shapes padded to the 16-row tile).
pub fn tune_dequant(
    m: i64,
    n: i64,
    k: i64,
    fmt: WeightFormat,
    dev: &Device,
    pen: &Penalties,
) -> Result<TuneResult<DequantConfig>, TuneError> {
    search::tune(&DequantTunable::new(m, n, k, fmt), dev, pen)
}

/// Cached [`tune_dequant`].
pub fn tune_dequant_cached(
    m: i64,
    n: i64,
    k: i64,
    fmt: WeightFormat,
    dev: &Device,
    pen: &Penalties,
    cache: &mut TuningCache,
) -> Result<TuneResult<DequantConfig>, TuneError> {
    tune_cached(&DequantTunable::new(m, n, k, fmt), dev, pen, cache)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::shapes::FA_SHAPES;

    #[test]
    fn gemm_tuner_finds_feasible_configs() {
        let dev = Device::a100();
        let r = tune_gemm(4096, 1024, 8192, DType::F16, &dev, &Penalties::none()).unwrap();
        assert!(r.evaluated > 5);
        assert!(r.report.time_us > 0.0);
        assert!(r.config.block_m >= 32);
        assert!(!r.cache_hit);
    }

    #[test]
    fn tuner_adapts_tiles_to_sequence_length() {
        let dev = Device::h100();
        // tiny workload: 8 heads x seq 256 -> 128-wide tiles leave most
        // SMs idle; the tuner must pick small blocks (the adaptive-tile
        // advantage over FA3's fixed 128 of §5.2)
        let tiny = AttnShape {
            name: "tiny",
            batch: 1,
            heads: 8,
            seq_len: 256,
            head_dim: 128,
            causal: false,
        };
        let tuned = tune_attention(&tiny, &dev, &Penalties::none()).unwrap();
        assert!(
            tuned.config.block_m <= 64,
            "tiny workloads should pick small tiles, got {}",
            tuned.config.block_m
        );
        // and the tuned config never loses to the fixed-128 config
        let fixed = AttnConfig {
            block_m: 128,
            block_n: 128,
            num_stages: 2,
            threads: 128,
            specialize: None,
        };
        let prog = crate::workloads::attention::flash_attention_program(8, 256, 128, false, &fixed);
        let fixed_r = simulate_kernel(&prog, &dev, &Penalties::none()).unwrap();
        assert!(tuned.report.time_us <= fixed_r.time_us * 1.001);
        // long sequences still reach good efficiency
        let long = tune_attention(&FA_SHAPES[4], &dev, &Penalties::none()).unwrap();
        assert!(long.report.tflops > tuned.report.tflops);
    }

    #[test]
    fn infeasible_spaces_are_errors_not_panics() {
        let dev = Device::a100();
        // 40 is not divisible by any candidate tile after the 16-pad
        let r = tune_gemm(40, 40, 40, DType::F16, &dev, &Penalties::none());
        assert!(matches!(&r, Err(TuneError::EmptySpace { .. })));
        // attention with a sequence no block divides
        let odd = AttnShape {
            name: "odd",
            batch: 1,
            heads: 2,
            seq_len: 40,
            head_dim: 64,
            causal: false,
        };
        let r = tune_attention(&odd, &dev, &Penalties::none());
        assert!(matches!(&r, Err(TuneError::EmptySpace { .. })));
        let err = r.unwrap_err().to_string();
        assert!(err.contains("empty tuning space"), "{}", err);
    }

    #[test]
    fn tuning_is_deterministic_across_runs() {
        let dev = Device::h100();
        let a = tune_gemm(1024, 1024, 1024, DType::F16, &dev, &Penalties::none()).unwrap();
        let b = tune_gemm(1024, 1024, 1024, DType::F16, &dev, &Penalties::none()).unwrap();
        assert_eq!(a.config, b.config);
        assert_eq!(a.evaluated, b.evaluated);
    }

    #[test]
    fn cache_hit_returns_identical_config_without_reevaluating() {
        let dev = Device::a100();
        let mut cache = TuningCache::in_memory();
        let first =
            tune_gemm_cached(2048, 1024, 2048, DType::F16, &dev, &Penalties::none(), &mut cache)
                .unwrap();
        assert!(first.evaluated > 0);
        assert!(!first.cache_hit);
        assert_eq!(cache.len(), 1);
        let second =
            tune_gemm_cached(2048, 1024, 2048, DType::F16, &dev, &Penalties::none(), &mut cache)
                .unwrap();
        assert_eq!(second.evaluated, 0, "cache hit must not re-sweep");
        assert!(second.cache_hit);
        assert_eq!(second.config, first.config);
        // a different penalty model is a different cache entry
        let tri = tune_gemm_cached(
            2048,
            1024,
            2048,
            DType::F16,
            &dev,
            &Penalties::triton_like(),
            &mut cache,
        )
        .unwrap();
        assert!(!tri.cache_hit);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn corrupt_cache_entries_fall_back_to_a_fresh_sweep() {
        let dev = Device::a100();
        let mut cache = TuningCache::in_memory();
        // poison the exact key tune_gemm_cached will look up with a
        // config that would divide-by-zero in lowering if accepted
        let key = CacheKey {
            workload: "gemm".into(),
            shape: vec![512, 512, 512],
            dtype: "float16".into(),
            device: dev.name.to_string(),
            variant: "default".into(),
            shards: 1,
        };
        let mut bad = TileConfig::default_for(512, 512, 512);
        bad.threads = 0;
        cache.put(key, bad.to_json(), 1.0);
        let r = tune_gemm_cached(512, 512, 512, DType::F16, &dev, &Penalties::none(), &mut cache)
            .unwrap();
        assert!(!r.cache_hit, "poisoned entry must not be served");
        assert!(r.evaluated > 0);
        assert!(r.config.threads > 0);
    }

    #[test]
    fn cache_persists_across_open() {
        let dir = std::env::temp_dir().join(format!("tilelang-tuner-test-{}", std::process::id()));
        let path = dir.join("cache.json");
        let _ = std::fs::remove_file(&path);
        let dev = Device::a100();
        let shape = FA_SHAPES[0];

        let mut cache = TuningCache::open(&path);
        let first = tune_attention_cached(&shape, &dev, &Penalties::none(), &mut cache).unwrap();
        assert!(first.evaluated > 0);
        cache.save().expect("save");

        let mut cache2 = TuningCache::open(&path);
        assert_eq!(cache2.len(), 1);
        let second = tune_attention_cached(&shape, &dev, &Penalties::none(), &mut cache2).unwrap();
        assert_eq!(second.evaluated, 0);
        assert!(second.cache_hit);
        assert_eq!(second.config, first.config);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn all_workload_families_tune_through_one_driver() {
        let dev = Device::h100();
        let pen = Penalties::none();
        let dq = tune_dequant(16, 256, 256, WeightFormat::Int4, &dev, &pen).unwrap();
        assert!(dq.evaluated > 0);
        let lin_shape = LinAttnShape {
            name: "t",
            batch: 1,
            nheads: 4,
            seq_len: 512,
            head_dim: 64,
            d_state: 128,
        };
        for kind in [ChunkKind::State, ChunkKind::Scan] {
            let r = tune_linear_attention(kind, &lin_shape, &dev, &pen).unwrap();
            assert!(r.evaluated > 0);
            assert!(lin_shape.seq_len % r.config.chunk == 0);
        }
        let mla_shape = MlaShape {
            batch: 2,
            heads: 32,
            seqlen_kv: 256,
            dim: 128,
            pe_dim: 64,
        };
        let r = tune_mla(&mla_shape, &dev, &pen).unwrap();
        assert!(r.evaluated > 0);
        assert!(mla_shape.heads % r.config.block_h == 0);
    }

    #[test]
    fn shard_counts_are_distinct_cache_entries() {
        let dev = Device::a100();
        let mut cache = TuningCache::in_memory();
        let t = GemmTunable::new(1024, 1024, 1024, DType::F16);
        let single = tune_cached(&t, &dev, &Penalties::none(), &mut cache).unwrap();
        assert!(!single.cache_hit);
        // the same problem under 2 shards is a distinct entry, not a hit
        let sharded =
            tune_cached_sharded(&t, &dev, &Penalties::none(), &mut cache, 2).unwrap();
        assert!(!sharded.cache_hit, "shard count must be part of the cache key");
        assert_eq!(cache.len(), 2);
        let again =
            tune_cached_sharded(&t, &dev, &Penalties::none(), &mut cache, 2).unwrap();
        assert!(again.cache_hit);
        assert_eq!(again.config, sharded.config);
    }

    #[test]
    fn penalty_variants_have_distinct_cache_keys() {
        assert_eq!(penalties_variant(&Penalties::none()), "default");
        let tri = penalties_variant(&Penalties::triton_like());
        let tor = penalties_variant(&Penalties::torch_like());
        assert_ne!(tri, "default");
        assert_ne!(tri, tor);
    }
}
