//! The generic parallel search driver.
//!
//! One argmin loop for every workload family: candidates come from a
//! [`Tunable`](super::Tunable), each is built into a `TileProgram`,
//! compiled and scored with the analytical model (`sim::simulate_kernel`)
//! across a pool of std threads, and the fastest feasible candidate wins.
//! Candidates that fail to compile (shared-memory budget, layout
//! constraints) are skipped — mirroring `tilelang.autotune`. The result
//! is deterministic regardless of thread count: scores are collected per
//! candidate index and reduced sequentially, ties broken by the lower
//! index.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::device::Device;
use crate::sim::model::{simulate_kernel, Penalties, SimReport};

use super::{TuneError, TuneResult, Tunable};

/// Score every candidate of `t` and return the fastest feasible one.
///
/// Never panics on infeasible spaces: an empty candidate set or a space
/// where no candidate compiles surfaces as a [`TuneError`].
pub fn tune<T: Tunable>(
    t: &T,
    dev: &Device,
    pen: &Penalties,
) -> Result<TuneResult<T::Config>, TuneError> {
    let cands = t.candidates();
    if cands.is_empty() {
        return Err(TuneError::EmptySpace {
            workload: t.workload().to_string(),
        });
    }
    let n = cands.len();
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n)
        .max(1);

    // Each worker claims candidate indices from a shared counter, builds
    // the program locally (cheaper than shipping built programs around;
    // configs are small and `Copy`-ish), and writes its score into a
    // fixed slot.
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<SimReport>>> = Mutex::new(vec![None; n]);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let prog = t.build(&cands[i]);
                let report = simulate_kernel(&prog, dev, pen).ok();
                slots.lock().unwrap()[i] = report;
            });
        }
    });
    let results = slots.into_inner().unwrap();

    let mut evaluated = 0usize;
    let mut best: Option<(usize, SimReport)> = None;
    for (i, r) in results.into_iter().enumerate() {
        if let Some(r) = r {
            evaluated += 1;
            let better = best
                .as_ref()
                .map(|(_, b)| r.time_us < b.time_us)
                .unwrap_or(true);
            if better {
                best = Some((i, r));
            }
        }
    }
    match best {
        Some((i, report)) => Ok(TuneResult {
            config: cands[i].clone(),
            report,
            evaluated,
            cache_hit: false,
        }),
        None => Err(TuneError::NoFeasibleConfig {
            workload: t.workload().to_string(),
            candidates: n,
        }),
    }
}
