//! Analytical GPU performance model (DESIGN.md substitution for the
//! paper's H100/A100/RTX4090/MI300X testbed).

pub mod device;
pub mod model;
